"""The streaming append lifecycle: append-then-query answers are
bit-identical to re-staging from scratch (and to the brute force) on
ALL SIX layouts, including sequences that force a tile-overflow
re-stage; overflow re-stages preserve the staging invariants (one
canonical slot per object, chunk boxes bound their members) and
re-establish the sharded ceil(T/D) per-device memory bound via owner
re-balancing; incremental probe/chunk-box refresh keeps routing exact
without a re-sort.  ``mesh=None`` here (sharded mode runs the exchange
in vmap simulation); the 8-device SPMD test runs under the CI
virtual-device job."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.kernels.range_probe import ops as rops
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import ServeConfig, SpatialServer

LAYOUTS = ["hc", "str", "fg", "bsp", "slc", "bos"]
N, N_BASE, NQ, K = 1500, 1000, 20, 4


def _qboxes(key, q, scale=0.06):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


@pytest.fixture(scope="module", params=["osm", "pi"])
def data(request):
    full = spatial_gen.dataset(request.param, jax.random.PRNGKey(0), N)
    return full, np.asarray(full)


def _assert_same_answers(srv, osrv, mbrs_np, qb, pts):
    """srv (appended-to) and osrv (staged from scratch on the full
    data) must answer bit-identically, and match the brute force."""
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    counts, _ = srv.range_counts(qb)
    ocounts, _ = osrv.range_counts(qb)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ocounts))
    assert [int(c) for c in counts] == [len(r) for r in ref]
    hid, _, ovf, _ = srv.range_ids(qb, max_hits=2048)
    ohid, _, oovf, _ = osrv.range_ids(qb, max_hits=2048)
    assert not np.asarray(ovf).any() and not np.asarray(oovf).any()
    np.testing.assert_array_equal(np.asarray(hid), np.asarray(ohid))
    # max_cand sized for the coincident-object bursts the overflow
    # tests inject (a refinement box can legitimately swallow them all)
    nn, d2, ovk, _ = srv.knn(pts, K, max_cand=4096)
    onn, od2, oovk, _ = osrv.knn(pts, K, max_cand=4096)
    assert not np.asarray(ovk).any()
    np.testing.assert_array_equal(np.asarray(ovk), np.asarray(oovk))
    np.testing.assert_array_equal(np.asarray(nn), np.asarray(onn))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(od2))
    want_ids, _ = knn_mod.knn_ref(mbrs_np, np.asarray(pts), K)
    np.testing.assert_array_equal(np.asarray(nn), want_ids)
    # the dense oracle on the appended server agrees with its pruned path
    dn, dd2, _, _ = srv.knn(pts, K, max_cand=4096, pruned=False)
    np.testing.assert_array_equal(np.asarray(nn), np.asarray(dn))


@pytest.mark.parametrize("method", LAYOUTS)
def test_append_bit_identical_to_restage(data, method):
    """Slack appends (no overflow): answers == from-scratch staging of
    the full dataset, on every layout."""
    full, mbrs_np = data
    base, extra = full[:N_BASE], full[N_BASE:]
    parts = api.partition(method, base, 120)
    cfg = ServeConfig(slack=600)
    srv = SpatialServer(parts, base, cfg)
    for i in range(0, N - N_BASE, 125):
        rep = srv.append(extra[i:i + 125])
        assert not rep["restaged"]          # slack absorbs everything
    assert srv.stats["n"] == N
    osrv = SpatialServer(parts, full, cfg)
    _assert_same_answers(srv, osrv, mbrs_np, _qboxes(jax.random.PRNGKey(1), NQ),
                         jax.random.uniform(jax.random.PRNGKey(2), (NQ, 2)))


@pytest.mark.parametrize("method", ["bsp", "hc", "fg"])
def test_overflow_restage_bit_identical(data, method):
    """A forced tile overflow re-stages at a grown capacity; answers
    stay bit-identical to the from-scratch staging and the width cache
    resets."""
    full, mbrs_np = data
    base, extra = full[:N_BASE], full[N_BASE:]
    parts = api.partition(method, base, 120)
    srv = SpatialServer(parts, base)            # slack=0
    qb = _qboxes(jax.random.PRNGKey(3), NQ)
    srv.range_counts(qb)                         # warm the width cache
    assert srv.widths._w
    # cap+1 copies into one tile guarantee the overflow path fires
    cap = srv.stats["cap"]
    tb = np.asarray(parts.boxes)[0]
    ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
    burst = np.tile(np.asarray(ctr + ctr, np.float32), (cap + 1, 1))
    rep = srv.append(burst)
    assert rep["restaged"] and srv.stats["restages"] == 1
    assert srv.stats["cap"] > cap
    assert not srv.widths._w                     # reset on re-stage
    srv.append(extra)                            # keep growing after
    every = np.concatenate([np.asarray(base), burst, np.asarray(extra)])
    osrv = SpatialServer(parts, jnp.asarray(every))
    _assert_same_answers(srv, osrv, every, qb,
                         jax.random.uniform(jax.random.PRNGKey(4), (NQ, 2)))


@pytest.mark.parametrize("method", ["bsp", "str"])
def test_restage_preserves_staging_invariants(data, method):
    """After an overflow re-stage: exactly one canonical slot per
    object, chunk boxes bound their chunks' canonical members, probe
    boxes bound every canonical member."""
    full, _ = data
    base = full[:N_BASE]
    parts = api.partition(method, base, 120)
    srv = SpatialServer(parts, base)
    cap = srv.stats["cap"]
    tb = np.asarray(parts.boxes)[0]
    ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
    srv.append(np.tile(np.asarray(ctr + ctr, np.float32), (cap + 1, 1)))
    assert srv.stats["restages"] == 1
    lay = srv.layout
    ids = np.asarray(lay.ids)
    canon = np.asarray(lay.canon_tiles[..., 0]) < 1e9
    n = srv.stats["n"]
    counts = np.bincount(ids[canon].ravel(), minlength=n)
    np.testing.assert_array_equal(counts, np.ones(n))
    ct = np.asarray(lay.canon_tiles)
    cb = np.asarray(lay.chunk_boxes)
    pb = np.asarray(lay.probe_boxes)
    chunk = rops.CHUNK
    for t in range(ct.shape[0]):
        live = ct[t, :, 0] < 1e9
        if live.any():
            assert np.all(pb[t, 0] <= ct[t][live][:, 0] + 1e-7)
            assert np.all(pb[t, 3] >= ct[t][live][:, 3] - 1e-7)
        for c in range(cb.shape[1]):
            sl = slice(c * chunk, min((c + 1) * chunk, ct.shape[1]))
            boxes = ct[t, sl][live[sl]]
            if boxes.size == 0:
                assert cb[t, c, 0] > cb[t, c, 2]
                continue
            assert np.all(cb[t, c, 0] <= boxes[:, 0] + 1e-7)
            assert np.all(cb[t, c, 2] >= boxes[:, 2] - 1e-7)


def test_incremental_boxes_bound_after_append(data):
    """Non-overflow appends refresh probe and chunk boxes in place:
    both still bound every canonical member they summarise."""
    full, _ = data
    base, extra = full[:N_BASE], full[N_BASE:]
    parts = api.partition("bsp", base, 120)
    srv = SpatialServer(parts, base, ServeConfig(slack=600))
    rep = srv.append(extra)
    assert not rep["restaged"]
    lay = srv.layout
    ct = np.asarray(lay.canon_tiles)
    cb = np.asarray(lay.chunk_boxes)
    pb = np.asarray(lay.probe_boxes)
    live = ct[..., 0] < 1e9
    chunk = rops.CHUNK
    for t in range(ct.shape[0]):
        if live[t].any():
            assert np.all(pb[t, 0] <= ct[t][live[t]][:, 0] + 1e-7)
            assert np.all(pb[t, 1] <= ct[t][live[t]][:, 1] + 1e-7)
            assert np.all(pb[t, 2] >= ct[t][live[t]][:, 2] - 1e-7)
            assert np.all(pb[t, 3] >= ct[t][live[t]][:, 3] - 1e-7)
        for c in range(cb.shape[1]):
            sl = slice(c * chunk, min((c + 1) * chunk, ct.shape[1]))
            boxes = ct[t, sl][live[t, sl]]
            if boxes.size:
                assert np.all(cb[t, c, 0] <= boxes[:, 0] + 1e-7)
                assert np.all(cb[t, c, 2] >= boxes[:, 2] - 1e-7)


@pytest.mark.parametrize("method", ["bsp", "hc"])
def test_sharded_append_and_rebalance_memory_bound(data, method):
    """Sharded streaming: slack appends keep owners fixed; an overflow
    re-stage re-balances owners on the new member counts and
    re-establishes the ceil(T/D) per-device memory bound — answers
    bit-identical throughout (vmap-simulated exchange)."""
    full, mbrs_np = data
    base, extra = full[:N_BASE], full[N_BASE:]
    parts = api.partition(method, base, 120)
    shards = 4
    cfg = ServeConfig(placement="sharded", shards=shards, slack=0)
    srv = SpatialServer(parts, base, cfg)
    owner_before = srv.slayout.owner.copy()
    cap0 = srv.stats["cap"]
    tb = np.asarray(parts.boxes)[0]
    ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
    burst = np.tile(np.asarray(ctr + ctr, np.float32), (cap0 + 1, 1))
    rep = srv.append(burst)
    assert rep["restaged"]
    assert "moved_tiles" in srv.stats           # re-balance reported
    srv.append(extra)
    t = srv.stats["t"]
    assert srv.stats["t_local"] == -(-t // shards)
    cap = srv.stats["cap"]
    tile_bytes = cap * 4 * 4 + cap * 4
    assert srv.resident_tile_bytes() <= t * tile_bytes / shards + tile_bytes
    # shards still partition the staging exactly
    canon_np, ids_np = srv._oracle_np
    s = srv.slayout
    np.testing.assert_array_equal(
        np.asarray(s.canon_shards)[s.owner, s.local], canon_np)
    np.testing.assert_array_equal(
        np.asarray(s.id_shards)[s.owner, s.local], ids_np)
    every = np.concatenate([np.asarray(base), burst, np.asarray(extra)])
    osrv = SpatialServer(parts, jnp.asarray(every), cfg)
    _assert_same_answers(srv, osrv, every,
                         _qboxes(jax.random.PRNGKey(5), NQ),
                         jax.random.uniform(jax.random.PRNGKey(6), (NQ, 2)))
    del owner_before   # placement may legitimately change on re-balance


def test_append_ids_continue_numbering(data):
    full, _ = data
    base, extra = full[:N_BASE], full[N_BASE:]
    parts = api.partition("fg", base, 120)
    srv = SpatialServer(parts, base, ServeConfig(slack=600))
    srv.append(extra[:100])
    ids = np.asarray(srv.layout.ids)
    assert ids.max() == N_BASE + 99
    # querying a box equal to an appended object's MBR finds its id
    target = np.asarray(extra[7]).reshape(1, 4)
    hid, _, _, _ = srv.range_ids(jnp.asarray(target), max_hits=2048)
    assert (N_BASE + 7) in set(np.asarray(hid[0]).tolist())


def test_restage_preserves_capacity_headroom(data):
    """An explicit capacity's headroom over the hottest tile is the
    user's slack policy: a re-stage must re-reserve at least that much,
    not collapse to minimal auto-sizing (which would thrash)."""
    full, _ = data
    base = full[:N_BASE]
    parts = api.partition("bsp", base, 120)
    srv = SpatialServer(parts, base, ServeConfig(capacity=1024))
    fill_max = 1024 - srv.append(np.zeros((0, 4), np.float32))["free_slots_min"]
    headroom = 1024 - fill_max
    tb = np.asarray(parts.boxes)[0]
    ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
    burst = np.tile(np.asarray(ctr + ctr, np.float32), (1025, 1))
    assert srv.append(burst)["restaged"]
    # hottest tile again has ~the configured headroom free (128-aligned)
    assert srv.append(np.zeros((0, 4), np.float32))["free_slots_min"] \
        >= headroom - 127


def test_append_keeps_knn_steps_warm(data):
    """n is a traced scalar in every kNN step, so appends (which change
    n each batch) reuse the compiled steps — no re-trace, no dead cache
    entries piling up."""
    from jax.sharding import Mesh
    full, _ = data
    base, extra = full[:N_BASE], full[N_BASE:]
    parts = api.partition("bsp", base, 120)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    pts = jax.random.uniform(jax.random.PRNGKey(7), (8, 2))
    srv = SpatialServer(parts, base, ServeConfig(slack=600), mesh=mesh)
    srv.knn(pts, K)
    n_steps = len(srv.tiles._steps)
    for i in range(0, 300, 100):
        assert not srv.append(extra[i:i + 100])["restaged"]
        srv.knn(pts, K)
    assert len(srv.tiles._steps) == n_steps    # same compiled steps


def test_empty_append_is_a_noop(data):
    full, _ = data
    parts = api.partition("bsp", full, 120)
    srv = SpatialServer(parts, full)
    before = dict(srv.stats)
    rep = srv.append(np.zeros((0, 4), np.float32))
    assert rep["appended"] == 0 and not rep["restaged"]
    assert srv.stats["n"] == before["n"]


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI virtual-device job)")
def test_streaming_spmd_mesh_bit_identical():
    """Appends (including an overflow re-stage) under a real 8-device
    mesh: replicated and sharded answers == from-scratch staging ==
    brute force."""
    from jax.sharding import Mesh
    full = spatial_gen.dataset("osm", jax.random.PRNGKey(0), 2000)
    base, extra = full[:1400], full[1400:]
    mesh = Mesh(np.array(jax.devices()[:8]), ("d",))
    parts = api.partition("bsp", base, 150)
    qb = _qboxes(jax.random.PRNGKey(1), 32, scale=0.05)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (32, 2))
    for cfg in [ServeConfig(slack=600),
                ServeConfig(placement="sharded", slack=600)]:
        srv = SpatialServer(parts, base, cfg, mesh=mesh)
        for i in range(0, 600, 200):
            srv.append(extra[i:i + 200])
        cap = srv.stats["cap"]
        tb = np.asarray(parts.boxes)[0]
        ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
        burst = np.tile(np.asarray(ctr + ctr, np.float32), (cap + 1, 1))
        assert srv.append(burst)["restaged"]
        every = np.concatenate([np.asarray(base), np.asarray(extra), burst])
        osrv = SpatialServer(parts, jnp.asarray(every), cfg, mesh=mesh)
        _assert_same_answers(srv, osrv, every, qb, pts)
