"""Hypothesis property tests on partitioner invariants.

Local runs without hypothesis skip this module; CI installs hypothesis
and sets ``REPRO_REQUIRE_HYPOTHESIS=1``, turning a silent skip into a
hard failure — the property tests must actually run there.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
    import hypothesis  # a missing dep is a CI config error, not a skip
else:
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import geometry, metrics
from repro.core.partition import api, partition_counts

coords = st.integers(min_value=0, max_value=10_000)


@st.composite
def mbr_sets(draw, min_n=8, max_n=120):
    n = draw(st.integers(min_n, max_n))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    c = rng.uniform(0, 1, (n, 2)).astype(np.float32)
    sz = rng.uniform(1e-4, 0.05, (n, 2)).astype(np.float32)
    return jnp.asarray(np.concatenate([c - sz, c + sz], axis=1))


@settings(max_examples=25, deadline=None)
@given(mbrs=mbr_sets(), payload=st.integers(4, 64))
def test_lambda_nonnegative_and_coverage(mbrs, payload):
    for method in ["fg", "bsp", "slc", "bos", "str", "hc"]:
        parts = api.partition(method, mbrs, payload)
        counts, copies = partition_counts(mbrs, parts)
        lam = float(metrics.boundary_ratio(counts, parts.valid,
                                           mbrs.shape[0]))
        assert lam >= -1e-6, (method, lam)
        assert float(metrics.coverage(copies)) == 1.0, method


@settings(max_examples=25, deadline=None)
@given(mbrs=mbr_sets(min_n=16), payload=st.integers(4, 32))
def test_bsp_tiles_parent_exactly(mbrs, payload):
    parts = api.partition("bsp", mbrs, payload)
    boxes = np.asarray(parts.boxes)[np.asarray(parts.valid)]
    uni = np.asarray(geometry.universe(mbrs))
    area = ((boxes[:, 2] - boxes[:, 0]) * (boxes[:, 3] - boxes[:, 1])).sum()
    uni_area = (uni[2] - uni[0]) * (uni[3] - uni[1])
    assert np.isclose(area, uni_area, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(mbrs=mbr_sets(min_n=20), payload=st.integers(5, 40))
def test_hc_groups_bounded(mbrs, payload):
    """HC packs ≤ payload objects per group by construction."""
    parts = api.partition("hc", mbrs, payload)
    k = int(parts.k())
    assert k == -(-mbrs.shape[0] // payload)


@settings(max_examples=20, deadline=None)
@given(mbrs=mbr_sets(min_n=24), payload=st.integers(6, 24))
def test_slc_strips_are_ordered_and_disjoint(mbrs, payload):
    parts = api.partition("slc", mbrs, payload)
    boxes = np.asarray(parts.boxes)[np.asarray(parts.valid)]
    order = np.argsort(boxes[:, 0])
    b = boxes[order]
    assert (b[1:, 0] >= b[:-1, 2] - 1e-5).all()
