"""SpatialServer end-to-end: staging invariants, SPMD step on a 1-device
mesh (multi-device covered in test_multidevice.py), packing, stats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.data import spatial_gen
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import (ServeConfig, SpatialServer,
                         engine as serve_engine, stage_tiles)


def _mesh():
    return Mesh(np.array(jax.devices()[:1]), ("d",))


@pytest.fixture(scope="module")
def mbrs():
    return spatial_gen.dataset("osm", jax.random.PRNGKey(0), 2000)


@pytest.fixture(scope="module")
def qboxes():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    c = jax.random.uniform(k1, (30, 2))
    s = jax.random.uniform(k2, (30, 2)) * 0.07
    return jnp.concatenate([c - s, c + s], axis=-1)


def test_staging_canonical_is_a_partition_of_ids(mbrs):
    """Every object has exactly one canonical slot; ids/masks agree."""
    from repro.core.partition import api
    parts = api.partition("hc", mbrs, 100)   # overlapping, replicated
    layout, stats = stage_tiles(parts, mbrs)
    ids = np.asarray(layout.ids)
    canon = np.asarray(layout.canon_tiles[..., 0] < 1e9)  # non-sentinel
    n = mbrs.shape[0]
    counts = np.bincount(ids[canon].ravel(), minlength=n)
    assert ids[canon].min() >= 0
    np.testing.assert_array_equal(counts, np.ones(n))
    assert stats["replication"] > 0.0   # hc replicates on this data


@pytest.mark.parametrize("mesh", [None, "one"])
def test_server_matches_bruteforce(mbrs, qboxes, mesh):
    srv = SpatialServer.from_method("bsp", mbrs, 150,
                                    mesh=_mesh() if mesh else None)
    ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qboxes))
    counts, stats = srv.range_counts(qboxes)
    assert [int(c) for c in counts] == [len(r) for r in ref]
    assert stats["fanout_mean"] >= 1.0
    hit_ids, cnts, ovf, _ = srv.range_ids(qboxes, max_hits=1024)
    assert not ovf.any()
    for i, want in enumerate(ref):
        np.testing.assert_array_equal(
            np.asarray(hit_ids[i][hit_ids[i] >= 0]), want)
    pts = jax.random.uniform(jax.random.PRNGKey(3), (12, 2))
    nn_ids, nn_d2, ovk, kst = srv.knn(pts, 3)
    want_ids, _ = knn_mod.knn_ref(np.asarray(mbrs), np.asarray(pts), 3)
    np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
    assert kst["fanout_mean"] >= 1.0


def test_range_ids_overflow_is_flagged(mbrs, qboxes):
    srv = SpatialServer.from_method("fg", mbrs, 150)
    hit_ids, counts, overflow, _ = srv.range_ids(qboxes, max_hits=4)
    big = np.asarray(counts) > 4
    assert big.any()                      # the fixture has fat queries
    np.testing.assert_array_equal(np.asarray(overflow), big)


def test_pack_queries_balances_and_covers():
    costs = np.array([8.0, 1, 1, 1, 1, 1, 1, 6], np.float64)
    slots, stats = serve_engine.pack_queries(costs, 2)
    live = slots[slots >= 0]
    assert sorted(live.tolist()) == list(range(8))   # each query once
    assert stats["makespan"] < costs.sum()           # actually split
    assert stats["skew"] < 1.5                       # LPT balances 8|6+rest


def test_server_rejects_overflowing_capacity(mbrs):
    from repro.core.partition import api
    parts = api.partition("fg", mbrs, 200)
    with pytest.raises(ValueError, match="overflow"):
        stage_tiles(parts, mbrs, ServeConfig(capacity=1))


def test_overflow_error_is_actionable(mbrs):
    """The capacity-overflow message names the max tile count and how
    many tiles overflow — enough to size a retry without bisecting."""
    from repro.core.partition import api, assign
    parts = api.partition("fg", mbrs, 200)
    counts, _ = assign.partition_counts(mbrs, parts)
    max_count = int(np.asarray(counts).max())
    n_over = int((np.asarray(counts) > 1).sum())
    with pytest.raises(ValueError) as ei:
        stage_tiles(parts, mbrs, ServeConfig(capacity=1))
    msg = str(ei.value)
    assert f"max tile count {max_count}" in msg
    assert f"{n_over} of {int(parts.k())} tiles overflow" in msg
    assert f"worst by {max_count - 1} members" in msg


def test_width_policy_caps_cached_widths():
    """One pathological observation can never inflate later batches
    past the live tile count."""
    wp = serve_engine.WidthPolicy(cap=16)
    wp.observe("range", 640)
    assert wp.at_least("range", 8) == 16
    wp.observe(("knn", 3, 1024), 9)
    assert wp.start(("knn", 3, 1024), 4) == 9      # under cap: kept


def test_width_policy_reset_forgets_widths():
    wp = serve_engine.WidthPolicy(cap=64)
    wp.observe("range", 32)
    assert wp.at_least("range", 8) == 32
    wp.reset()
    assert wp.at_least("range", 8) == 8            # back to the floor
    assert wp.start(("knn", 3, 1024), 4) == 4      # cold default again


def test_server_width_policy_capped_at_t_live(mbrs, qboxes):
    """The server wires t_live as the cap, so even a seeded/observed
    pathological width is clamped on the observe path and answers stay
    exact."""
    srv = SpatialServer.from_method("bsp", mbrs, 150)
    assert srv.widths.cap == srv.stats["t_live"]
    srv.widths.observe("range", 10 * srv.stats["t_live"])
    counts, stats = srv.range_counts(qboxes)
    assert stats["f_max"] <= srv.stats["t_live"]
    ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qboxes))
    assert [int(c) for c in counts] == [len(r) for r in ref]


def test_range_width_cache_hit_reuses_wide_f_max(mbrs, qboxes):
    """Adaptive f_max: a narrow batch after a wide one reuses the
    cached (already-compiled) width instead of recomputing a smaller
    one — and the answers stay exact."""
    srv = SpatialServer.from_method("bsp", mbrs, 150)
    _, wide_stats = srv.range_counts(qboxes)           # fat fixture boxes
    hits_before = srv.widths.hits
    narrow = jnp.concatenate([qboxes[:, :2], qboxes[:, :2] + 1e-4], axis=-1)
    counts, narrow_stats = srv.range_counts(narrow)
    assert srv.widths.hits == hits_before + 1          # cache hit path
    assert narrow_stats["f_max"] == wide_stats["f_max"]
    ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(narrow))
    assert [int(c) for c in counts] == [len(r) for r in ref]


def test_knn_width_cache_starts_from_converged_width(mbrs):
    """Adaptive f_max for kNN: the first batch's converged frontier is
    the second batch's starting width — no repeated widening ladder."""
    srv = SpatialServer.from_method("bsp", mbrs, 150)
    pts = jax.random.uniform(jax.random.PRNGKey(7), (8, 2))
    _, _, _, s1 = srv.knn(pts, 3)
    misses_before = srv.widths.misses
    _, _, _, s2 = srv.knn(pts, 3)
    assert srv.widths.misses == misses_before          # pure cache hit
    assert s2["f_max"] == s1["f_max"] and s2["retries"] == 0


def test_from_method_passes_capacity_through(mbrs):
    """Regression: staging knobs given to ``from_method`` must reach
    the config path — ``capacity`` used to be silently swallowed."""
    srv = SpatialServer.from_method("bsp", mbrs, 150,
                                    ServeConfig(capacity=512))
    assert srv.stats["cap"] == 512


def test_slack_reserves_free_slots(mbrs):
    """``ServeConfig.slack`` raises auto-sized capacity so every tile
    keeps at least that many free append slots."""
    from repro.core.partition import api
    parts = api.partition("bsp", mbrs, 150)
    base, _ = stage_tiles(parts, mbrs)
    slacked, st = stage_tiles(parts, mbrs, ServeConfig(slack=256))
    assert st["cap"] >= base.ids.shape[1] + 256 - 127   # 128-aligned
    fill = (np.asarray(slacked.ids) >= 0).sum(axis=1)
    assert (st["cap"] - fill).min() >= 256
