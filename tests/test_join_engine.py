"""Distributed spatial join vs brute-force oracle (1-device mesh here;
multi-device covered in test_multidevice.py via subprocess)."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.data import spatial_gen
from repro.kernels.mbr_join import ref as mref
from repro.query import balance, dedup, engine


def _mesh():
    return Mesh(np.array(jax.devices()[:1]), ("d",))


@pytest.fixture(scope="module")
def rs():
    r = spatial_gen.dataset("osm", jax.random.PRNGKey(0), 1200)
    s = spatial_gen.dataset("osm", jax.random.PRNGKey(9), 900)
    return r, s, int(mref.intersect_count(r, s))


@pytest.mark.parametrize("method", ["fg", "bsp", "slc", "bos", "str", "hc"])
def test_join_count_matches_oracle(rs, method):
    r, s, oracle = rs
    plan = engine.plan_join(method, r, s, 200, 1)
    got = engine.spatial_join_count(plan, _mesh(), "d",
                                    max_pairs_per_tile=8192)
    assert got == oracle, f"{method}: {got} != {oracle}"


@pytest.mark.parametrize("method", ["fg", "bsp", "slc", "bos"])
def test_rp_dedup_equals_masj_for_nonoverlapping(rs, method):
    r, s, oracle = rs
    plan = engine.plan_join(method, r, s, 250, 1)
    rp = engine.run_join_count(plan, _mesh(), "d", dedup="rp")
    masj = engine.run_join_pairs_masj(plan, _mesh(), "d",
                                      max_pairs_per_tile=8192)
    assert rp == masj == oracle


def test_unique_pairs_vs_numpy():
    rng = np.random.default_rng(0)
    rid = rng.integers(0, 50, 500).astype(np.int32)
    sid = rng.integers(0, 50, 500).astype(np.int32)
    pad = rng.random(500) < 0.2
    rid[pad] = -1
    sid[pad] = -1
    n, _ = dedup.unique_pairs(jax.numpy.asarray(rid), jax.numpy.asarray(sid))
    want = len(set(zip(rid[~pad], sid[~pad])))
    assert int(n) == want


def test_lpt_beats_round_robin():
    rng = np.random.default_rng(1)
    costs = rng.pareto(1.3, 300) + 1.0
    _, mk_lpt, mean = balance.lpt_pack(costs, 16)
    _, mk_rr, _ = balance.round_robin_pack(costs, 16)
    assert mk_lpt <= mk_rr
    # Graham bound: LPT ≤ 4/3·OPT, and OPT ≥ max(mean load, biggest tile)
    opt_lb = max(mean, float(costs.max()))
    assert mk_lpt <= 4.0 / 3.0 * opt_lb + 1e-9


def test_plan_stats_sane(rs):
    r, s, _ = rs
    plan = engine.plan_join("bos", r, s, 200, 4)
    st = plan.stats
    assert st["lambda_r"] >= 0 and st["lambda_s"] >= 0
    assert st["skew"] >= 1.0
    assert st["k"] >= 1 and not st["overlapping"]
