"""range_probe Pallas kernel: shape sweep vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.range_probe import ops, ref


def _boxes(key, n, scale=0.1):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (n, 2))
    s = jax.random.uniform(k2, (n, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


def _tiles(key, t, cap, scale=0.1):
    return _boxes(key, t * cap, scale).reshape(t, cap, 4)


@pytest.mark.parametrize("q,t,cap", [(1, 1, 1), (7, 3, 50), (128, 4, 128),
                                     (300, 9, 257), (513, 2, 640)])
def test_counts_match_ref(q, t, cap):
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t + 1), t, cap)
    assert bool(jnp.all(ops.probe_counts(qb, tiles)
                        == ref.probe_counts(qb, tiles)))


@pytest.mark.parametrize("q,t,cap", [(5, 2, 30), (130, 3, 140)])
def test_mask_matches_ref(q, t, cap):
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t), t, cap)
    got = ops.probe_mask(qb, tiles)
    want = jnp.swapaxes(ref.probe_mask(qb, tiles), 0, 1)
    assert bool(jnp.all(got == want))


@pytest.mark.parametrize("bq", [128, 256])
def test_block_shape_sweep(bq):
    qb = _boxes(jax.random.PRNGKey(0), 700, 0.15)
    tiles = _tiles(jax.random.PRNGKey(1), 5, 200)
    assert bool(jnp.all(ops.probe_counts(qb, tiles, bq=bq)
                        == ref.probe_counts(qb, tiles)))


def test_sentinel_padding_never_matches():
    """Heavy query and member padding must contribute zero hits."""
    qb = _boxes(jax.random.PRNGKey(4), 3, 0.5)
    tiles = _tiles(jax.random.PRNGKey(5), 2, 5, 0.5)
    counts = ops.probe_counts(qb, tiles)
    assert counts.shape == (3, 2)
    assert bool(jnp.all(counts == ref.probe_counts(qb, tiles)))


def test_touching_boxes_hit():
    qb = jnp.array([[0.0, 0.0, 1.0, 1.0]])
    tiles = jnp.array([[[1.0, 1.0, 2.0, 2.0]]])   # shares one corner
    assert int(ops.probe_counts(qb, tiles)[0, 0]) == 1
