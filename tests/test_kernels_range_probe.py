"""range_probe Pallas kernels (dense + gathered): shape sweep vs the
pure-jnp oracles."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.range_probe import ops, ref


def _boxes(key, n, scale=0.1):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (n, 2))
    s = jax.random.uniform(k2, (n, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


def _tiles(key, t, cap, scale=0.1):
    return _boxes(key, t * cap, scale).reshape(t, cap, 4)


@pytest.mark.parametrize("q,t,cap", [(1, 1, 1), (7, 3, 50), (128, 4, 128),
                                     (300, 9, 257), (513, 2, 640)])
def test_counts_match_ref(q, t, cap):
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t + 1), t, cap)
    assert bool(jnp.all(ops.probe_counts(qb, tiles)
                        == ref.probe_counts(qb, tiles)))


@pytest.mark.parametrize("q,t,cap", [(5, 2, 30), (130, 3, 140)])
def test_mask_matches_ref(q, t, cap):
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t), t, cap)
    got = ops.probe_mask(qb, tiles)
    want = jnp.swapaxes(ref.probe_mask(qb, tiles), 0, 1)
    assert bool(jnp.all(got == want))


@pytest.mark.parametrize("bq", [128, 256])
def test_block_shape_sweep(bq):
    qb = _boxes(jax.random.PRNGKey(0), 700, 0.15)
    tiles = _tiles(jax.random.PRNGKey(1), 5, 200)
    assert bool(jnp.all(ops.probe_counts(qb, tiles, bq=bq)
                        == ref.probe_counts(qb, tiles)))


def test_sentinel_padding_never_matches():
    """Heavy query and member padding must contribute zero hits."""
    qb = _boxes(jax.random.PRNGKey(4), 3, 0.5)
    tiles = _tiles(jax.random.PRNGKey(5), 2, 5, 0.5)
    counts = ops.probe_counts(qb, tiles)
    assert counts.shape == (3, 2)
    assert bool(jnp.all(counts == ref.probe_counts(qb, tiles)))


def test_touching_boxes_hit():
    qb = jnp.array([[0.0, 0.0, 1.0, 1.0]])
    tiles = jnp.array([[[1.0, 1.0, 2.0, 2.0]]])   # shares one corner
    assert int(ops.probe_counts(qb, tiles)[0, 0]) == 1


def _gather_rows(tiles, cand):
    """Row-major gather with -1 -> sentinel tile, for the jnp oracle."""
    sent = jnp.array([9e9, 9e9, -9e9, -9e9])
    rows = jnp.concatenate([tiles, jnp.broadcast_to(
        sent, (1,) + tiles.shape[1:])], axis=0)
    return rows[jnp.where(cand >= 0, cand, tiles.shape[0])]


@pytest.mark.parametrize("q,t,cap,f", [(1, 1, 1, 1), (7, 5, 30, 3),
                                       (130, 9, 140, 4), (300, 6, 257, 8)])
def test_gathered_counts_match_ref(q, t, cap, f):
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t + 1), t, cap)
    cand = jax.random.randint(jax.random.PRNGKey(f), (q, f), -1, t)
    want = ref.gathered_counts(qb, _gather_rows(tiles, cand))
    # interpret=True forces the Pallas kernel; default picks the
    # backend's executor — both must match the oracle
    got_k = ops.gathered_counts(qb, tiles, cand, interpret=True)
    got = ops.gathered_counts(qb, tiles, cand)
    assert got_k.shape == got.shape == (q, f)
    assert bool(jnp.all(got_k == want))
    assert bool(jnp.all(got == want))


@pytest.mark.parametrize("q,t,cap,f", [(5, 3, 30, 2), (130, 4, 140, 3)])
def test_gathered_mask_matches_ref(q, t, cap, f):
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t), t, cap)
    cand = jax.random.randint(jax.random.PRNGKey(f + 7), (q, f), -1, t)
    want = ref.gathered_mask(qb, _gather_rows(tiles, cand))
    got_k = ops.gathered_mask(qb, tiles, cand, interpret=True)
    got = ops.gathered_mask(qb, tiles, cand)
    assert got_k.shape == got.shape == (q, f, cap)
    assert bool(jnp.all(got_k == want))
    assert bool(jnp.all(got == want))


def test_gathered_consistent_with_dense():
    """Gathering every tile for every query must reproduce the dense
    probe exactly (same hits, different layout)."""
    q, t, cap = 40, 6, 50
    qb = _boxes(jax.random.PRNGKey(0), q, 0.3)
    tiles = _tiles(jax.random.PRNGKey(1), t, cap)
    cand = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (q, t))
    got = ops.gathered_counts(qb, tiles, cand)
    assert bool(jnp.all(got == ops.probe_counts(qb, tiles)))


def test_gathered_all_padding_is_zero():
    """A query whose candidate list is entirely -1 hits nothing."""
    qb = _boxes(jax.random.PRNGKey(2), 3, 0.5)
    tiles = _tiles(jax.random.PRNGKey(3), 2, 5, 0.5)
    cand = jnp.full((3, 4), -1, jnp.int32)
    assert int(jnp.sum(ops.gathered_counts(qb, tiles, cand))) == 0
    assert not bool(jnp.any(ops.gathered_mask(qb, tiles, cand)))


# --------------------------------------------------------------------------
# chunk-skipping (local-index) variants
# --------------------------------------------------------------------------

def _chunk_boxes(tiles):
    """True per-128-slot MBR summary of ``tiles`` (staging invariant)."""
    t, cap, _ = tiles.shape
    c = -(-cap // ops.CHUNK)
    sent = jnp.array([9e9, 9e9, -9e9, -9e9])
    pad = c * ops.CHUNK - cap
    if pad:
        tiles = jnp.concatenate(
            [tiles, jnp.broadcast_to(sent, (t, pad, 4))], axis=1)
    g = tiles.reshape(t, c, ops.CHUNK, 4)
    return jnp.concatenate(
        [jnp.min(g[..., :2], axis=2), jnp.max(g[..., 2:], axis=2)], axis=-1)


@pytest.mark.parametrize("q,t,cap", [(7, 3, 50), (130, 4, 257),
                                     (256, 2, 640)])
def test_skip_variants_equal_unindexed_with_true_boxes(q, t, cap):
    """With bounding chunk boxes the skip kernels (Pallas interpret and
    default executor) reproduce the unindexed results bit-for-bit."""
    qb = _boxes(jax.random.PRNGKey(q), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(t + 1), t, cap)
    cb = _chunk_boxes(tiles)
    cand = jax.random.randint(jax.random.PRNGKey(cap), (q, 3), -1, t)

    want_c = ref.probe_counts(qb, tiles)
    assert bool(jnp.all(ops.probe_counts_skip(qb, tiles, cb) == want_c))
    assert bool(jnp.all(
        ops.probe_counts_skip(qb, tiles, cb, interpret=True) == want_c))
    want_m = ops.probe_mask(qb, tiles)
    assert bool(jnp.all(ops.probe_mask_skip(qb, tiles, cb) == want_m))
    assert bool(jnp.all(
        ops.probe_mask_skip(qb, tiles, cb, interpret=True) == want_m))

    want_gc = ops.gathered_counts(qb, tiles, cand)
    assert bool(jnp.all(
        ops.gathered_counts_skip(qb, tiles, cb, cand) == want_gc))
    assert bool(jnp.all(
        ops.gathered_counts_skip(qb, tiles, cb, cand, interpret=True)
        == want_gc))
    want_gm = ops.gathered_mask(qb, tiles, cand)
    assert bool(jnp.all(
        ops.gathered_mask_skip(qb, tiles, cb, cand) == want_gm))
    assert bool(jnp.all(
        ops.gathered_mask_skip(qb, tiles, cb, cand, interpret=True)
        == want_gm))


def test_skip_kernels_match_masked_ref_with_arbitrary_boxes():
    """The kernels implement exactly the refs' chunk-masked semantics —
    even for chunk boxes that do NOT bound their members (a staging bug
    would surface as an answer diff, not silent corruption)."""
    q, t, cap, f = 130, 4, 257, 3
    qb = _boxes(jax.random.PRNGKey(1), q, 0.2)
    tiles = _tiles(jax.random.PRNGKey(2), t, cap)
    c = -(-cap // ops.CHUNK)
    cb = _boxes(jax.random.PRNGKey(3), t * c, 0.05).reshape(t, c, 4)
    cand = jax.random.randint(jax.random.PRNGKey(4), (q, f), -1, t)

    want = ref.probe_counts_skip(qb, tiles, cb)
    assert bool(jnp.all(
        ops.probe_counts_skip(qb, tiles, cb, interpret=True) == want))
    want_g = ref.gathered_counts_skip(qb, ops.gathered_rows(tiles, cand),
                                      ops.gathered_chunk_boxes(cb, cand))
    assert bool(jnp.all(
        ops.gathered_counts_skip(qb, tiles, cb, cand, interpret=True)
        == want_g))
    want_gm = ref.gathered_mask_skip(qb, ops.gathered_rows(tiles, cand),
                                     ops.gathered_chunk_boxes(cb, cand))
    assert bool(jnp.all(
        ops.gathered_mask_skip(qb, tiles, cb, cand, interpret=True)
        == want_gm))


def test_sentinel_chunks_always_skip_and_rate_reports_them():
    """All-sentinel chunks (inverted boxes) contribute nothing and count
    as skipped in the measured rate."""
    qb = jnp.array([[0.0, 0.0, 1.0, 1.0]])     # hits everything real
    tiles = _tiles(jax.random.PRNGKey(0), 2, 128, 0.1)
    sent = jnp.array([9e9, 9e9, -9e9, -9e9])
    tiles = jnp.concatenate(
        [tiles, jnp.broadcast_to(sent, (2, 128, 4))], axis=1)  # cap 256
    cb = _chunk_boxes(tiles)
    assert bool(jnp.all(cb[:, 1, 0] > cb[:, 1, 2]))    # sentinel chunk
    cand = jnp.array([[0, 1]], jnp.int32)
    got = ops.gathered_counts_skip(qb, tiles, cb, cand)
    assert bool(jnp.all(got == ops.gathered_counts(qb, tiles, cand)))
    rate = float(ops.chunk_skip_rate(qb, cb, cand))
    assert rate == pytest.approx(0.5)   # live chunks hit, sentinels skip
