"""mbr_join Pallas kernel: shape/dtype sweep vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.mbr_join import kernel, ops, ref


def _boxes(key, n, scale=0.1):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (n, 2))
    s = jax.random.uniform(k2, (n, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


@pytest.mark.parametrize("n,m", [(1, 1), (7, 5), (128, 128), (300, 257),
                                 (1024, 513)])
def test_count_matches_ref(n, m):
    r = _boxes(jax.random.PRNGKey(n), n)
    s = _boxes(jax.random.PRNGKey(m + 1), m)
    assert int(ops.join_count(r, s)) == int(ref.intersect_count(r, s))


@pytest.mark.parametrize("n,m", [(5, 9), (130, 260), (511, 140)])
def test_mask_matches_ref(n, m):
    r = _boxes(jax.random.PRNGKey(n), n)
    s = _boxes(jax.random.PRNGKey(m), m)
    assert bool(jnp.all(ops.join_mask(r, s) == ref.intersect_mask(r, s)))


@pytest.mark.parametrize("br,bs", [(128, 128), (256, 128), (512, 256)])
def test_block_shape_sweep(br, bs):
    r = _boxes(jax.random.PRNGKey(0), 700)
    s = _boxes(jax.random.PRNGKey(1), 300)
    assert int(ops.join_count(r, s, br=br, bs=bs)) == \
        int(ref.intersect_count(r, s))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    r = _boxes(jax.random.PRNGKey(2), 256).astype(dtype)
    s = _boxes(jax.random.PRNGKey(3), 256).astype(dtype)
    # wrapper casts to f32; compare against f32 oracle on the cast data
    rf, sf = r.astype(jnp.float32), s.astype(jnp.float32)
    assert int(ops.join_count(r, s)) == int(ref.intersect_count(rf, sf))


def test_touching_boxes_intersect():
    r = jnp.array([[0.0, 0.0, 1.0, 1.0]])
    s = jnp.array([[1.0, 1.0, 2.0, 2.0]])   # shares exactly one corner
    assert int(ops.join_count(r, s)) == 1


def test_sentinel_padding_never_matches():
    r = _boxes(jax.random.PRNGKey(4), 3)    # heavy padding to 256
    s = _boxes(jax.random.PRNGKey(5), 2)
    assert int(ops.join_count(r, s)) == int(ref.intersect_count(r, s))
