"""The intra-tile local index (chunk-skipping probe layer): the staged
sort is a pure per-tile permutation that preserves canonical marking,
chunk boxes bound their chunks' canonical members, and range/kNN
answers with ``local_index="x"`` or ``"hilbert"`` are bit-identical to
the unindexed (``"off"``) oracle staging across ALL SIX layouts on
skewed (osm) and uniform (pi) data — replicated and sharded (vmap
simulation here; the 8-device SPMD job runs the mesh test below
whenever ≥ 8 devices are visible)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.kernels.range_probe import ops as rops
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import ServeConfig, SpatialServer, stage_tiles

LAYOUTS = ["hc", "str", "fg", "bsp", "slc", "bos"]
DATASETS = ["osm", "pi"]
N, NQ, K, SHARDS = 1500, 24, 4, 4


def _qboxes(key, q, scale=0.06):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


@pytest.fixture(scope="module", params=DATASETS)
def data(request):
    mbrs = spatial_gen.dataset(request.param, jax.random.PRNGKey(0), N)
    return mbrs, np.asarray(mbrs)


@pytest.fixture(scope="module")
def staged_pairs(data):
    """(indexed layout, unindexed layout, parts) per layout method."""
    mbrs, _ = data
    out = {}
    for m in LAYOUTS:
        parts = api.partition(m, mbrs, 120)
        indexed, _ = stage_tiles(parts, mbrs, ServeConfig(local_index="x"))
        plain, _ = stage_tiles(parts, mbrs, ServeConfig(local_index="off"))
        out[m] = (indexed, plain, parts)
    return out


@pytest.mark.parametrize("method", LAYOUTS)
def test_sort_is_pure_per_tile_permutation(data, staged_pairs, method):
    """Property: per tile, the sorted layout's ids are a permutation of
    the unsorted layout's ids (with identical canonical id sets), and
    exactly one canonical slot per object survives globally."""
    indexed, plain, _ = staged_pairs[method]
    ids_s, ids_u = np.asarray(indexed.ids), np.asarray(plain.ids)
    canon_s = np.asarray(indexed.canon_tiles[..., 0]) < 1e9
    canon_u = np.asarray(plain.canon_tiles[..., 0]) < 1e9
    for t in range(ids_s.shape[0]):
        np.testing.assert_array_equal(np.sort(ids_s[t]), np.sort(ids_u[t]))
        assert (set(ids_s[t][canon_s[t]].tolist())
                == set(ids_u[t][canon_u[t]].tolist())), t
    n = int(max(ids_u.max(), 0)) + 1
    counts = np.bincount(ids_s[canon_s].ravel(), minlength=n)
    np.testing.assert_array_equal(counts, np.ones(n))
    # member boxes moved with their ids: every slot still holds its
    # object's MBR
    mbrs_np = data[1]
    tiles_s = np.asarray(indexed.tiles)
    live = ids_s >= 0
    np.testing.assert_allclose(tiles_s[live], mbrs_np[ids_s[live]],
                               rtol=0, atol=0)


@pytest.mark.parametrize("method", LAYOUTS)
def test_sorted_canonicals_lead_in_x_order(data, staged_pairs, method):
    """The sort contract the chunk boxes rely on: canonical members come
    first in ascending xmin; non-canonical copies and padding trail."""
    indexed, _, _ = staged_pairs[method]
    key = np.asarray(indexed.canon_tiles[..., 0])     # 9e9 for non-canon
    canon = key < 1e9
    for t in range(key.shape[0]):
        k = canon[t].sum()
        assert not canon[t][k:].any()                 # canonicals lead
        assert np.all(np.diff(key[t][:k]) >= 0)       # ascending xmin


@pytest.mark.parametrize("method", LAYOUTS)
def test_chunk_boxes_bound_canonical_members(data, staged_pairs, method):
    """The skip-safety invariant: chunk c's box contains every canonical
    member MBR in slots [c·128, (c+1)·128); all-sentinel chunks carry
    inverted (never-matching) boxes."""
    indexed, _, _ = staged_pairs[method]
    ct = np.asarray(indexed.canon_tiles)
    cb = np.asarray(indexed.chunk_boxes)
    t, cap, _ = ct.shape
    chunk = rops.CHUNK
    assert cb.shape == (t, -(-cap // chunk), 4)
    live = ct[..., 0] < 1e9
    for ti in range(t):
        for c in range(cb.shape[1]):
            sl = slice(c * chunk, min((c + 1) * chunk, cap))
            boxes = ct[ti, sl][live[ti, sl]]
            if boxes.size == 0:
                assert cb[ti, c, 0] > cb[ti, c, 2]    # sentinel chunk
                continue
            assert np.all(cb[ti, c, 0] <= boxes[:, 0] + 1e-7)
            assert np.all(cb[ti, c, 1] <= boxes[:, 1] + 1e-7)
            assert np.all(cb[ti, c, 2] >= boxes[:, 2] - 1e-7)
            assert np.all(cb[ti, c, 3] >= boxes[:, 3] - 1e-7)


@pytest.fixture(scope="module")
def servers(data):
    mbrs, _ = data
    return {m: (SpatialServer.from_method(m, mbrs, 120),
                SpatialServer.from_method(
                    m, mbrs, 120, ServeConfig(local_index="off")))
            for m in LAYOUTS}


@pytest.mark.parametrize("method", LAYOUTS)
def test_local_index_range_bit_identical_to_oracle(data, servers, method):
    """local_index="x" answers == local_index="off" answers == brute
    force, replicated pruned path."""
    _, mbrs_np = data
    srv, osrv = servers[method]
    assert srv.stats["local_index"] == "x"
    assert osrv.stats["local_index"] == "off"
    qb = _qboxes(jax.random.PRNGKey(1), NQ)
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))

    counts, _ = srv.range_counts(qb)
    ocounts, _ = osrv.range_counts(qb)
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ocounts))
    assert [int(c) for c in counts] == [len(r) for r in ref]

    hit_ids, cnts, ovf, _ = srv.range_ids(qb, max_hits=2048)
    o_ids, o_cnts, o_ovf, _ = osrv.range_ids(qb, max_hits=2048)
    assert not np.asarray(ovf).any() and not np.asarray(o_ovf).any()
    np.testing.assert_array_equal(np.asarray(hit_ids), np.asarray(o_ids))
    for i, want in enumerate(ref):
        got = np.asarray(hit_ids[i])
        np.testing.assert_array_equal(got[got >= 0], want)


@pytest.mark.parametrize("method", LAYOUTS)
def test_local_index_knn_bit_identical_to_oracle(data, servers, method):
    _, mbrs_np = data
    srv, osrv = servers[method]
    pts = jax.random.uniform(jax.random.PRNGKey(2), (NQ, 2))
    want_ids, want_d2 = knn_mod.knn_ref(mbrs_np, np.asarray(pts), K)

    nn_ids, nn_d2, ovf, _ = srv.knn(pts, K)
    o_ids, o_d2, o_ovf, _ = osrv.knn(pts, K)
    assert not np.asarray(ovf).any() and not np.asarray(o_ovf).any()
    np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
    np.testing.assert_array_equal(np.asarray(nn_ids), np.asarray(o_ids))
    np.testing.assert_array_equal(np.asarray(nn_d2), np.asarray(o_d2))


@pytest.mark.parametrize("method", LAYOUTS)
def test_local_index_sharded_bit_identical(data, method):
    """Sharded serving (vmap-simulated exchange) with chunk shards ==
    the dense oracle == brute force."""
    mbrs, mbrs_np = data
    srv = SpatialServer.from_method(
        method, mbrs, 120,
        ServeConfig(placement="sharded", shards=SHARDS))
    assert srv.slayout.chunk_shards is not None
    qb = _qboxes(jax.random.PRNGKey(3), NQ)
    pts = jax.random.uniform(jax.random.PRNGKey(4), (NQ, 2))
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    counts, _ = srv.range_counts(qb)
    assert [int(c) for c in counts] == [len(r) for r in ref], method
    hit_ids, _, ovf, _ = srv.range_ids(qb, max_hits=2048)
    d_ids, _, _, _ = srv.range_ids(qb, max_hits=2048, pruned=False)
    assert not np.asarray(ovf).any()
    np.testing.assert_array_equal(np.asarray(hit_ids), np.asarray(d_ids))
    nn_ids, nn_d2, ovk, _ = srv.knn(pts, K)
    d_nn, d_d2, _, _ = srv.knn(pts, K, pruned=False)
    assert not np.asarray(ovk).any()
    np.testing.assert_array_equal(np.asarray(nn_ids), np.asarray(d_nn))
    np.testing.assert_array_equal(np.asarray(nn_d2), np.asarray(d_d2))


def test_chunk_skip_rate_positive_on_multichunk_layout(data):
    """A layout whose capacity spans several chunks must actually skip:
    the measured rate is in (0, 1] and 0.0 for unindexed staging."""
    mbrs, _ = data
    srv = SpatialServer.from_method("fg", mbrs, 120)
    osrv = SpatialServer.from_method(
        "fg", mbrs, 120, ServeConfig(local_index="off"))
    qb = _qboxes(jax.random.PRNGKey(5), NQ, scale=0.03)
    if srv.stats["chunks"] < 2:
        pytest.skip("fixture capacity fits one chunk")
    rate = srv.chunk_skip_rate(qb)
    assert 0.0 < rate <= 1.0
    assert osrv.chunk_skip_rate(qb) == 0.0


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI virtual-device job)")
def test_local_index_spmd_mesh_bit_identical():
    """Chunk shards travel the real all_to_all exchange: mesh answers ==
    dense oracle == brute force, replicated and sharded."""
    from jax.sharding import Mesh
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), 2000)
    mesh = Mesh(np.array(jax.devices()[:8]), ("d",))
    qb = _qboxes(jax.random.PRNGKey(1), 32, scale=0.05)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (32, 2))
    ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qb))
    want_ids, _ = knn_mod.knn_ref(np.asarray(mbrs), np.asarray(pts), 5)
    for m in ["bsp", "hc"]:
        for srv in [SpatialServer.from_method(m, mbrs, 150, mesh=mesh),
                    SpatialServer.from_method(
                        m, mbrs, 150,
                        ServeConfig(placement="sharded"), mesh=mesh)]:
            counts, _ = srv.range_counts(qb)
            assert [int(c) for c in counts] == [len(r) for r in ref], m
            hit_ids, _, ovf, _ = srv.range_ids(qb, max_hits=2048)
            d_ids, _, _, _ = srv.range_ids(qb, max_hits=2048, pruned=False)
            assert not np.asarray(ovf).any()
            np.testing.assert_array_equal(np.asarray(hit_ids),
                                          np.asarray(d_ids))
            nn_ids, _, ovk, _ = srv.knn(pts, 5)
            assert not np.asarray(ovk).any()
            np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)


# --------------------------------------------------------------------------
# Hilbert intra-tile order (local_index="hilbert")
# --------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["bsp", "hc"])
def test_hilbert_sort_contract_and_bit_identity(data, method):
    """``local_index="hilbert"``: canonical members lead each tile in
    ascending Hilbert key of their MBR centre (live slots stay a
    prefix), chunk boxes still bound their members, and answers are
    bit-identical to the x-sorted and unindexed stagings."""
    from repro.kernels.hilbert import ops as hilbert_ops
    mbrs, mbrs_np = data
    parts = api.partition(method, mbrs, 120)
    hil, _ = stage_tiles(parts, mbrs, ServeConfig(local_index="hilbert"))
    ids = np.asarray(hil.ids)
    canon = np.asarray(hil.canon_tiles[..., 0]) < 1e9
    centers = np.asarray((hil.canon_tiles[..., :2]
                          + hil.canon_tiles[..., 2:]) * 0.5)
    keys = np.asarray(hilbert_ops.hilbert_keys(
        jnp.asarray(centers.reshape(-1, 2)), hil.uni)
    ).reshape(ids.shape)
    for t in range(ids.shape[0]):
        kc = canon[t].sum()
        assert not canon[t][kc:].any()                # canonicals lead
        assert np.all(np.diff(keys[t][:kc].astype(np.int64)) >= 0)
        live = (ids[t] >= 0).sum()
        assert (ids[t][:live] >= 0).all()             # live slots prefix
    # same chunk-box bounding invariant as the x sort
    cb = np.asarray(hil.chunk_boxes)
    ct = np.asarray(hil.canon_tiles)
    chunk = rops.CHUNK
    for ti in range(ct.shape[0]):
        for c in range(cb.shape[1]):
            sl = slice(c * chunk, min((c + 1) * chunk, ct.shape[1]))
            boxes = ct[ti, sl][ct[ti, sl, 0] < 1e9]
            if boxes.size:
                assert np.all(cb[ti, c, 0] <= boxes[:, 0] + 1e-7)
                assert np.all(cb[ti, c, 3] >= boxes[:, 3] - 1e-7)
    # bit-identical serving vs x-sorted and unindexed
    hsrv = SpatialServer.from_method(
        method, mbrs, 120, ServeConfig(local_index="hilbert"))
    xsrv = SpatialServer.from_method(method, mbrs, 120)
    qb = _qboxes(jax.random.PRNGKey(6), NQ)
    pts = jax.random.uniform(jax.random.PRNGKey(7), (NQ, 2))
    hc_, _ = hsrv.range_counts(qb)
    xc_, _ = xsrv.range_counts(qb)
    np.testing.assert_array_equal(np.asarray(hc_), np.asarray(xc_))
    hids, _, hovf, _ = hsrv.range_ids(qb, max_hits=2048)
    xids, _, _, _ = xsrv.range_ids(qb, max_hits=2048)
    assert not np.asarray(hovf).any()
    np.testing.assert_array_equal(np.asarray(hids), np.asarray(xids))
    hnn, hd2, hko, _ = hsrv.knn(pts, K)
    wnn, wd2 = knn_mod.knn_ref(mbrs_np, np.asarray(pts), K)
    assert not np.asarray(hko).any()
    np.testing.assert_array_equal(np.asarray(hnn), wnn)


def test_hilbert_skip_rate_measured(data):
    """The hilbert staging yields a real (0, 1] chunk-skip rate on a
    multi-chunk layout — the quantity BENCH_serving.json compares
    against the x sort."""
    mbrs, _ = data
    srv = SpatialServer.from_method(
        "fg", mbrs, 120, ServeConfig(local_index="hilbert"))
    if srv.stats["chunks"] < 2:
        pytest.skip("fixture capacity fits one chunk")
    qb = _qboxes(jax.random.PRNGKey(8), NQ, scale=0.03)
    assert 0.0 < srv.chunk_skip_rate(qb) <= 1.0


def test_chunk_granularity_256_same_bits(data):
    """``chunk=256``: coarser chunk boxes are broadcast to the 128-slot
    kernel grid — looser skips, identical answers."""
    mbrs, mbrs_np = data
    srv = SpatialServer.from_method("bsp", mbrs, 120,
                                    ServeConfig(chunk=256))
    qb = _qboxes(jax.random.PRNGKey(9), NQ)
    counts, _ = srv.range_counts(qb)
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    assert [int(c) for c in counts] == [len(r) for r in ref]
