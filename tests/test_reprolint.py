"""reprolint analyzer tests: per-rule known-bad / known-good fixtures
(each bad fixture stops firing when its rule is disabled — the guard
that a rule can't silently be deleted), suppression-rationale policy,
CLI exit codes, and the live-tree self-check.

Fixture trees are written under tmp_path mimicking the repo's layout
(serve/, kernels/<fam>/) because rule applicability is path-driven.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import api

REPO = Path(__file__).resolve().parent.parent


def run_on(tmp_path, files, disable=()):
    root = tmp_path / "src"
    for rel, text in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return api.run(root, disable=set(disable), use_allowlist=False)


def rule_findings(report, rule):
    return [f for f in report.findings if f.rule == rule]


# ---------------------------------------------------------------------------
# R1 jit-closure-capture
# ---------------------------------------------------------------------------

R1_BAD = {"repro/serve/stage.py": """
    import jax
    import jax.numpy as jnp

    def build_step(data):
        tiles = jnp.asarray(data)
        step = jax.jit(lambda q: q @ tiles)
        return step
    """}

R1_GOOD = {"repro/serve/stage.py": """
    import jax
    import jax.numpy as jnp

    def build_step(data):
        tiles = jnp.asarray(data)
        step = jax.jit(lambda q, t: q @ t)
        return step, tiles
    """}


def test_r1_flags_closure_captured_array(tmp_path):
    found = rule_findings(run_on(tmp_path, R1_BAD), "jit-closure-capture")
    assert len(found) == 1
    assert "'tiles'" in found[0].message


def test_r1_local_def_capture(tmp_path):
    files = {"repro/serve/stage.py": """
        import jax
        import jax.numpy as jnp

        def build_step(data):
            tiles = jnp.asarray(data)
            def step(q):
                return q @ tiles
            return jax.jit(step)
        """}
    found = rule_findings(run_on(tmp_path, files), "jit-closure-capture")
    assert len(found) == 1 and found[0].func == "build_step"


def test_r1_good_and_disabled(tmp_path):
    assert not run_on(tmp_path, R1_GOOD).findings
    assert not run_on(tmp_path, R1_BAD,
                      disable=["jit-closure-capture"]).findings


# ---------------------------------------------------------------------------
# R2 recompile-hazard
# ---------------------------------------------------------------------------

R2_BAD = {"repro/serve/width.py": """
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("width",))
    def probe(x, width):
        return x[:width]

    def serve(xs, batch):
        n = len(batch)
        return probe(xs, width=n)
    """}

R2_GOOD = {"repro/serve/width.py": """
    import functools
    import jax

    def round_up(x, m):
        return (x + m - 1) // m * m

    @functools.partial(jax.jit, static_argnames=("width",))
    def probe(x, width):
        return x[:width]

    def serve(xs, batch):
        n = round_up(len(batch), 8)
        return probe(xs, width=n)
    """}


def test_r2_flags_unbucketed_static(tmp_path):
    found = rule_findings(run_on(tmp_path, R2_BAD), "recompile-hazard")
    assert len(found) == 1
    assert "'width'" in found[0].message


def test_r2_positional_and_cross_module(tmp_path):
    files = {
        "repro/kernels/fam/ops.py": """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("bq",))
            def probe_counts(qboxes, bq=128, *, alive=None):
                return qboxes[:bq]
            """,
        "repro/serve/caller.py": """
            from ..kernels.fam import ops as rops

            def serve(qboxes, batch):
                return rops.probe_counts(qboxes, len(batch))
            """,
    }
    found = rule_findings(run_on(tmp_path, files), "recompile-hazard")
    assert len(found) == 1 and found[0].path.endswith("caller.py")


def test_r2_good_and_disabled(tmp_path):
    assert not rule_findings(run_on(tmp_path, R2_GOOD), "recompile-hazard")
    assert not run_on(tmp_path, R2_BAD,
                      disable=["recompile-hazard"]).findings


# ---------------------------------------------------------------------------
# R3 host-sync
# ---------------------------------------------------------------------------

R3_BAD = {"repro/serve/exchange.py": """
    import jax.numpy as jnp

    def merge(parts):
        total = jnp.sum(parts)
        return float(total)
    """}

R3_GOOD = {"repro/serve/exchange.py": """
    import jax.numpy as jnp

    def merge(parts):
        return jnp.sum(parts)

    def host_merge(host_counts):
        return float(sum(host_counts))
    """}


def test_r3_flags_hot_path_sync(tmp_path):
    found = rule_findings(run_on(tmp_path, R3_BAD), "host-sync")
    assert len(found) == 1
    assert "float()" in found[0].message


def test_r3_cold_module_exempt(tmp_path):
    files = {"repro/serve/coldplane.py": R3_BAD["repro/serve/exchange.py"]}
    assert not run_on(tmp_path, files).findings


def test_r3_good_and_disabled(tmp_path):
    assert not run_on(tmp_path, R3_GOOD).findings
    assert not run_on(tmp_path, R3_BAD, disable=["host-sync"]).findings


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_suppression_with_rationale_silences(tmp_path):
    files = {"repro/serve/exchange.py": """
        import jax.numpy as jnp

        def merge(parts):
            total = jnp.sum(parts)
            # reprolint: disable=host-sync -- merge result must come home
            return float(total)
        """}
    rep = run_on(tmp_path, files)
    assert not rep.findings
    assert len(rep.suppressed) == 1


def test_suppression_without_rationale_is_a_finding(tmp_path):
    files = {"repro/serve/exchange.py": """
        import jax.numpy as jnp

        def merge(parts):
            total = jnp.sum(parts)
            # reprolint: disable=host-sync
            return float(total)
        """}
    rep = run_on(tmp_path, files)
    rules = sorted(f.rule for f in rep.findings)
    # the rationale-free suppression suppresses nothing AND is flagged
    assert rules == ["bad-suppression", "host-sync"]


def test_suppression_unknown_rule_is_a_finding(tmp_path):
    files = {"repro/serve/exchange.py": """
        # reprolint: disable=no-such-rule -- rationale present
        X = 1
        """}
    rep = run_on(tmp_path, files)
    assert [f.rule for f in rep.findings] == ["bad-suppression"]


# ---------------------------------------------------------------------------
# R4 kernel-twin-parity
# ---------------------------------------------------------------------------

R4_HEADER = """
    import jax.numpy as jnp

    def probe_counts(qboxes, tiles, *, alive=None):
        hit = qboxes[:, None, 0, None] <= tiles[None, :, :, 2]
        if alive is not None:
            hit = hit & alive[None]
        return jnp.sum(hit, axis=2).astype(jnp.int32)
    """

R4_BAD_AVAL = {"repro/kernels/fake/ops.py": R4_HEADER + """
    def probe_counts_skip(qboxes, tiles, cboxes, *, alive=None):
        hit = qboxes[:, None, 0, None] <= tiles[None, :, :, 2]
        if alive is not None:
            hit = hit & alive[None]
        return jnp.sum(hit, axis=(1, 2)).astype(jnp.int32)
    """}

R4_GOOD = {"repro/kernels/fake/ops.py": R4_HEADER + """
    def probe_counts_skip(qboxes, tiles, cboxes, *, alive=None):
        hit = qboxes[:, None, 0, None] <= tiles[None, :, :, 2]
        live = qboxes[:, None, 0, None] <= cboxes[None, :, :, 2]
        if alive is not None:
            hit = hit & alive[None]
        return (jnp.sum(hit, axis=2) * live[..., 0]).astype(jnp.int32)
    """}


def test_r4_missing_alive(tmp_path):
    files = {"repro/kernels/fake/ops.py": """
        import jax.numpy as jnp

        def probe_counts(qboxes, tiles):
            return jnp.sum(tiles, axis=(1, 2))
        """}
    found = rule_findings(run_on(tmp_path, files), "kernel-twin-parity")
    assert len(found) == 1 and "tombstone" in found[0].message


def test_r4_unused_alive(tmp_path):
    files = {"repro/kernels/fake/ops.py": """
        import jax.numpy as jnp

        def probe_counts(qboxes, tiles, *, alive=None):
            return jnp.sum(tiles, axis=(1, 2))
        """}
    found = rule_findings(run_on(tmp_path, files), "kernel-twin-parity")
    assert len(found) == 1 and "never uses" in found[0].message


R4_BAD_SIG = {"repro/kernels/fake/ops.py": R4_HEADER + """
    def probe_counts_skip(qboxes, tiles, cboxes, extra, *, alive=None):
        if alive is not None:
            tiles = tiles * alive[..., None]
        return jnp.sum(tiles * extra, axis=(1, 2))
    """}


def test_r4_twin_signature_mismatch(tmp_path):
    found = rule_findings(run_on(tmp_path, R4_BAD_SIG),
                          "kernel-twin-parity")
    assert any("signature mismatch" in f.message for f in found)


def test_r4_orphan_skip_twin(tmp_path):
    files = {"repro/kernels/fake/ops.py": """
        import jax.numpy as jnp

        def gathered_mask_skip(qboxes, gtiles, gcboxes, *, galive=None):
            m = qboxes[:, None, 0, None] <= gtiles[..., 2]
            if galive is not None:
                m = m & galive
            return m
        """}
    found = rule_findings(run_on(tmp_path, files), "kernel-twin-parity")
    assert any("no base twin" in f.message for f in found)


def test_r4_aval_mismatch_via_eval_shape(tmp_path):
    found = rule_findings(run_on(tmp_path, R4_BAD_AVAL),
                          "kernel-twin-parity")
    assert any("output avals differ" in f.message for f in found)


def test_r4_good_and_disabled(tmp_path):
    assert not run_on(tmp_path, R4_GOOD).findings
    assert not run_on(tmp_path, R4_BAD_AVAL,
                      disable=["kernel-twin-parity"]).findings


# ---------------------------------------------------------------------------
# R5 layout-conformance
# ---------------------------------------------------------------------------

R5_PRELUDE = """
    from typing import Protocol

    class TileLayout(Protocol):
        mode: str
        def append(self, mbrs): ...
        def range_counts(self, qboxes): ...

    class Base:
        def __init__(self):
            self.mode = "x"
        def append(self, mbrs):
            return self._scatter({})
        def _scatter(self, plan):
            return 0
    """

R5_BAD = {"repro/serve/layout.py": R5_PRELUDE + """
    class Good(Base):
        def range_counts(self, qboxes):
            return 0

    class Bad(Base):
        pass

    _PLACEMENT_CLS = {"good": Good, "bad": Bad}
    """}

R5_GOOD = {"repro/serve/layout.py": R5_PRELUDE + """
    class Good(Base):
        def range_counts(self, qboxes):
            return 0

    _PLACEMENT_CLS = {"good": Good}
    """}


def test_r5_missing_member(tmp_path):
    found = rule_findings(run_on(tmp_path, R5_BAD), "layout-conformance")
    assert len(found) == 1
    assert "'Bad'" in found[0].message and "range_counts" in found[0].message


def test_r5_unregistered_subclass(tmp_path):
    files = {"repro/serve/layout.py": R5_GOOD["repro/serve/layout.py"] + """

    class Rogue(Base):
        def range_counts(self, qboxes):
            return 1
    """}
    found = rule_findings(run_on(tmp_path, files), "layout-conformance")
    assert len(found) == 1 and "not registered" in found[0].message


def test_r5_replica_fanout_chain(tmp_path):
    files = {"repro/serve/layout.py": R5_PRELUDE + """
    class Sharded(Base):
        def range_counts(self, qboxes):
            return 0
        def _placements(self, t_idx):
            return [t_idx]          # never consults rep_owner
        def _owner_scatter(self, arr, t_idx, slot_idx, vals):
            return self._placements(t_idx)
        def _scatter(self, plan):
            return 1                # skips _owner_scatter entirely

    _PLACEMENT_CLS = {"sharded": Sharded}
    """}
    found = rule_findings(run_on(tmp_path, files), "layout-conformance")
    msgs = " | ".join(f.message for f in found)
    assert "_owner_scatter" in msgs and "rep_owner" in msgs


def test_r5_good_and_disabled(tmp_path):
    assert not run_on(tmp_path, R5_GOOD).findings
    assert not run_on(tmp_path, R5_BAD,
                      disable=["layout-conformance"]).findings


# ---------------------------------------------------------------------------
# live tree + CLI
# ---------------------------------------------------------------------------

def test_live_src_tree_is_clean():
    rep = api.run(REPO / "src",
                  baseline=REPO / "tools" / "reprolint_baseline.json")
    assert rep.findings == [], "\n".join(f.render() for f in rep.findings)


def test_live_suppressions_all_carry_rationales():
    rep = api.run(REPO / "src")
    assert not [f for f in rep.findings if f.rule == "bad-suppression"]
    assert rep.suppressed, "expected deliberate suppressed sites in src/"


def test_baseline_file_is_empty():
    data = json.loads(
        (REPO / "tools" / "reprolint_baseline.json").read_text())
    assert data == {"fingerprints": []}


@pytest.mark.slow
def test_cli_json_and_exit_codes(tmp_path):
    env_root = str(REPO)
    out = subprocess.run(
        [sys.executable, "tools/reprolint.py", "src", "--json"],
        cwd=env_root, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    payload = json.loads(out.stdout)
    assert payload["counts"]["findings"] == 0

    bad = tmp_path / "src" / "repro" / "serve" / "stage.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(textwrap.dedent(
        R1_BAD["repro/serve/stage.py"]))
    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "reprolint.py"),
         str(tmp_path / "src"), "--no-baseline"],
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "jit-closure-capture" in out.stdout

    out = subprocess.run(
        [sys.executable, str(REPO / "tools" / "reprolint.py"), "src",
         "--disable", "no-such-rule"],
        cwd=env_root, capture_output=True, text=True)
    assert out.returncode == 2
