"""Query-heat-aware placement: the heat tracker, co-locating
rebalance, and hot-tile replication must never change an answer — only
where bytes live.  Bit-identity vs the dense oracle and the numpy
brute force is asserted across ALL SIX layouts on skewed (osm) and
uniform (pi) data, before and after a rebalance under traffic, and
through the full ingest lifecycle (append / delete / update / forced
compaction) while replicas are live.  The tracker itself must be
deterministic — same batches, same plan — and ``HeatSharded`` must
stay inside its declared memory bound: ``ceil(T/D) + replicate_top``
tile rows per device.  ``mesh=None`` runs the exchange in vmap
simulation; the 8-device SPMD test runs whenever the process sees ≥ 8
devices (the CI virtual-device job)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import placement
from repro.data import spatial_gen
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import (HeatTracker, PlacementPolicy, ServeConfig,
                         SpatialServer)

LAYOUTS = ["hc", "str", "fg", "bsp", "slc", "bos"]
DATASETS = ["osm", "pi"]
N, NQ, K, SHARDS, TOP = 1200, 24, 4, 4, 2


def _hot_qboxes(key, q, frac=0.8):
    """Skewed stream: most query centres cluster in one hotspot patch
    with larger boxes, the rest uniform — heat worth observing."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_hot = int(q * frac)
    ctr = jax.random.uniform(k1, (2,)) * 0.6 + 0.2
    c_hot = ctr + (jax.random.uniform(k2, (n_hot, 2)) - 0.5) * 0.2
    c = jnp.concatenate(
        [c_hot, jax.random.uniform(k3, (q - n_hot, 2))], axis=0)
    s = jax.random.uniform(k4, (q, 2)) * 0.05
    s = s.at[:n_hot].add(0.08)
    return jnp.concatenate([c - s, c + s], axis=-1)


def _heat_cfg(**kw):
    return ServeConfig(placement="heat", shards=SHARDS,
                       policy=PlacementPolicy(heat_decay=0.9,
                                              replicate_top=TOP), **kw)


@pytest.fixture(scope="module", params=DATASETS)
def data(request):
    mbrs = spatial_gen.dataset(request.param, jax.random.PRNGKey(0), N)
    return mbrs, np.asarray(mbrs)


@pytest.fixture(scope="module")
def hot_qb():
    return _hot_qboxes(jax.random.PRNGKey(1), NQ)


# -- tracker determinism ---------------------------------------------------

def test_heat_tracker_is_deterministic():
    """Same candidate batches into two trackers ⇒ identical heat and
    co-occurrence, and identical placement plans out of them."""
    rng = np.random.default_rng(0)
    batches = [rng.integers(-1, 12, (16, 6)).astype(np.int32)
               for _ in range(5)]
    a, b = HeatTracker(12, decay=0.9), HeatTracker(12, decay=0.9)
    for cand in batches:
        a.observe(cand)
        b.observe(cand.copy())
    ha, ca = a.snapshot()
    hb, cb = b.snapshot()
    np.testing.assert_array_equal(ha, hb)
    np.testing.assert_array_equal(ca, cb)
    costs = rng.pareto(1.0, 12) + 1.0
    own_a, *_ = placement.colocate_tiles(costs, ca, 4, 3)
    own_b, *_ = placement.colocate_tiles(costs, cb, 4, 3)
    np.testing.assert_array_equal(own_a, own_b)
    # co-occurrence counts pairs within a batch row, never diagonal
    assert np.all(np.diagonal(ca) == 0)
    assert np.all(ha >= 0) and a.batches == 5


def test_same_traffic_same_plan(data, hot_qb):
    """Two identical servers fed identical batches rebalance to the
    identical placement — plan determinism end to end."""
    mbrs, _ = data
    srvs = [SpatialServer.from_method("bsp", mbrs, 120, _heat_cfg())
            for _ in range(2)]
    for srv in srvs:
        for _ in range(3):
            srv.range_counts(hot_qb)
        srv.rebalance()
    a, b = srvs[0].slayout, srvs[1].slayout
    np.testing.assert_array_equal(a.owner, b.owner)
    np.testing.assert_array_equal(a.rep_owner, b.rep_owner)
    np.testing.assert_array_equal(a.rep_local, b.rep_local)


# -- bit-identity across layouts -------------------------------------------

@pytest.mark.parametrize("method", LAYOUTS)
def test_heat_placement_bit_identical(data, hot_qb, method):
    """Replica-aware routing answers bit-identically to the dense
    oracle and the brute force, before and after a heat rebalance."""
    mbrs, mbrs_np = data
    srv = SpatialServer.from_method(method, mbrs, 120, _heat_cfg())
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(hot_qb))
    pts = jax.random.uniform(jax.random.PRNGKey(2), (NQ, 2))
    want_ids, _ = knn_mod.knn_ref(mbrs_np, np.asarray(pts), K)
    for round_ in range(2):
        counts, stats = srv.range_counts(hot_qb)
        assert stats["mode"] == "heat"
        assert [int(c) for c in counts] == [len(r) for r in ref]
        hit_ids, cnts, ovf, _ = srv.range_ids(hot_qb, max_hits=2048)
        d_ids, d_cnts, d_ovf, _ = srv.range_ids(hot_qb, max_hits=2048,
                                                pruned=False)
        assert not np.asarray(ovf).any() and not np.asarray(d_ovf).any()
        np.testing.assert_array_equal(np.asarray(hit_ids),
                                      np.asarray(d_ids))
        np.testing.assert_array_equal(np.asarray(cnts), np.asarray(d_cnts))
        nn_ids, nn_d2, ovk, _ = srv.knn(pts, K)
        assert not np.asarray(ovk).any()
        np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
        d_nn, d_d2, _, _ = srv.knn(pts, K, pruned=False)
        np.testing.assert_array_equal(np.asarray(nn_d2), np.asarray(d_d2))
        if round_ == 0:
            rep = srv.rebalance()     # round 2 runs on the heat plan
            assert rep["replicated_tiles"] >= 0


# -- memory bound ----------------------------------------------------------

def test_heat_memory_bound(data):
    """Per-device shard rows are exactly ``ceil(T/D) + replicate_top``
    for every layout — replication never grows past its declared
    budget, even after a rebalance places different replicas."""
    mbrs, _ = data
    for m in LAYOUTS:
        srv = SpatialServer.from_method(m, mbrs, 120, _heat_cfg())
        t = srv.stats["t"]
        want_rows = -(-t // SHARDS) + TOP
        assert srv.slayout.canon_shards.shape[:2] == (SHARDS, want_rows)
        srv.range_counts(_hot_qboxes(jax.random.PRNGKey(3), NQ))
        srv.rebalance()
        assert srv.slayout.canon_shards.shape[:2] == (SHARDS, want_rows)
        # replicas genuinely are copies of their primaries
        s = srv.slayout
        reps = np.flatnonzero(s.rep_owner >= 0)
        assert reps.size <= TOP * SHARDS
        canon = np.asarray(s.canon_shards)
        ids = np.asarray(s.id_shards)
        for tt in reps.tolist():
            np.testing.assert_array_equal(
                canon[s.rep_owner[tt], s.rep_local[tt]],
                canon[s.owner[tt], s.local[tt]])
            np.testing.assert_array_equal(
                ids[s.rep_owner[tt], s.rep_local[tt]],
                ids[s.owner[tt], s.local[tt]])


# -- ingest through replicas -----------------------------------------------

def test_ingest_through_replicas_with_forced_compaction(data, hot_qb):
    """Appends, deletes, updates, and a forced compaction all fan out
    to every replica row: answers stay bit-identical to the brute force
    of the surviving set while hot tiles hold second copies."""
    mbrs, mbrs_np = data
    srv = SpatialServer.from_method(
        "bsp", mbrs, 120, _heat_cfg(slack=64, compact_dead_frac=None))
    for _ in range(3):
        srv.range_counts(hot_qb)
    rep = srv.rebalance()
    assert rep["replicated_tiles"] > 0
    rng = np.random.default_rng(1)
    lo = rng.uniform(0.0, 1.0, (40, 2)).astype(np.float32)
    ex = rng.uniform(0.0, 0.05, (40, 2)).astype(np.float32)
    srv.append(np.concatenate([lo, lo + ex], axis=1))
    live = {i: mbrs_np[i] for i in range(N)}
    live.update({N + i: np.concatenate([lo[i], lo[i] + ex[i]])
                 for i in range(40)})
    dels = rng.choice(np.arange(N + 40), 25, replace=False)
    srv.delete(dels)
    for i in dels:
        del live[int(i)]
    upd = rng.choice(sorted(live), 10, replace=False)
    ulo = rng.uniform(0.0, 1.0, (10, 2)).astype(np.float32)
    uex = rng.uniform(0.0, 0.05, (10, 2)).astype(np.float32)
    srv.update(upd, np.concatenate([ulo, ulo + uex], axis=1))
    for j, i in enumerate(upd):
        live[int(i)] = np.concatenate([ulo[j], ulo[j] + uex[j]])
    crep = srv.compact()
    assert crep["compacted_tiles"] > 0

    ids_live = np.array(sorted(live))
    boxes_live = np.stack([live[i] for i in ids_live])
    ref = range_mod.range_query_ref(boxes_live, np.asarray(hot_qb))
    hit_ids, cnts, ovf, _ = srv.range_ids(hot_qb, max_hits=2048)
    d_ids, _, _, _ = srv.range_ids(hot_qb, max_hits=2048, pruned=False)
    assert not np.asarray(ovf).any()
    np.testing.assert_array_equal(np.asarray(hit_ids), np.asarray(d_ids))
    for qi, rows in enumerate(ref):
        got = np.asarray(hit_ids[qi])
        np.testing.assert_array_equal(np.sort(got[got >= 0]),
                                      np.sort(ids_live[rows]))


# -- co-location unit contracts --------------------------------------------

def test_colocate_tiles_contracts():
    """The co-locating search respects the per-device cap, never
    increases the cut, and a valid ``prev_owner`` seed is preserved
    where the traffic gives no reason to move."""
    rng = np.random.default_rng(2)
    # cap leaves slack (12 tiles, 4×4 rows) so single moves can act;
    # a perfectly tight cap leaves only pairwise swaps in play
    t, d, cap = 12, 4, 4
    costs = rng.uniform(1.0, 2.0, t)
    cooc = np.zeros((t, t))
    # two hot cliques that pay to co-locate
    for grp in ([0, 3, 7], [1, 5, 9]):
        for i in grp:
            for j in grp:
                if i != j:
                    cooc[i, j] = 50.0
    # balance_tol loose enough that a 4th tile on one device is legal;
    # at the default 1.25 the load guard vetoes the grouping moves
    owner, makespan, mean, stats = placement.colocate_tiles(
        costs, cooc, d, cap, balance_tol=2.5)
    assert np.bincount(owner, minlength=d).max() <= cap
    assert stats["cut_after"] <= stats["cut_before"]
    assert len({owner[0], owner[3], owner[7]}) == 1
    assert len({owner[1], owner[5], owner[9]}) == 1
    # a no-traffic rebalance keeps the previous plan verbatim
    prev = owner.copy()
    owner2, *_ = placement.colocate_tiles(
        costs, np.zeros((t, t)), d, cap, prev_owner=prev)
    np.testing.assert_array_equal(owner2, prev)


def test_replicas_route_to_one_resident_copy(data, hot_qb):
    """Every candidate in a routed batch resolves to exactly one
    ``(owner, local)`` row that actually holds the tile — primary or
    replica — and each query's candidates are covered exactly once.
    That owner-disjointness is what keeps the sharded merge exact."""
    mbrs, _ = data
    srv = SpatialServer.from_method("slc", mbrs, 120, _heat_cfg())
    for _ in range(3):
        srv.range_counts(hot_qb)
    srv.rebalance()
    s = srv.slayout
    assert np.any(s.rep_owner >= 0)          # replicas actually in play
    cand, costs, _ = srv._route_batch(hot_qb)
    slots, ss, sc, xstats = srv.tiles._exchange_plan(
        np.asarray(cand), costs)
    cand = np.asarray(cand)
    inv = {}
    for t, (o, lt) in enumerate(zip(s.owner, s.local)):
        inv[(int(o), int(lt))] = t
    for t in np.flatnonzero(s.rep_owner >= 0):
        inv[(int(s.rep_owner[t]), int(s.rep_local[t]))] = int(t)
    got = {q: [] for q in range(cand.shape[0])}
    for h in range(ss.shape[0]):
        for o in range(ss.shape[1]):
            for mi in range(ss.shape[2]):
                if ss[h, o, mi] < 0:
                    continue
                q = slots[h, ss[h, o, mi]]
                lts = sc[h, o, mi]
                got[int(q)].extend(inv[(o, int(lt))]
                                   for lt in lts[lts >= 0])
    for q in range(cand.shape[0]):
        want = sorted(cand[q][cand[q] >= 0].tolist())
        assert sorted(got[q]) == want, q     # once each, no copy twice
    assert xstats["probe_load_imbalance"] >= 1.0
    assert xstats["exchange_bytes"] > 0
    assert xstats["routed_alt"] >= 0


# -- SPMD mesh -------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI virtual-device job)")
def test_heat_spmd_mesh_bit_identical():
    """HeatSharded on a real 8-device mesh: bit-identical answers
    through rebalance, replicated ingest, and forced compaction."""
    from jax.sharding import Mesh
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), 2000)
    mesh = Mesh(np.array(jax.devices()[:8]), ("d",))
    cfg = ServeConfig(placement="heat", slack=64, compact_dead_frac=None,
                      policy=PlacementPolicy(heat_decay=0.9,
                                             replicate_top=2))
    qb = _hot_qboxes(jax.random.PRNGKey(1), 32)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (32, 2))
    for m in ["bsp", "slc"]:
        srv = SpatialServer.from_method(m, mbrs, 150, cfg, mesh=mesh)
        for _ in range(3):
            srv.range_counts(qb)
        srv.rebalance()
        hit_ids, _, ovf, _ = srv.range_ids(qb, max_hits=4096)
        d_ids, _, _, _ = srv.range_ids(qb, max_hits=4096, pruned=False)
        assert not np.asarray(ovf).any()
        np.testing.assert_array_equal(np.asarray(hit_ids),
                                      np.asarray(d_ids))
        nn_ids, nn_d2, _, _ = srv.knn(pts, 5)
        d_nn, d_d2, _, _ = srv.knn(pts, 5, pruned=False)
        np.testing.assert_array_equal(np.asarray(nn_ids), np.asarray(d_nn))
        np.testing.assert_array_equal(np.asarray(nn_d2), np.asarray(d_d2))
        rng = np.random.default_rng(3)
        lo = rng.uniform(0.0, 1.0, (32, 2)).astype(np.float32)
        ex = rng.uniform(0.0, 0.02, (32, 2)).astype(np.float32)
        srv.append(np.concatenate([lo, lo + ex], axis=1))
        srv.delete(np.arange(0, 64, 4))
        srv.compact()
        hit_ids, _, _, _ = srv.range_ids(qb, max_hits=4096)
        d_ids, _, _, _ = srv.range_ids(qb, max_hits=4096, pruned=False)
        np.testing.assert_array_equal(np.asarray(hit_ids),
                                      np.asarray(d_ids))
        assert len(srv.slayout.canon_shards.addressable_shards) == 8
