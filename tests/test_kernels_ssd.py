"""SSD kernel: intra-chunk vs oracle, end-to-end vs sequential scan,
gradient path, and shape sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import kernel, ops, ref


def _inputs(key, b, l, h, p, g, s):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.1
    a_log = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, g, s)) * 0.3
    cm = jax.random.normal(ks[4], (b, l, g, s)) * 0.3
    return x, dt, a_log, bm, cm


@pytest.mark.parametrize("q,p,s", [(128, 64, 128), (128, 32, 64),
                                   (64, 16, 32)])
def test_intra_chunk_kernel_vs_ref(q, p, s):
    key = jax.random.PRNGKey(q + p)
    inst = 6
    x = jax.random.normal(key, (inst, q, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (inst, q))) * 0.1
    cl = jnp.cumsum(-dt * 0.5, axis=1)
    b = jax.random.normal(jax.random.fold_in(key, 2), (inst, q, s)) * 0.3
    c = jax.random.normal(jax.random.fold_in(key, 3), (inst, q, s)) * 0.3
    got = kernel.intra_chunk_pallas(x, dt, cl, b, c, interpret=True)
    want = ref.intra_chunk_ref(x, dt, cl, b, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("l,chunk", [(256, 128), (384, 128), (128, 64)])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_end_to_end_vs_sequential(l, chunk, use_kernel):
    b, h, p, g, s = 2, 4, 32, 2, 64
    x, dt, a_log, bm, cm = _inputs(jax.random.PRNGKey(0), b, l, h, p, g, s)
    y = ops.ssd_forward(x, dt, a_log, bm, cm, chunk=chunk,
                        use_kernel=use_kernel)
    rep = h // g
    for bi in range(b):
        for hi in range(h):
            yo, _ = ref.ssd_scan_ref(x[bi, :, hi], dt[bi, :, hi], a_log[hi],
                                     bm[bi, :, hi // rep], cm[bi, :, hi // rep])
            np.testing.assert_allclose(np.asarray(y[bi, :, hi]),
                                       np.asarray(yo), rtol=2e-4, atol=2e-4)


def test_kernel_and_einsum_paths_agree():
    x, dt, a_log, bm, cm = _inputs(jax.random.PRNGKey(1), 2, 256, 4, 32, 2, 64)
    y1 = ops.ssd_forward(x, dt, a_log, bm, cm, use_kernel=True)
    y2 = ops.ssd_forward(x, dt, a_log, bm, cm, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)


def test_gradients_flow_through_kernel_path():
    """custom_vjp: kernel forward, oracle backward — grads must match the
    pure-einsum autodiff."""
    x, dt, a_log, bm, cm = _inputs(jax.random.PRNGKey(2), 1, 128, 2, 16, 1, 32)

    def loss(use_kernel):
        def f(args):
            return jnp.sum(ops.ssd_forward(*args, use_kernel=use_kernel) ** 2)
        return jax.grad(f)((x, dt, a_log, bm, cm))

    g1 = loss(True)
    g2 = loss(False)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
