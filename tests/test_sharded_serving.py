"""Owner-routed sharded serving vs the dense single-device oracle and
the numpy brute force: bit-identical answers across ALL SIX layouts on
skewed (osm) and uniform (pi) data — the acceptance bar for the
exchange path — plus the per-device memory bound, the owner-split
translation contract, and the kNN widen-and-retry ladder under
sharding.  ``mesh=None`` runs the exchange in vmap simulation; the
8-device SPMD test runs whenever the process sees ≥ 8 devices (the CI
virtual-device job) and in ``test_multidevice.py`` via subprocess."""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import placement
from repro.data import spatial_gen
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import (ServeConfig, SpatialServer,
                         engine as serve_engine, router)

LAYOUTS = ["hc", "str", "fg", "bsp", "slc", "bos"]
DATASETS = ["osm", "pi"]
N, NQ, K, SHARDS = 1200, 24, 4, 4


def _qboxes(key, q, scale=0.06):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


@pytest.fixture(scope="module", params=DATASETS)
def data(request):
    mbrs = spatial_gen.dataset(request.param, jax.random.PRNGKey(0), N)
    return mbrs, np.asarray(mbrs)


@pytest.fixture(scope="module")
def servers(data):
    mbrs, _ = data
    cfg = ServeConfig(placement="sharded", shards=SHARDS)
    return {m: SpatialServer.from_method(m, mbrs, 120, cfg)
            for m in LAYOUTS}


@pytest.mark.parametrize("method", LAYOUTS)
def test_sharded_range_bit_identical_to_oracle(data, servers, method):
    _, mbrs_np = data
    srv = servers[method]
    qb = _qboxes(jax.random.PRNGKey(1), NQ)
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))

    counts, stats = srv.range_counts(qb)
    assert stats["mode"] == "sharded" and stats["shards"] == SHARDS
    dcounts, dstats = srv.range_counts(qb, pruned=False)
    assert dstats["mode"] == "dense"
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(dcounts))
    assert [int(c) for c in counts] == [len(r) for r in ref]

    hit_ids, cnts, ovf, _ = srv.range_ids(qb, max_hits=2048)
    d_ids, d_cnts, d_ovf, _ = srv.range_ids(qb, max_hits=2048, pruned=False)
    assert not np.asarray(ovf).any() and not np.asarray(d_ovf).any()
    np.testing.assert_array_equal(np.asarray(hit_ids), np.asarray(d_ids))
    np.testing.assert_array_equal(np.asarray(cnts), np.asarray(d_cnts))
    for i, want in enumerate(ref):
        got = np.asarray(hit_ids[i])
        np.testing.assert_array_equal(got[got >= 0], want)


@pytest.mark.parametrize("method", LAYOUTS)
def test_sharded_knn_bit_identical_to_oracle(data, servers, method):
    _, mbrs_np = data
    srv = servers[method]
    pts = jax.random.uniform(jax.random.PRNGKey(2), (NQ, 2))
    want_ids, want_d2 = knn_mod.knn_ref(mbrs_np, np.asarray(pts), K)

    nn_ids, nn_d2, ovf, stats = srv.knn(pts, K)
    assert stats["mode"] == "sharded"
    assert not np.asarray(ovf).any()
    np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
    d_ids, d_d2, _, dstats = srv.knn(pts, K, pruned=False)
    assert dstats["mode"] == "dense"
    np.testing.assert_array_equal(np.asarray(nn_ids), np.asarray(d_ids))
    # bit-identical, not merely close: the merge reuses the oracle's
    # (distance, id) tie-break on identical f32 inputs
    np.testing.assert_array_equal(np.asarray(nn_d2), np.asarray(d_d2))


def test_per_device_memory_bound(data):
    """Capped-LPT placement: every device's staged shard is at most one
    tile over an even split of the replicated staging — the O(total/D)
    claim, asserted, for every layout."""
    mbrs, _ = data
    for m in LAYOUTS:
        srv = SpatialServer.from_method(
            m, mbrs, 120, ServeConfig(placement="sharded", shards=5))
        t, cap = srv.stats["t"], srv.stats["cap"]
        t_local = srv.stats["t_local"]
        assert t_local == -(-t // 5)                    # ceil(T/D)
        tile_bytes = cap * 4 * 4 + cap * 4              # canon row + ids row
        total = t * tile_bytes
        assert srv.resident_tile_bytes() <= total / 5 + tile_bytes
        # the shards really partition the staging: scatter-back inverts
        canon_np, ids_np = srv._oracle_np
        s = srv.slayout
        np.testing.assert_array_equal(
            np.asarray(s.canon_shards)[s.owner, s.local], canon_np)
        np.testing.assert_array_equal(
            np.asarray(s.id_shards)[s.owner, s.local], ids_np)


def test_owner_split_translation_contract(data):
    """The per-owner tables are a lossless re-expression of the global
    candidate lists: every (query, owner) pair gets exactly one message
    whose local tiles map back to exactly the query's candidates owned
    there."""
    mbrs, _ = data
    srv = SpatialServer.from_method(
        "bsp", mbrs, 120, ServeConfig(placement="sharded", shards=SHARDS))
    qb = _qboxes(jax.random.PRNGKey(3), 17, scale=0.1)
    cand, costs, _ = srv._route_batch(qb)
    cand = np.asarray(cand)
    slots, _ = serve_engine.pack_queries(costs, SHARDS)
    ss, sc, stats = router.owner_split(cand, slots, srv.slayout.owner,
                                       srv.slayout.local)
    d = SHARDS
    # global tile for (owner, local) pairs
    inv = {}
    for t, (o, lt) in enumerate(zip(srv.slayout.owner, srv.slayout.local)):
        inv[(int(o), int(lt))] = t
    seen = {}
    for h in range(d):
        for o in range(d):
            for mi in range(ss.shape[2]):
                s = ss[h, o, mi]
                if s < 0:
                    assert np.all(sc[h, o, mi] == -1)
                    continue
                q = slots[h, s]
                assert q >= 0
                assert (q, o) not in seen        # one message per pair
                lts = sc[h, o, mi]
                tiles = {inv[(o, int(lt))] for lt in lts[lts >= 0]}
                seen[(q, o)] = tiles
    for q in range(17):
        want = set(cand[q][cand[q] >= 0].tolist())
        got = set().union(*(tiles for (qq, _), tiles in seen.items()
                            if qq == q)) if want else set()
        assert got == want, q
    assert stats["messages"] == len(seen)


def test_sharded_knn_widen_retry_is_logged_once(data, caplog):
    """A deliberately narrow seeded frontier must be caught by the miss
    check, widened exactly once (the doubled width hits the live-tile
    cap), logged once, and still answer exactly."""
    mbrs, mbrs_np = data
    srv = SpatialServer.from_method(
        "bsp", mbrs, 80, ServeConfig(placement="sharded", shards=3))
    t_live = srv.stats["t_live"]
    if t_live < 10:
        pytest.skip("fixture layout too small to under-size a frontier")
    k = N                                   # forces covering radii
    # raw (unbucketed) seed: one doubling reaches the t_live cap, so
    # exactly one widen retry is guaranteed
    seed = t_live // 2 + 1
    assert seed < t_live                    # genuinely narrow
    srv.widths.seed(("knn", k, 2048), seed)
    pts = jax.random.uniform(jax.random.PRNGKey(4), (4, 2))
    with caplog.at_level(logging.INFO, logger="repro.serve.engine"):
        nn_ids, nn_d2, ovf, stats = srv.knn(pts, k, max_cand=2048)
    assert stats["retries"] == 1
    widen_logs = [r for r in caplog.records if "widening" in r.message]
    assert len(widen_logs) == 1
    assert not np.asarray(ovf).any()
    want_ids, _ = knn_mod.knn_ref(mbrs_np, np.asarray(pts), k)
    np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
    # the converged width is cached: the next batch starts wide, no retry
    _, _, _, stats2 = srv.knn(pts, k, max_cand=2048)
    assert stats2["retries"] == 0 and stats2["f_max"] == stats["f_max"]


def test_shard_tiles_memory_cap_with_degenerate_costs():
    """All-zero and heavy-tailed cost vectors both respect the
    ceil(T/D) per-device cap (uncapped LPT would pile zero-cost tiles
    onto one device)."""
    for costs in [np.zeros(11), np.r_[1e9, np.zeros(10)],
                  np.random.default_rng(0).pareto(1.0, 11)]:
        owner, local, t_local, _ = placement.shard_tiles(costs, 4)
        assert t_local == 3
        counts = np.bincount(owner, minlength=4)
        assert counts.max() <= 3 and counts.sum() == 11
        for dev in range(4):
            mine = local[owner == dev]
            assert sorted(mine.tolist()) == list(range(len(mine)))


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI virtual-device job)")
def test_sharded_spmd_mesh_bit_identical():
    """The all_to_all exchange on a real 8-device mesh returns the same
    answers as the dense oracle and the brute force."""
    from jax.sharding import Mesh
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), 2000)
    mesh = Mesh(np.array(jax.devices()[:8]), ("d",))
    qb = _qboxes(jax.random.PRNGKey(1), 32, scale=0.05)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (32, 2))
    ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qb))
    want_ids, _ = knn_mod.knn_ref(np.asarray(mbrs), np.asarray(pts), 5)
    for m in ["bsp", "hc"]:
        srv = SpatialServer.from_method(
            m, mbrs, 150, ServeConfig(placement="sharded"), mesh=mesh)
        counts, _ = srv.range_counts(qb)
        assert [int(c) for c in counts] == [len(r) for r in ref]
        hit_ids, _, ovf, _ = srv.range_ids(qb, max_hits=2048)
        d_ids, _, _, _ = srv.range_ids(qb, max_hits=2048, pruned=False)
        assert not np.asarray(ovf).any()
        np.testing.assert_array_equal(np.asarray(hit_ids),
                                      np.asarray(d_ids))
        nn_ids, nn_d2, ovk, _ = srv.knn(pts, 5)
        d_nn, d_d2, _, _ = srv.knn(pts, 5, pruned=False)
        assert not np.asarray(ovk).any()
        np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
        np.testing.assert_array_equal(np.asarray(nn_d2), np.asarray(d_d2))
        # tiles really live one shard per device
        assert len(srv.slayout.canon_shards.addressable_shards) == 8
