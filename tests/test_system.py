"""End-to-end system test: the paper's full pipeline on one process.

generate (skewed data) → partition (all six) → MASJ stage → cost-model
LPT packing → tile joins → dedup → metrics, cross-checked against the
brute-force oracle; then the sampling and balanced-batching variants.
"""
import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import metrics
from repro.core.partition import partition_counts
from repro.data import spatial_gen
from repro.kernels.mbr_join import ref as mref
from repro.query import engine


def test_paper_pipeline_end_to_end():
    key = jax.random.PRNGKey(42)
    r = spatial_gen.dataset("osm", key, 1500)
    s = spatial_gen.dataset("osm", jax.random.PRNGKey(43), 1000)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    oracle = int(mref.intersect_count(r, s))

    results = {}
    for method in ["fg", "bsp", "slc", "bos", "str", "hc"]:
        plan = engine.plan_join(method, r, s, 250, 1)
        cnt = engine.spatial_join_count(plan, mesh, "d",
                                        max_pairs_per_tile=8192)
        results[method] = (cnt, plan.stats)
        assert cnt == oracle, f"{method}: {cnt} != oracle {oracle}"

    # the paper's qualitative findings hold on our generators:
    # (a) FG is the most skewed on hotspot data
    skews = {m: st["skew"] for m, (_, st) in results.items()}
    assert skews["fg"] >= max(skews["bsp"], skews["bos"]) - 1e-9
    # (b) data-oriented strips have low boundary ratio at this payload
    lams = {m: st["lambda_r"] for m, (_, st) in results.items()}
    assert lams["bos"] <= lams["hc"]


def test_quality_metrics_reproduce_fig3_ordering():
    """Fig 3: FG stddev ≫ adaptive methods on skewed data."""
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(7), 4000)
    stds = {}
    for method in ["fg", "bsp", "slc", "bos"]:
        from repro.core.partition import api
        parts = api.partition(method, mbrs, 200)
        counts, _ = partition_counts(mbrs, parts)
        stds[method] = float(metrics.balance_stddev(counts, parts.valid))
    assert stds["fg"] > 2.0 * stds["bos"]
    assert stds["fg"] > 2.0 * stds["bsp"]
