"""Structural invariants of the six partitioners (paper Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometry, metrics
from repro.core.partition import api, partition_counts
from repro.data import spatial_gen

METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]
NON_OVERLAPPING = ["fg", "bsp", "slc", "bos"]


def _data(name="osm", n=1500, seed=0):
    return spatial_gen.dataset(name, jax.random.PRNGKey(seed), n)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("dataset", ["osm", "pi"])
def test_full_coverage_of_objects(method, dataset):
    """MASJ: every object lands in ≥1 partition (paper §2.2)."""
    mbrs = _data(dataset)
    parts = api.partition(method, mbrs, 100)
    _, copies = partition_counts(mbrs, parts)
    assert float(metrics.coverage(copies)) == 1.0


@pytest.mark.parametrize("method", NON_OVERLAPPING)
def test_non_overlapping_boxes(method):
    """Table 1: FG/BSP/SLC/BOS regions have disjoint interiors."""
    mbrs = _data(n=800)
    parts = api.partition(method, mbrs, 100)
    boxes = np.asarray(parts.boxes)[np.asarray(parts.valid)]
    eps = 1e-5
    shrunk = boxes + np.array([eps, eps, -eps, -eps])
    inter = np.array(geometry.intersect_matrix(
        jnp.asarray(shrunk), jnp.asarray(shrunk)))
    np.fill_diagonal(inter, False)
    assert not inter.any(), f"{method} produced overlapping regions"


@pytest.mark.parametrize("method", NON_OVERLAPPING)
def test_universe_coverage(method):
    """Space-covering methods tile the whole universe: any random point
    hits exactly one region (interior)."""
    mbrs = _data(n=700, seed=3)
    parts = api.partition(method, mbrs, 80)
    uni = np.asarray(geometry.universe(mbrs))
    rng = np.random.default_rng(0)
    pts = rng.uniform(uni[:2] + 1e-6, uni[2:] - 1e-6, size=(512, 2))
    hits = np.asarray(geometry.contains_point(
        parts.boxes, jnp.asarray(pts, jnp.float32)))
    hits = hits & np.asarray(parts.valid)[None, :]
    assert (hits.sum(1) >= 1).all(), f"{method} leaves gaps"


@pytest.mark.parametrize("method", ["slc", "bos", "hc", "str"])
def test_packing_k_near_optimal(method):
    """Bottom-up packers produce k ≈ ceil(N/b) partitions (size bound)."""
    mbrs = _data(n=1000, seed=1)
    parts = api.partition(method, mbrs, 100)
    k = int(parts.k())
    assert k >= 10
    assert k <= 16, f"{method}: k={k} far above ceil(N/b)=10"


def test_fg_grid_count():
    mbrs = _data(n=1000)
    parts = api.partition("fg", mbrs, 100)
    m = int(np.ceil(np.sqrt(1000 / 100)))
    assert parts.kmax == m * m


def test_bsp_payload_bound():
    """BSP splits until every leaf holds ≤ b construction members."""
    mbrs = _data(n=1024, seed=2)
    b = 64
    parts = api.partition("bsp", mbrs, b)
    # count by centroid containment (construction membership, no MASJ)
    c = geometry.centroids(mbrs)
    hits = np.asarray(geometry.contains_point(parts.boxes, c))
    hits = hits & np.asarray(parts.valid)[None, :]
    # centroid on a shared edge may double-count; use first hit
    first = hits.argmax(1)
    counts = np.bincount(first[hits.any(1)], minlength=parts.kmax)
    assert counts.max() <= b + 1


def test_bos_fewer_boundary_objects_than_slc():
    """BOS exists to beat SLC on boundary objects (paper §4.2)."""
    mbrs = _data("osm", n=2000, seed=5)
    lam = {}
    for m in ["slc", "bos"]:
        parts = api.partition(m, mbrs, 150)
        counts, _ = partition_counts(mbrs, parts)
        lam[m] = float(metrics.boundary_ratio(counts, parts.valid, 2000))
    assert lam["bos"] <= lam["slc"] + 1e-6


def test_classification_registry_matches_table1():
    info = api.methods()
    assert not info["fg"].overlapping and info["fg"].criterion == "space"
    assert not info["bsp"].overlapping and info["bsp"].search == "top-down"
    assert info["hc"].overlapping and info["hc"].search == "bottom-up"
    assert info["str"].overlapping and info["str"].criterion == "data"
    assert not info["slc"].overlapping and info["slc"].criterion == "data"
    assert not info["bos"].overlapping
