"""Multi-device behaviour via subprocess (8 host devices): the SPMD join
engine, MapReduce-style parallel partitioning, compressed psum, and a
small-mesh lower+compile — without polluting this process's device count.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=ROOT,
                         capture_output=True, text=True, timeout=520)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_spmd_join_all_methods_match_oracle():
    out = _run("""
import jax, numpy as np, json
from jax.sharding import Mesh
from repro.data import spatial_gen
from repro.kernels.mbr_join import ref as mref
from repro.query import engine
r = spatial_gen.dataset('osm', jax.random.PRNGKey(0), 2000)
s = spatial_gen.dataset('pi', jax.random.PRNGKey(1), 1500)
mesh = Mesh(np.array(jax.devices()).reshape(8), ('d',))
oracle = int(mref.intersect_count(r, s))
res = {}
for m in ['fg','bsp','slc','bos','str','hc']:
    plan = engine.plan_join(m, r, s, 300, 8)
    res[m] = engine.spatial_join_count(plan, mesh, 'd', max_pairs_per_tile=8192)
print(json.dumps({'oracle': oracle, **res}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    oracle = res.pop("oracle")
    assert all(v == oracle for v in res.values()), res


@pytest.mark.slow
def test_parallel_partition_covers_everything():
    out = _run("""
import jax, numpy as np, json
from jax.sharding import Mesh
from repro.data import spatial_gen
from repro.query import parallel_partition as pp
from repro.core.partition import partition_counts
from repro.core import metrics
r = spatial_gen.dataset('osm', jax.random.PRNGKey(3), 4000)
mesh = Mesh(np.array(jax.devices()).reshape(8), ('d',))
parts, stats = pp.parallel_partition(jax.random.PRNGKey(1), r, 200, mesh, 'd')
counts, copies = partition_counts(r, parts)
print(json.dumps({'dropped': stats['dropped'],
                  'coverage': float(metrics.coverage(copies)),
                  'k': int(parts.k())}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["dropped"] == 0
    assert res["coverage"] == 1.0
    assert res["k"] >= 8


@pytest.mark.slow
def test_spmd_serving_matches_bruteforce():
    """Range counts + kNN from the 8-device serving step equal the
    brute-force oracle, and the fan-out stats survive the packing."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.data import spatial_gen
from repro.query import knn as kq, range as rq
from repro.serve import SpatialServer
mbrs = spatial_gen.dataset('osm', jax.random.PRNGKey(0), 3000)
mesh = Mesh(np.array(jax.devices()).reshape(8), ('d',))
k1, k2 = jax.random.split(jax.random.PRNGKey(1))
c = jax.random.uniform(k1, (64, 2)); s = jax.random.uniform(k2, (64, 2)) * 0.05
qb = jnp.concatenate([c - s, c + s], axis=-1)
pts = jax.random.uniform(jax.random.PRNGKey(2), (64, 2))
ref = rq.range_query_ref(np.asarray(mbrs), np.asarray(qb))
want_ids, _ = kq.knn_ref(np.asarray(mbrs), np.asarray(pts), 5)
res = {}
for m in ['bsp', 'hc']:
    srv = SpatialServer.from_method(m, mbrs, 200, mesh=mesh)
    counts, stats = srv.range_counts(qb)
    nn_ids, _, _, _ = srv.knn(pts, 5)
    res[m] = dict(
        range_ok=bool(all(int(counts[i]) == len(ref[i]) for i in range(64))),
        knn_ok=bool(np.array_equal(np.asarray(nn_ids), want_ids)),
        fanout=stats['fanout_mean'], skew=stats['skew'])
print(json.dumps(res))
""")
    res = json.loads(out.strip().splitlines()[-1])
    for m, r in res.items():
        assert r["range_ok"] and r["knn_ok"], (m, r)
        assert r["fanout"] >= 1.0


@pytest.mark.slow
def test_spmd_sharded_serving_matches_oracle():
    """Owner-routed tile sharding on 8 devices: the all_to_all exchange
    answers range + kNN bit-identically to the dense oracle and the
    brute force, tiles live one shard per device, and per-device staged
    memory respects the ceil(T/D) bound."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh
from repro.data import spatial_gen
from repro.query import knn as kq, range as rq
from repro.serve import ServeConfig, SpatialServer
mbrs = spatial_gen.dataset('osm', jax.random.PRNGKey(0), 3000)
mesh = Mesh(np.array(jax.devices()).reshape(8), ('d',))
k1, k2 = jax.random.split(jax.random.PRNGKey(1))
c = jax.random.uniform(k1, (64, 2)); s = jax.random.uniform(k2, (64, 2)) * 0.05
qb = jnp.concatenate([c - s, c + s], axis=-1)
pts = jax.random.uniform(jax.random.PRNGKey(2), (64, 2))
ref = rq.range_query_ref(np.asarray(mbrs), np.asarray(qb))
want_ids, _ = kq.knn_ref(np.asarray(mbrs), np.asarray(pts), 5)
res = {}
for m in ['bsp', 'hc']:
    srv = SpatialServer.from_method(m, mbrs, 200,
                                    ServeConfig(placement='sharded'), mesh=mesh)
    counts, stats = srv.range_counts(qb)
    hit_ids, _, ovf, _ = srv.range_ids(qb, max_hits=2048)
    d_ids, _, _, _ = srv.range_ids(qb, max_hits=2048, pruned=False)
    nn_ids, nn_d2, ovk, _ = srv.knn(pts, 5)
    d_nn, d_d2, _, _ = srv.knn(pts, 5, pruned=False)
    t, cap, tl = srv.stats['t'], srv.stats['cap'], srv.stats['t_local']
    tile_bytes = cap * 20
    res[m] = dict(
        range_ok=bool(all(int(counts[i]) == len(ref[i]) for i in range(64))),
        ids_ok=bool(np.array_equal(np.asarray(hit_ids), np.asarray(d_ids))),
        knn_ok=bool(np.array_equal(np.asarray(nn_ids), want_ids)),
        knn_bitident=bool(np.array_equal(np.asarray(nn_d2), np.asarray(d_d2))),
        no_overflow=bool(not np.asarray(ovf).any() and not np.asarray(ovk).any()),
        shards=len(srv.slayout.canon_shards.addressable_shards),
        mem_ok=bool(srv.resident_tile_bytes() <= t * tile_bytes / 8 + tile_bytes),
        t_local_ok=bool(tl == -(-t // 8)),
        mode=stats['mode'])
print(json.dumps(res))
""")
    res = json.loads(out.strip().splitlines()[-1])
    for m, r in res.items():
        assert r["range_ok"] and r["ids_ok"], (m, r)
        assert r["knn_ok"] and r["knn_bitident"], (m, r)
        assert r["no_overflow"] and r["mode"] == "sharded", (m, r)
        assert r["shards"] == 8 and r["mem_ok"] and r["t_local_ok"], (m, r)


@pytest.mark.slow
def test_compressed_psum_error_feedback_converges():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.compress import compressed_psum
from repro.core.compat import shard_map
mesh = Mesh(np.array(jax.devices()).reshape(8), ('pod',))
g = {'w': jnp.linspace(-1, 1, 64)}
def step(t, e):
    return compressed_psum(t, 'pod', e)
fn = jax.jit(shard_map(step, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False))
err = jax.tree.map(jnp.zeros_like, g)
accum_true = jnp.zeros(64); accum_q = jnp.zeros(64)
for i in range(20):
    red, err = fn(g, err)
    accum_true += g['w']; accum_q += red['w']
rel = float(jnp.max(jnp.abs(accum_q - accum_true)) / jnp.max(jnp.abs(accum_true)))
print(json.dumps({'rel_err': rel}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    # error feedback keeps long-run drift tiny despite int8 quantisation
    assert res["rel_err"] < 0.01, res


@pytest.mark.slow
def test_small_mesh_lower_compile_smoke_arch():
    """A reduced config lowers+compiles on a (2, 4) host mesh with the
    production sharding rules — the dry-run path end-to-end, in small."""
    out = _run("""
import jax, numpy as np, json, dataclasses
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro import configs
from repro.models import api, lm
from repro.dist import sharding as rules
from repro.optim import adamw
cfg = dataclasses.replace(configs.smoke('mixtral_8x22b'), vocab=512)
model = api.build(cfg)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ('data', 'model'))
lm.set_activation_spec(P('data', None, None))
opt = adamw.AdamWConfig()
state = api.init_train_state(model, jax.random.PRNGKey(0), opt)
pspecs = rules.param_specs(state.params, shard_experts=cfg.shard_experts, mesh=mesh)
ps = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                  is_leaf=lambda x: isinstance(x, P))
ss = api.TrainState(params=ps, opt=adamw.OptState(m=ps, v=ps,
                    step=NamedSharding(mesh, P())), step=NamedSharding(mesh, P()))
bs = {'tokens': NamedSharding(mesh, P('data', None))}
step = jax.jit(api.make_train_step(model, opt), in_shardings=(ss, bs),
               out_shardings=(ss, None), donate_argnums=(0,))
batch = {'tokens': jnp.zeros((8, 64), jnp.int32)}
with mesh:
    c = step.lower(state, batch).compile()
    state2, metrics = step(state, batch)
print(json.dumps({'loss': float(metrics['loss'])}))
""")
    res = json.loads(out.strip().splitlines()[-1])
    assert res["loss"] > 0 and res["loss"] < 20
