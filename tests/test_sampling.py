"""Sampling-based partitioning (paper §5.2 / Fig 9)."""
import jax
import numpy as np
import pytest

from repro.core import metrics, sampling
from repro.core.partition import api, partition_counts
from repro.data import spatial_gen


@pytest.fixture(scope="module")
def osm():
    return spatial_gen.dataset("osm", jax.random.PRNGKey(0), 4000)


@pytest.mark.parametrize("method", ["fg", "bsp", "slc", "bos"])
def test_sampled_layout_covers_full_dataset(osm, method):
    res = sampling.sampled_partition(method, osm, 200, 0.2,
                                     jax.random.PRNGKey(1))
    counts, copies = sampling.evaluate_on_full(res, osm)
    assert float(metrics.coverage(copies)) == 1.0


@pytest.mark.parametrize("method", ["hc", "str"])
def test_tight_mbr_methods_leave_gaps_on_samples(osm, method):
    """The paper's §5.2 caveat: HC/STR sampled layouts don't cover."""
    res = sampling.sampled_partition(method, osm, 200, 0.1,
                                     jax.random.PRNGKey(2))
    counts, copies = sampling.evaluate_on_full(res, osm)
    uncovered = float(np.mean(np.asarray(copies) == 0))
    assert uncovered > 0.0   # gaps exist...
    fb = sampling.nearest_box_fallback(osm, res.parts)
    assert fb.shape == (4000,)  # ...and the fallback assigns everyone
    assert int(fb.max()) < res.parts.kmax


def test_higher_sampling_rate_improves_balance(osm):
    """Fig 9: balance quality improves with γ (on the skewed dataset)."""
    stds = []
    for gamma in [0.05, 0.5]:
        res = sampling.sampled_partition("bsp", osm, 200, gamma,
                                         jax.random.PRNGKey(3))
        counts, _ = sampling.evaluate_on_full(res, osm)
        stds.append(float(metrics.balance_stddev(counts, res.parts.valid)))
    assert stds[1] <= stds[0] * 1.5   # allow noise, demand no blow-up


def test_sample_payload_scaling():
    mbrs = spatial_gen.dataset("pi", jax.random.PRNGKey(1), 1000)
    res = sampling.sampled_partition("slc", mbrs, 100, 0.3,
                                     jax.random.PRNGKey(0))
    assert res.sample_size == 300
    assert res.sample_payload == 30
    # layout granularity ~ full-data granularity
    assert abs(int(res.parts.k()) - 10) <= 2


@pytest.mark.parametrize("method", ["hc", "str"])
def test_nearest_box_fallback_assigns_all_gap_objects(osm, method):
    """Gap objects from sampled tight-MBR layouts must land in a valid
    partition, agreeing with a numpy brute-force nearest-center scan."""
    res = sampling.sampled_partition(method, osm, 200, 0.1,
                                     jax.random.PRNGKey(4))
    counts, copies = sampling.evaluate_on_full(res, osm)
    gaps = np.asarray(copies) == 0
    assert gaps.any()                      # §5.2: samples leave gaps

    fb = np.asarray(sampling.nearest_box_fallback(osm, res.parts))
    valid = np.asarray(res.parts.valid)
    assert fb.min() >= 0 and fb.max() < res.parts.kmax
    assert valid[fb].all()                 # never a padding partition

    mbrs = np.asarray(osm)
    c = (mbrs[:, :2] + mbrs[:, 2:]) * 0.5
    boxes = np.asarray(res.parts.boxes)
    bc = (boxes[:, :2] + boxes[:, 2:]) * 0.5
    d2 = np.sum((c[:, None, :] - bc[None, :, :]) ** 2, axis=-1)
    d2[:, ~valid] = np.inf
    np.testing.assert_array_equal(fb, np.argmin(d2, axis=1))
