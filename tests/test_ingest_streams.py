"""Differential stream testing of the O(M) ingest engine.

A seeded command generator interleaves ``append`` / ``delete`` /
``update`` / ``compact`` / query ops against two implementations at
once: the served ``SpatialServer`` (scatter appends, tombstone alive
bits, the compaction policy) and a numpy brute-force oracle of the
live object set.  After any generated sequence the server's range and
kNN answers must be **bit-identical** to the oracle — and to a
from-scratch staging of the live set — on all six layouts, both
datasets, replicated and sharded, through forced compactions and
tile-overflow re-stages.

Two generators drive the same interpreter:

- a fixed deterministic corpus (always runs, so CI can never skip the
  differential bar), and
- a hypothesis-driven generator (property-based interleavings; local
  runs without hypothesis skip it, CI installs hypothesis and sets
  ``REPRO_REQUIRE_HYPOTHESIS=1`` so the skip is impossible there).

The error contract rides along: deleting unknown ids, repeating an id
in one batch, or deleting an already-deleted id raises ``ValueError``
naming the offending ids — never a silent wrong answer.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import ServeConfig, SpatialServer

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
        raise   # CI: property tests must run, a skip is a failure
    HAVE_HYPOTHESIS = False

LAYOUTS = ["hc", "str", "fg", "bsp", "slc", "bos"]
N_BASE, PAYLOAD, K = 400, 64, 3
MAX_HITS = 4096


# -- the numpy oracle -------------------------------------------------------

class LiveSet:
    """Brute-force model: the set of live (id, box) pairs."""

    def __init__(self, mbrs):
        mbrs = np.asarray(mbrs, np.float32)
        self.boxes = {i: mbrs[i] for i in range(len(mbrs))}
        self.n_total = len(mbrs)

    def append(self, mbrs):
        for b in np.asarray(mbrs, np.float32):
            self.boxes[self.n_total] = b
            self.n_total += 1

    def delete(self, ids):
        for i in ids:
            del self.boxes[int(i)]

    def update(self, ids, mbrs):
        for i, b in zip(ids, np.asarray(mbrs, np.float32)):
            self.boxes[int(i)] = b

    def live(self):
        """-> (ids ascending (m,) int64, boxes (m, 4) f32)."""
        ids = np.array(sorted(self.boxes), np.int64)
        return ids, np.stack([self.boxes[int(i)] for i in ids])


# -- the command interpreter ------------------------------------------------

def _boxes(rng, m, scale=0.01):
    lo = rng.uniform(0.0, 1.0, (m, 2)).astype(np.float32)
    ex = rng.uniform(0.0, scale, (m, 2)).astype(np.float32)
    return np.concatenate([lo, lo + ex], axis=1)


def _qboxes(rng, q, scale=0.08):
    c = rng.uniform(0.0, 1.0, (q, 2)).astype(np.float32)
    s = rng.uniform(0.0, scale, (q, 2)).astype(np.float32)
    return np.concatenate([c - s, c + s], axis=1)


def _pick_live(model, rng, count):
    ids, _ = model.live()
    count = min(count, max(ids.size - 60, 0))   # keep the live set big
    return rng.choice(ids, size=count, replace=False) if count else \
        np.zeros(0, np.int64)


def _apply(srv, model, op, rng):
    """Run one command on both implementations."""
    kind = op[0]
    if kind == "append":
        nb = _boxes(rng, op[1])
        srv.append(jnp.asarray(nb))
        model.append(nb)
    elif kind == "delete":
        ids = _pick_live(model, rng, max(1, int(op[1] * len(model.boxes))))
        if ids.size:
            srv.delete(ids)
            model.delete(ids)
    elif kind == "update":
        ids = _pick_live(model, rng, op[1])
        if ids.size:
            nb = _boxes(rng, ids.size)
            srv.update(ids, jnp.asarray(nb))
            model.update(ids, nb)
    elif kind == "compact":
        rep = srv.compact()
        assert rep["dead_frac"] == 0.0
    elif kind == "burst":
        # cap+1 coincident objects into one tile: guaranteed overflow,
        # exercising the id-preserving re-stage of the live set
        cap = srv.stats["cap"]
        tb = np.asarray(srv.parts.boxes)[0]
        ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
        nb = np.tile(np.asarray(ctr + ctr, np.float32), (cap + 1, 1))
        assert srv.append(jnp.asarray(nb))["restaged"]
        model.append(nb)
    elif kind == "check":
        _check(srv, model, rng)
    else:                                              # pragma: no cover
        raise ValueError(op)


def _check(srv, model, rng, nq=10, npts=6):
    """The differential bar: server answers == brute force on the live
    set, ids remapped through the live id list (ascending, so the
    remap preserves sort order and kNN tie order)."""
    ids_live, lb = model.live()
    assert srv.stats["n"] == ids_live.size
    qb = _qboxes(rng, nq)
    ref = range_mod.range_query_ref(lb, qb)
    counts, _ = srv.range_counts(jnp.asarray(qb))
    assert [int(c) for c in counts] == [len(r) for r in ref]
    hid, cnt, ovf, _ = srv.range_ids(jnp.asarray(qb), max_hits=MAX_HITS)
    assert not np.asarray(ovf).any()
    want = np.full((nq, MAX_HITS), -1, np.int32)
    for i, r in enumerate(ref):
        v = np.sort(ids_live[r]).astype(np.int32)
        want[i, :v.size] = v
    np.testing.assert_array_equal(np.asarray(hid), want)
    pts = rng.uniform(0.0, 1.0, (npts, 2)).astype(np.float32)
    nn, d2, ovk, _ = srv.knn(jnp.asarray(pts), K, max_cand=MAX_HITS)
    assert not np.asarray(ovk).any()
    want_nn, want_d2 = knn_mod.knn_ref(lb, pts, K)
    want_nn = np.where(want_nn >= 0,
                       ids_live[np.clip(want_nn, 0, None)], -1)
    np.testing.assert_array_equal(np.asarray(nn), want_nn)
    # the numpy ref sums squares in a different order: allclose here,
    # bitwise identity is asserted server-vs-fresh-staging below
    np.testing.assert_allclose(np.asarray(d2), want_d2, rtol=1e-6,
                               atol=1e-9)


def _check_vs_fresh_staging(srv, model, cfg, rng, nq=10, npts=6):
    """Answers must also be bit-identical to staging the live set from
    scratch (same partitioning, same config, fresh ids remapped)."""
    ids_live, lb = model.live()
    fresh = SpatialServer(srv.parts, jnp.asarray(lb), cfg)
    qb = _qboxes(rng, nq)
    got, _ = srv.range_counts(jnp.asarray(qb))
    fc, _ = fresh.range_counts(jnp.asarray(qb))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(fc))
    dense, _ = srv.range_counts(jnp.asarray(qb), pruned=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))
    pts = rng.uniform(0.0, 1.0, (npts, 2)).astype(np.float32)
    nn, d2, _, _ = srv.knn(jnp.asarray(pts), K, max_cand=MAX_HITS)
    fnn, fd2, _, _ = fresh.knn(jnp.asarray(pts), K, max_cand=MAX_HITS)
    fnn = np.where(np.asarray(fnn) >= 0,
                   ids_live[np.clip(np.asarray(fnn), 0, None)], -1)
    np.testing.assert_array_equal(np.asarray(nn), fnn)
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(fd2))


def _run_stream(method, dataset, placement, commands, seed, *,
                mesh=None, compact_dead_frac=0.5, restage_dead_frac=None):
    rng = np.random.default_rng(seed)
    full = spatial_gen.dataset(dataset, jax.random.PRNGKey(seed), N_BASE)
    parts = api.partition(method, full, PAYLOAD)
    cfg = ServeConfig(placement=placement,
                      shards=None if mesh is not None or
                      placement == "replicated" else 4,
                      slack=256, compact_dead_frac=compact_dead_frac,
                      restage_dead_frac=restage_dead_frac)
    srv = SpatialServer(parts, full, cfg, mesh=mesh)
    model = LiveSet(full)
    for op in commands:
        _apply(srv, model, op, rng)
    _check(srv, model, rng)
    _check_vs_fresh_staging(srv, model, cfg, rng)
    return srv


# -- the fixed deterministic corpus (always runs) ---------------------------

# Every lifecycle transition in one stream: slack appends, scattered
# deletes, in-place updates, a forced compaction, a tile-overflow
# re-stage, then more churn on the re-staged layout.
FIXED_STREAM = [
    ("append", 80), ("delete", 0.10), ("check",),
    ("update", 25), ("append", 60), ("delete", 0.25),
    ("compact",), ("check",),
    ("burst",), ("delete", 0.15), ("update", 10),
]


@pytest.mark.parametrize("placement", ["replicated", "sharded"])
@pytest.mark.parametrize("dataset", ["osm", "pi"])
@pytest.mark.parametrize("method", LAYOUTS)
def test_fixed_stream_differential(method, dataset, placement):
    srv = _run_stream(method, dataset, placement, FIXED_STREAM, seed=7)
    assert srv.stats["restages"] == 1          # the burst re-staged
    assert srv.stats["compactions"] >= 1       # the forced compact ran


def test_auto_compaction_stream():
    """The config thresholds fire on their own under heavy churn and
    answers stay exact (no explicit ``compact`` command needed)."""
    stream = [("append", 60), ("delete", 0.4), ("check",),
              ("delete", 0.3), ("update", 20), ("check",)]
    srv = _run_stream("bsp", "osm", "replicated", stream, seed=11,
                      compact_dead_frac=0.25)
    assert srv.stats["compactions"] >= 1


def test_restage_threshold_stream():
    """``restage_dead_frac`` escalates churn to a full re-stage that
    also reclaims non-canonical copies."""
    stream = [("delete", 0.35), ("check",), ("delete", 0.3), ("check",)]
    srv = _run_stream("str", "osm", "sharded", stream, seed=13,
                      compact_dead_frac=None, restage_dead_frac=0.3)
    assert srv.stats["restages"] >= 1


# -- hypothesis-driven interleavings ----------------------------------------

if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("append"), st.integers(1, 60)),
        st.tuples(st.just("delete"), st.floats(0.05, 0.35)),
        st.tuples(st.just("update"), st.integers(1, 30)),
        st.tuples(st.just("compact")),
        st.tuples(st.just("check")),
    )

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.data_too_large])
    @given(commands=st.lists(_op, min_size=3, max_size=8),
           seed=st.integers(0, 2 ** 16),
           method=st.sampled_from(LAYOUTS),
           placement=st.sampled_from(["replicated", "sharded"]))
    def test_generated_stream_differential(commands, seed, method,
                                           placement):
        _run_stream(method, "osm", placement, commands, seed,
                    compact_dead_frac=0.4)
else:                                                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed (CI installs it "
                             "and sets REPRO_REQUIRE_HYPOTHESIS=1)")
    def test_generated_stream_differential():
        pass


# -- the error contract -----------------------------------------------------

@pytest.fixture(scope="module")
def small_server():
    full = spatial_gen.dataset("osm", jax.random.PRNGKey(3), 200)
    parts = api.partition("bsp", full, PAYLOAD)
    return SpatialServer(parts, full, ServeConfig(slack=64))


def test_delete_unknown_id_raises(small_server):
    with pytest.raises(ValueError, match=r"delete of unknown id\(s\): "
                                         r"999, 1234"):
        small_server.delete(np.array([999, 1234]))
    assert small_server.stats["n"] == 200      # nothing half-applied


def test_delete_repeated_id_in_batch_raises(small_server):
    with pytest.raises(ValueError, match=r"delete batch repeats "
                                         r"id\(s\): 5"):
        small_server.delete(np.array([5, 7, 5]))
    assert small_server.stats["n"] == 200


def test_double_delete_raises(small_server):
    small_server.delete(np.array([42]))
    with pytest.raises(ValueError, match=r"delete of already-deleted "
                                         r"id\(s\): 42"):
        small_server.delete(np.array([42]))
    assert small_server.stats["n"] == 199


def test_update_unknown_and_mismatch_raise(small_server):
    with pytest.raises(ValueError, match=r"update of unknown id\(s\)"):
        small_server.update(np.array([10 ** 6]),
                            np.zeros((1, 4), np.float32))
    with pytest.raises(ValueError, match="length mismatch"):
        small_server.update(np.array([1, 2]), np.zeros((3, 4), np.float32))


# -- slot reuse: appends drain tombstoned slots before slack ----------------

def test_deleted_slots_reused_before_slack():
    """Dead canonical slots opened by deletes are drained by later
    appends before any fresh slack is consumed: sustained delete/append
    churn holds the fill frontier (and so the overflow re-stage) flat
    instead of marching through the slack, and answers stay exact."""
    full = spatial_gen.dataset("osm", jax.random.PRNGKey(5), N_BASE)
    parts = api.partition("bsp", full, PAYLOAD)
    cfg = ServeConfig(slack=64, compact_dead_frac=None)
    srv = SpatialServer(parts, full, cfg)
    model = LiveSet(full)
    rng = np.random.default_rng(17)
    fill0 = int(srv.tiles._fill.sum())
    for _ in range(6):
        ids = _pick_live(model, rng, 40)
        srv.delete(ids)
        model.delete(ids)
        assert srv.tiles._n_free.sum() > 0     # slots opened for reuse
        nb = _boxes(rng, 40)
        srv.append(jnp.asarray(nb))
        model.append(nb)
    # six 40-object rounds insert ≥ 240 copies; deletes free one
    # canonical slot per object, so without reuse the frontier would
    # march ≥ 240 slots.  Reuse holds the growth to the replicated
    # residue (copies landing in tiles with no free slot), and the
    # slack never overflows into a re-stage
    assert int(srv.tiles._fill.sum()) - fill0 <= 120
    assert srv.stats["restages"] == 0
    _check(srv, model, rng)
    _check_vs_fresh_staging(srv, model, cfg, rng)


# -- scatter cost: appends and deletes no longer move the layout ------------

def test_append_transfers_touched_cells_not_layout():
    """The O(M) bar in-process: a small append's device transfer is a
    sliver of the staged member data (PR 5 re-uploaded all of it)."""
    full = spatial_gen.dataset("osm", jax.random.PRNGKey(4), 3000)
    parts = api.partition("str", full, 100)
    srv = SpatialServer(parts, full, ServeConfig(slack=128))
    staged = srv.layout.canon_tiles.nbytes
    rep = srv.append(_boxes(np.random.default_rng(0), 10))
    assert not rep["restaged"]
    assert 0 < rep["bytes_transferred"] < staged / 20
    # deletes are a few bytes of alive bits plus refreshed probe rows
    rep = srv.delete(np.arange(10))
    assert 0 < rep["bytes_transferred"] < staged / 20


# -- SPMD: the same streams over a real 8-device mesh -----------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI virtual-device job)")
@pytest.mark.parametrize("placement", ["replicated", "sharded"])
def test_ingest_stream_spmd_mesh(placement):
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:8]), ("d",))
    _run_stream("bsp", "osm", placement, FIXED_STREAM, seed=7, mesh=mesh)
