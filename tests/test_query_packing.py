"""LPT query packing (`serve.engine.pack_queries`): permutation
completeness, -1 padding, the greedy makespan bound, and the all-zero
cost degenerate case (must round-robin, not pile onto device 0)."""
import numpy as np
import pytest

from repro.serve import engine as serve_engine


@pytest.mark.parametrize("q,d,seed", [(1, 1, 0), (7, 3, 1), (64, 8, 2),
                                      (100, 7, 3), (5, 8, 4)])
def test_slots_are_a_permutation_with_minus_one_padding(q, d, seed):
    """Every query appears exactly once across the slot table; every
    other slot is exactly -1."""
    costs = np.random.default_rng(seed).uniform(0.1, 10.0, q)
    slots, stats = serve_engine.pack_queries(costs, d)
    assert slots.shape[0] == max(1, d)
    assert slots.dtype == np.int32
    live = slots[slots >= 0]
    assert sorted(live.tolist()) == list(range(q))
    assert np.all(slots[~(slots >= 0)] == -1)
    assert stats["qpd"] == slots.shape[1]


@pytest.mark.parametrize("q,d,seed", [(40, 4, 0), (33, 5, 1), (16, 2, 2)])
def test_makespan_within_greedy_bound(q, d, seed):
    """Greedy list scheduling guarantees makespan ≤ mean + max cost;
    LPT (sorted greedy) must meet at least that bound."""
    costs = np.random.default_rng(seed).pareto(1.5, q) + 0.01
    slots, stats = serve_engine.pack_queries(costs, d)
    loads = np.array([costs[row[row >= 0]].sum() for row in slots])
    assert np.isclose(loads.max(), stats["makespan"])
    assert stats["makespan"] <= costs.sum() / d + costs.max() + 1e-9


def test_all_zero_costs_round_robin():
    """An all-zero cost vector (e.g. every query routed nowhere) must
    still spread queries evenly instead of piling them on device 0."""
    slots, stats = serve_engine.pack_queries(np.zeros(10), 4)
    per_dev = (slots >= 0).sum(axis=1)
    assert per_dev.max() - per_dev.min() <= 1
    assert sorted(slots[slots >= 0].tolist()) == list(range(10))
    assert stats["skew"] <= 1.35   # loads 3,3,2,2 -> makespan/mean = 1.2


def test_single_device_takes_everything():
    slots, stats = serve_engine.pack_queries(np.array([3.0, 1.0, 2.0]), 1)
    assert slots.shape == (1, 3)
    assert sorted(slots[0].tolist()) == [0, 1, 2]
    assert stats["skew"] == pytest.approx(1.0)


@pytest.mark.parametrize("t,d,seed", [(16, 4, 0), (17, 4, 1), (9, 8, 2)])
def test_lpt_pack_capped_respects_cap(t, d, seed):
    """Capacitated LPT: every item placed, no device over the cap, and
    cost balance no worse than the uncapped greedy bound allows."""
    from repro.core import placement
    costs = np.random.default_rng(seed).pareto(1.5, t) + 0.01
    cap = -(-t // d)
    owner, makespan, mean = placement.lpt_pack_capped(costs, d, cap)
    counts = np.bincount(owner, minlength=d)
    assert counts.sum() == t and counts.max() <= cap
    loads = np.zeros(d)
    np.add.at(loads, owner, costs)
    assert np.isclose(loads.max(), makespan)


def test_lpt_pack_capped_infeasible_raises():
    from repro.core import placement
    with pytest.raises(ValueError, match="cannot place"):
        placement.lpt_pack_capped(np.ones(9), 2, 4)


def test_balance_shim_reexports_placement():
    """The historical ``repro.query.balance`` path must keep working
    for the join engine and downstream users."""
    from repro.core import placement
    from repro.query import balance
    assert balance.lpt_pack is placement.lpt_pack
    assert balance.tile_costs is placement.tile_costs
