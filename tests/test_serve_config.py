"""ServeConfig validation, the legacy→config deprecation shims (one
release: ``stage``, ``stage_sharded``, the boolean ``SpatialServer``
kwargs), and the from_method passthrough contract.  The dedicated CI
job runs the whole suite with ``LegacyServeWarning`` escalated to an
error, so the shim tests here are the *only* place the legacy surface
is exercised — via ``pytest.deprecated_call``/``pytest.warns``, which
records instead of raising."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.serve import (
    LegacyServeWarning,
    ServeConfig,
    SpatialServer,
    engine as serve_engine,
    stage_tiles,
)


@pytest.fixture(scope="module")
def mbrs():
    return spatial_gen.dataset("osm", jax.random.PRNGKey(0), 1200)


@pytest.fixture(scope="module")
def parts(mbrs):
    return api.partition("bsp", mbrs, 120)


# -- validation -------------------------------------------------------------

def test_config_is_frozen_and_hashable():
    cfg = ServeConfig()
    with pytest.raises(Exception):
        cfg.placement = "sharded"
    assert hash(cfg) == hash(ServeConfig())
    assert cfg.replace(probe="dense").probe == "dense"
    assert cfg.probe == "pruned"                  # replace didn't mutate


@pytest.mark.parametrize("bad", [
    dict(placement="mirrored"),
    dict(probe="fuzzy"),
    dict(local_index="y"),
    dict(local_index=True),            # booleans are legacy-only
    dict(chunk=64),
    dict(chunk=129),
    dict(capacity=0),
    dict(slack=-1),
    dict(shards=0),
    dict(shards=4),                    # shards without placement=sharded
])
def test_config_rejects_invalid(bad):
    with pytest.raises(ValueError):
        ServeConfig(**bad)


def test_from_legacy_mapping():
    cfg = ServeConfig.from_legacy(pruned=False, sharded=True, shards=3,
                                  local_index=False, capacity=256)
    assert cfg == ServeConfig(placement="sharded", probe="dense",
                              local_index="off", capacity=256, shards=3)
    # shards alongside sharded=False was legal (and ignored) before —
    # whether it arrives via the kwargs or an already-sharded base config
    assert ServeConfig.from_legacy(sharded=False, shards=3).shards is None
    cfg = ServeConfig.from_legacy(ServeConfig(placement="sharded", shards=3),
                                  sharded=False)
    assert cfg.placement == "replicated" and cfg.shards is None


# -- deprecated shims -------------------------------------------------------

def test_stage_shim_warns_and_matches_config_path(parts, mbrs):
    with pytest.deprecated_call():
        legacy, lstats = serve_engine.stage(parts, mbrs)
    new, nstats = stage_tiles(parts, mbrs)
    np.testing.assert_array_equal(np.asarray(legacy.ids), np.asarray(new.ids))
    np.testing.assert_array_equal(np.asarray(legacy.canon_tiles),
                                  np.asarray(new.canon_tiles))
    np.testing.assert_array_equal(np.asarray(legacy.chunk_boxes),
                                  np.asarray(new.chunk_boxes))
    assert lstats["cap"] == nstats["cap"]
    with pytest.warns(LegacyServeWarning):
        plain, _ = serve_engine.stage(parts, mbrs, local_index=False)
    assert plain.chunk_boxes is None


def test_stage_sharded_shim_warns_and_shards(parts, mbrs):
    with pytest.deprecated_call():
        slay, (canon_np, ids_np), stats = serve_engine.stage_sharded(
            parts, mbrs, 4)
    assert stats["shards"] == 4
    np.testing.assert_array_equal(
        np.asarray(slay.id_shards)[slay.owner, slay.local], ids_np)


def test_server_boolean_kwargs_warn_and_map(parts, mbrs):
    with pytest.deprecated_call():
        srv = SpatialServer(parts, mbrs, pruned=False, sharded=True,
                            shards=3, local_index=False, capacity=256)
    assert srv.config == ServeConfig(placement="sharded", probe="dense",
                                     local_index="off", capacity=256,
                                     shards=3)
    assert srv.stats["cap"] == 256 and srv.shards == 3
    qb = jnp.asarray([[0.4, 0.4, 0.6, 0.6]], jnp.float32)
    _, stats = srv.range_counts(qb)
    assert stats["mode"] == "dense"               # probe default respected


def test_server_unknown_kwarg_raises(parts, mbrs):
    with pytest.raises(TypeError, match="unknown"):
        SpatialServer(parts, mbrs, sharted=True)


def test_new_surface_is_warning_free(parts, mbrs, recwarn):
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", LegacyServeWarning)
        srv = SpatialServer(parts, mbrs, ServeConfig())
        srv.range_counts(jnp.asarray([[0.4, 0.4, 0.6, 0.6]], jnp.float32))
        srv.append(np.asarray([[0.1, 0.1, 0.2, 0.2]], np.float32))
        stage_tiles(parts, mbrs)


def test_legacy_attribute_views(parts, mbrs):
    """PR-4 public attributes stay readable for one release, derived
    from the config."""
    srv = SpatialServer(parts, mbrs, ServeConfig(placement="sharded",
                                                 shards=3, probe="dense",
                                                 local_index="off"))
    assert srv.sharded and not srv.pruned and not srv.local_index
    assert srv.axis == "d" and srv.n_devices == 1 and srv.shards == 3


# -- from_method passthrough ------------------------------------------------

def test_from_method_config_reaches_staging(mbrs):
    srv = SpatialServer.from_method(
        "fg", mbrs, 150, ServeConfig(capacity=384, local_index="hilbert"))
    assert srv.stats["cap"] == 384
    assert srv.stats["local_index"] == "hilbert"
    assert srv.stats["method"] == "fg"
