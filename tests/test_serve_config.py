"""ServeConfig validation and the constructor/from_method contract of
the config-only serving surface (the PR-4 legacy shims — ``stage``,
``stage_sharded``, boolean ``SpatialServer`` kwargs — were removed
after their one-release deprecation window)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.serve import ServeConfig, SpatialServer


@pytest.fixture(scope="module")
def mbrs():
    return spatial_gen.dataset("osm", jax.random.PRNGKey(0), 1200)


@pytest.fixture(scope="module")
def parts(mbrs):
    return api.partition("bsp", mbrs, 120)


# -- validation -------------------------------------------------------------

def test_config_is_frozen_and_hashable():
    cfg = ServeConfig()
    with pytest.raises(Exception):
        cfg.placement = "sharded"
    assert hash(cfg) == hash(ServeConfig())
    assert cfg.replace(probe="dense").probe == "dense"
    assert cfg.probe == "pruned"                  # replace didn't mutate


@pytest.mark.parametrize("bad", [
    dict(placement="mirrored"),
    dict(probe="fuzzy"),
    dict(local_index="y"),
    dict(local_index=True),            # mode strings only, not booleans
    dict(chunk=64),
    dict(chunk=129),
    dict(capacity=0),
    dict(slack=-1),
    dict(shards=0),
    dict(shards=4),                    # shards without placement=sharded
])
def test_config_rejects_invalid(bad):
    with pytest.raises(ValueError):
        ServeConfig(**bad)


# -- the config-only surface ------------------------------------------------

def test_server_rejects_legacy_kwargs(parts, mbrs):
    """The boolean kwargs are gone, not silently accepted."""
    with pytest.raises(TypeError):
        SpatialServer(parts, mbrs, sharded=True)
    with pytest.raises(TypeError):
        SpatialServer(parts, mbrs, pruned=False)
    with pytest.raises(AttributeError):
        import repro.serve.engine as serve_engine
        serve_engine.stage


def test_config_drives_server(parts, mbrs):
    srv = SpatialServer(parts, mbrs, ServeConfig(placement="sharded",
                                                 shards=3, probe="dense",
                                                 local_index="off",
                                                 capacity=256))
    assert srv.stats["cap"] == 256 and srv.shards == 3
    qb = jnp.asarray([[0.4, 0.4, 0.6, 0.6]], jnp.float32)
    _, stats = srv.range_counts(qb)
    assert stats["mode"] == "dense"               # probe default respected


def test_new_surface_is_warning_free(parts, mbrs):
    import warnings
    from repro.serve import stage_tiles
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        srv = SpatialServer(parts, mbrs, ServeConfig())
        srv.range_counts(jnp.asarray([[0.4, 0.4, 0.6, 0.6]], jnp.float32))
        srv.append(np.asarray([[0.1, 0.1, 0.2, 0.2]], np.float32))
        stage_tiles(parts, mbrs)


# -- from_method passthrough ------------------------------------------------

def test_from_method_config_reaches_staging(mbrs):
    srv = SpatialServer.from_method(
        "fg", mbrs, 150, ServeConfig(capacity=384, local_index="hilbert"))
    assert srv.stats["cap"] == 384
    assert srv.stats["local_index"] == "hilbert"
    assert srv.stats["method"] == "fg"
