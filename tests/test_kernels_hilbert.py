"""Hilbert kernel: exactness vs oracle + curve properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hilbert as chil
from repro.kernels.hilbert import ops


@pytest.mark.parametrize("n", [1, 100, 1024, 4097])
@pytest.mark.parametrize("order", [4, 8, 16])
def test_matches_reference(n, order):
    key = jax.random.PRNGKey(n + order)
    lim = jnp.uint32(1 << order)
    gx = jax.random.randint(key, (n,), 0, lim).astype(jnp.uint32)
    gy = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0,
                            lim).astype(jnp.uint32)
    got = ops.encode(gx, gy, order)
    want = chil.xy2d(gx, gy, order)
    assert bool(jnp.all(got == want))


def test_bijective_on_full_grid():
    """Order-4 curve visits all 256 cells exactly once."""
    g = jnp.arange(16, dtype=jnp.uint32)
    gx, gy = jnp.meshgrid(g, g)
    d = ops.encode(gx.ravel(), gy.ravel(), 4)
    assert len(np.unique(np.asarray(d))) == 256
    assert int(d.max()) == 255


def test_adjacency():
    """Consecutive curve positions are grid neighbours (Hilbert property
    that Z-order lacks — the reason the paper picks HC)."""
    g = jnp.arange(16, dtype=jnp.uint32)
    gx, gy = jnp.meshgrid(g, g)
    gx, gy = gx.ravel(), gy.ravel()
    d = np.asarray(ops.encode(gx, gy, 4))
    order = np.argsort(d)
    x, y = np.asarray(gx)[order], np.asarray(gy)[order]
    step = np.abs(np.diff(x.astype(int))) + np.abs(np.diff(y.astype(int)))
    assert (step == 1).all()


def test_keys_from_points():
    pts = jax.random.uniform(jax.random.PRNGKey(0), (500, 2))
    bounds = jnp.array([0.0, 0.0, 1.0, 1.0])
    assert bool(jnp.all(ops.hilbert_keys(pts, bounds)
                        == chil.hilbert_keys(pts, bounds)))
