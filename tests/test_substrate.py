"""Optimizer, checkpoint/elastic-restore, FT runtime, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.core import cost_model
from repro.data import balanced, spatial_gen, tokens
from repro.dist import compress
from repro.ft.runtime import FTConfig, run_loop
from repro.optim import adamw


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup=0,
                            total_steps=200)
    state = adamw.init_state(params, cfg)
    grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
    for _ in range(150):
        params, state, _ = adamw.update(grad_fn(params), state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


@pytest.mark.parametrize("policy", ["fp32", "bf16_m", "bf16_mv"])
def test_adamw_state_policies(policy):
    params = {"w": jnp.ones((8, 8))}
    cfg = adamw.AdamWConfig(state_policy=policy)
    st = adamw.init_state(params, cfg)
    assert st.m["w"].dtype == (jnp.bfloat16 if policy != "fp32"
                               else jnp.float32)
    assert st.v["w"].dtype == (jnp.bfloat16 if policy == "bf16_mv"
                               else jnp.float32)
    g = {"w": jnp.full((8, 8), 0.1)}
    p2, st2, m = adamw.update(g, st, params, cfg)
    assert np.isfinite(float(m["grad_norm"]))


def test_grad_clip():
    params = {"w": jnp.zeros(4)}
    cfg = adamw.AdamWConfig(grad_clip=1.0, lr=1.0, warmup=0, weight_decay=0)
    st = adamw.init_state(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = adamw.update(g, st, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_checkpoint_roundtrip_and_elastic_restore():
    state = {"p": jnp.arange(12.0).reshape(3, 4),
             "n": {"s": jnp.ones((5,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, state, 7)
        store.save(d, jax.tree.map(lambda x: x * 2, state), 9)
        assert store.latest_step(d) == 9
        got, step = store.restore(d, state)
        assert step == 9
        np.testing.assert_allclose(np.asarray(got["p"]),
                                   np.asarray(state["p"]) * 2)
        got7, _ = store.restore(d, state, step=7)
        np.testing.assert_allclose(np.asarray(got7["p"]),
                                   np.asarray(state["p"]))


def test_checkpoint_atomicity_on_failure(monkeypatch):
    state = {"p": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, state, 1)
        calls = {"n": 0}
        orig = np.save

        def boom(*a, **k):
            calls["n"] += 1
            if calls["n"] > 1:
                raise IOError("disk died")
            return orig(*a, **k)

        monkeypatch.setattr(np, "save", boom)
        state2 = {"p": jnp.ones((4,)), "q": jnp.zeros((2,))}
        with pytest.raises(IOError):
            store.save(d, state2, 2)
        monkeypatch.setattr(np, "save", orig)
        # step 1 still intact; no step_2 garbage
        assert store.latest_step(d) == 1
        got, _ = store.restore(d, state)
        np.testing.assert_allclose(np.asarray(got["p"]), 1.0)


def test_ft_restart_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        cfg = FTConfig(ckpt_dir=d, ckpt_every=3, max_restarts=2)

        def step(st, _):
            return {"x": st["x"] + 1}, {}

        st, _, info = run_loop(step, {"x": jnp.zeros(())}, list(range(10)),
                               cfg, inject_failure_at=7)
        assert info["restarts"] == 1
        assert float(st["x"]) == 10.0


def test_balanced_batching_beats_naive():
    lengths = tokens.doc_lengths(0, 2048, 8192)
    _, s_bal = balanced.balanced_bins(lengths, 16)
    _, s_naive = balanced.naive_bins(lengths, 16)
    assert s_bal["skew"] < s_naive["skew"]
    assert s_bal["skew"] < 1.6


def test_token_pipeline_determinism_and_host_sharding():
    cfg = tokens.TokenPipelineConfig(vocab=1000, seq_len=16, global_batch=8,
                                     n_hosts=4, host_id=2)
    b1 = tokens.batch_for_step(cfg, 5)
    b2 = tokens.batch_for_step(cfg, 5)
    assert b1["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    other = tokens.TokenPipelineConfig(vocab=1000, seq_len=16, global_batch=8,
                                       n_hosts=4, host_id=3)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(tokens.batch_for_step(other, 5)["tokens"]))


def test_quantize_roundtrip_error_bounded():
    x = jnp.linspace(-3, 3, 100)
    q, scale = compress.quantize(x)
    err = jnp.max(jnp.abs(compress.dequantize(q, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_cost_model_interior_optimum():
    """With α(k) rising in k, cost has an interior sweet spot (paper §2.3)."""
    ks = np.array([1, 4, 16, 64, 256, 1024, 4096], np.float32)
    alphas = 0.002 * np.sqrt(ks)            # boundary ratio grows with k
    params = cost_model.CostParams(beta=2000.0)
    i, costs = cost_model.optimal_k(1e5, 1e5, ks, alphas, params)
    costs = np.asarray(costs)
    assert 0 < int(i) < len(ks) - 1 or costs[int(i)] <= costs.min() + 1e-3


def test_spatial_generators_calibration():
    """OSM-like data is far more skewed than PI-like (paper §6.2)."""
    from repro.core import metrics
    from repro.core.partition import api, partition_counts
    key = jax.random.PRNGKey(0)
    skews = {}
    for name in ["osm", "pi"]:
        m = spatial_gen.dataset(name, key, 4000)
        assert bool(jnp.all(m[:, 2] >= m[:, 0]))
        parts = api.partition("fg", m, 100)
        counts, _ = partition_counts(m, parts)
        skews[name] = float(metrics.skew_ratio(counts, parts.valid))
    assert skews["osm"] > 3.0 * skews["pi"]
