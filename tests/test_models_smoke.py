"""Per-architecture smoke tests: reduced config, one forward/train step,
shape + finiteness assertions (assignment requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, encdec, lm
from repro.optim.adamw import AdamWConfig


def _batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["img"] = jax.random.normal(
            key, (b, cfg.vis_tokens, cfg.vis_dim), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.src_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.smoke(arch)
    model = api.build(cfg)
    key = jax.random.PRNGKey(0)
    state = api.init_train_state(model, key, AdamWConfig())
    b, s = 2, 32
    batch = _batch(cfg, key, b, s)

    # forward shapes
    if cfg.family == "encdec":
        logits, _ = encdec.forward(state.params, batch["frames"],
                                   batch["tokens"], cfg)
    else:
        logits, _ = lm.forward(state.params, batch["tokens"], cfg,
                               img=batch.get("img"), remat="none")
    exp_s = s + (cfg.vis_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab])))

    # one train step: finite loss, params change
    step = jax.jit(api.make_train_step(model, AdamWConfig()))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b2.astype(jnp.float32))))
                for a, b2 in zip(jax.tree.leaves(state.params),
                                 jax.tree.leaves(state2.params)))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ["gemma2_27b", "mixtral_8x22b",
                                  "recurrentgemma_9b", "mamba2_1p3b",
                                  "whisper_medium"])
def test_decode_matches_teacher_forcing(arch):
    """Ring caches / SSM recurrences / cross-attn caches reproduce the
    training forward exactly (fp32, high MoE capacity)."""
    cfg = dataclasses.replace(configs.smoke(arch), dtype="float32",
                              capacity_factor=16.0)
    model = api.build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    b, s = 2, 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (b, cfg.src_len, cfg.d_model))
        tf_logits, _ = encdec.forward(params, frames, toks, cfg)
        cache = encdec.init_cache(params, frames, cfg, s)
    else:
        tf_logits, _ = lm.forward(params, toks, cfg, remat="none")
        cache = model.init_cache(b, s)
    step = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos))
    for pos in range(s):
        logits, cache = step(params, cache, toks[:, pos], pos)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(tf_logits[:, pos]),
                                   rtol=1e-4, atol=1e-4)


def test_param_counts_match_published_scale():
    """Full configs land near their published parameter counts."""
    expect = {
        "gemma2_27b": 27e9, "stablelm_12b": 12e9, "qwen15_4b": 4e9,
        "command_r_35b": 35e9, "mixtral_8x22b": 141e9, "arctic_480b": 480e9,
        "internvl2_26b": 20e9,  # LM backbone only (ViT is stubbed)
        "recurrentgemma_9b": 9e9, "mamba2_1p3b": 1.3e9,
    }
    for arch, want in expect.items():
        got = configs.get(arch).n_params()
        assert 0.5 * want < got < 1.7 * want, \
            f"{arch}: n_params()={got / 1e9:.1f}B vs published {want / 1e9:.0f}B"


def test_moe_active_params_below_total():
    cfg = configs.get("mixtral_8x22b")
    assert cfg.n_active_params() < 0.45 * cfg.n_params()
