"""The async request plane, tested deterministically.

Policy (admission, DRR fairness, deadline-or-full closing, the batch
shape ladder, timeouts) runs on a ``VirtualClock`` — no sleeps, no
wall-clock flakiness.  Exactness is the usual bar: padded front-end
batches must return answers **bit-identical** to calling the batched
``SpatialServer`` API directly with the same queries, on both
placements (and on a real 8-device mesh in the CI virtual-device job).
The asyncio wrapper gets a live smoke test; everything timing-critical
stays on the virtual clock.
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import spatial_gen
from repro.serve import ServeConfig, SpatialServer
from repro.serve.frontend import (
    Arrival,
    FrontendConfig,
    Outcome,
    Request,
    RequestPlane,
    ServeFrontend,
    VirtualClock,
    execute_batch,
    poisson_workload,
    simulate_open_loop,
)
from repro.serve.frontend.plane import Batch

N, PAYLOAD = 1500, 130


def _req(kind="range_counts", payload=None, params=(), tenant="default",
         deadline=float("inf")):
    return Request(kind=kind,
                   payload=payload if payload is not None else np.zeros(4),
                   params=params, tenant=tenant, deadline=deadline)


@pytest.fixture(scope="module")
def mbrs():
    return spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)


@pytest.fixture(scope="module")
def qboxes():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    c = jax.random.uniform(k1, (13, 2))
    s = jax.random.uniform(k2, (13, 2)) * 0.06
    return np.asarray(jnp.concatenate([c - s, c + s], axis=-1))


@pytest.fixture(scope="module")
def pts():
    return np.asarray(jax.random.uniform(jax.random.PRNGKey(2), (13, 2)))


@pytest.fixture(scope="module", params=["replicated", "sharded"])
def server(request, mbrs):
    cfg = (ServeConfig() if request.param == "replicated"
           else ServeConfig(placement="sharded", shards=4))
    return SpatialServer.from_method("bsp", mbrs, PAYLOAD, cfg)


# -- config -----------------------------------------------------------------

def test_config_validates():
    cfg = FrontendConfig()
    assert cfg.max_batch == cfg.ladder[-1]
    assert cfg.width_for(1) == cfg.ladder[0]
    assert cfg.width_for(cfg.ladder[-1]) == cfg.ladder[-1]
    assert cfg.replace(max_delay=0.5).max_delay == 0.5
    for bad in (dict(ladder=()), dict(ladder=(128, 64)),
                dict(ladder=(0, 64)), dict(max_delay=-1.0),
                dict(queue_limit=0), dict(quantum=0)):
        with pytest.raises(ValueError):
            FrontendConfig(**bad)
    with pytest.raises(ValueError):
        FrontendConfig(ladder=(4,)).width_for(5)


# -- batch forming: deadline-or-full on a virtual clock ---------------------

def test_batch_closes_on_deadline_not_before():
    cfg = FrontendConfig(ladder=(4, 8), max_delay=0.010)
    plane = RequestPlane(cfg)
    for t in (0.0, 0.001, 0.002):
        assert plane.submit(_req(), now=t)
    assert plane.next_due(0.002) == pytest.approx(0.010)
    batch, expired = plane.form_batch(0.009)
    assert batch is None and not expired          # oldest not yet due
    batch, expired = plane.form_batch(0.010)      # exactly due closes
    assert batch is not None and not expired
    assert len(batch.requests) == 3 and batch.width == 4
    assert [r.seq for r in batch.requests] == [0, 1, 2]   # FIFO
    assert plane.pending == 0


def test_batch_closes_immediately_when_full():
    cfg = FrontendConfig(ladder=(4, 8), max_delay=10.0)
    plane = RequestPlane(cfg)
    for _ in range(9):
        plane.submit(_req(), now=0.0)
    assert plane.next_due(0.0) == 0.0             # full: due now
    batch, _ = plane.form_batch(0.0)
    assert len(batch.requests) == 8 and batch.width == 8
    assert plane.pending == 1                      # remainder waits
    batch, _ = plane.form_batch(10.0)
    assert len(batch.requests) == 1 and batch.width == 4


def test_ladder_pads_to_smallest_fitting_rung():
    cfg = FrontendConfig(ladder=(4, 8, 16), max_delay=0.0)
    plane = RequestPlane(cfg)
    for n, want in ((3, 4), (5, 8), (9, 16)):
        for _ in range(n):
            plane.submit(_req(), now=0.0)
        batch, _ = plane.form_batch(0.0)
        assert len(batch.requests) == n and batch.width == want


def test_kinds_and_params_batch_separately():
    plane = RequestPlane(FrontendConfig(max_delay=0.0))
    plane.submit(_req("range_ids", params=(64,)), now=0.0)
    plane.submit(_req("range_ids", params=(128,)), now=0.0)
    plane.submit(_req("knn", np.zeros(2), (4, 64)), now=0.0)
    widths = set()
    for _ in range(3):
        batch, _ = plane.form_batch(0.0)
        assert len(batch.requests) == 1
        widths.add((batch.kind, batch.params))
    assert widths == {("range_ids", (64,)), ("range_ids", (128,)),
                      ("knn", (4, 64))}
    assert plane.form_batch(0.0) == (None, [])
    with pytest.raises(ValueError):
        plane.submit(_req("nearest"), now=0.0)


# -- fairness: deficit round robin across tenants ---------------------------

def test_drr_hot_tenant_cannot_starve_others():
    cfg = FrontendConfig(ladder=(8,), max_delay=0.0, quantum=2)
    plane = RequestPlane(cfg)
    for i in range(100):
        plane.submit(_req(tenant="hog"), now=0.0)
    for i in range(4):
        plane.submit(_req(tenant=f"small{i}"), now=0.0)
    batch, _ = plane.form_batch(0.0)
    by_tenant = {}
    for r in batch.requests:
        by_tenant[r.tenant] = by_tenant.get(r.tenant, 0) + 1
    # one 8-slot batch: hog gets its 2-request quantum turns, every
    # small tenant gets served in the same batch
    assert by_tenant == {"hog": 4, "small0": 1, "small1": 1,
                         "small2": 1, "small3": 1}


def test_deadline_close_serves_exhausted_deficit_tenant():
    """The DRR × deadline interaction: a class queue that is not full
    must still close at ``max_delay`` even when the hot tenant's
    rotation turns would exhaust its quantum many times over — and the
    starved tenant's request rides the same deadline-formed batch
    (fairness never delays a due close)."""
    cfg = FrontendConfig(ladder=(8,), max_delay=0.010, quantum=2)
    plane = RequestPlane(cfg)
    for _ in range(6):
        plane.submit(_req(tenant="hog"), now=0.0)
    plane.submit(_req(tenant="slow"), now=0.002)
    # 7 < max_batch: nothing closes before the oldest's max_delay
    assert plane.form_batch(0.009) == (None, [])
    assert plane.next_due(0.009) == pytest.approx(0.010)
    batch, expired = plane.form_batch(0.010)
    assert batch is not None and not expired
    assert len(batch.requests) == 7
    # the pop order shows the deficit turns: hog's 2-request quantum,
    # then slow's turn, then hog drains through repeat rotation visits
    assert [r.tenant for r in batch.requests] == \
        ["hog", "hog", "slow", "hog", "hog", "hog", "hog"]
    assert plane.pending == 0


def test_deadline_expiry_inside_exhausted_deficit_batch():
    """A starved tenant's request whose own deadline lapses while hog
    turns consumed earlier batches is timed out at pop time — counted,
    returned separately, never executed — and the deadline-formed
    batch still carries the live requests."""
    cfg = FrontendConfig(ladder=(4,), max_delay=0.010, quantum=4)
    plane = RequestPlane(cfg)
    for _ in range(4):
        plane.submit(_req(tenant="hog"), now=0.0)
    doomed = _req(tenant="slow", deadline=0.004)
    plane.submit(doomed, now=0.0)
    batch, expired = plane.form_batch(0.0)   # full: hog's quantum fills
    assert [r.tenant for r in batch.requests] == ["hog"] * 4
    assert not expired
    # slow's lone request is now overdue for the class deadline but
    # past its own: the close still happens, the request times out
    assert plane.next_due(0.009) == pytest.approx(0.010)
    batch, expired = plane.form_batch(0.010)
    assert batch is None and expired == [doomed]
    assert plane.metrics.timed_out == 1
    assert plane.pending == 0


def test_drr_rotation_persists_across_batches():
    cfg = FrontendConfig(ladder=(2,), max_delay=0.0, quantum=1)
    plane = RequestPlane(cfg)
    for t in ("a", "b", "c"):
        for _ in range(2):
            plane.submit(_req(tenant=t), now=0.0)
    order = []
    for _ in range(3):
        batch, _ = plane.form_batch(0.0)
        order.append([r.tenant for r in batch.requests])
    # round robin continues where the last batch stopped, so every
    # tenant is fully served after 3 batches of 2
    assert sorted(t for pair in order for t in pair) == list("aabbcc")
    assert order[0] == ["a", "b"] and order[1] == ["c", "a"]


# -- admission control and deadlines ----------------------------------------

def test_backpressure_rejects_at_queue_limit():
    plane = RequestPlane(FrontendConfig(queue_limit=3))
    assert all(plane.submit(_req(tenant="t"), 0.0) for _ in range(3))
    assert not plane.submit(_req(tenant="t"), 0.0)
    m = plane.metrics
    assert m.rejected == 1 and m.admitted == 3
    assert m.tenants["t"].rejected == 1
    # draining the queue re-opens admission
    plane.form_batch(1.0)
    assert plane.submit(_req(tenant="t"), 1.0)


def test_expired_requests_time_out_not_execute():
    plane = RequestPlane(FrontendConfig(ladder=(4,), max_delay=0.0))
    dead = _req(deadline=0.5)
    live = _req(deadline=5.0)
    plane.submit(dead, 0.0)
    plane.submit(live, 0.0)
    batch, expired = plane.form_batch(1.0)
    assert expired == [dead]
    assert batch.requests == [live]
    assert plane.metrics.timed_out == 1


def test_default_deadline_budget_applies():
    plane = RequestPlane(FrontendConfig(default_deadline=0.25))
    r = _req()
    plane.submit(r, 1.0)
    assert r.deadline == pytest.approx(1.25)
    explicit = _req(deadline=9.0)
    plane.submit(explicit, 1.0)
    assert explicit.deadline == 9.0               # explicit wins


# -- metrics ----------------------------------------------------------------

def test_metrics_fill_ratio_and_padded_slots():
    plane = RequestPlane(FrontendConfig(ladder=(8,), max_delay=0.0))
    for _ in range(5):
        plane.submit(_req(), 0.0)
    plane.form_batch(0.0)
    m = plane.metrics
    assert m.batch_slots == 8 and m.batch_fill == 5
    assert m.padded_slots == 3
    assert m.batch_fill_ratio == pytest.approx(5 / 8)
    snap = m.snapshot()
    assert snap["batches"] == 1 and snap["padded_slots"] == 3


def test_histogram_percentiles_and_decimation():
    from repro.serve.frontend.metrics import Histogram
    h = Histogram(cap=64)
    for i in range(1000):
        h.record(float(i))
    assert h.count == 1000 and h.max == 999.0
    assert h.mean == pytest.approx(499.5)
    assert len(h.samples) < 64
    assert h.percentile(50) == pytest.approx(500.0, rel=0.1)
    assert h.percentile(99) == pytest.approx(990.0, rel=0.05)


# -- open-loop simulation ---------------------------------------------------

def _stub_execute(service_s):
    def execute(server, batch):
        return [0] * len(batch.requests), service_s
    return execute


def test_sim_is_deterministic_and_conserves_requests():
    wl = poisson_workload(
        10000.0, 0.1,
        lambda rng, i: ("range_counts", np.zeros(4), (),
                        "hot" if rng.random() < 0.7 else f"t{i % 3}"),
        seed=11)
    for a in wl[::7]:
        a.deadline = 0.002                        # tight SLO: some miss
    cfg = FrontendConfig(ladder=(8, 16), max_delay=0.002, queue_limit=64)
    runs = [simulate_open_loop(None, wl, cfg, execute=_stub_execute(0.004))
            for _ in range(2)]
    (r1, m1), (r2, m2) = runs
    assert m1.snapshot() == m2.snapshot()         # bit-for-bit repeatable
    s = m1.snapshot()
    assert s["rejected"] > 0 and s["timed_out"] > 0   # overloaded on purpose
    ok = sum(r.ok for r in r1)
    assert ok + s["rejected"] + s["timed_out"] == len(wl)
    assert s["completed"] == ok
    assert [r.outcome for r in r1] == [r.outcome for r in r2]


def test_sim_latency_grows_with_load():
    def make(rng, i):
        return "range_counts", np.zeros(4), (), "default"
    cfg = FrontendConfig(ladder=(8, 16), max_delay=0.001)
    _, light = simulate_open_loop(
        None, poisson_workload(500.0, 0.2, make, seed=1), cfg,
        execute=_stub_execute(0.002))
    _, heavy = simulate_open_loop(
        None, poisson_workload(6000.0, 0.2, make, seed=1), cfg,
        execute=_stub_execute(0.002))
    assert heavy.total_s.percentile(99) > light.total_s.percentile(99)
    assert heavy.batch_fill_ratio > light.batch_fill_ratio


# -- bit-identity against the batched server --------------------------------

def test_padded_batches_bit_identical_to_direct_calls(server, qboxes, pts):
    """The acceptance bar: frontend answers == direct batched answers,
    for every kind, across padded widths, on both placements."""
    nq = qboxes.shape[0]
    reqs = [Request("range_counts", qboxes[i], ()) for i in range(nq)]
    got = execute_batch(server, Batch("range_counts", (), reqs, 16, 0.0))
    want, _ = server.range_counts(jnp.asarray(qboxes))
    assert got == [int(c) for c in np.asarray(want)]

    reqs = [Request("range_ids", qboxes[i], (256,)) for i in range(nq)]
    got = execute_batch(server, Batch("range_ids", (256,), reqs, 16, 0.0))
    ids_w, cnt_w, ov_w, _ = server.range_ids(jnp.asarray(qboxes),
                                             max_hits=256)
    ids_w, cnt_w = np.asarray(ids_w), np.asarray(cnt_w)
    ov_w = np.asarray(ov_w)
    for i in range(nq):
        np.testing.assert_array_equal(got[i][0], ids_w[i])
        assert got[i][1] == int(cnt_w[i]) and got[i][2] == bool(ov_w[i])

    reqs = [Request("knn", pts[i], (5, 256)) for i in range(nq)]
    got = execute_batch(server, Batch("knn", (5, 256), reqs, 16, 0.0))
    nn_w, d2_w, ov_w, _ = server.knn(jnp.asarray(pts), 5, max_cand=256)
    nn_w, d2_w, ov_w = np.asarray(nn_w), np.asarray(d2_w), np.asarray(ov_w)
    for i in range(nq):
        np.testing.assert_array_equal(got[i][0], nn_w[i])
        np.testing.assert_array_equal(got[i][1], d2_w[i])
        assert got[i][2] == bool(ov_w[i])


def test_split_batches_match_one_direct_batch(server, qboxes):
    """Answers are per-query: however the plane slices a stream into
    batches, the union of responses equals one direct call."""
    plane = RequestPlane(FrontendConfig(ladder=(4, 8), max_delay=0.0))
    reqs = [Request("range_counts", qboxes[i], ()) for i in
            range(qboxes.shape[0])]
    for r in reqs:
        plane.submit(r, 0.0)
    got = {}
    while plane.pending:
        batch, _ = plane.form_batch(0.0, force=True)
        for req, val in zip(batch.requests, execute_batch(server, batch)):
            got[req.seq] = val
    want, _ = server.range_counts(jnp.asarray(qboxes))
    assert [got[r.seq] for r in reqs] == [int(c) for c in np.asarray(want)]


def test_open_loop_sim_bit_identical_on_live_server(server, qboxes):
    """The bench path end to end: seeded Poisson arrivals, real
    execution, responses keyed back to their queries exactly."""
    nq = qboxes.shape[0]
    wl = poisson_workload(
        2000.0, 0.05,
        lambda rng, i: ("range_counts", qboxes[i % nq], (), "default"),
        seed=5)
    responses, metrics = simulate_open_loop(
        server, wl, FrontendConfig(ladder=(8, 16), max_delay=0.002))
    want = np.asarray(server.range_counts(jnp.asarray(qboxes))[0])
    assert all(r.ok for r in responses)
    for i, r in enumerate(responses):
        assert r.value == int(want[i % nq])
    assert metrics.completed == len(wl)


# -- the asyncio wrapper ----------------------------------------------------

def test_asyncio_frontend_serves_mixed_kinds(server, qboxes, pts):
    async def main():
        direct_counts = np.asarray(
            server.range_counts(jnp.asarray(qboxes))[0])
        nn_w, d2_w, _, _ = server.knn(jnp.asarray(pts), 3, max_cand=256)
        nn_w, d2_w = np.asarray(nn_w), np.asarray(d2_w)
        async with ServeFrontend(
                server, FrontendConfig(ladder=(16,),
                                       max_delay=0.005)) as fe:
            counts = asyncio.gather(
                *[fe.range_counts(qboxes[i], tenant=f"t{i % 3}")
                  for i in range(qboxes.shape[0])])
            knns = asyncio.gather(
                *[fe.knn(pts[i], 3, max_cand=256)
                  for i in range(pts.shape[0])])
            counts, knns = await counts, await knns
        assert all(r.ok for r in counts) and all(r.ok for r in knns)
        assert [r.value for r in counts] == [int(c) for c in direct_counts]
        for i, r in enumerate(knns):
            np.testing.assert_array_equal(r.value[0], nn_w[i])
            np.testing.assert_array_equal(r.value[1], d2_w[i])
        snap = fe.metrics.snapshot()
        assert snap["completed"] == 2 * qboxes.shape[0]
        assert snap["total_s"]["count"] == snap["completed"]
        assert set(snap["tenants"]) == {"default", "t0", "t1", "t2"}
    asyncio.run(main())


def test_asyncio_frontend_rejects_when_full(server, qboxes):
    async def main():
        fe = ServeFrontend(server, FrontendConfig(
            ladder=(4,), max_delay=0.05, queue_limit=2))
        fe.start()
        try:
            rs = await asyncio.gather(
                *[fe.range_counts(qboxes[i]) for i in range(6)])
        finally:
            await fe.close()
        outcomes = [r.outcome for r in rs]
        assert outcomes.count(Outcome.REJECTED) >= 1
        assert all(o in (Outcome.OK, Outcome.REJECTED) for o in outcomes)
    asyncio.run(main())


def test_asyncio_close_drains_pending(server, qboxes):
    async def main():
        fe = ServeFrontend(server, FrontendConfig(
            ladder=(64,), max_delay=30.0))     # never due on its own
        fe.start()
        futs = [asyncio.ensure_future(fe.range_counts(qboxes[i]))
                for i in range(4)]
        await asyncio.sleep(0)                 # let submits land
        await fe.close()                       # force-drains
        rs = await asyncio.gather(*futs)
        assert all(r.ok for r in rs)
    asyncio.run(main())


# -- SPMD: the frontend over a real mesh ------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI virtual-device job)")
def test_frontend_spmd_mesh_bit_identical(mbrs, qboxes):
    """Frontend batches through a sharded server on a real 8-device
    mesh: same answers as the single-device direct call."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:8]), ("d",))
    srv = SpatialServer.from_method(
        "bsp", mbrs, PAYLOAD,
        ServeConfig(placement="sharded", shards=8), mesh=mesh)
    plain = SpatialServer.from_method("bsp", mbrs, PAYLOAD)
    nq = qboxes.shape[0]
    reqs = [Request("range_ids", qboxes[i], (256,)) for i in range(nq)]
    got = execute_batch(srv, Batch("range_ids", (256,), reqs, 16, 0.0))
    ids_w, cnt_w, _, _ = plain.range_ids(jnp.asarray(qboxes), max_hits=256)
    ids_w, cnt_w = np.asarray(ids_w), np.asarray(cnt_w)
    for i in range(nq):
        np.testing.assert_array_equal(got[i][0], ids_w[i])
        assert got[i][1] == int(cnt_w[i])
