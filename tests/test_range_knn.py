"""Batched range + kNN vs numpy brute force: exact hit sets and exact
k-neighbour sets (ties by id) across overlapping (hc/str) and
non-overlapping (fg/bsp) layouts, on skewed (osm) and uniform (pi) data
— the acceptance bar for the serving subsystem."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import router, stage_tiles

LAYOUTS = ["hc", "str", "fg", "bsp"]
DATASETS = ["osm", "pi"]


def _qboxes(key, q, scale=0.06):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


@pytest.fixture(scope="module", params=DATASETS)
def data(request):
    mbrs = spatial_gen.dataset(request.param, jax.random.PRNGKey(0), 2500)
    return mbrs, np.asarray(mbrs)


@pytest.fixture(scope="module")
def staged(data):
    mbrs, _ = data
    out = {}
    for m in LAYOUTS:
        parts = api.partition(m, mbrs, 150)
        out[m] = (parts,) + stage_tiles(parts, mbrs)
    return out


@pytest.mark.parametrize("method", LAYOUTS)
def test_range_counts_exact(data, staged, method):
    _, mbrs_np = data
    _, layout, _ = staged[method]
    qb = _qboxes(jax.random.PRNGKey(1), 40)
    counts = range_mod.range_counts(qb, layout.canon_tiles)
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    assert [int(c) for c in counts] == [len(r) for r in ref]


@pytest.mark.parametrize("method", LAYOUTS)
def test_range_hit_sets_exact(data, staged, method):
    _, mbrs_np = data
    _, layout, _ = staged[method]
    qb = _qboxes(jax.random.PRNGKey(2), 40)
    hit_ids, counts, overflow = range_mod.range_ids(
        qb, layout.canon_tiles, layout.ids, max_hits=1024)
    assert not bool(jnp.any(overflow))
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    for i, want in enumerate(ref):
        got = np.asarray(hit_ids[i][hit_ids[i] >= 0])
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("method", ["fg", "bsp"])
def test_range_counts_rp_exact_nonoverlapping(data, staged, method):
    """Reference-point dedup needs no canonical mark — exact for
    non-overlapping covering layouts (Table 1), like the join's rp path."""
    _, mbrs_np = data
    _, layout, _ = staged[method]
    qb = _qboxes(jax.random.PRNGKey(3), 40)
    counts = range_mod.range_counts_rp(qb, layout.tiles, layout.tile_boxes,
                                       layout.uni)
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    assert [int(c) for c in counts] == [len(r) for r in ref]


@pytest.mark.parametrize("method", ["fg", "bsp"])
def test_routed_range_counts_exact(data, staged, method):
    """The pruned path (global-index gather) agrees with brute force when
    max_fanout is sized from the router."""
    _, mbrs_np = data
    parts, layout, _ = staged[method]
    qb = _qboxes(jax.random.PRNGKey(4), 25)
    rmask, fanout = router.route_range(parts, qb)
    counts, overflow = range_mod.routed_range_counts(
        qb, layout.tiles, layout.tile_boxes, layout.uni, rmask,
        max_fanout=int(jnp.max(fanout)))
    assert not bool(jnp.any(overflow))
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))
    assert [int(c) for c in counts] == [len(r) for r in ref]
    # undersized fan-out budget must be flagged, not silent
    if int(jnp.max(fanout)) > 1:
        _, overflow = range_mod.routed_range_counts(
            qb, layout.tiles, layout.tile_boxes, layout.uni, rmask,
            max_fanout=1)
        assert bool(jnp.any(overflow))
        np.testing.assert_array_equal(np.asarray(overflow),
                                      np.asarray(fanout) > 1)


@pytest.mark.parametrize("method", LAYOUTS)
@pytest.mark.parametrize("k", [1, 5])
def test_knn_exact(data, staged, method, k):
    _, mbrs_np = data
    _, layout, _ = staged[method]
    pts = jax.random.uniform(jax.random.PRNGKey(5), (30, 2))
    nn_ids, nn_d2, _, overflow, _ = knn_mod.batched_knn(
        pts, k, layout.canon_tiles, layout.ids, layout.uni)
    assert not bool(jnp.any(overflow))
    want_ids, want_d2 = knn_mod.knn_ref(mbrs_np, np.asarray(pts), k)
    np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
    np.testing.assert_allclose(np.asarray(nn_d2), want_d2, rtol=1e-5,
                               atol=1e-7)


def test_knn_tie_break_by_id():
    """Coincident objects: the k reported neighbours are the lowest ids."""
    mbrs = jnp.broadcast_to(jnp.array([0.5, 0.5, 0.6, 0.6]), (8, 4))
    parts = api.partition("fg", mbrs, 4)
    layout, _ = stage_tiles(parts, mbrs)
    pts = jnp.array([[0.1, 0.1]])
    nn_ids, _, _, _, _ = knn_mod.batched_knn(pts, 3, layout.canon_tiles,
                                             layout.ids, layout.uni)
    np.testing.assert_array_equal(np.asarray(nn_ids[0]), [0, 1, 2])


def test_knn_initial_radius_from_live_count_saves_rounds():
    """Regression (density bias): sizing the initial radius from the
    padded T·cap slot count starts the deepening too shallow — passing
    the live canonical member count must answer identically with
    strictly fewer deepening rounds on a high-padding layout."""
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), 400)
    mbrs_np = np.asarray(mbrs)
    parts = api.partition("hc", mbrs, 30)        # small payload, cap
    layout, stats = stage_tiles(parts, mbrs)   # rounds up to 128
    n_slots = stats["t"] * stats["cap"]
    assert n_slots > 4 * stats["n"]              # genuinely padded
    pts = jax.random.uniform(jax.random.PRNGKey(9), (20, 2))
    k = 5
    ids_new, d2_new, _, _, rounds_new = knn_mod.batched_knn(
        pts, k, layout.canon_tiles, layout.ids, layout.uni,
        n_live=stats["n"])
    # old behaviour: n_live=None falls back to the padded slot count
    ids_old, d2_old, _, _, rounds_old = knn_mod.batched_knn(
        pts, k, layout.canon_tiles, layout.ids, layout.uni)
    np.testing.assert_array_equal(np.asarray(ids_new), np.asarray(ids_old))
    want_ids, _ = knn_mod.knn_ref(mbrs_np, np.asarray(pts), k)
    np.testing.assert_array_equal(np.asarray(ids_new), want_ids)
    assert int(jnp.sum(rounds_old)) > int(jnp.sum(rounds_new))
    assert bool(jnp.all(rounds_new <= rounds_old))


def test_router_fanout_orders_layouts(data):
    """Low-replication layouts route narrower (the paper's thesis made a
    serving metric): fan-out is at least 1 and bounded by k."""
    mbrs, _ = data
    qb = _qboxes(jax.random.PRNGKey(6), 50)
    for m in LAYOUTS:
        parts = api.partition(m, mbrs, 150)
        mask, fanout = router.route_range(parts, qb)
        assert int(jnp.min(fanout)) >= 0
        assert int(jnp.max(fanout)) <= int(parts.k())
        assert bool(jnp.all(jnp.sum(mask, axis=1) == fanout))


def test_route_knn_orders_by_mindist(data):
    mbrs, _ = data
    parts = api.partition("bsp", mbrs, 150)
    pts = jax.random.uniform(jax.random.PRNGKey(8), (10, 2))
    order, d2 = router.route_knn(parts, pts)
    picked = jnp.take_along_axis(d2, order, axis=1)
    assert bool(jnp.all(picked[:, 1:] >= picked[:, :-1]))  # ascending
    # valid partitions come first
    n_valid = int(parts.k())
    assert bool(jnp.all(jnp.isfinite(picked[:, :n_valid])))
