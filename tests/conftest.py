import os
import sys

# tests must see exactly ONE device by default (multi-device behaviour
# is covered by subprocesses that set their own flags); make sure
# nothing leaked into the environment.  CI's virtual-device job opts in
# to keeping XLA_FLAGS (REPRO_KEEP_XLA_FLAGS=1) so the in-process
# 8-device mesh tests actually see the forced host device count.
if not os.environ.get("REPRO_KEEP_XLA_FLAGS"):
    os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
