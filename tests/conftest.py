import os
import sys

# tests must see exactly ONE device (the dry-run sets its own flags in a
# separate process); make sure nothing leaked into the environment
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
