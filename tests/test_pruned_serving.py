"""Pruned (routed candidate-tile) serving vs the dense oracle and the
numpy brute force: exact equality across ALL SIX layouts on skewed
(osm) and uniform (pi) data — the acceptance bar for the routed
executor — plus router candidate-list contracts and the gathered
kernel paths feeding it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.partition import api
from repro.data import spatial_gen
from repro.query import knn as knn_mod, range as range_mod
from repro.serve import SpatialServer, router, stage_tiles

LAYOUTS = ["hc", "str", "fg", "bsp", "slc", "bos"]
DATASETS = ["osm", "pi"]
N, NQ, K = 1500, 24, 4


def _qboxes(key, q, scale=0.06):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


@pytest.fixture(scope="module", params=DATASETS)
def data(request):
    mbrs = spatial_gen.dataset(request.param, jax.random.PRNGKey(0), N)
    return mbrs, np.asarray(mbrs)


@pytest.fixture(scope="module")
def servers(data):
    mbrs, _ = data
    return {m: SpatialServer.from_method(m, mbrs, 120) for m in LAYOUTS}


@pytest.mark.parametrize("method", LAYOUTS)
def test_pruned_range_equals_dense_and_bruteforce(data, servers, method):
    _, mbrs_np = data
    srv = servers[method]
    qb = _qboxes(jax.random.PRNGKey(1), NQ)
    ref = range_mod.range_query_ref(mbrs_np, np.asarray(qb))

    counts, stats = srv.range_counts(qb)                 # pruned default
    assert stats["mode"] == "pruned"
    assert stats["f_max"] <= srv.stats["t"]
    dcounts, dstats = srv.range_counts(qb, pruned=False)  # dense oracle
    assert dstats["mode"] == "dense"
    assert [int(c) for c in counts] == [len(r) for r in ref]
    assert [int(c) for c in dcounts] == [len(r) for r in ref]

    hit_ids, cnts, ovf, _ = srv.range_ids(qb, max_hits=2048)
    d_ids, _, d_ovf, _ = srv.range_ids(qb, max_hits=2048, pruned=False)
    assert not np.asarray(ovf).any() and not np.asarray(d_ovf).any()
    np.testing.assert_array_equal(np.asarray(hit_ids), np.asarray(d_ids))
    for i, want in enumerate(ref):
        got = np.asarray(hit_ids[i])
        np.testing.assert_array_equal(got[got >= 0], want)


@pytest.mark.parametrize("method", LAYOUTS)
def test_pruned_knn_equals_dense_and_bruteforce(data, servers, method):
    _, mbrs_np = data
    srv = servers[method]
    pts = jax.random.uniform(jax.random.PRNGKey(2), (NQ, 2))
    want_ids, want_d2 = knn_mod.knn_ref(mbrs_np, np.asarray(pts), K)

    nn_ids, nn_d2, ovf, stats = srv.knn(pts, K)
    assert stats["mode"] == "pruned"
    assert not np.asarray(ovf).any()
    np.testing.assert_array_equal(np.asarray(nn_ids), want_ids)
    np.testing.assert_allclose(np.asarray(nn_d2), want_d2, rtol=1e-5,
                               atol=1e-7)
    d_ids, d_d2, _, dstats = srv.knn(pts, K, pruned=False)
    assert dstats["mode"] == "dense"
    np.testing.assert_array_equal(np.asarray(nn_ids), np.asarray(d_ids))


def test_pruned_range_ids_small_candidate_wide_budget(data, servers):
    """max_hits larger than the gathered F·cap table must still pad to
    the contracted width instead of silently narrowing."""
    mbrs, _ = data
    srv = servers["fg"]
    layout = srv.layout
    qb = _qboxes(jax.random.PRNGKey(3), 4, scale=0.01)
    cand, _, _ = router.candidate_range(layout.probe_boxes, qb, 1)
    wide = layout.ids.shape[1] + 128
    hit_ids, counts, overflow = range_mod.pruned_range_ids(
        qb, layout.canon_tiles, layout.ids, cand, max_hits=wide)
    assert hit_ids.shape == (4, wide)


def test_candidate_range_truncation_is_flagged(data):
    """Undersized f_max must flag overflow per query, never silently."""
    mbrs, _ = data
    parts = api.partition("fg", mbrs, 120)
    layout, _ = stage_tiles(parts, mbrs)
    qb = _qboxes(jax.random.PRNGKey(4), 16, scale=0.2)
    full_fan = np.asarray(router.probe_fanout(layout.probe_boxes, qb))
    if full_fan.max() <= 1:
        pytest.skip("fixture produced no multi-tile queries")
    cand, fanout, overflow = router.candidate_range(
        layout.probe_boxes, qb, 1)
    np.testing.assert_array_equal(np.asarray(fanout), full_fan)
    np.testing.assert_array_equal(np.asarray(overflow), full_fan > 1)
    assert cand.shape == (16, 1)


def test_candidate_knn_frontier_contract(data):
    """Frontier distances ascend, -1 pads empty tiles, and the excluded
    distance lower-bounds every tile left out."""
    mbrs, _ = data
    parts = api.partition("bsp", mbrs, 120)
    layout, _ = stage_tiles(parts, mbrs)
    pts = jax.random.uniform(jax.random.PRNGKey(5), (10, 2))
    t = layout.probe_boxes.shape[0]
    f = min(4, t)
    cand, dist, excl = router.candidate_knn(layout.probe_boxes, pts, f)
    assert cand.shape == (10, f)
    d = np.asarray(dist)
    assert np.all(d[:, 1:] >= d[:, :-1] - 1e-7)          # ascending
    assert np.all(np.asarray(excl) >= d[:, -1] - 1e-7)   # true frontier
    if f < t:
        # excluded really is the (f+1)-th smallest distance
        all_d = np.sort(np.asarray(
            router.linf_dist(pts, layout.probe_boxes)), axis=1)
        np.testing.assert_allclose(np.asarray(excl), all_d[:, f], rtol=1e-6)


def test_probe_boxes_cover_canonical_members(data):
    """The staged probe box of every tile contains all its canonical
    member MBRs — the invariant the pruned path's exactness rests on."""
    mbrs, _ = data
    for m in LAYOUTS:
        parts = api.partition(m, mbrs, 120)
        layout, _ = stage_tiles(parts, mbrs)
        ct = np.asarray(layout.canon_tiles)
        pb = np.asarray(layout.probe_boxes)
        live = ct[..., 0] <= ct[..., 2]                  # non-sentinel
        for t in range(ct.shape[0]):
            if not live[t].any():
                assert pb[t, 0] > pb[t, 2]               # sentinel box
                continue
            boxes = ct[t][live[t]]
            assert np.all(pb[t, 0] <= boxes[:, 0] + 1e-7)
            assert np.all(pb[t, 1] <= boxes[:, 1] + 1e-7)
            assert np.all(pb[t, 2] >= boxes[:, 2] - 1e-7)
            assert np.all(pb[t, 3] >= boxes[:, 3] - 1e-7)
