#!/usr/bin/env python
"""Markdown link check (CI docs job, stdlib only).

Walks the repo's markdown (README.md, ROADMAP.md, CHANGES.md, PAPER.md,
PAPERS.md, docs/** including subdirectories) and verifies:

- every *relative* link target exists on disk, resolved against the
  file containing the link;
- every anchor fragment — both intra-page ``#section`` links and
  ``file.md#section`` cross-file links — names a real heading in the
  target markdown file (GitHub-style slugs, duplicate headings get
  ``-1``/``-2`` suffixes).

External (http/https/mailto) links are skipped — CI must not depend on
the network.  Exit codes follow tools/_cli.py: 0 clean, 1 broken links,
2 usage error.

    python tools/check_links.py [repo_root] [--json] [--out PATH]
"""
from __future__ import annotations

import pathlib
import re
import sys

import _cli
from _cli import EXIT_FINDINGS, EXIT_OK, EXIT_USAGE

# [text](target) — target captured up to the first unescaped ')'
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")


def _slugify(heading: str) -> str:
    """GitHub's anchor slug: lowercase, markdown/punctuation stripped,
    spaces to hyphens."""
    text = re.sub(r"[*_`]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors(md: pathlib.Path, cache: dict) -> set[str]:
    """All heading anchors of one markdown file (code fences skipped),
    with GitHub's -1/-2 suffixes for duplicate headings."""
    if md not in cache:
        seen: dict[str, int] = {}
        out: set[str] = set()
        in_code = False
        for line in md.read_text(encoding="utf-8").splitlines():
            if line.lstrip().startswith("```"):
                in_code = not in_code
            if in_code:
                continue
            m = _HEADING.match(line)
            if not m:
                continue
            slug = _slugify(m.group(1))
            n = seen.get(slug, 0)
            seen[slug] = n + 1
            out.add(slug if n == 0 else f"{slug}-{n}")
        cache[md] = out
    return cache[md]


def md_files(root: pathlib.Path) -> list[pathlib.Path]:
    tops = ["README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md",
            "PAPERS.md", "ISSUE.md", "SNIPPETS.md"]
    files = [root / t for t in tops if (root / t).is_file()]
    files += sorted((root / "docs").rglob("*.md"))
    return files


def check(root: pathlib.Path) -> list[str]:
    broken = []
    anchor_cache: dict = {}
    for md in md_files(root):
        text = md.read_text(encoding="utf-8")
        in_code = False
        for lineno, line in enumerate(text.splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_code = not in_code
            if in_code:
                continue
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(_SKIP_PREFIXES):
                    continue
                path, _, frag = target.partition("#")
                resolved = (md.parent / path).resolve() if path else md
                if path and not resolved.exists():
                    broken.append(
                        f"{md.relative_to(root)}:{lineno}: broken link "
                        f"-> {target}")
                    continue
                if frag and resolved.suffix == ".md":
                    if frag.lower() not in anchors(resolved, anchor_cache):
                        broken.append(
                            f"{md.relative_to(root)}:{lineno}: broken "
                            f"anchor -> {target}")
    return broken


def main(argv: list[str] | None = None) -> int:
    p = _cli.make_parser("check_links",
                         "markdown link + anchor checker (stdlib only)")
    p.add_argument("root", nargs="?", default=".",
                   help="repo root to scan (default: .)")
    args = p.parse_args(argv)
    root = pathlib.Path(args.root).resolve()
    if not root.is_dir():
        print(f"no such directory: {root}", file=sys.stderr)
        return EXIT_USAGE
    broken = check(root)
    n_files = len(md_files(root))
    payload = {"broken": broken,
               "counts": {"broken": len(broken), "files": n_files}}
    if broken:
        human = "\n".join(broken) + (
            f"\nFAILED: {len(broken)} broken link(s) across "
            f"{n_files} markdown file(s)")
    else:
        human = (f"OK: all relative links and anchors valid across "
                 f"{n_files} markdown file(s)")
    _cli.emit(payload, human, args.as_json, args.out)
    return EXIT_FINDINGS if broken else EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
