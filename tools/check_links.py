#!/usr/bin/env python
"""Markdown link check (CI docs job, stdlib only).

Walks the repo's markdown (README.md, ROADMAP.md, CHANGES.md, PAPER.md,
PAPERS.md, docs/**) and verifies every *relative* link target exists on
disk, resolved against the file containing the link.  External
(http/https/mailto) links and intra-page #anchors are skipped — CI must
not depend on the network.  Exits non-zero listing every broken link.

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — target captured up to the first unescaped ')'
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def md_files(root: pathlib.Path) -> list[pathlib.Path]:
    tops = ["README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md",
            "PAPERS.md", "ISSUE.md", "SNIPPETS.md"]
    files = [root / t for t in tops if (root / t).is_file()]
    files += sorted((root / "docs").rglob("*.md"))
    return files


def check(root: pathlib.Path) -> list[str]:
    broken = []
    for md in md_files(root):
        text = md.read_text(encoding="utf-8")
        in_code = False
        for lineno, line in enumerate(text.splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_code = not in_code
            if in_code:
                continue
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(_SKIP_PREFIXES):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    broken.append(
                        f"{md.relative_to(root)}:{lineno}: broken link "
                        f"-> {target}")
    return broken


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    broken = check(root)
    n_files = len(md_files(root))
    if broken:
        print("\n".join(broken))
        print(f"FAILED: {len(broken)} broken link(s) across "
              f"{n_files} markdown file(s)", file=sys.stderr)
        return 1
    print(f"OK: all relative links valid across {n_files} markdown file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
