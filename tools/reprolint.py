#!/usr/bin/env python3
"""reprolint — static hazard analysis for the serving stack.

    python tools/reprolint.py src/              # human findings, exit 1 if any
    python tools/reprolint.py src/ --json       # machine-readable
    python tools/reprolint.py src/ --write-baseline   # accept current debt

Rules (see docs/ARCHITECTURE.md "Static analysis"): jit-closure-capture,
recompile-hazard, host-sync, kernel-twin-parity, layout-conformance.
Suppress inline with ``# reprolint: disable=<rule> -- <rationale>``;
a suppression without a rationale is itself a finding.

AST + jax.eval_shape only — never executes a kernel.
"""

from __future__ import annotations

import sys
from pathlib import Path

import _cli
from _cli import EXIT_FINDINGS, EXIT_OK, EXIT_USAGE

_cli.ensure_src_on_path()

DEFAULT_BASELINE = _cli.REPO_ROOT / "tools" / "reprolint_baseline.json"


def main(argv: list[str] | None = None) -> int:
    p = _cli.make_parser("reprolint",
                         "static hazard analyzer for the jax/pallas "
                         "serving stack")
    p.add_argument("root", nargs="?", default="src",
                   help="directory tree to scan (default: src)")
    p.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                   help="baseline file of accepted fingerprints")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline (report all findings)")
    p.add_argument("--write-baseline", action="store_true",
                   help="accept current findings into the baseline")
    p.add_argument("--no-allowlist", action="store_true",
                   help="also report findings the config allowlist "
                        "silences (audit mode)")
    p.add_argument("--disable", action="append", default=[],
                   metavar="RULE", help="disable a rule id (repeatable)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    args = p.parse_args(argv)

    from repro.analysis import api
    from repro.analysis.core import write_baseline

    if args.list_rules:
        for rid in api.RULE_IDS:
            print(f"{rid:22s} {api.RULE_DOCS[rid]}")
        return EXIT_OK

    bad = set(args.disable) - set(api.RULE_IDS)
    if bad:
        print(f"unknown rule id(s): {sorted(bad)}", file=sys.stderr)
        return EXIT_USAGE
    root = Path(args.root)
    if not root.exists():
        print(f"no such path: {root}", file=sys.stderr)
        return EXIT_USAGE

    report = api.run(
        root, disable=set(args.disable),
        baseline=None if args.no_baseline else args.baseline,
        use_allowlist=not args.no_allowlist)

    if args.write_baseline:
        write_baseline(args.baseline, report.findings)
        print(f"baseline: {len(report.findings)} fingerprint(s) -> "
              f"{args.baseline}")
        return EXIT_OK

    lines = [f.render() for f in report.findings]
    summary = (f"reprolint: {len(report.findings)} finding(s), "
               f"{len(report.suppressed)} suppressed, "
               f"{len(report.allowlisted)} allowlisted, "
               f"{len(report.baselined)} baselined")
    human = "\n".join(lines + [summary]) if lines else summary + " — OK"
    _cli.emit(report.to_json(), human, args.as_json, args.out)
    return EXIT_FINDINGS if report.findings else EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
