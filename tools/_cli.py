"""Shared CLI plumbing for the tools/ checkers (check_links, reprolint).

Exit-code contract for every tool here:
  0  clean
  1  findings / broken checks
  2  usage or internal error
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

EXIT_OK = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2

REPO_ROOT = Path(__file__).resolve().parent.parent


def make_parser(prog: str, description: str) -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog=prog, description=description)
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit machine-readable JSON on stdout")
    p.add_argument("--out", type=Path, default=None, metavar="PATH",
                   help="also write the JSON report to PATH")
    return p


def emit(payload: dict, human: str, as_json: bool,
         out: Path | None = None) -> None:
    """Print either the JSON payload or the human rendering; --out gets
    the JSON regardless of the stdout mode (CI artifact)."""
    text = json.dumps(payload, indent=2)
    if out is not None:
        out.write_text(text + "\n")
    print(text if as_json else human)


def ensure_src_on_path() -> None:
    src = REPO_ROOT / "src"
    if str(src) not in sys.path:
        sys.path.insert(0, str(src))
