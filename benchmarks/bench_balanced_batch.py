"""Paper-integration bench: partitioner-based LM batch balancing vs the
naive dataloader (device-payload skew = SPMD straggler factor)."""
from __future__ import annotations

from repro.data import balanced, tokens

from .common import emit, timeit


def main() -> None:
    lengths = tokens.doc_lengths(0, 16384, 8192)
    for bins in [16, 256]:
        us = timeit(lambda b=bins: balanced.balanced_bins(lengths, b)[0],
                    warmup=0, iters=1)
        _, s_bal = balanced.balanced_bins(lengths, bins)
        _, s_naive = balanced.naive_bins(lengths, bins)
        emit(f"balanced_batch/slc/bins{bins}", us,
             f"skew={s_bal['skew']:.3f};naive={s_naive['skew']:.3f}")
