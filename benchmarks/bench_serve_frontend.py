"""Open-loop tail-latency benchmark for the async request plane.

The closed-loop bench (``bench_range_query``) answers "how fast can
the server chew pre-formed batches"; this one answers the serving
question: under an **open-loop** arrival stream — seeded Poisson
arrivals that keep coming whether or not earlier requests finished —
what latency does a single request see through queueing + batch
forming + execution, and what throughput does the plane sustain?

Per (placement × offered load) the run drives
``frontend.simulate_open_loop``: arrivals and every plane decision
(admission, DRR, deadline-or-full closing) happen in deterministic
virtual time from one seed, while each formed batch is executed for
real against the ``SpatialServer`` and its measured wall service time
advances the virtual clock (single-server queueing model).  Reported
rows carry p50/p99 queue/total latency, sustained QPS, batch fill
ratio, and the admission counters.  Exactness is asserted: every
response must equal the direct batched call for its query.

``--smoke`` shrinks the dataset and stream for CI.  ``--json`` merges
a ``frontend`` section into ``BENCH_serving.json`` (written by
``bench_range_query --json``; run that first in CI) rather than
clobbering the closed-loop rows.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import spatial_gen
from repro.serve import ServeConfig, SpatialServer
from repro.serve.frontend import (FrontendConfig, poisson_workload,
                                  simulate_open_loop)

from .common import emit

JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"


def _qboxes(rng, q, scale=0.05):
    c = rng.uniform(0, 1, (q, 2)).astype(np.float32)
    s = rng.uniform(0, scale, (q, 2)).astype(np.float32)
    return np.concatenate([c - s, c + s], axis=-1)


def _workload(qboxes, pts, rate, duration, seed):
    """80% range_counts / 20% knn mix over a pooled query set, with a
    70%-hot tenant skew — the shape of real multi-tenant traffic."""
    nq, npt = qboxes.shape[0], pts.shape[0]

    def make(rng, i):
        tenant = "hot" if rng.random() < 0.7 else f"t{i % 4}"
        if rng.random() < 0.8:
            return "range_counts", qboxes[i % nq], (), tenant
        return "knn", pts[i % npt], (8, 512), tenant

    return poisson_workload(rate, duration, make, seed=seed)


def _verify(server, workload, responses, want_counts, want_knn, nq, npt):
    """Every OK response must be bit-identical to the direct batched
    call for its query (the frontend exactness bar)."""
    for i, (a, r) in enumerate(zip(workload, responses)):
        if not r.ok:
            continue
        if a.kind == "range_counts":
            assert r.value == want_counts[i % nq], (i, r.value)
        else:
            nn_ids, nn_d2, _ = r.value
            np.testing.assert_array_equal(nn_ids, want_knn[0][i % npt])
            np.testing.assert_array_equal(nn_d2, want_knn[1][i % npt])


def main(smoke: bool = False, json_out: bool = False) -> None:
    n, payload = (1500, 130) if smoke else (6000, 120)
    duration = 0.25 if smoke else 1.0
    rates = (2000.0,) if smoke else (1000.0, 4000.0, 16000.0)
    fcfg = FrontendConfig(ladder=(64, 128, 256, 512), max_delay=0.002)

    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), n)
    rng = np.random.default_rng(42)
    qboxes = _qboxes(rng, 64)
    pts = rng.uniform(0, 1, (64, 2)).astype(np.float32)

    sections = []
    for placement in ("replicated", "sharded"):
        cfg = (ServeConfig() if placement == "replicated"
               else ServeConfig(placement="sharded", shards=4))
        srv = SpatialServer.from_method("bsp", mbrs, payload, cfg)
        want_counts = [int(c) for c in
                       np.asarray(srv.range_counts(jnp.asarray(qboxes))[0])]
        nn_w, d2_w, _, _ = srv.knn(jnp.asarray(pts), 8, max_cand=512)
        want_knn = (np.asarray(nn_w), np.asarray(d2_w))
        # warm the compiled ladder widths so the open-loop run measures
        # serving, not first-batch compilation
        for w in fcfg.ladder:
            srv.range_counts(jnp.zeros((w, 4), jnp.float32))
            srv.knn(jnp.zeros((w, 2), jnp.float32), 8, max_cand=512)

        for rate in rates:
            wl = _workload(qboxes, pts, rate, duration, seed=7)
            t0 = time.perf_counter()
            responses, metrics = simulate_open_loop(srv, wl, fcfg)
            wall_s = time.perf_counter() - t0
            _verify(srv, wl, responses, want_counts, want_knn,
                    qboxes.shape[0], pts.shape[0])
            snap = metrics.snapshot()
            done = snap["completed"]
            # sustained QPS: completions over the virtual makespan (the
            # open-loop clock the latencies are measured on)
            makespan = max((r.total_s + a.t for a, r in
                            zip(wl, responses) if r.ok), default=0.0)
            qps = done / makespan if makespan else 0.0
            row = dict(
                placement=placement, offered_qps=rate,
                requests=len(wl), completed=done,
                rejected=snap["rejected"], timed_out=snap["timed_out"],
                sustained_qps=round(qps, 1),
                p50_ms=round(snap["total_s"]["p50"] * 1e3, 3),
                p99_ms=round(snap["total_s"]["p99"] * 1e3, 3),
                queue_p99_ms=round(snap["queue_s"]["p99"] * 1e3, 3),
                execute_p99_ms=round(snap["execute_s"]["p99"] * 1e3, 3),
                batches=snap["batches"],
                batch_fill_ratio=snap["batch_fill_ratio"],
                padded_slots=snap["padded_slots"],
                queue_depth_max=snap["queue_depth_max"],
                wall_s=round(wall_s, 3),
            )
            sections.append(row)
            emit(f"frontend_open_loop/{placement}/rate{rate:.0f}",
                 snap["total_s"]["p50"] * 1e6,
                 f"p99_ms={row['p99_ms']};qps={row['sustained_qps']}"
                 f";fill={row['batch_fill_ratio']}"
                 f";rejected={row['rejected']}"
                 f";timed_out={row['timed_out']}"
                 f";batches={row['batches']}")

    if json_out:
        doc = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() else {}
        doc["frontend"] = dict(
            smoke=smoke, n_objects=n, duration_s=duration,
            max_delay_s=fcfg.max_delay, ladder=list(fcfg.ladder),
            backend=jax.default_backend(), rows=sections)
        JSON_PATH.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"# merged frontend section into {JSON_PATH}",
              file=sys.stderr)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, json_out="--json" in sys.argv)
