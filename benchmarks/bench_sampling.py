"""Fig 9: partition quality vs sampling rate γ (balance + λ)."""
from __future__ import annotations

import jax

from repro.core import metrics, sampling
from repro.data import spatial_gen

from .common import emit, timeit

N = 20000
GAMMAS = [0.01, 0.1, 0.5, 1.0]
METHODS = ["bsp", "slc", "bos"]


def main() -> None:
    key = jax.random.PRNGKey(0)
    mbrs = spatial_gen.dataset("osm", key, N)
    for m in METHODS:
        for g in GAMMAS:
            def run(mm=m, gg=g):
                res = sampling.sampled_partition(mm, mbrs, 400, gg,
                                                 jax.random.PRNGKey(1))
                return res
            us = timeit(lambda: run().parts.boxes, warmup=0, iters=1)
            res = run()
            counts, copies = sampling.evaluate_on_full(res, mbrs)
            std = float(metrics.balance_stddev(counts, res.parts.valid))
            lam = float(metrics.boundary_ratio(counts, res.parts.valid, N))
            emit(f"fig9_sampling/osm/{m}/g{g}", us,
                 f"std={std:.1f};lambda={lam:.4f}")
