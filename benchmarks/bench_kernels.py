"""Pallas kernel microbenches (interpret mode on CPU): kernel-vs-oracle
wall time + the derived bytes/FLOP terms the TPU roofline uses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hilbert as chil
from repro.kernels.hilbert import ops as hops
from repro.kernels.mbr_join import ops as mops, ref as mref
from repro.kernels.ssd import ops as sops

from .common import emit, timeit


def main() -> None:
    key = jax.random.PRNGKey(0)
    # mbr_join: 4096 x 4096 pairs
    c = jax.random.uniform(key, (4096, 2))
    sz = jax.random.uniform(jax.random.fold_in(key, 1), (4096, 2)) * 0.05
    r = jnp.concatenate([c - sz, c + sz], -1)
    us_k = timeit(lambda: mops.join_count(r, r))
    us_r = timeit(lambda: mref.intersect_count(r, r))
    # 4096² pair tests ≈ 8 compares each → VPU-bound: bytes = 2·4·4096·4
    emit("kernel/mbr_join/4096x4096", us_k,
         f"interp_vs_ref={us_k / us_r:.2f}")

    # hilbert: 1M points
    pts = jax.random.uniform(key, (1 << 20, 2))
    bounds = jnp.array([0.0, 0.0, 1.0, 1.0])
    us_k = timeit(lambda: hops.hilbert_keys(pts, bounds))
    us_r = timeit(lambda: chil.hilbert_keys(pts, bounds))
    emit("kernel/hilbert/1M", us_k, f"interp_vs_ref={us_k / us_r:.2f}")

    # ssd: (B=2, L=1024, H=8, P=64, S=128)
    x = jax.random.normal(key, (2, 1024, 8, 64)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(key, (2, 1024, 8))) * 0.1
    a_log = -jnp.exp(jax.random.normal(key, (8,)) * 0.3)
    bm = jax.random.normal(key, (2, 1024, 1, 128)) * 0.3
    cm = jax.random.normal(key, (2, 1024, 1, 128)) * 0.3
    us_k = timeit(lambda: sops.ssd_forward(x, dt, a_log, bm, cm,
                                           use_kernel=True))
    us_e = timeit(lambda: sops.ssd_forward(x, dt, a_log, bm, cm,
                                           use_kernel=False))
    emit("kernel/ssd/B2L1024H8", us_k, f"interp_vs_einsum={us_k / us_e:.2f}")
