"""Range/kNN serving throughput across all six layouts × both datasets,
pruned (routed candidate-tile probe, with the intra-tile local index)
vs unindexed (``ServeConfig(local_index="off")``, same routing, linear
tile sweep) vs dense (all-tile oracle sweep) vs sharded (owner-routed
all_to_all exchange) — the paper's layout-quality thesis measured as
queries/sec, not just mean fan-out: the better the layout routes, the
smaller each query's candidate list and the larger the pruned speedup;
the local index then skips dead 128-member chunks *inside* each
candidate tile (chunk-skip rate reported per layout, for the default
``"x"`` sort and the ``"hilbert"`` sort — square-ish chunk boxes vs
x-strips).  Streaming rows time ``append`` throughput into reserved
slack (and the scattered device bytes per appended object — the O(M)
ingest bar: flat per object, independent of the T×cap layout size) and
the cost of a forced tile-overflow re-stage.  The
``interleaved_stream`` scenario runs a sustained append/delete/update/
query mix against one server and reports ingest ops/sec and the query
p50 under churn (with the compaction policy live).  The ``heat_plan``
rows replay a skewed hotspot stream and compare exchange messages under
the count-balanced shard plan, after heat-aware co-location of the same
server, and on a ``placement="heat"`` server (co-location + hot-tile
replicas) — with bit-identity asserted against the dense reference on
every leg, and a hard check on ``osm`` that co-location never adds
exchange traffic.

``--smoke`` runs a small configuration (CI: exercises the pruned,
local-index, and sharded paths and the exactness assertions on every
push without the full timing).  ``--devices N`` forces N virtual host
devices (``--xla_force_host_platform_device_count``) so the sharded
rows run the real mesh exchange; without it the exchange runs in
simulation over 4 virtual owners.  ``--json`` additionally writes
``BENCH_serving.json`` at the repo root (queries/sec, fan-out,
chunk-skip rate per layout × dataset) so the perf trajectory is
recorded run over run; CI uploads it as an artifact.
"""
from __future__ import annotations

import json
import os
import pathlib
import sys
import time

if __name__ == "__main__" and "--devices" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={_n}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import spatial_gen
from repro.query import range as range_mod
from repro.serve import PlacementPolicy, ServeConfig, SpatialServer

from .common import emit, timeit, timeit_many

METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]
DATASETS = ["osm", "pi"]
JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serving.json"


def _qboxes(key, q, scale=0.05):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


def _hot_qboxes(key, q, frac=0.85, hot_scale=0.14, scale=0.05):
    """Skewed query stream for the heat-placement rows: ``frac`` of the
    query centres cluster inside one small hotspot patch and carry
    larger boxes (``hot_scale``), so each hot query's candidates span
    several tiles — the multi-owner fan-out that co-location + hot-tile
    replicas exist to collapse.  The rest stay uniform."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n_hot = int(q * frac)
    ctr = jax.random.uniform(k1, (2,)) * 0.6 + 0.2
    c_hot = ctr + (jax.random.uniform(k2, (n_hot, 2)) - 0.5) * 0.2
    c_cold = jax.random.uniform(k3, (q - n_hot, 2))
    c = jnp.concatenate([c_hot, c_cold], axis=0)
    s = jax.random.uniform(k4, (q, 2)) * scale
    s = s.at[:n_hot].set(
        jax.random.uniform(jax.random.fold_in(k4, 1), (n_hot, 2))
        * hot_scale + 0.02)
    return jnp.concatenate([c - s, c + s], axis=-1)


def _heat_experiment(ds, m, mbrs, qb_hot, want_hot, payload, shards,
                     mesh, smoke) -> dict:
    """Heat-plan delta on the skewed stream: exchange messages under the
    count-balanced shard plan, after heat-aware co-location of the same
    server, and on a fresh ``placement="heat"`` server (co-location +
    hot-tile replicas).  Every answer must stay bit-identical to the
    dense reference — placement only moves bytes, never results."""
    ssrv = SpatialServer.from_method(
        m, mbrs, payload, ServeConfig(placement="sharded", shards=shards),
        mesh=mesh)
    counts, st0 = ssrv.range_counts(qb_hot)
    assert [int(c) for c in counts] == want_hot, (ds, m, "hot/balanced")
    for _ in range(4):      # accrue heat through the public batched path
        ssrv.range_counts(qb_hot)
    ssrv.rebalance()
    counts, st1 = ssrv.range_counts(qb_hot)
    assert [int(c) for c in counts] == want_hot, (ds, m, "hot/colocated")
    if ds == "osm":     # CI smoke gate: co-location must not add traffic
        assert st1["messages"] <= st0["messages"], \
            (m, st0["messages"], st1["messages"])

    top = 2 if smoke else 4
    hsrv = SpatialServer.from_method(
        m, mbrs, payload,
        ServeConfig(placement="heat", shards=shards,
                    policy=PlacementPolicy(heat_decay=0.9,
                                           replicate_top=top)),
        mesh=mesh)
    for _ in range(5):
        hsrv.range_counts(qb_hot)
    t0 = time.perf_counter()
    rep = hsrv.rebalance()
    dt_rb = time.perf_counter() - t0
    counts, st2 = hsrv.range_counts(qb_hot)
    assert [int(c) for c in counts] == want_hot, (ds, m, "hot/heat")
    emit(f"heat_plan/{ds}/{m}/d{shards}", dt_rb * 1e6,
         f"msgs_balanced={st0['messages']}"
         f";msgs_colocated={st1['messages']}"
         f";msgs_heat={st2['messages']}"
         f";replicated={rep['replicated_tiles']}"
         f";moved={rep['moved_tiles']}"
         f";imbalance={st0['probe_load_imbalance']:.2f}"
         f"->{st2['probe_load_imbalance']:.2f}"
         f";xbytes={st0['exchange_bytes']}->{st2['exchange_bytes']}")
    return dict(
        exchange_messages_hot_balanced=int(st0["messages"]),
        exchange_messages_hot_colocated=int(st1["messages"]),
        exchange_messages_hot_heat=int(st2["messages"]),
        exchange_bytes_hot=int(st0["exchange_bytes"]),
        exchange_bytes_hot_heat=int(st2["exchange_bytes"]),
        probe_load_imbalance_hot=round(
            float(st0["probe_load_imbalance"]), 3),
        probe_load_imbalance_hot_heat=round(
            float(st2["probe_load_imbalance"]), 3),
        heat_replicated_tiles=int(rep["replicated_tiles"]),
        heat_moved_tiles=int(rep["moved_tiles"]),
        heat_rebalance_ms=round(dt_rb * 1e3, 2))


def _interleaved_stream(ds: str, mbrs, qb, payload: int,
                        smoke: bool) -> dict:
    """Sustained append/delete/update/query churn against one server:
    ingest ops/sec and the query p50 while the alive mask and the
    compaction policy are doing real work."""
    rng = np.random.default_rng(0)
    n = int(mbrs.shape[0])
    head = mbrs[: 4 * n // 5]
    srv = SpatialServer.from_method(
        "bsp", head, payload,
        ServeConfig(slack=1024, compact_dead_frac=0.4))
    live = np.arange(head.shape[0])
    next_id = head.shape[0]
    rounds, m_app, m_del, m_upd = (4, 64, 32, 16) if smoke \
        else (12, 128, 64, 32)
    q_times = []

    def one_round():
        nonlocal live, next_id
        lo = rng.uniform(0.0, 1.0, (m_app, 2)).astype(np.float32)
        ex = rng.uniform(0.0, 0.01, (m_app, 2)).astype(np.float32)
        srv.append(np.concatenate([lo, lo + ex], axis=1))
        live = np.concatenate([live, np.arange(next_id, next_id + m_app)])
        next_id += m_app
        dels = rng.choice(live, m_del, replace=False)
        srv.delete(dels)
        live = np.setdiff1d(live, dels)
        upd = rng.choice(live, m_upd, replace=False)
        lo = rng.uniform(0.0, 1.0, (m_upd, 2)).astype(np.float32)
        ex = rng.uniform(0.0, 0.01, (m_upd, 2)).astype(np.float32)
        srv.update(upd, np.concatenate([lo, lo + ex], axis=1))
        tq = time.perf_counter()
        np.asarray(srv.range_counts(qb)[0])
        q_times.append(time.perf_counter() - tq)

    one_round()            # warmup: one scatter compile per size bucket
    q_times.clear()
    ops = rounds * (m_app + m_del + m_upd)
    t0 = time.perf_counter()
    for _ in range(rounds):
        one_round()
    total = time.perf_counter() - t0
    assert srv.stats["n"] == live.size
    p50_us = float(np.median(q_times) * 1e6)
    emit(f"interleaved_stream/{ds}/bsp", total * 1e6,
         f"ingest_ops_per_s={ops / max(total, 1e-9):.0f}"
         f";query_p50_us={p50_us:.1f}"
         f";compactions={srv.stats['compactions']}"
         f";restages={srv.stats['restages']};n_final={srv.stats['n']}")
    return dict(dataset=ds, layout="bsp", rounds=rounds,
                ingest_ops_per_s=round(ops / max(total, 1e-9), 1),
                query_p50_us=round(p50_us, 1),
                compactions=int(srv.stats["compactions"]),
                restages=int(srv.stats["restages"]),
                n_final=int(srv.stats["n"]))


def main(smoke: bool = False, json_out: bool = False) -> None:
    n, q, k, payload = (1200, 128, 4, 100) if smoke else (6000, 512, 8, 120)
    iters = 5 if smoke else 15      # range counts are cheap; drown drift
    if jax.device_count() > 1:
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()), ("d",))
        shards = jax.device_count()
    else:
        mesh, shards = None, 4          # exchange in vmap simulation
    rows, stream_rows = [], []
    for ds in DATASETS:
        mbrs = spatial_gen.dataset(ds, jax.random.PRNGKey(0), n)
        qb = _qboxes(jax.random.PRNGKey(1), q)
        pts = jax.random.uniform(jax.random.PRNGKey(2), (q, 2))
        ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qb))
        want = [len(r) for r in ref]
        qb_hot = _hot_qboxes(jax.random.PRNGKey(3), q)
        ref_hot = range_mod.range_query_ref(np.asarray(mbrs),
                                            np.asarray(qb_hot))
        want_hot = [len(r) for r in ref_hot]
        for m in METHODS:
            srv = SpatialServer.from_method(m, mbrs, payload, mesh=mesh)
            usrv = SpatialServer.from_method(
                m, mbrs, payload, ServeConfig(local_index="off"),
                mesh=mesh)
            ssrv = SpatialServer.from_method(
                m, mbrs, payload,
                ServeConfig(placement="sharded", shards=shards),
                mesh=mesh)
            hsrv = SpatialServer.from_method(
                m, mbrs, payload, ServeConfig(local_index="hilbert"),
                mesh=mesh)
            counts, rstats = srv.range_counts(qb)
            assert [int(c) for c in counts] == want, (ds, m, "local")
            ucounts, _ = usrv.range_counts(qb)
            assert [int(c) for c in ucounts] == want, (ds, m, "unindexed")
            dcounts, _ = srv.range_counts(qb, pruned=False)
            assert [int(c) for c in dcounts] == want, (ds, m, "dense")
            scounts, sstats = ssrv.range_counts(qb)
            assert [int(c) for c in scounts] == want, (ds, m, "sharded")
            hcounts, _ = hsrv.range_counts(qb)
            assert [int(c) for c in hcounts] == want, (ds, m, "hilbert")
            skip_rate = srv.chunk_skip_rate(qb)
            skip_rate_h = hsrv.chunk_skip_rate(qb)

            # streaming: stage 90% with slack, stream the tail in, then
            # force one tile overflow and time the re-stage
            head, tail = mbrs[: 9 * n // 10], np.asarray(mbrs[9 * n // 10:])
            asrv = SpatialServer.from_method(m, head, payload,
                                             ServeConfig(slack=512))
            bs = max(64, tail.shape[0] // 8)
            # warmup on a throwaway server: the eager scatter steps are
            # cached by shape globally, and identical batches produce
            # identical size buckets — the timed loop below runs warm
            wsrv = SpatialServer.from_method(m, head, payload,
                                             ServeConfig(slack=512))
            for i in range(0, tail.shape[0], bs):
                wsrv.append(tail[i:i + bs])
            del wsrv
            append_bytes, append_rates = 0, []
            t0 = time.perf_counter()
            for i in range(0, tail.shape[0], bs):
                chunk = tail[i:i + bs]
                tb0 = time.perf_counter()
                rep = asrv.append(chunk)
                append_rates.append(
                    chunk.shape[0] / max(time.perf_counter() - tb0, 1e-9))
                append_bytes += rep["bytes_transferred"]
            dt_append = time.perf_counter() - t0
            append_rate = float(np.median(append_rates))
            acounts, _ = asrv.range_counts(qb)
            assert [int(c) for c in acounts] == want, (ds, m, "append")
            append_restages = asrv.stats["restages"]
            # cap+1 copies into one tile guarantees the overflow path
            tb = np.asarray(asrv.parts.boxes)[0]
            ctr = [(tb[0] + tb[2]) / 2, (tb[1] + tb[3]) / 2]
            burst = np.tile(np.asarray(ctr + ctr, np.float32),
                            (asrv.stats["cap"] + 1, 1))
            t0 = time.perf_counter()
            rep = asrv.append(burst)
            dt_restage = time.perf_counter() - t0
            assert rep["restaged"], (ds, m, "restage")

            # interleaved: the local-vs-unindexed and pruned-vs-sharded
            # deltas are the point, so machine drift must hit all legs
            # equally
            us_p, us_u, us_d, us_s = timeit_many(
                [lambda: srv.range_counts(qb)[0],
                 lambda: usrv.range_counts(qb)[0],
                 lambda: srv.range_counts(qb, pruned=False)[0],
                 lambda: ssrv.range_counts(qb)[0]],
                warmup=1, iters=iters)
            emit(f"range_serve/{ds}/{m}/q{q}", us_p,
                 f"qps={q / (us_p * 1e-6):.0f}"
                 f";fanout={rstats['fanout_mean']:.2f}"
                 f";f_max={rstats['f_max']};tiles={srv.stats['t']}"
                 f";chunks={srv.stats['chunks']}"
                 f";chunk_skip={skip_rate:.3f}"
                 f";chunk_skip_hilbert={skip_rate_h:.3f}"
                 f";unindexed_us={us_u:.1f}"
                 f";dense_us={us_d:.1f};speedup={us_d / us_p:.2f}")
            emit(f"range_serve_sharded/{ds}/{m}/q{q}/d{shards}", us_s,
                 f"qps={q / (us_s * 1e-6):.0f}"
                 f";msgs={sstats['messages']};f_local={sstats['f_local']}"
                 f";xbytes={sstats['exchange_bytes']}"
                 f";imbalance={sstats['probe_load_imbalance']:.2f}"
                 f";dev_bytes={ssrv.resident_tile_bytes()}"
                 f";repl_bytes={srv.resident_tile_bytes()}"
                 f";mem_ratio={srv.resident_tile_bytes() / max(ssrv.resident_tile_bytes(), 1):.2f}")

            _, _, _, kstats = srv.knn(pts, k)
            us_pk = timeit(lambda: srv.knn(pts, k)[0], warmup=1, iters=3)
            us_dk = timeit(lambda: srv.knn(pts, k, pruned=False)[0],
                           warmup=1, iters=3)
            us_sk = timeit(lambda: ssrv.knn(pts, k)[0], warmup=1, iters=3)
            emit(f"append_serve/{ds}/{m}", dt_append * 1e6,
                 f"objs_per_s={append_rate:.0f}"
                 f";bytes_per_obj={append_bytes / tail.shape[0]:.1f}"
                 f";restages={append_restages}"
                 f";restage_ms={dt_restage * 1e3:.1f}")
            emit(f"knn_serve/{ds}/{m}/k{k}", us_pk,
                 f"qps={q / (us_pk * 1e-6):.0f}"
                 f";fanout={kstats['fanout_mean']:.2f}"
                 f";f_max={kstats['f_max']};rounds={kstats['rounds']}"
                 f";dense_us={us_dk:.1f};speedup={us_dk / us_pk:.2f}"
                 f";sharded_us={us_sk:.1f}")
            rows.append(dict(
                dataset=ds, layout=m, queries=q,
                range_qps=round(q / (us_p * 1e-6), 1),
                range_qps_unindexed=round(q / (us_u * 1e-6), 1),
                range_qps_dense=round(q / (us_d * 1e-6), 1),
                range_qps_sharded=round(q / (us_s * 1e-6), 1),
                knn_qps=round(q / (us_pk * 1e-6), 1),
                knn_qps_dense=round(q / (us_dk * 1e-6), 1),
                fanout_mean=round(rstats["fanout_mean"], 3),
                f_max=int(rstats["f_max"]),
                knn_rounds=int(kstats["rounds"]),
                tiles=int(srv.stats["t"]), chunks=int(srv.stats["chunks"]),
                chunk_skip_rate=round(skip_rate, 4),
                chunk_skip_rate_hilbert=round(skip_rate_h, 4),
                append_objs_per_s=round(append_rate, 1),
                append_bytes_per_obj=round(
                    append_bytes / tail.shape[0], 1),
                append_restages=int(append_restages),
                restage_ms=round(dt_restage * 1e3, 2),
                exchange_messages=int(sstats["messages"]),
                exchange_bytes=int(sstats["exchange_bytes"]),
                probe_load_imbalance=round(
                    float(sstats["probe_load_imbalance"]), 3),
                shard_bytes_per_device=int(ssrv.resident_tile_bytes()),
            ))
            rows[-1].update(_heat_experiment(
                ds, m, mbrs, qb_hot, want_hot, payload, shards, mesh,
                smoke))
        stream_rows.append(_interleaved_stream(ds, mbrs, qb, payload, smoke))
    if json_out:
        # aggregate the local-vs-unindexed comparison per dataset: the
        # per-layout ratios carry ±5% machine noise even interleaved,
        # the geomean is the stable "no worse than unindexed" signal
        summary = {}
        for ds in DATASETS:
            ratios = [r["range_qps"] / r["range_qps_unindexed"]
                      for r in rows if r["dataset"] == ds]
            prod = 1.0
            for x in ratios:
                prod *= x
            summary[f"{ds}_range_local_over_unindexed_geomean"] = round(
                prod ** (1.0 / len(ratios)), 4)
            summary[f"{ds}_chunk_skip_rate_mean"] = round(
                sum(r["chunk_skip_rate"] for r in rows
                    if r["dataset"] == ds) / len(ratios), 4)
            summary[f"{ds}_chunk_skip_rate_hilbert_mean"] = round(
                sum(r["chunk_skip_rate_hilbert"] for r in rows
                    if r["dataset"] == ds) / len(ratios), 4)
            # geomean exchange-message cut of the heat plan vs the
            # count-balanced shard plan on the skewed hotspot stream —
            # the headline number for query-heat-aware placement
            hratios = [r["exchange_messages_hot_balanced"]
                       / max(r["exchange_messages_hot_heat"], 1)
                       for r in rows if r["dataset"] == ds]
            hprod = 1.0
            for x in hratios:
                hprod *= x
            hgeo = hprod ** (1.0 / len(hratios))
            summary[f"{ds}_heat_exchange_messages_cut_geomean"] = round(
                1.0 - 1.0 / hgeo, 4)
        payload_doc = dict(
            bench="serving", smoke=smoke, n_objects=n, batch_queries=q,
            knn_k=k, payload=payload, backend=jax.default_backend(),
            devices=jax.device_count(), shards=shards, summary=summary,
            rows=rows, interleaved_stream=stream_rows)
        JSON_PATH.write_text(json.dumps(payload_doc, indent=2) + "\n")
        print(f"# wrote {JSON_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, json_out="--json" in sys.argv)
