"""Range/kNN serving throughput and per-query partition fan-out across
all six layouts — the paper's layout-quality thesis on the workloads of
§6 (queries/sec from the batched server, fan-out as the boundary-object
cost made workload-facing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import spatial_gen
from repro.query import range as range_mod
from repro.serve import SpatialServer

from .common import emit, timeit

N = 6000
Q = 512
K = 8
METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]


def _qboxes(key, q, scale=0.05):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


def main() -> None:
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)
    qb = _qboxes(jax.random.PRNGKey(1), Q)
    pts = jax.random.uniform(jax.random.PRNGKey(2), (Q, 2))
    ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qb))
    want = [len(r) for r in ref]
    for m in METHODS:
        srv = SpatialServer.from_method(m, mbrs, 300)
        counts, rstats = srv.range_counts(qb)
        assert [int(c) for c in counts] == want, m

        us = timeit(lambda: srv.range_counts(qb)[0], warmup=1, iters=3)
        qps = Q / (us * 1e-6)
        emit(f"range_serve/osm/{m}/q{Q}", us,
             f"qps={qps:.0f};fanout={rstats['fanout_mean']:.2f}")

        _, _, _, kstats = srv.knn(pts, K)
        us = timeit(lambda: srv.knn(pts, K)[0], warmup=1, iters=3)
        qps = Q / (us * 1e-6)
        emit(f"knn_serve/osm/{m}/k{K}", us,
             f"qps={qps:.0f};fanout={kstats['fanout_mean']:.2f}")


if __name__ == "__main__":
    main()
