"""Range/kNN serving throughput across all six layouts × both datasets,
pruned (routed candidate-tile probe) vs dense (all-tile oracle sweep)
vs sharded (owner-routed all_to_all exchange) — the paper's
layout-quality thesis measured as queries/sec, not just mean fan-out:
the better the layout routes, the smaller each query's candidate list
and the larger the pruned speedup.  Sharded rows also report the
per-device resident tile bytes the exchange divides by D.

``--smoke`` runs a small configuration (CI: exercises the pruned and
sharded paths and the exactness assertions on every push without the
full timing).  ``--devices N`` forces N virtual host devices
(``--xla_force_host_platform_device_count``) so the sharded rows run
the real mesh exchange; without it the exchange runs in simulation
over 4 virtual owners.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__" and "--devices" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={_n}")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import spatial_gen
from repro.query import range as range_mod
from repro.serve import SpatialServer

from .common import emit, timeit

METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]
DATASETS = ["osm", "pi"]


def _qboxes(key, q, scale=0.05):
    k1, k2 = jax.random.split(key)
    c = jax.random.uniform(k1, (q, 2))
    s = jax.random.uniform(k2, (q, 2)) * scale
    return jnp.concatenate([c - s, c + s], axis=-1)


def main(smoke: bool = False) -> None:
    n, q, k, payload = (1200, 128, 4, 100) if smoke else (6000, 512, 8, 120)
    if jax.device_count() > 1:
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()), ("d",))
        shards = jax.device_count()
    else:
        mesh, shards = None, 4          # exchange in vmap simulation
    for ds in DATASETS:
        mbrs = spatial_gen.dataset(ds, jax.random.PRNGKey(0), n)
        qb = _qboxes(jax.random.PRNGKey(1), q)
        pts = jax.random.uniform(jax.random.PRNGKey(2), (q, 2))
        ref = range_mod.range_query_ref(np.asarray(mbrs), np.asarray(qb))
        want = [len(r) for r in ref]
        for m in METHODS:
            srv = SpatialServer.from_method(m, mbrs, payload, mesh=mesh)
            ssrv = SpatialServer.from_method(m, mbrs, payload, mesh=mesh,
                                             sharded=True, shards=shards)
            counts, rstats = srv.range_counts(qb)
            assert [int(c) for c in counts] == want, (ds, m, "pruned")
            dcounts, _ = srv.range_counts(qb, pruned=False)
            assert [int(c) for c in dcounts] == want, (ds, m, "dense")
            scounts, sstats = ssrv.range_counts(qb)
            assert [int(c) for c in scounts] == want, (ds, m, "sharded")

            us_p = timeit(lambda: srv.range_counts(qb)[0],
                          warmup=1, iters=3)
            us_d = timeit(lambda: srv.range_counts(qb, pruned=False)[0],
                          warmup=1, iters=3)
            us_s = timeit(lambda: ssrv.range_counts(qb)[0],
                          warmup=1, iters=3)
            emit(f"range_serve/{ds}/{m}/q{q}", us_p,
                 f"qps={q / (us_p * 1e-6):.0f}"
                 f";fanout={rstats['fanout_mean']:.2f}"
                 f";f_max={rstats['f_max']};tiles={srv.stats['t']}"
                 f";dense_us={us_d:.1f};speedup={us_d / us_p:.2f}")
            emit(f"range_serve_sharded/{ds}/{m}/q{q}/d{shards}", us_s,
                 f"qps={q / (us_s * 1e-6):.0f}"
                 f";msgs={sstats['messages']};f_local={sstats['f_local']}"
                 f";dev_bytes={ssrv.resident_tile_bytes()}"
                 f";repl_bytes={srv.resident_tile_bytes()}"
                 f";mem_ratio={srv.resident_tile_bytes() / max(ssrv.resident_tile_bytes(), 1):.2f}")

            _, _, _, kstats = srv.knn(pts, k)
            us_p = timeit(lambda: srv.knn(pts, k)[0], warmup=1, iters=3)
            us_d = timeit(lambda: srv.knn(pts, k, pruned=False)[0],
                          warmup=1, iters=3)
            us_sk = timeit(lambda: ssrv.knn(pts, k)[0], warmup=1, iters=3)
            emit(f"knn_serve/{ds}/{m}/k{k}", us_p,
                 f"qps={q / (us_p * 1e-6):.0f}"
                 f";fanout={kstats['fanout_mean']:.2f}"
                 f";f_max={kstats['f_max']}"
                 f";dense_us={us_d:.1f};speedup={us_d / us_p:.2f}"
                 f";sharded_us={us_sk:.1f}")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)
