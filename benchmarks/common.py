"""Shared benchmark helpers.  Output contract: ``name,us_per_call,derived``."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of ``fn(*args)`` in microseconds (blocks on jax)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def timeit_many(fns, warmup: int = 1, iters: int = 3) -> list[float]:
    """Median wall times (µs) of several callables, **interleaved**: each
    iteration times every fn once, in order, so slow machine-load drift
    hits all of them equally — the fair way to compare two executors of
    the same query (sequential ``timeit`` calls confound drift with the
    executor difference)."""
    for _ in range(warmup):
        for fn in fns:
            jax.block_until_ready(fn())
    times: list[list[float]] = [[] for _ in fns]
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times[i].append(time.perf_counter() - t0)
    return [sorted(t)[len(t) // 2] * 1e6 for t in times]


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
