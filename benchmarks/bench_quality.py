"""Figs 3 & 4: partition balance (stddev) and boundary ratio λ per
algorithm × granularity × dataset."""
from __future__ import annotations

import jax

from repro.core import metrics
from repro.core.partition import api, partition_counts
from repro.data import spatial_gen

from .common import emit, timeit

N = 20000
FRACTIONS = [0.001, 0.005, 0.01, 0.05]   # of N (paper Table 2 subset)
METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]


def main() -> None:
    key = jax.random.PRNGKey(0)
    for ds in ["osm", "pi"]:
        mbrs = spatial_gen.dataset(ds, key, N)
        for f in FRACTIONS:
            payload = max(8, int(f * N))
            for m in METHODS:
                parts = api.partition(m, mbrs, payload)
                counts, _ = partition_counts(mbrs, parts)
                std = float(metrics.balance_stddev(counts, parts.valid))
                lam = float(metrics.boundary_ratio(counts, parts.valid, N))
                us = timeit(lambda mm=m: api.partition(mm, mbrs, payload),
                            warmup=1, iters=1)
                emit(f"fig3_balance/{ds}/{m}/b{payload}", us, f"{std:.2f}")
                emit(f"fig4_lambda/{ds}/{m}/b{payload}", us, f"{lam:.4f}")
