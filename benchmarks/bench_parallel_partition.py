"""Fig 8: MapReduce-style parallel partitioning.

Benches see one device, so true scaling lives in the dry-run/tests; here
we measure the SPMD pipeline end-to-end on the local mesh and derive the
phase decomposition (sample / map+shuffle / reduce) — the quantity the
paper's Fig 8 scaling follows (reduce is embarrassingly parallel; the
sampled coarse split is the serial fraction)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import geometry, hilbert
from repro.data import spatial_gen
from repro.query import parallel_partition as pp

from .common import emit, timeit

N = 50000


def main() -> None:
    key = jax.random.PRNGKey(0)
    mbrs = spatial_gen.dataset("osm", key, N)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))

    us_total = timeit(
        lambda: pp.parallel_partition(key, mbrs, 500, mesh, "d")[0].boxes,
        warmup=1, iters=2)
    emit(f"fig8_parallel/osm/pipeline/n{N}", us_total, "end-to-end")

    us_sample = timeit(lambda: pp.coarse_splitters(key, mbrs, 8),
                       warmup=1, iters=3)
    emit(f"fig8_parallel/osm/phase_sample/n{N}", us_sample,
         f"serial_frac={us_sample / us_total:.3f}")

    keys_fn = jax.jit(lambda m: hilbert.hilbert_keys(
        geometry.centroids(m), geometry.universe(m)))
    us_map = timeit(keys_fn, mbrs, warmup=1, iters=3)
    emit(f"fig8_parallel/osm/phase_map_keys/n{N}", us_map,
         f"parallel_frac={us_map / us_total:.3f}")
