"""Fig 5: spatial-join performance under each partitioning method ×
granularity (real execution on the local mesh)."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.data import spatial_gen
from repro.kernels.mbr_join import ref as mref
from repro.query import engine

from .common import emit, timeit

N = 6000
METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]


def main() -> None:
    r = spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)
    s = spatial_gen.dataset("osm", jax.random.PRNGKey(1), N)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    oracle = int(mref.intersect_count(r, s))
    for payload in [200, 800]:
        for m in METHODS:
            plan = engine.plan_join(m, r, s, payload, 1)
            if plan.stats["overlapping"]:
                fn = lambda: engine.run_join_pairs_masj(  # noqa: E731
                    plan, mesh, "d", max_pairs_per_tile=16384)
            else:
                fn = lambda: engine.run_join_count(  # noqa: E731
                    plan, mesh, "d", dedup="rp")
            cnt = fn()
            assert cnt == oracle, (m, payload, cnt, oracle)
            us = timeit(fn, warmup=1, iters=3)
            emit(f"fig5_join/osm/{m}/b{payload}", us,
                 f"skew={plan.stats['skew']:.2f}")
