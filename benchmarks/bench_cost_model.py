"""§2.3 cost model: predicted optimal granularity vs measured join time."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import cost_model, metrics
from repro.core.partition import api, partition_counts
from repro.data import spatial_gen
from repro.query import engine

from .common import emit, timeit

N = 4000


def main() -> None:
    r = spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)
    s = spatial_gen.dataset("osm", jax.random.PRNGKey(1), N)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    merged = jax.numpy.concatenate([r, s])

    ks, alphas, times = [], [], []
    for payload in [100, 400, 1600]:
        parts = api.partition("bos", merged, payload)
        counts, _ = partition_counts(merged, parts)
        lam = float(metrics.boundary_ratio(counts, parts.valid, 2 * N))
        plan = engine.plan_join("bos", r, s, payload, 1)
        us = timeit(lambda: engine.run_join_count(plan, mesh, "d"),
                    warmup=1, iters=2)
        ks.append(int(parts.k()))
        alphas.append(lam)
        times.append(us)
        emit(f"cost_model/measured/b{payload}", us,
             f"k={ks[-1]};alpha={lam:.3f}")

    pred = [float(cost_model.join_cost(N, N, k, a)) for k, a in
            zip(ks, alphas)]
    # report rank agreement between model and measurement
    agree = int(np.argmin(pred) == np.argmin(times))
    emit("cost_model/rank_agreement", 0.0, f"argmin_match={agree}")
