"""§Perf hillclimb 3 — the paper's own workload, measured end-to-end.

Iterates the spatial-join pipeline from the paper-faithful baseline to
the beyond-paper optimized configuration, reporting measured wall time
per stage (8 simulated devices when run via tests/examples; local mesh
here):

  v0  FG layout + round-robin packing + MASJ materialise/sort dedup
      (the literal Hadoop-GIS translation)
  v1  + BOS layout                      (paper's boundary-optimal pick)
  v2  + cost-model LPT packing          (SPMD straggler mitigation)
  v3  + reference-point dedup           (beyond-paper, zero-comm)
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.data import spatial_gen
from repro.kernels.mbr_join import ref as mref
from repro.query import engine

from .common import emit, timeit

N = 6000
PAYLOAD = 300


def main() -> None:
    r = spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)
    s = spatial_gen.dataset("osm", jax.random.PRNGKey(1), N)
    n_dev = jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(n_dev), ("d",))
    oracle = int(mref.intersect_count(r, s))

    variants = [
        ("v0_fg_rr_masj", "fg", "round_robin", "masj"),
        ("v1_bos_rr_masj", "bos", "round_robin", "masj"),
        ("v2_bos_lpt_masj", "bos", "lpt", "masj"),
        ("v3_bos_lpt_rp", "bos", "lpt", "rp"),
    ]
    for name, method, packer, dedup in variants:
        plan = engine.plan_join(method, r, s, PAYLOAD, n_dev, packer=packer)
        if dedup == "masj":
            fn = lambda: engine.run_join_pairs_masj(  # noqa: E731
                plan, mesh, "d", max_pairs_per_tile=16384)
        else:
            fn = lambda: engine.run_join_count(  # noqa: E731
                plan, mesh, "d", dedup="rp")
        got = fn()
        assert got == oracle, (name, got, oracle)
        us = timeit(fn, warmup=1, iters=3)
        emit(f"paper_hillclimb/{name}", us,
             f"skew={plan.stats['skew']:.3f};lam={plan.stats['lambda_r']:.3f}")
