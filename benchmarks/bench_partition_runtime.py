"""Figs 6 & 7: partitioner runtime per dataset and per granularity."""
from __future__ import annotations

import jax

from repro.core.partition import api
from repro.data import spatial_gen

from .common import emit, timeit

N = 50000
METHODS = ["fg", "bsp", "slc", "bos", "str", "hc"]


def main() -> None:
    key = jax.random.PRNGKey(0)
    for ds in ["osm", "pi"]:
        mbrs = spatial_gen.dataset(ds, key, N)
        for m in METHODS:
            us = timeit(lambda mm=m: api.partition(mm, mbrs, 500),
                        warmup=1, iters=3)
            emit(f"fig6_runtime/{ds}/{m}/n{N}", us, f"k~{N // 500}")
    # Fig 7: granularity sensitivity (OSM)
    mbrs = spatial_gen.dataset("osm", key, N)
    for m in METHODS:
        for payload in [100, 500, 2500]:
            us = timeit(lambda mm=m, b=payload: api.partition(mm, mbrs, b),
                        warmup=1, iters=1)
            emit(f"fig7_granularity/osm/{m}/b{payload}", us,
                 f"k~{N // payload}")
