"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (stdout), one per cell.
"""
from __future__ import annotations

import sys
import traceback

from . import (bench_balanced_batch, bench_cost_model, bench_join,
               bench_kernels, bench_paper_hillclimb,
               bench_parallel_partition, bench_partition_runtime,
               bench_quality, bench_range_query, bench_sampling)

ALL = {
    "quality": bench_quality,            # Figs 3 & 4
    "join": bench_join,                  # Fig 5
    "range_query": bench_range_query,    # §6 selection workloads
    "partition_runtime": bench_partition_runtime,   # Figs 6 & 7
    "parallel_partition": bench_parallel_partition,  # Fig 8
    "sampling": bench_sampling,          # Fig 9
    "cost_model": bench_cost_model,      # §2.3
    "kernels": bench_kernels,            # Pallas microbenches
    "balanced_batch": bench_balanced_batch,          # LM integration
    "paper_hillclimb": bench_paper_hillclimb,        # §Perf cell 3
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    failed = []
    for name in which:
        try:
            ALL[name].main()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
