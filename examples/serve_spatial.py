"""Scenario: batched range + kNN serving over a partitioned layout.

Stages an OSM-like dataset once per layout, then streams query batches
through the SPMD serving step — routed/pruned (the default) vs the
dense oracle sweep — printing queries/sec for both and the per-query
partition fan-out that separates the layouts (the paper's
boundary-object cost, workload-facing).

    PYTHONPATH=src python examples/serve_spatial.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.data import spatial_gen
from repro.serve import SpatialServer

N, Q, K = 20_000, 1024, 10

if __name__ == "__main__":
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)
    mesh = Mesh(np.array(jax.devices()), ("d",))
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    c = jax.random.uniform(k1, (Q, 2))
    s = jax.random.uniform(k2, (Q, 2)) * 0.03
    qboxes = jnp.concatenate([c - s, c + s], axis=-1)
    pts = jax.random.uniform(k3, (Q, 2))

    print(f"serving {Q}-query batches over {N} objects, "
          f"{len(mesh.devices)} device(s)")
    for method in ["fg", "bsp", "slc", "bos", "str", "hc"]:
        srv = SpatialServer.from_method(method, mbrs, 500, mesh=mesh)
        srv.range_counts(qboxes)                      # warm the jit cache
        srv.range_counts(qboxes, pruned=False)
        t0 = time.perf_counter()
        counts, stats = srv.range_counts(qboxes)      # routed candidates
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        srv.range_counts(qboxes, pruned=False)        # dense oracle
        dt_dense = time.perf_counter() - t0
        nn_ids, _, _, kstats = srv.knn(pts, K)
        print(f"{method:>4}: pruned {Q / dt:>9.0f} q/s "
              f"(dense {Q / dt_dense:>9.0f}, f_max {stats['f_max']:>3d})  "
              f"fanout {stats['fanout_mean']:.2f}  "
              f"knn fanout {kstats['fanout_mean']:.2f}  "
              f"replication {srv.stats['replication']:.3f}")
