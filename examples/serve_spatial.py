"""Scenario: batched range + kNN serving over a partitioned layout.

Stages an OSM-like dataset once per layout, then streams query batches
through the SPMD serving step — routed/pruned (the default) vs the
dense oracle sweep, and replicated vs owner-routed *sharded* tiles —
printing queries/sec, the per-query partition fan-out that separates
the layouts (the paper's boundary-object cost, workload-facing), and
the per-device resident tile bytes that sharding divides by D.

    PYTHONPATH=src python examples/serve_spatial.py [--devices N]

``--devices N`` forces N virtual host devices
(``--xla_force_host_platform_device_count``), so the all_to_all
exchange path runs on a laptop exactly as it would on an N-chip mesh.
"""
import os
import sys

if __name__ == "__main__" and "--devices" in sys.argv:
    _n = int(sys.argv[sys.argv.index("--devices") + 1])
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={_n}")

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.data import spatial_gen
from repro.serve import ServeConfig, SpatialServer

N, Q, K = 20_000, 1024, 10

if __name__ == "__main__":
    mbrs = spatial_gen.dataset("osm", jax.random.PRNGKey(0), N)
    mesh = Mesh(np.array(jax.devices()), ("d",))
    n_dev = len(mesh.devices.ravel())
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    c = jax.random.uniform(k1, (Q, 2))
    s = jax.random.uniform(k2, (Q, 2)) * 0.03
    qboxes = jnp.concatenate([c - s, c + s], axis=-1)
    pts = jax.random.uniform(k3, (Q, 2))

    print(f"serving {Q}-query batches over {N} objects, "
          f"{n_dev} device(s)")
    for method in ["fg", "bsp", "slc", "bos", "str", "hc"]:
        srv = SpatialServer.from_method(method, mbrs, 500, mesh=mesh)
        ssrv = SpatialServer.from_method(
            method, mbrs, 500, ServeConfig(placement="sharded"),
            mesh=mesh)
        for s_ in (srv, ssrv):                        # warm the jit cache
            s_.range_counts(qboxes)
        srv.range_counts(qboxes, pruned=False)
        t0 = time.perf_counter()
        counts, stats = srv.range_counts(qboxes)      # routed candidates
        dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        srv.range_counts(qboxes, pruned=False)        # dense oracle
        dt_dense = time.perf_counter() - t0
        t0 = time.perf_counter()
        scounts, sstats = ssrv.range_counts(qboxes)   # owner-routed shards
        dt_sh = time.perf_counter() - t0
        assert np.array_equal(np.asarray(counts), np.asarray(scounts))
        nn_ids, _, _, kstats = ssrv.knn(pts, K)
        print(f"{method:>4}: pruned {Q / dt:>9.0f} q/s "
              f"(dense {Q / dt_dense:>9.0f}, sharded {Q / dt_sh:>9.0f}, "
              f"f_max {stats['f_max']:>3d})  "
              f"fanout {stats['fanout_mean']:.2f}  "
              f"chunk-skip {srv.chunk_skip_rate(qboxes):.2f}  "
              f"knn fanout {kstats['fanout_mean']:.2f}  "
              f"replication {srv.stats['replication']:.3f}  "
              f"resident/dev {srv.resident_tile_bytes() / 2**20:6.2f} MiB "
              f"repl vs {ssrv.resident_tile_bytes() / 2**20:6.2f} MiB "
              f"sharded")

    # streaming: stage 90% with slack, append the rest, keep serving
    head, tail = mbrs[: 9 * N // 10], mbrs[9 * N // 10:]
    srv = SpatialServer.from_method("bsp", head, 500,
                                    ServeConfig(slack=1024))
    t0 = time.perf_counter()
    for i in range(0, tail.shape[0], 256):
        rep = srv.append(tail[i:i + 256])
    dt = time.perf_counter() - t0
    counts, _ = srv.range_counts(qboxes)
    full = SpatialServer.from_method("bsp", mbrs, 500)
    fcounts, _ = full.range_counts(qboxes)
    assert np.array_equal(np.asarray(counts), np.asarray(fcounts))
    print(f"append: {tail.shape[0] / dt:>9.0f} obj/s streamed into slack "
          f"(restages {srv.stats['restages']}, answers == full restage)")
