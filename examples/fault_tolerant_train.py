"""Scenario: node failure mid-training → checkpoint restart.

Injects a failure at step 30 of 60; the FT runtime restores the last
checkpoint and finishes the run (watch the restart warning).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import logging

from repro.launch import train

logging.basicConfig(level=logging.WARNING)

if __name__ == "__main__":
    raise SystemExit(train.main([
        "--steps", "60", "--batch", "4", "--seq", "64",
        "--ckpt-dir", "runs/ckpt_ft_demo", "--ckpt-every", "10",
        "--inject-failure-at", "30", "--log-every", "20"]))
