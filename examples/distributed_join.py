"""Scenario: multi-device spatial analytics (8 simulated devices).

Shows the SPMD path end-to-end: MapReduce-style distributed partitioning
(sample → hilbert shuffle → per-device reduce), cost-model LPT packing,
tile-parallel join with both dedup strategies, straggler factors.

    PYTHONPATH=src python examples/distributed_join.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import metrics  # noqa: E402
from repro.core.partition import partition_counts  # noqa: E402
from repro.data import spatial_gen  # noqa: E402
from repro.kernels.mbr_join import ref as oracle  # noqa: E402
from repro.query import engine, parallel_partition as pp  # noqa: E402

key = jax.random.PRNGKey(0)
r = spatial_gen.dataset("osm", key, 6000)
s = spatial_gen.dataset("pi", jax.random.PRNGKey(5), 4000)
mesh = Mesh(np.array(jax.devices()).reshape(8), ("d",))

# 1. distributed partitioning (paper §5.1)
parts, stats = pp.parallel_partition(key, r, 300, mesh, "d")
counts, copies = partition_counts(r, parts)
print(f"distributed partition: k={int(parts.k())} dropped={stats['dropped']} "
      f"coverage={float(metrics.coverage(copies)):.3f}")

# 2. planned, balanced join — LPT vs round-robin packing
want = int(oracle.intersect_count(r, s))
for packer in ["lpt", "round_robin"]:
    plan = engine.plan_join("bsp", r, s, 300, 8, packer=packer)
    got = engine.run_join_count(plan, mesh, "d", dedup="rp")
    assert got == want, (got, want)
    print(f"{packer:>12}: join={got} makespan-skew={plan.stats['skew']:.3f}")

# 3. paper-faithful MASJ dedup agrees with zero-comm reference-point dedup
plan = engine.plan_join("slc", r, s, 300, 8)
masj = engine.run_join_pairs_masj(plan, mesh, "d", max_pairs_per_tile=8192)
print(f"MASJ sort-unique dedup: {masj} == rp dedup: {want}")
assert masj == want
print("OK")
