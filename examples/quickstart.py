"""Quickstart: the paper's pipeline in 40 lines.

Generates a skewed (OSM-like) dataset, partitions it with all six
algorithms, prints the paper's quality metrics, and runs a distributed
spatial join whose result is checked against the brute-force oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import metrics
from repro.core.partition import api, partition_counts
from repro.data import spatial_gen
from repro.kernels.mbr_join import ref as oracle
from repro.query import engine

N, PAYLOAD = 4000, 250

key = jax.random.PRNGKey(0)
r = spatial_gen.dataset("osm", key, N)
s = spatial_gen.dataset("osm", jax.random.PRNGKey(1), N // 2)

print(f"{'method':>6} {'k':>5} {'λ':>8} {'stddev':>8} {'skew':>6}")
for method in ["fg", "bsp", "slc", "bos", "str", "hc"]:
    parts = api.partition(method, r, PAYLOAD)
    counts, copies = partition_counts(r, parts)
    print(f"{method:>6} {int(parts.k()):>5} "
          f"{float(metrics.boundary_ratio(counts, parts.valid, N)):>8.4f} "
          f"{float(metrics.balance_stddev(counts, parts.valid)):>8.2f} "
          f"{float(metrics.skew_ratio(counts, parts.valid)):>6.2f}")

mesh = Mesh(np.array(jax.devices()), ("d",) )
want = int(oracle.intersect_count(r, s))
plan = engine.plan_join("bos", r, s, PAYLOAD, jax.device_count())
got = engine.spatial_join_count(plan, mesh, "d")
print(f"\nspatial join |R ⋈ S| = {got} (oracle {want}) "
      f"tile-skew={plan.stats['skew']:.2f} λ_R={plan.stats['lambda_r']:.3f}")
assert got == want
print("OK")
