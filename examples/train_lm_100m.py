"""End-to-end driver: train a ~110M-param dense LM for a few hundred
steps with checkpointing + fault-tolerant restart (CPU-scaled batch; on
a pod, raise --batch/--seq and point the mesh at real devices).

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 200]
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    args = sys.argv[1:] or ["--steps", "200"]
    raise SystemExit(train.main([
        "--preset", "100m", "--batch", "2", "--seq", "32",
        "--ckpt-dir", "runs/ckpt_100m", "--log-every", "20", *args]))
