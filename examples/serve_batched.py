"""Scenario: batched greedy serving with KV/SSM caches.

Serves a reduced Gemma-2-style model (local+global attention, softcaps)
and a Mamba2 model (O(1) SSM state) side by side.

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve

if __name__ == "__main__":
    for arch in ["gemma2_27b", "mamba2_1p3b"]:
        serve.main(["--arch", arch, "--batch", "8",
                    "--prompt-len", "16", "--gen", "32"])
