"""Fault-tolerant training runtime.

TPU fleets lose nodes; the recovery contract here is the standard one:
  * checkpoint every ``ckpt_every`` steps (atomic, logical shapes),
  * on any step failure, restore the latest checkpoint and resume —
    possibly onto a *different* mesh (elastic restart),
  * stragglers at the data layer are handled by the paper's balanced
    partitioning (query engine) / balanced batching (LM pipeline);
    step-time watchdogs only flag, since SPMD cannot reassign work
    mid-step.

``run_loop`` is deliberately host-driven and synchronous — it is the
control plane, the data plane is the jitted step.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

from ..checkpoint import store

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0   # step-time watchdog threshold


class StepFailure(RuntimeError):
    pass


def run_loop(step_fn: Callable, state, batches, cfg: FTConfig,
             shardings=None, inject_failure_at: int | None = None):
    """Run ``step_fn`` over ``batches`` with checkpoint/restart.

    ``inject_failure_at``: test hook — raises StepFailure once at that
    step to exercise the restart path.
    """
    start = store.latest_step(cfg.ckpt_dir)
    step = 0
    if start is not None:
        state, step = store.restore(cfg.ckpt_dir, state, shardings=shardings)
        log.info("resumed from step %d", step)

    restarts = 0
    times: list[float] = []
    metrics = None
    injected = False
    it = enumerate(batches)
    pending = list(it)
    i = 0
    while i < len(pending):
        gstep = step + i
        _, batch = pending[i]
        t0 = time.perf_counter()
        try:
            if inject_failure_at is not None and gstep == inject_failure_at \
                    and not injected:
                injected = True
                raise StepFailure(f"injected node failure at step {gstep}")
            state, metrics = step_fn(state, batch)
        except StepFailure as e:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            log.warning("step %d failed (%s); restarting from checkpoint",
                        gstep, e)
            last = store.latest_step(cfg.ckpt_dir)
            if last is not None:
                state, ck = store.restore(cfg.ckpt_dir, state,
                                          shardings=shardings)
                i = ck - step
            continue
        dt = time.perf_counter() - t0
        if times and dt > cfg.straggler_factor * (sum(times) / len(times)):
            log.warning("straggler step %d: %.3fs vs mean %.3fs",
                        gstep, dt, sum(times) / len(times))
        times.append(dt)
        if (gstep + 1) % cfg.ckpt_every == 0:
            store.save(cfg.ckpt_dir, state, gstep + 1)
        i += 1
    return state, metrics, {"restarts": restarts, "steps": len(pending),
                            "mean_step_s": sum(times) / max(len(times), 1)}
