"""Synthetic spatial dataset generators calibrated to the paper's data.

- ``osm_like``: hotspot-clustered, heavy-tailed — mixture of power-law-
  weighted Gaussian clusters plus a uniform background; object sizes
  log-normal.  Reproduces the paper's observation that a 1000×1000 fixed
  grid has a ~3-orders-of-magnitude max/mean tile skew.
- ``pi_like``: pathology-imaging-like — dense, near-uniform small objects
  (segmented cells), mild local density variation.

Both are seeded, jit-compiled, and stream in chunks for the ETL path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnums=(1, 2))
def osm_like(key: jax.Array, n: int, n_clusters: int = 64) -> jax.Array:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # power-law cluster weights -> heavy skew
    w = jax.random.pareto(k1, 1.2, (n_clusters,)) + 1.0
    probs = w / jnp.sum(w)
    cid = jax.random.choice(k2, n_clusters, (n,), p=probs)
    centers = jax.random.uniform(k3, (n_clusters, 2), minval=0.0, maxval=1.0)
    spread = 10.0 ** jax.random.uniform(k4, (n_clusters, 1),
                                        minval=-3.0, maxval=-1.3)
    pts = centers[cid] + spread[cid] * jax.random.normal(k5, (n, 2))
    # 5% uniform background (rural roads / sparse features)
    bg = jax.random.uniform(k6, (n, 3))
    pts = jnp.where(bg[:, :1] < 0.05, bg[:, 1:3], pts)
    pts = jnp.clip(pts, 0.0, 1.0)
    # log-normal object extents (buildings .. lakes)
    ks = jax.random.split(key, 2)[1]
    sz = 10.0 ** jax.random.uniform(ks, (n, 2), minval=-5.0, maxval=-2.5)
    return jnp.concatenate([pts - sz, pts + sz], axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnums=(1,))
def pi_like(key: jax.Array, n: int) -> jax.Array:
    k1, k2, k3 = jax.random.split(key, 3)
    pts = jax.random.uniform(k1, (n, 2))
    # gentle density ripple (tissue texture), small cell-scale extents
    ripple = 0.15 * jnp.sin(6.28 * 3 * pts[:, :1]) * jnp.sin(6.28 * 2 * pts[:, 1:])
    pts = jnp.clip(pts + ripple * jax.random.normal(k2, (n, 2)) * 0.02, 0, 1)
    sz = 10.0 ** jax.random.uniform(k3, (n, 2), minval=-4.2, maxval=-3.2)
    return jnp.concatenate([pts - sz, pts + sz], axis=-1).astype(jnp.float32)


def dataset(name: str, key: jax.Array, n: int) -> jax.Array:
    if name == "osm":
        return osm_like(key, n)
    if name == "pi":
        return pi_like(key, n)
    raise KeyError(name)
