"""Deterministic synthetic token pipeline (host-sharded).

Each data-parallel host materialises only its shard of the global batch
(`host_id`/`n_hosts`), from a counter-based PRNG — no host ever holds
the global batch, and any host can re-derive any shard (important for
elastic restart: a new host joining at step N regenerates exactly the
shard it owns).

Documents have a heavy-tailed length distribution; ``balanced.py`` turns
them into payload-balanced batches with the paper's partitioners.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0


def batch_for_step(cfg: TokenPipelineConfig, step: int) -> dict:
    """The host's shard of the step's global batch: (B/H, S) int32."""
    per_host = cfg.global_batch // cfg.n_hosts
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.host_id)
    toks = jax.random.randint(key, (per_host, cfg.seq_len), 0, cfg.vocab,
                              dtype=jnp.int32)
    return {"tokens": toks}


def doc_lengths(seed: int, n_docs: int, max_len: int) -> np.ndarray:
    """Heavy-tailed document lengths (lognormal, clipped)."""
    rng = np.random.default_rng(seed)
    raw = rng.lognormal(mean=5.5, sigma=1.2, size=n_docs)
    return np.clip(raw.astype(np.int64), 16, max_len)
