"""Paper-integration: partitioner-based load-balanced batch packing.

Token pipelines feed variable-length documents to fixed-shape device
batches; a skewed assignment leaves devices idle at every lock-step
collective — exactly the paper's straggler argument.  We embed documents
as degenerate MBRs in (arrival-index × length) space and reuse the
paper's partitioners (SLC by default: strips of equal *token payload*)
to build device bins, then report balance with the same metrics used
for spatial tiles.  This is the technique applied where it IS applicable
to LM training (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core import metrics
from ..core.partition import api
from ..query import balance as qbalance


def docs_as_mbrs(lengths: np.ndarray) -> jnp.ndarray:
    """Documents -> point MBRs at (cumulative-token-position, length)."""
    starts = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.float32)
    ln = lengths.astype(np.float32)
    x = starts + ln * 0.5          # token-mass coordinate
    y = ln
    return jnp.stack([x, y, x, y], axis=-1)


def balanced_bins(lengths: np.ndarray, n_bins: int, method: str = "slc"):
    """Assign docs to ``n_bins`` device bins with ~equal token payload.

    SLC in token-mass space gives equal-token strips (the paper's
    payload bound); LPT on top handles stragglers from rounding.
    Returns (bin_assignment[n_docs], stats).
    """
    n = len(lengths)
    mbrs = docs_as_mbrs(lengths)
    payload = max(1, n // n_bins)
    parts = api.partition(method, mbrs, payload)
    boxes = np.asarray(parts.boxes)
    valid = np.asarray(parts.valid)
    x = np.asarray(mbrs[:, 0])
    # strip index via cut positions (SLC boxes tile the x axis)
    order = np.argsort(boxes[:, 0])
    order = order[valid[order]]
    cuts = boxes[order, 0]
    strip = np.clip(np.searchsorted(cuts, x, side="right") - 1, 0,
                    len(order) - 1)
    # strips -> bins by token cost (LPT), strips count may exceed bins
    strip_tokens = np.zeros(len(order))
    np.add.at(strip_tokens, strip, lengths)
    sbin, makespan, mean = qbalance.lpt_pack(strip_tokens, n_bins)
    assignment = sbin[strip]

    bin_tokens = np.zeros(n_bins)
    np.add.at(bin_tokens, assignment, lengths)
    stats = {
        "skew": float(bin_tokens.max() / max(bin_tokens.mean(), 1e-9)),
        "stddev": float(bin_tokens.std()),
        "makespan": makespan,
    }
    return assignment, stats


def naive_bins(lengths: np.ndarray, n_bins: int):
    """Round-robin baseline (what a plain dataloader does)."""
    assignment = np.arange(len(lengths)) % n_bins
    bin_tokens = np.zeros(n_bins)
    np.add.at(bin_tokens, assignment, lengths)
    return assignment, {
        "skew": float(bin_tokens.max() / max(bin_tokens.mean(), 1e-9)),
        "stddev": float(bin_tokens.std()),
    }
