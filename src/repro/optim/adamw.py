"""AdamW with global-norm clipping and memory-dtype policies.

Policies (per-chip optimizer bytes/param, excluding the bf16 compute
copy):  ``fp32`` m+v fp32 (8B) — default;  ``bf16_m`` m bf16, v fp32
(6B);  ``bf16_mv`` m+v bf16 (4B) — used by the largest configs (arctic)
to fit the v5e HBM budget (see EXPERIMENTS.md §Dry-run).
Optimizer state inherits the parameter sharding (ZeRO-style: params are
already FSDP-sharded over ``data``, so state is too).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_policy: str = "fp32"      # fp32 | bf16_m | bf16_mv
    warmup: int = 100
    total_steps: int = 10000


def _m_dtype(p):
    return jnp.bfloat16 if p in ("bf16_m", "bf16_mv") else jnp.float32


def _v_dtype(p):
    return jnp.bfloat16 if p == "bf16_mv" else jnp.float32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    step: jax.Array


def init_state(params, cfg: AdamWConfig) -> OptState:
    return OptState(
        m=jax.tree.map(lambda p: jnp.zeros_like(p, _m_dtype(cfg.state_policy)),
                       params),
        v=jax.tree.map(lambda p: jnp.zeros_like(p, _v_dtype(cfg.state_policy)),
                       params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup)
                    / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: OptState, params, cfg: AdamWConfig):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(step, cfg)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p32 - lr * (u + decay * p32)
        return (new_p.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(m=new_m, v=new_v, step=step), {
        "grad_norm": gnorm, "lr": lr}
