"""R1 jit-closure-capture.

The PR-5 bug class: a staged device array captured by closure in a
callable handed to jax.jit / shard_map / pallas_call becomes a baked-in
traced constant — per-device copies silently collapse to one, and
re-staging no longer reaches the compiled step.  Arrays must be passed
as arguments (the repo's ``_call`` seam passes staging via ``consts``).

Flags lambdas and locally-defined functions passed to a jit sink whose
free variables are classified arrayish in the enclosing scope.  Module
globals and unknown values are never flagged.
"""

from __future__ import annotations

import ast
import builtins

from . import config
from .core import (ArrayishEnv, Finding, Module, Project, func_defs,
                   last_attr, module_globals, param_names)

RULE = "jit-closure-capture"
_BUILTINS = set(dir(builtins))


def check(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for mod in project.modules:
        globals_ = module_globals(mod.tree)
        for fn in func_defs(mod.tree):
            out.extend(_check_function(mod, fn, globals_))
    return out


def _check_function(mod: Module, fn: ast.FunctionDef,
                    globals_: set[str]) -> list[Finding]:
    env = ArrayishEnv(fn, mod)
    local_defs = {n.name: n for n in ast.walk(fn)
                  if isinstance(n, ast.FunctionDef) and n is not fn}
    bound = set(param_names(fn)) | set(env.env) | set(local_defs)
    out: list[Finding] = []
    for call in ast.walk(fn):
        if not (isinstance(call, ast.Call)
                and last_attr(call.func) in config.JIT_SINKS):
            continue
        for arg in list(call.args) + [k.value for k in call.keywords]:
            inner = None
            if isinstance(arg, ast.Lambda):
                inner = arg
            elif isinstance(arg, ast.Name) and arg.id in local_defs:
                inner = local_defs[arg.id]
            if inner is None:
                continue
            for name in sorted(_free_vars(inner)):
                if name in _BUILTINS or name in globals_:
                    continue
                if name in bound and env.env.get(name, False):
                    label = ("lambda" if isinstance(inner, ast.Lambda)
                             else inner.name)
                    out.append(Finding(
                        RULE, mod.rel, arg.lineno,
                        f"device array '{name}' captured by closure in "
                        f"'{label}' handed to "
                        f"'{last_attr(call.func)}'",
                        hint="pass it as an argument (staging goes "
                             "through consts/in_specs), not a closure",
                        func=fn.name))
    return out


def _free_vars(fn: ast.Lambda | ast.FunctionDef) -> set[str]:
    """Names loaded inside fn that fn itself does not bind."""
    local = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                             + fn.args.kwonlyargs)}
    for va in (fn.args.vararg, fn.args.kwarg):
        if va is not None:
            local.add(va.arg)
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    loads: set[str] = set()
    for stmt in body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name):
                if isinstance(node.ctx, ast.Load):
                    loads.add(node.id)
                else:
                    local.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.Lambda)):
                local.update(a.arg for a in (node.args.posonlyargs
                                             + node.args.args
                                             + node.args.kwonlyargs))
            elif isinstance(node, ast.comprehension):
                for t in ast.walk(node.target):
                    if isinstance(t, ast.Name):
                        local.add(t.id)
    return loads - local
