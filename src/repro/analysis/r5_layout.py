"""R5 TileLayout conformance.

Every placement class registered in ``_PLACEMENT_CLS`` (and any future
layout subclassing the layout bases elsewhere) must structurally
implement the full ``TileLayout`` executor + ingest contract: protocol
methods/properties as defs, protocol attributes as class-level or
``self.X = ...`` assignments somewhere in the MRO.

Also enforces two repo invariants around the registry:

* a class deriving from the layout bases but absent from the registry is
  unreachable from ``ServeConfig.placement`` — flagged;
* the PR-8 replica fan-out chain: any layout with an ``_owner_scatter``
  in its MRO must route ``_scatter`` through it, and the placement
  resolution must consult ``rep_owner`` — otherwise ingest writes miss
  replica copies and replicas drift from their owners.
"""

from __future__ import annotations

import ast

from . import config
from .core import Finding, Module, Project

RULE = "layout-conformance"


def check(project: Project) -> list[Finding]:
    out: list[Finding] = []
    layout_mods = []
    for mod in project.modules:
        classes = {c.name: c for c in mod.tree.body
                   if isinstance(c, ast.ClassDef)}
        proto = _find_protocol(classes)
        registry = _find_registry(mod.tree)
        if proto is None or registry is None:
            continue
        layout_mods.append((mod, classes, proto, registry))
        out.extend(_check_module(mod, classes, proto, registry))
    out.extend(_check_external_subclasses(project, layout_mods))
    return out


# ---------------------------------------------------------------------------
# contract extraction
# ---------------------------------------------------------------------------

def _find_protocol(classes: dict) -> dict | None:
    cls = classes.get(config.PROTOCOL_NAME)
    if cls is None:
        return None
    if not any("Protocol" in _base_name(b) for b in cls.bases):
        return None
    methods = {n.name for n in cls.body if isinstance(n, ast.FunctionDef)
               and not n.name.startswith("__")}
    attrs = {n.target.id for n in cls.body
             if isinstance(n, ast.AnnAssign)
             and isinstance(n.target, ast.Name)}
    return {"methods": methods, "attrs": attrs, "line": cls.lineno}


def _base_name(b: ast.expr) -> str:
    while isinstance(b, ast.Subscript):
        b = b.value
    parts = []
    while isinstance(b, ast.Attribute):
        parts.append(b.attr)
        b = b.value
    if isinstance(b, ast.Name):
        parts.append(b.id)
    return ".".join(reversed(parts))


def _find_registry(tree: ast.Module) -> dict | None:
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == config.REGISTRY_NAME
                and isinstance(node.value, ast.Dict)):
            entries = {}
            for k, v in zip(node.value.keys, node.value.values):
                if (isinstance(k, ast.Constant) and isinstance(v, ast.Name)):
                    entries[k.value] = v.id
            return {"entries": entries, "line": node.lineno}
    return None


def _mro(classes: dict, name: str) -> list[ast.ClassDef]:
    """Linearized in-module ancestry, derived-first (good enough for
    single inheritance chains, which is all the layouts use)."""
    out, seen, queue = [], set(), [name]
    while queue:
        n = queue.pop(0)
        cls = classes.get(n)
        if cls is None or n in seen:
            continue
        seen.add(n)
        out.append(cls)
        queue.extend(_base_name(b).split(".")[-1] for b in cls.bases)
    return out


def _members(mro: list[ast.ClassDef]) -> tuple[set[str], dict]:
    """(implemented member names, method name -> def node resolved
    derived-first across the MRO)."""
    names: set[str] = set()
    methods: dict[str, ast.FunctionDef] = {}
    for cls in mro:
        for node in cls.body:
            if isinstance(node, ast.FunctionDef):
                names.add(node.name)
                methods.setdefault(node.name, node)
                for stmt in ast.walk(node):
                    if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                        targets = (stmt.targets
                                   if isinstance(stmt, ast.Assign)
                                   else [stmt.target])
                        for t in targets:
                            if (isinstance(t, ast.Attribute)
                                    and isinstance(t.value, ast.Name)
                                    and t.value.id == "self"):
                                names.add(t.attr)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
            elif isinstance(node, ast.AnnAssign):
                if isinstance(node.target, ast.Name):
                    names.add(node.target.id)
    return names, methods


# ---------------------------------------------------------------------------
# checks
# ---------------------------------------------------------------------------

def _check_module(mod: Module, classes: dict, proto: dict,
                  registry: dict) -> list[Finding]:
    out: list[Finding] = []
    registered = set(registry["entries"].values())
    contract = proto["methods"] | proto["attrs"]
    base_of_registered: set[str] = set()
    for cname in registered:
        for cls in _mro(classes, cname)[1:]:
            base_of_registered.add(cls.name)

    for key, cname in registry["entries"].items():
        cls = classes.get(cname)
        if cls is None:
            out.append(Finding(
                RULE, mod.rel, registry["line"],
                f"registry entry '{key}' points at unknown class "
                f"'{cname}'", func=config.REGISTRY_NAME))
            continue
        mro = _mro(classes, cname)
        have, methods = _members(mro)
        missing = sorted(contract - have)
        if missing:
            out.append(Finding(
                RULE, mod.rel, cls.lineno,
                f"'{cname}' does not implement TileLayout members: "
                f"{missing}",
                hint="implement the full executor + ingest contract "
                     "(see the TileLayout protocol)", func=cname))
        out.extend(_check_fanout(mod, cname, cls, methods))

    # a layout subclass outside the registry is dead code to ServeConfig
    for cname, cls in classes.items():
        if cname in registered or cname in base_of_registered:
            continue
        if cname == config.PROTOCOL_NAME:
            continue
        bases = {_base_name(b).split(".")[-1] for b in cls.bases}
        if bases & (registered | base_of_registered):
            out.append(Finding(
                RULE, mod.rel, cls.lineno,
                f"layout class '{cname}' subclasses a placement base "
                f"but is not registered in {config.REGISTRY_NAME}",
                hint="register it (or it is unreachable from "
                     "ServeConfig.placement)", func=cname))
    return out


def _check_fanout(mod: Module, cname: str, cls: ast.ClassDef,
                  methods: dict) -> list[Finding]:
    scatter, owner, place, marker = config.FANOUT_CHAIN
    if owner not in methods:
        return []  # unsharded layout: no replica copies to fan out to
    out: list[Finding] = []
    if scatter not in methods or not _calls(methods[scatter], owner):
        out.append(Finding(
            RULE, mod.rel, cls.lineno,
            f"'{cname}._scatter' does not route through "
            f"'{owner}' — ingest writes would miss replica copies",
            hint="PR-8 invariant: every ingest scatter fans out to all "
                 "resident copies via _owner_scatter", func=cname))
    if not _calls(methods[owner], place):
        out.append(Finding(
            RULE, mod.rel, methods[owner].lineno,
            f"'{cname}.{owner}' does not resolve placements via "
            f"'{place}'", func=cname))
    elif place in methods and not _references(methods[place], marker):
        out.append(Finding(
            RULE, mod.rel, methods[place].lineno,
            f"'{cname}.{place}' never consults '{marker}' — replica "
            "copies are invisible to ingest placement", func=cname))
    return out


def _calls(fn: ast.FunctionDef, name: str) -> bool:
    return any(isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute)
               and n.func.attr == name for n in ast.walk(fn))


def _references(fn: ast.FunctionDef, name: str) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Attribute) and n.attr == name:
            return True
        if isinstance(n, ast.Name) and n.id == name:
            return True
    return False


def _check_external_subclasses(project: Project,
                               layout_mods: list) -> list[Finding]:
    """Layout subclasses in other modules still owe the contract."""
    if not layout_mods:
        return []
    out: list[Finding] = []
    base_names: set[str] = set()
    contract: set[str] = set()
    base_members: set[str] = set()
    for mod, classes, proto, registry in layout_mods:
        registered = set(registry["entries"].values())
        contract |= proto["methods"] | proto["attrs"]
        for cname in registered:
            for cls in _mro(classes, cname):
                base_names.add(cls.name)
                have, _ = _members([cls])
                base_members |= have
    for mod in project.modules:
        if any(mod is lm[0] for lm in layout_mods):
            continue
        classes = {c.name: c for c in mod.tree.body
                   if isinstance(c, ast.ClassDef)}
        for cname, cls in classes.items():
            bases = {_base_name(b).split(".")[-1] for b in cls.bases}
            if not bases & base_names:
                continue
            have, _ = _members(_mro(classes, cname))
            missing = sorted(contract - have - base_members)
            if missing:
                out.append(Finding(
                    RULE, mod.rel, cls.lineno,
                    f"external layout subclass '{cname}' misses "
                    f"TileLayout members: {missing}", func=cname))
    return out
