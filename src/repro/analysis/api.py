"""Analyzer driver: build the project index, run the enabled rules,
apply inline suppressions, the config allowlist, and the baseline."""

from __future__ import annotations

import dataclasses
from pathlib import Path

from . import config
from .core import Finding, Project, load_baseline

RULE_IDS = (
    "jit-closure-capture",
    "recompile-hazard",
    "host-sync",
    "kernel-twin-parity",
    "layout-conformance",
    "bad-suppression",
)

RULE_DOCS = {
    "jit-closure-capture": "device arrays captured by closure in "
                           "callables handed to jit/shard_map/pallas "
                           "(PR-5 bug class)",
    "recompile-hazard": "data-dependent ints into static jit args "
                        "without bucketing (PR-7 bug class)",
    "host-sync": "float()/int()/np.asarray/.item() on device values in "
                 "hot-path modules",
    "kernel-twin-parity": "*_skip twin signatures + eval_shape aval "
                          "parity + alive-mask threading",
    "layout-conformance": "TileLayout contract + registry + PR-8 "
                          "replica fan-out invariant",
    "bad-suppression": "reprolint suppression without a rationale or "
                       "with an unknown rule id",
}


@dataclasses.dataclass
class Report:
    findings: list[Finding]        # actionable (unsuppressed, new)
    suppressed: list[Finding]      # silenced inline with a rationale
    allowlisted: list[Finding]     # silenced by config.ALLOWLIST
    baselined: list[Finding]       # known debt from the baseline file

    def to_json(self) -> dict:
        return {
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [f.to_json() for f in self.suppressed],
            "allowlisted": [f.to_json() for f in self.allowlisted],
            "baselined": [f.to_json() for f in self.baselined],
            "counts": {
                "findings": len(self.findings),
                "suppressed": len(self.suppressed),
                "allowlisted": len(self.allowlisted),
                "baselined": len(self.baselined),
            },
        }


def run(root: str | Path, files: list[Path] | None = None,
        disable: set[str] | frozenset[str] = frozenset(),
        baseline: Path | None = None,
        use_allowlist: bool = True) -> Report:
    from . import r1_closure, r2_recompile, r3_hostsync, r4_twins, r5_layout

    project = Project(Path(root), files)
    rules = {
        "jit-closure-capture": r1_closure.check,
        "recompile-hazard": r2_recompile.check,
        "host-sync": r3_hostsync.check,
        "kernel-twin-parity": r4_twins.check,
        "layout-conformance": r5_layout.check,
    }
    raw: list[Finding] = list(project.errors)
    for rule_id, checker in rules.items():
        if rule_id not in disable:
            raw.extend(checker(project))
    if "bad-suppression" not in disable:
        for mod in project.modules:
            raw.extend(mod.bad_suppressions)

    by_rel = {m.rel: m for m in project.modules}
    report = Report([], [], [], [])
    known = load_baseline(baseline) if baseline else set()
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        mod = by_rel.get(f.path)
        if (mod is not None and f.rule != "bad-suppression"
                and mod.suppressed(f.line, f.rule)):
            report.suppressed.append(f)
        elif use_allowlist and _allowlisted(f):
            report.allowlisted.append(f)
        elif f.fingerprint() in known:
            report.baselined.append(f)
        else:
            report.findings.append(f)
    return report


def _allowlisted(f: Finding) -> bool:
    for suffix, func, rule, reason in config.ALLOWLIST:
        assert reason, "allowlist entries must carry a rationale"
        if not f.path.endswith(suffix):
            continue
        if func is not None and f.func != func:
            continue
        if rule is not None and f.rule != rule:
            continue
        return True
    return False
