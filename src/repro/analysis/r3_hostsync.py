"""R3 host-sync audit.

On the per-batch serving hot path (config.HOT_MODULES), ``float()`` /
``int()`` / ``bool()`` / ``np.asarray()`` / ``.item()`` on a device
value blocks the host on a device round-trip — a stall per call, per
batch.  serve/layout.py alone has >100 such candidate call sites;
almost all fold host numpy and are fine, which is why the rule only
fires when the operand is positively classified arrayish (jnp results,
staging attributes, values derived from them).

Deliberate host-side planes are allowlisted in config.ALLOWLIST with a
rationale; one-off deliberate folds carry inline suppressions.
"""

from __future__ import annotations

import ast

from . import config
from .core import (ArrayishEnv, Finding, Module, Project, dotted_name,
                   func_defs)

RULE = "host-sync"


def check(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for mod in project.modules:
        if not mod.rel.endswith(config.HOT_MODULES):
            continue
        numpy_aliases = {name for name, dotted in mod.imports.items()
                         if dotted == "numpy"}
        for fn in func_defs(mod.tree):
            env = ArrayishEnv(fn, mod)
            for call in ast.walk(fn):
                if not isinstance(call, ast.Call):
                    continue
                site = _classify_site(call, numpy_aliases)
                if site is None or not call.args and site != "method":
                    continue
                operand = (call.func.value if site == "method"
                           else call.args[0] if call.args else None)
                if operand is None or not env.is_arrayish(operand):
                    continue
                label = (f".{call.func.attr}()" if site == "method"
                         else f"{dotted_name(call.func)}()")
                out.append(Finding(
                    RULE, mod.rel, call.lineno,
                    f"{label} on a device value blocks on a "
                    "device->host transfer in a hot-path module",
                    hint="keep the value on device, or fold once via a "
                         "single np.asarray and suppress with a "
                         "rationale if the sync is deliberate",
                    func=fn.name))
    return out


def _classify_site(call: ast.Call, numpy_aliases: set[str]) -> str | None:
    f = call.func
    if isinstance(f, ast.Name) and f.id in config.HOST_CAST_FUNCS:
        return "cast"
    if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
            and f.value.id in numpy_aliases
            and f.attr in config.NUMPY_DOWNLOAD_FUNCS):
        return "download"
    if isinstance(f, ast.Attribute) and f.attr in config.HOST_SYNC_METHODS:
        return "method"
    return None
