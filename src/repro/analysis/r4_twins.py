"""R4 kernel-twin parity + tombstone-mask threading.

Two structural contracts over the probe surface:

1. Twins: every ``X_skip`` (chunk-skipping local-index executor) must
   pair with an ``X`` whose signature it extends only by the chunk-box
   parameter, and — for the row-major ops/ref surface — both twins must
   produce identical output avals under ``jax.eval_shape`` (abstract
   tracing only; no kernel ever runs).

2. Tombstones (PR 7): every public probe entry point that takes
   member-slot data (``tiles``/``gtiles``/``canon_tiles``) must accept
   and *use* a per-slot ``alive`` mask, so a new kernel family cannot
   silently resurrect deleted objects.

Abstract inputs are synthesized by parameter name from
config.ABSTRACT_SHAPES; a required parameter the table cannot synthesize
is itself a finding — extending a family extends the table.
"""

from __future__ import annotations

import ast
import importlib
import importlib.util

from . import config
from .core import Finding, Module, Project, func_defs, param_names

RULE = "kernel-twin-parity"


def check(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for mod in project.modules:
        family = _family_file(mod)
        surface = family or mod.rel.endswith(config.PROBE_SURFACE_SUFFIXES)
        if not surface:
            continue
        fns = {fn.name: fn for fn in mod.tree.body
               if isinstance(fn, ast.FunctionDef)}
        out.extend(_check_twins(mod, fns))
        out.extend(_check_alive(mod, fns))
        if family in config.ABSTRACT_PARITY_FILES:
            out.extend(_check_abstract_parity(mod, fns))
    return out


def _family_file(mod: Module) -> str | None:
    parts = mod.rel.split("/")
    if (len(parts) >= 3 and parts[-3] == "kernels"
            and parts[-1] in config.KERNEL_FAMILY_FILES):
        return parts[-1]
    return None


# ---------------------------------------------------------------------------
# signature parity
# ---------------------------------------------------------------------------

def _twin_pairs(fns: dict[str, ast.FunctionDef]):
    for name, fn in fns.items():
        if name.startswith("_") or not (name.endswith("_skip")
                                        or name.endswith("_skip_pallas")):
            continue
        base = name.replace("_skip", "")
        yield name, fn, base, fns.get(base)


def _check_twins(mod: Module, fns: dict) -> list[Finding]:
    out: list[Finding] = []
    for name, fn, base, base_fn in _twin_pairs(fns):
        if base_fn is None:
            out.append(Finding(
                RULE, mod.rel, fn.lineno,
                f"'{name}' has no base twin '{base}' in the same module",
                hint="every *_skip executor pairs with an unindexed "
                     "oracle twin", func=name))
            continue
        skip_params = [p for p in param_names(fn)
                       if p not in config.SKIP_EXTRA_PARAMS]
        base_params = param_names(base_fn)
        if skip_params != base_params:
            out.append(Finding(
                RULE, mod.rel, fn.lineno,
                f"twin signature mismatch: '{name}'{skip_params} vs "
                f"'{base}'{base_params} (chunk-box params "
                f"{sorted(config.SKIP_EXTRA_PARAMS)} excepted)",
                hint="twins must be drop-in substitutes for the "
                     "executor selection in serve/", func=name))
    return out


# ---------------------------------------------------------------------------
# alive threading
# ---------------------------------------------------------------------------

def _check_alive(mod: Module, fns: dict) -> list[Finding]:
    out: list[Finding] = []
    for name, fn in fns.items():
        if name.startswith("_"):
            continue
        params = set(param_names(fn))
        if not params & config.MEMBER_DATA_PARAMS:
            continue
        alive = params & config.ALIVE_PARAMS
        if not alive:
            out.append(Finding(
                RULE, mod.rel, fn.lineno,
                f"probe entry point '{name}' takes member-slot data but "
                "no 'alive' tombstone mask — deleted objects would "
                "resurface on this path",
                hint="thread a keyword 'alive' (or 'galive') parameter "
                     "through, like kernels/range_probe", func=name))
            continue
        used = {n.id for n in ast.walk(fn)
                if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
        if not alive & used:
            out.append(Finding(
                RULE, mod.rel, fn.lineno,
                f"'{name}' accepts '{sorted(alive)[0]}' but never uses "
                "it — the mask is dropped on the floor",
                hint="apply the mask to the hit table / pass it down",
                func=name))
    return out


# ---------------------------------------------------------------------------
# abstract aval parity (jax.eval_shape — traces, never runs)
# ---------------------------------------------------------------------------

def _check_abstract_parity(mod: Module, fns: dict) -> list[Finding]:
    pairs = [(n, fn, b, bfn) for n, fn, b, bfn in _twin_pairs(fns)
             if bfn is not None]
    if not pairs:
        return []
    live, err = _import_module(mod)
    if live is None:
        return [Finding(RULE, mod.rel, 1,
                        f"cannot import module for abstract parity: {err}",
                        hint="the family must be importable for "
                             "jax.eval_shape checks", func="")]
    import jax
    import jax.numpy as jnp  # noqa: F401  (families assume jax present)

    def synth(pname: str):
        spec = config.ABSTRACT_SHAPES.get(pname)
        if spec is None:
            return None
        shape, dtype = spec
        return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))

    out: list[Finding] = []
    for name, fn, base, base_fn in pairs:
        kwargs, missing = _build_kwargs(fn, synth)
        bkwargs, bmissing = _build_kwargs(base_fn, synth)
        if missing or bmissing:
            for p in sorted(set(missing + bmissing)):
                out.append(Finding(
                    RULE, mod.rel, fn.lineno,
                    f"cannot synthesize abstract input for parameter "
                    f"'{p}' of twin pair '{base}'/'{name}'",
                    hint="extend ABSTRACT_SHAPES in "
                         "repro/analysis/config.py", func=name))
            continue
        for with_alive in (False, True):
            kw = dict(kwargs)
            bkw = dict(bkwargs)
            if not with_alive:
                for a in config.ALIVE_PARAMS:
                    kw.pop(a, None)
                    bkw.pop(a, None)
            try:
                got = jax.eval_shape(getattr(live, name), **kw)
                want = jax.eval_shape(getattr(live, base), **bkw)
            except Exception as e:  # trace-time type error is a finding
                out.append(Finding(
                    RULE, mod.rel, fn.lineno,
                    f"abstract trace of twin pair '{base}'/'{name}' "
                    f"(alive={'on' if with_alive else 'off'}) failed: "
                    f"{type(e).__name__}: {e}", func=name))
                break
            gf = [(x.shape, str(x.dtype)) for x in jax.tree_util.tree_leaves(got)]
            wf = [(x.shape, str(x.dtype)) for x in jax.tree_util.tree_leaves(want)]
            if gf != wf:
                out.append(Finding(
                    RULE, mod.rel, fn.lineno,
                    f"twin output avals differ "
                    f"(alive={'on' if with_alive else 'off'}): "
                    f"'{name}' -> {gf} but '{base}' -> {wf}",
                    hint="twins must agree on output shape/dtype so the "
                         "executor switch stays bit-compatible",
                    func=name))
    return out


def _build_kwargs(fn: ast.FunctionDef, synth):
    """Synthesized kwargs for every defaultless param (+ alive params,
    to exercise the mask path); returns (kwargs, unsynthesizable)."""
    a = fn.args
    pos = a.posonlyargs + a.args
    n_required = len(pos) - len(a.defaults)
    required = [p.arg for p in pos[:n_required]]
    required += [p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
                 if d is None]
    optional_alive = [p.arg for p in pos[n_required:] + a.kwonlyargs
                      if p.arg in config.ALIVE_PARAMS]
    kwargs, missing = {}, []
    for p in required:
        v = synth(p)
        if v is None:
            missing.append(p)
        else:
            kwargs[p] = v
    for p in optional_alive:
        v = synth(p)
        if v is not None:
            kwargs[p] = v
    return kwargs, missing


def _import_module(mod: Module):
    dotted = mod.rel[:-3].replace("/", ".")
    try:
        return importlib.import_module(dotted), None
    except ImportError as e:
        first = e
    # fixture trees aren't on sys.path: load straight from the file
    try:
        uniq = "reprolint_fixture_" + dotted.replace(".", "_")
        spec = importlib.util.spec_from_file_location(uniq, mod.path)
        m = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(m)
        return m, None
    except Exception as e:
        return None, f"{first} / {e}"
