"""Rule configuration for reprolint.

Everything repo-specific lives here so the rule engines in r1..r5 stay
mechanical: sink names, the staging-attribute vocabulary, the hot-path
module set for the host-sync audit, the module/function allowlist (each
entry carries its rationale — the analyzer refuses entries without one),
and the abstract-input synthesis table for kernel-twin parity.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# R1 jit-closure-capture
# ---------------------------------------------------------------------------

# A call whose callee's final name is one of these stages the callable it
# receives: closure-captured arrays become baked-in constants (the PR-5
# replicated-staging bug: per-device copies silently became one traced
# constant).  ``_call`` is the repo's own SPMD staging seam
# (serve/layout.py), included so layout code gets the same scrutiny.
JIT_SINKS = {"jit", "pmap", "pallas_call", "shard_map", "_call"}

# Attribute names that hold staged device arrays.  An attribute access
# with one of these names is classified "arrayish" regardless of the
# object it hangs off — the vocabulary is the repo's staging convention
# (StagedLayout / ShardedLayout / _TilesBase mirrors).
STAGING_ATTRS = {
    "tiles", "ids", "canon_tiles", "tile_boxes", "probe_boxes",
    "chunk_boxes", "alive", "uni", "canon_shards", "id_shards",
    "alive_shards", "chunk_shards", "staged", "slayout", "gtiles",
}

# Attribute accesses that read host-side metadata off a device array
# without a transfer — never a sync, never arrayish.
META_ATTRS = {"shape", "dtype", "ndim", "size", "nbytes", "itemsize",
              "sharding", "weak_type"}

# ---------------------------------------------------------------------------
# R2 recompile-hazard
# ---------------------------------------------------------------------------

# Calls that launder a data-dependent int into a compile-safe one.  The
# PR-7 bucketing helpers: round_up (core.partition.assign), _f_width
# (serve/engine), _pad_pow2 (serve/layout).
SANITIZER_FUNCS = {"round_up", "_f_width", "_pad_pow2"}
# Method-call sanitizers: WidthPolicy.at_least/.start and the power-of-2
# idiom ``(n - 1).bit_length()``.
SANITIZER_METHODS = {"at_least", "start", "bit_length"}

# ---------------------------------------------------------------------------
# R3 host-sync audit
# ---------------------------------------------------------------------------

# Modules on the per-batch serving hot path: a device->host fold here is
# a synchronization stall unless explicitly justified.  Matched as
# posix-path suffixes against the scanned file's path.
HOT_MODULES = (
    "serve/layout.py",
    "serve/engine.py",
    "serve/exchange.py",
    "serve/router.py",
    "query/range.py",
    "query/knn.py",
    "kernels/range_probe/ops.py",
    "kernels/range_probe/ref.py",
    "kernels/range_probe/kernel.py",
    "core/placement.py",
)

# Builtin casts that force a device->host transfer when fed a traced /
# device value, and the numpy download calls.
HOST_CAST_FUNCS = {"float", "int", "bool"}
NUMPY_DOWNLOAD_FUNCS = {"asarray", "array"}
HOST_SYNC_METHODS = {"item", "tolist"}

# ---------------------------------------------------------------------------
# Allowlist: (path suffix, function name or None for whole module, rule
# id or None for all rules, rationale).  The rationale is mandatory —
# these are deliberate host-side planes, documented here instead of
# sprinkling dozens of inline suppressions over code that is host-side
# by design.
# ---------------------------------------------------------------------------

ALLOWLIST = (
    ("serve/router.py", None, "host-sync",
     "global-index routing plane: folds overlap matrices to numpy by "
     "design — one transfer per batch, the price of host-side LPT "
     "packing and heat tracking"),
    ("core/placement.py", None, "host-sync",
     "placement planning is host-only numpy (capped LPT, co-location "
     "local search); it never sees traced values"),
    ("serve/layout.py", "stage_tiles", "host-sync",
     "staging-time capacity sizing and stats fold once per (re)stage, "
     "not per batch"),
    ("serve/layout.py", "shard_staged", "host-sync",
     "staging-time sharding planner: downloads the canonical staging "
     "once per (re)shard for host placement and the dense-oracle "
     "mirror"),
    ("serve/layout.py", "_mirror", "host-sync",
     "install-time host mirror download: ingest bookkeeping needs "
     "numpy copies of the staged arrays, once per (re)install"),
)

# ---------------------------------------------------------------------------
# R4 kernel-twin parity
# ---------------------------------------------------------------------------

# Modules making up the probe surface: every public function taking
# member-slot data must thread the tombstone mask.  kernels/<fam>/ files
# are matched by glob-ish suffix; the query/serve modules are explicit.
PROBE_SURFACE_SUFFIXES = (
    "query/range.py",
    "query/knn.py",
    "serve/exchange.py",
)
KERNEL_FAMILY_FILES = {"ops.py", "ref.py", "kernel.py"}

# Parameters that carry per-slot member data (boxes at canonical slots).
MEMBER_DATA_PARAMS = {"tiles", "gtiles", "canon_tiles"}
# Acceptable names for the threaded tombstone mask.
ALIVE_PARAMS = {"alive", "galive"}
# Extra parameters a *_skip twin may add over its base twin.
SKIP_EXTRA_PARAMS = {"cboxes", "gcboxes"}

# Abstract-aval parity via jax.eval_shape runs for these family files
# (row-major public surface).  kernel.py twins are component-major
# pallas entry points — they get signature parity only; their avals are
# covered transitively because ops.py calls them.
ABSTRACT_PARITY_FILES = {"ops.py", "ref.py"}

# Name-driven synthesis of abstract inputs: T=4 tiles, cap=128 slots,
# Q=8 queries, F=2 candidates, C=1 chunk of 128.  A required parameter
# missing from this table is itself a finding — a new family must
# extend the table, it cannot silently dodge the parity check.
ABSTRACT_SHAPES = {
    "qboxes": ((8, 4), "float32"),
    "tiles": ((4, 128, 4), "float32"),
    "gtiles": ((8, 2, 128, 4), "float32"),
    "cboxes": ((4, 1, 4), "float32"),
    "gcboxes": ((8, 2, 1, 4), "float32"),
    "cand": ((8, 2), "int32"),
    "ids": ((4, 128), "int32"),
    "alive": ((4, 128), "bool"),
    "galive": ((8, 2, 128), "bool"),
}

# ---------------------------------------------------------------------------
# R5 TileLayout conformance
# ---------------------------------------------------------------------------

PROTOCOL_NAME = "TileLayout"
REGISTRY_NAME = "_PLACEMENT_CLS"
# The PR-8 replica fan-out chain: a sharded layout's scatter must route
# through _owner_scatter -> _placements -> rep_owner so every ingest
# write lands on ALL replica copies.
FANOUT_CHAIN = ("_scatter", "_owner_scatter", "_placements", "rep_owner")
