"""R2 recompile-hazard.

The PR-7 bug class: a data-dependent Python int (``len(batch)``, a host
fold of a device reduction, a running counter) passed as a *static* jit
argument mints a fresh executable per distinct value — a recompile storm
under traffic.  The repo's contract is that every such int passes
through a bucketing sanitizer first (``round_up``, ``WidthPolicy
.at_least``, ``_pad_pow2``, ``.bit_length()``).

Uses a project-wide index of jit-staticized functions (decorator scan
with import-alias resolution) and flags tainted expressions arriving in
static parameter positions at their call sites.
"""

from __future__ import annotations

import ast

from .core import Finding, Module, Project, TaintEnv, func_defs

RULE = "recompile-hazard"


def check(project: Project) -> list[Finding]:
    out: list[Finding] = []
    for mod in project.modules:
        for fn in func_defs(mod.tree):
            out.extend(_check_function(project, mod, fn))
    return out


def _check_function(project: Project, mod: Module,
                    fn: ast.FunctionDef) -> list[Finding]:
    taint = TaintEnv(fn, mod)
    out: list[Finding] = []
    for call in ast.walk(fn):
        if not isinstance(call, ast.Call):
            continue
        target = _resolve(project, mod, call.func)
        if target is None:
            continue
        info = project.jit_static.get(target)
        if not info or not info["statics"]:
            continue
        params = info["params"]
        for i, arg in enumerate(call.args):
            if i < len(params) and params[i] in info["statics"]:
                if taint.is_tainted(arg):
                    out.append(_finding(mod, fn, call, params[i], target))
        for kw in call.keywords:
            if kw.arg in info["statics"] and taint.is_tainted(kw.value):
                out.append(_finding(mod, fn, call, kw.arg, target))
    return out


def _finding(mod: Module, fn: ast.FunctionDef, call: ast.Call,
             pname: str, target: tuple[str, str]) -> Finding:
    return Finding(
        RULE, mod.rel, call.lineno,
        f"data-dependent int flows into static arg '{pname}' of "
        f"jitted '{target[1]}' — one recompile per distinct value",
        hint="bucket it first (round_up / WidthPolicy.at_least / "
             "_pad_pow2 / .bit_length())",
        func=fn.name)


def _resolve(project: Project, mod: Module,
             func: ast.expr) -> tuple[str, str] | None:
    """Map a call's callee expression to a (module rel, fname) key in
    the project's jit-static index, through import aliases."""
    if isinstance(func, ast.Name):
        key = (mod.rel, func.id)
        return key if key in project.jit_static else None
    if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
        alias = mod.imports.get(func.value.id)
        if alias is None:
            return None
        target_mod = project.by_dotted.get(alias)
        if target_mod is None:
            return None
        key = (target_mod.rel, func.attr)
        return key if key in project.jit_static else None
    return None
