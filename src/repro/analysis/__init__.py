"""reprolint: static hazard analysis for the jax/pallas serving stack.

Five repo-specific rules, each encoding a bug class this repo actually
shipped (see docs/ARCHITECTURE.md "Static analysis"):

  jit-closure-capture   R1  arrays baked into jitted callables (PR 5)
  recompile-hazard      R2  unbucketed ints into static jit args (PR 7)
  host-sync             R3  device->host folds on hot paths
  kernel-twin-parity    R4  *_skip twins + alive threading (PR 4/7)
  layout-conformance    R5  TileLayout contract + replica fan-out (PR 8)

Entry point: ``repro.analysis.api.run`` (used by tools/reprolint.py).
The analyzer is AST + ``jax.eval_shape`` only — it never executes a
kernel.
"""

from .core import Finding  # noqa: F401
from .api import RULE_IDS, run  # noqa: F401
