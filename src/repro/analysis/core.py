"""Analyzer core: findings, suppressions, the project index, and the
small expression classifiers (arrayish / taint) the rules share.

Pure stdlib (ast + re) — jax is imported only by the R4 abstract-parity
pass, and only to trace, never to run.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path

from . import config

SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable=([A-Za-z0-9_\-, ]+?)\s*(?:--\s*(.*))?$")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # posix path relative to the scan root's parent
    line: int
    message: str
    hint: str = ""
    func: str = ""     # enclosing function, for allowlist matching

    def fingerprint(self) -> str:
        # line-free so the baseline survives unrelated edits above the site
        return f"{self.rule}::{self.path}::{self.func}::{self.message}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "func": self.func, "message": self.message,
                "hint": self.hint}

    def render(self) -> str:
        loc = f"{self.path}:{self.line}"
        tail = f"  [fix: {self.hint}]" if self.hint else ""
        return f"{loc}: {self.rule}: {self.message}{tail}"


class Module:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        # line -> set of rule ids suppressed on that line
        self.suppress: dict[int, set[str]] = {}
        self.bad_suppressions: list[Finding] = []
        self._parse_suppressions()
        self.imports = self._import_aliases()

    # -- suppressions -----------------------------------------------------

    def _parse_suppressions(self) -> None:
        from .api import RULE_IDS
        for i, raw in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(raw)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            rationale = (m.group(2) or "").strip()
            unknown = rules - set(RULE_IDS)
            if unknown:
                self.bad_suppressions.append(Finding(
                    "bad-suppression", self.rel, i,
                    f"unknown rule id(s) {sorted(unknown)} in suppression",
                    hint="valid ids: " + ", ".join(RULE_IDS)))
                rules &= set(RULE_IDS)
            if not rationale:
                self.bad_suppressions.append(Finding(
                    "bad-suppression", self.rel, i,
                    "suppression without a rationale",
                    hint="append ' -- <why this host fold / exemption is "
                         "deliberate>'"))
                continue  # a rationale-free suppression suppresses nothing
            target = i
            if raw.lstrip().startswith("#"):
                # standalone comment: applies to the next code line
                j = i
                while j < len(self.lines):
                    nxt = self.lines[j].strip()
                    if nxt and not nxt.startswith("#"):
                        target = j + 1
                        break
                    j += 1
            self.suppress.setdefault(target, set()).update(rules)

    def suppressed(self, line: int, rule: str) -> bool:
        return rule in self.suppress.get(line, set())

    # -- imports ----------------------------------------------------------

    def _import_aliases(self) -> dict[str, str]:
        """local name -> dotted module path ('' segments resolved against
        this module's package for relative imports)."""
        pkg_parts = self.rel.split("/")[:-1]  # package dirs of this module
        out: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    base = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                else:
                    base = []
                mod = ".".join(base + (node.module or "").split("."))
                for a in node.names:
                    out[a.asname or a.name] = (
                        f"{mod}.{a.name}" if mod else a.name)
        return out


class Project:
    """All modules under a scan root, plus cross-module registries."""

    def __init__(self, root: Path, files: list[Path] | None = None):
        self.root = root.resolve()
        self.modules: list[Module] = []
        self.errors: list[Finding] = []
        paths = files if files is not None else sorted(
            p for p in self.root.rglob("*.py") if "__pycache__" not in p.parts)
        anchor = self.root if self.root.is_dir() else self.root.parent
        for p in paths:
            rel = p.resolve().relative_to(anchor).as_posix()
            try:
                text = p.read_text()
                self.modules.append(Module(p, rel, text))
            except (SyntaxError, UnicodeDecodeError) as e:
                self.errors.append(Finding(
                    "parse-error", rel, getattr(e, "lineno", 0) or 0,
                    f"could not parse: {e}"))
        # dotted module name -> Module, for cross-module call resolution
        self.by_dotted: dict[str, Module] = {}
        for m in self.modules:
            dotted = m.rel[:-3].replace("/", ".")
            self.by_dotted[dotted] = m
            # also register without the leading source dir (repro.x.y)
            parts = dotted.split(".")
            for i in range(1, len(parts)):
                self.by_dotted.setdefault(".".join(parts[i:]), m)
        self.jit_static = _index_jit_statics(self.modules)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.expr) -> str:
    """'jax.numpy.sum' for nested Attribute/Name chains, '' otherwise."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def last_attr(node: ast.expr) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def func_defs(tree: ast.AST):
    """Yield (def_node, qualname-ish enclosing name) for all functions."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def module_globals(tree: ast.Module) -> set[str]:
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
        elif isinstance(node, ast.Import):
            for a in node.names:
                names.add(a.asname or a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                names.add(a.asname or a.name)
    return names


# ---------------------------------------------------------------------------
# arrayish classification (R1 / R3)
# ---------------------------------------------------------------------------

_DEVICE_ROOTS = {"jnp", "jax"}


class ArrayishEnv:
    """Forward-pass classification of local names as device-array-ish.

    Deliberately conservative: unknown stays unknown (False), so the
    rules built on it under-report rather than spam.  The vocabulary
    that makes something arrayish: jnp./jax. call results, staging
    attributes (config.STAGING_ATTRS), and values derived from either.
    """

    def __init__(self, fn: ast.FunctionDef, mod: Module):
        self.mod = mod
        self.env: dict[str, bool] = {}
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign):
                val = self.is_arrayish(stmt.value)
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        self.env[t.id] = val
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                if isinstance(stmt.target, ast.Name):
                    self.env[stmt.target.id] = self.is_arrayish(stmt.value)

    def is_arrayish(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return self.env.get(node.id, False)
        if isinstance(node, ast.Attribute):
            if node.attr in config.META_ATTRS:
                return False
            if node.attr in config.STAGING_ATTRS:
                return True
            return False
        if isinstance(node, ast.Subscript):
            return self.is_arrayish(node.value)
        if isinstance(node, ast.Call):
            root = dotted_name(node.func).split(".")[0]
            if root in _DEVICE_ROOTS:
                # jax.* / jnp.* produce device values; numpy stays host
                return True
            if isinstance(node.func, ast.Attribute):
                # method call on an arrayish value returns arrayish
                # (x.sum(), x.any(), x.astype(...))
                return self.is_arrayish(node.func.value)
            return False
        if isinstance(node, ast.BinOp):
            return (self.is_arrayish(node.left)
                    or self.is_arrayish(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.is_arrayish(node.operand)
        if isinstance(node, ast.Compare):
            return (self.is_arrayish(node.left)
                    or any(self.is_arrayish(c) for c in node.comparators))
        if isinstance(node, ast.IfExp):
            return self.is_arrayish(node.body) or self.is_arrayish(node.orelse)
        return False


# ---------------------------------------------------------------------------
# taint classification (R2)
# ---------------------------------------------------------------------------

class TaintEnv:
    """Tracks Python ints whose value depends on the data (not just on
    static shapes): ``len(...)``, host folds of device reductions, and
    arithmetic thereon.  Calls through a bucketing sanitizer clear the
    taint — that is exactly the PR-7 contract."""

    def __init__(self, fn: ast.FunctionDef, mod: Module):
        self.mod = mod
        self.env: dict[str, bool] = {}
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Assign):
                val = self.is_tainted(stmt.value)
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        self.env[t.id] = val

    def is_tainted(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return self.env.get(node.id, False)
        if isinstance(node, ast.Call):
            fname = last_attr(node.func)
            if fname in config.SANITIZER_FUNCS:
                return False
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in config.SANITIZER_METHODS):
                return False
            if fname == "len":
                return True
            if fname in {"int", "float"} and node.args:
                return self._is_device_fold(node.args[0])
            if fname in {"max", "min", "sum", "abs"}:
                return any(self.is_tainted(a) for a in node.args)
            return False
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        return False

    @staticmethod
    def _is_device_fold(node: ast.expr) -> bool:
        """int(jnp.max(counts)) / int(x.max()) — a data-dependent host
        int born from a device reduction."""
        if not isinstance(node, ast.Call):
            return False
        root = dotted_name(node.func).split(".")[0]
        if root in _DEVICE_ROOTS:
            return True
        return (isinstance(node.func, ast.Attribute)
                and node.func.attr in {"max", "min", "sum", "item"})


def _index_jit_statics(modules: list[Module]) -> dict:
    """(module, fname) -> {'params': [...], 'statics': {...}} for every
    function jitted with static_argnames, across the whole project."""
    out: dict[tuple[str, str], dict] = {}
    for m in modules:
        for fn in func_defs(m.tree):
            for dec in fn.decorator_list:
                statics = _statics_from_decorator(dec)
                if statics is None:
                    continue
                out[(m.rel, fn.name)] = {
                    "params": param_names(fn), "statics": statics,
                    "line": fn.lineno}
    return out


def _statics_from_decorator(dec: ast.expr) -> set[str] | None:
    """static_argnames from @functools.partial(jax.jit, ...) or
    @jax.jit(...) decorator forms; None if not a jit decorator."""
    if not isinstance(dec, ast.Call):
        return None
    head = last_attr(dec.func)
    target = None
    if head == "partial" and dec.args:
        if last_attr(dec.args[0]) == "jit":
            target = dec
    elif head == "jit":
        target = dec
    if target is None:
        return None
    for kw in target.keywords:
        if kw.arg == "static_argnames":
            vals: set[str] = set()
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    vals.add(el.value)
            return vals
    return set()


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def write_baseline(path: Path, findings: list[Finding]) -> None:
    path.write_text(json.dumps(
        {"fingerprints": sorted(f.fingerprint() for f in findings)},
        indent=2) + "\n")
