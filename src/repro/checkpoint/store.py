"""Checkpoint save/restore with elastic re-sharding.

Checkpoints are stored with *logical* (unsharded) shapes — one ``.npy``
per leaf plus a JSON manifest — so a checkpoint written on a 256-chip
mesh restores onto 512 chips, 8 chips, or 1 CPU device: restore simply
``device_put``s each leaf with the sharding derived from the *target*
mesh (elastic scaling).  Writes are atomic (tmp dir + rename) so a crash
mid-save never corrupts the latest checkpoint — the FT runtime
(``repro.ft``) relies on this.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        items.append((name, leaf))
    return items, treedef


def save(path: str, state, step: int) -> str:
    """Atomically write ``state`` to ``path/step_<N>``."""
    items, _ = _flatten(state)
    final = os.path.join(path, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    manifest = {"step": step, "leaves": []}
    try:
        for i, (name, leaf) in enumerate(items):
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if dtype == "bfloat16":     # numpy can't round-trip ml_dtypes
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            manifest["leaves"].append(
                {"name": name, "file": f"leaf_{i:05d}.npy",
                 "dtype": dtype, "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, like, step: int | None = None, shardings=None):
    """Restore into the structure of ``like``; per-leaf ``shardings``
    (any target mesh) makes the restore elastic."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    items, treedef = _flatten(like)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    leaves = []
    shard_items = None
    if shardings is not None:
        shard_items, _ = _flatten(shardings)
    for i, (name, leaf) in enumerate(items):
        m = by_name[name]
        arr = np.load(os.path.join(d, m["file"]))
        if m["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if shard_items is not None:
            leaves.append(jax.device_put(arr, shard_items[i][1]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
