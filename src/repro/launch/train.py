"""End-to-end training driver.

Runs a real training loop (data pipeline → balanced batching → jitted
train step → checkpoint/restart via the FT runtime) on whatever devices
exist — the production path on a pod, the example path on CPU.

  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch mamba2_1p3b --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..data import tokens as data_tokens
from ..ft.runtime import FTConfig, run_loop
from ..models import api
from ..models.config import ModelConfig
from ..optim.adamw import AdamWConfig

PRESETS = {
    # ~110M params: the end-to-end example scale
    "100m": ModelConfig(name="repro-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                        vocab=32768, head_dim=64),
    # ~20M params: fast CPU quickstart
    "20m": ModelConfig(name="repro-20m", family="dense", n_layers=8,
                       d_model=384, n_heads=6, n_kv=2, d_ff=1024,
                       vocab=8192, head_dim=64),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default=None, choices=list(PRESETS))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config of --arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.preset:
        cfg = PRESETS[args.preset]
    elif args.arch:
        cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    else:
        cfg = PRESETS["20m"]

    model = api.build(cfg)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup=args.steps // 10)
    state = api.init_train_state(model, jax.random.PRNGKey(0), opt)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"arch={cfg.name} params={n_params:,} devices={jax.device_count()}")

    step_fn = jax.jit(api.make_train_step(model, opt), donate_argnums=(0,))
    pipe = data_tokens.TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    def make_batch(i):
        b = data_tokens.batch_for_step(pipe, i)
        if cfg.family == "vlm":
            b["img"] = jnp.zeros((args.batch, cfg.vis_tokens, cfg.vis_dim),
                                 jnp.bfloat16)
        if cfg.family == "encdec":
            b["frames"] = jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, cfg.src_len, cfg.d_model),
                jnp.bfloat16)
        return b

    losses = []
    t_start = time.time()

    def logged_step(st, batch_idx):
        st, metrics = step_fn(st, make_batch(batch_idx))
        losses.append(float(metrics["loss"]))
        i = len(losses)
        if i % args.log_every == 0 or i == 1:
            dt = (time.time() - t_start) / i
            print(f"step {i:5d}  loss {losses[-1]:.4f}  "
                  f"{dt * 1e3:.0f} ms/step")
        return st, metrics

    ft = FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    state, metrics, info = run_loop(
        logged_step, state, list(range(args.steps)), ft,
        inject_failure_at=args.inject_failure_at)
    print(f"done: steps={info['steps']} restarts={info['restarts']} "
          f"first_loss={losses[0]:.4f} last_loss={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "loss did not decrease"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
