"""§Perf hillclimb driver: A/B variants of one dry-run cell.

Each named variant is a (hypothesis → change) pair from EXPERIMENTS.md
§Perf; the driver lowers+compiles each and records the three roofline
terms so before/after deltas are measured, not guessed.

  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch qwen15_4b --shape train_4k --mesh single \
      --variants baseline,micro4,micro4+fast,micro4+fast+bf16g
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

from . import dryrun  # noqa: E402

VARIANTS = {
    "baseline": {},
    "micro2": dict(n_micro=2),
    "micro4": dict(n_micro=4),
    "micro8": dict(n_micro=8),
    "fast": dict(fast_attn=True),
    "bf16g": dict(bf16_weight_gather=True),
    "dots": dict(remat="dots"),
    "noremat": dict(remat="none"),
    "moelocal": dict(moe_local=True),
    "cachehd": dict(cache_shard="hd"),
}


def variant_kwargs(spec: str) -> dict:
    kw: dict = {}
    for part in spec.split("+"):
        if part not in VARIANTS:
            raise KeyError(f"unknown variant {part!r}")
        kw.update(VARIANTS[part])
    return kw


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="runs/perf_log.jsonl")
    args = ap.parse_args(argv)

    rows = []
    with open(args.out, "a") as f:
        for spec in args.variants.split(","):
            kw = variant_kwargs(spec)
            rec = dryrun.run_cell(args.arch, args.shape,
                                  args.mesh == "multi", verbose=False, **kw)
            rec["variant"] = spec
            f.write(json.dumps(rec) + "\n")
            f.flush()
            rows.append(rec)
            if rec["status"] == "ok":
                print(f"{spec:>22}: t_comp={rec['t_compute_s']:.3f}s "
                      f"t_mem={rec['t_memory_s']:.3f}s "
                      f"t_coll={rec['t_collective_s']:.3f}s "
                      f"bound={rec['bottleneck']} "
                      f"roofline={rec['roofline_fraction']:.4f} "
                      f"peakHBM={rec['peak_memory_bytes'] / 1e9:.1f}G "
                      f"fits={rec['fits_hbm']}")
            else:
                print(f"{spec:>22}: {rec['status']} "
                      f"{rec.get('error', '')[:120]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
