"""The paper's partitioning + spatial join as a distributed ETL job.

  PYTHONPATH=src python -m repro.launch.partition_etl \
      --dataset osm --n 20000 --method bos --payload 500
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import Mesh

from ..core import metrics
from ..core.partition import api as papi, partition_counts
from ..data import spatial_gen
from ..query import engine, parallel_partition
from . import mesh as mesh_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="osm", choices=["osm", "pi"])
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--method", default="bos", choices=list(papi.methods()))
    ap.add_argument("--payload", type=int, default=500)
    ap.add_argument("--parallel", action="store_true",
                    help="use the MapReduce-style distributed partitioner")
    ap.add_argument("--join", action="store_true", help="run a self-join")
    args = ap.parse_args(argv)

    n_dev = jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(n_dev), ("d",))
    key = jax.random.PRNGKey(0)
    mbrs = spatial_gen.dataset(args.dataset, key, args.n)

    t0 = time.time()
    if args.parallel:
        parts, stats = parallel_partition.parallel_partition(
            key, mbrs, args.payload, mesh, "d")
        print(f"parallel partition stats: {stats}")
    else:
        parts = papi.partition(args.method, mbrs, args.payload)
    jax.block_until_ready(parts.boxes)
    t_part = time.time() - t0

    counts, copies = partition_counts(mbrs, parts)
    print(f"method={args.method} n={args.n} payload={args.payload} "
          f"k={int(parts.k())} time={t_part * 1e3:.1f}ms")
    print(f"  λ(boundary ratio) = {float(metrics.boundary_ratio(counts, parts.valid, args.n)):.4f}")
    print(f"  balance stddev    = {float(metrics.balance_stddev(counts, parts.valid)):.2f}")
    print(f"  skew (max/mean)   = {float(metrics.skew_ratio(counts, parts.valid)):.2f}")
    print(f"  coverage          = {float(metrics.coverage(copies)):.4f}")

    if args.join:
        s = spatial_gen.dataset(args.dataset, jax.random.PRNGKey(7), args.n)
        t0 = time.time()
        plan = engine.plan_join(args.method, mbrs, s, args.payload, n_dev)
        cnt = engine.spatial_join_count(plan, mesh, "d")
        dt = time.time() - t0
        print(f"  join: |R⋈S| = {cnt}  ({dt:.2f}s incl. planning; "
              f"tile skew {plan.stats['skew']:.2f})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
