import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ These MUST be the first two lines — before ANY other import — since
# jax locks the device count on first init.  The 512 placeholder host
# devices exist only in this process; tests and benches see 1 device.
#
# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
# Usage:
#   python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
#   python -m repro.launch.dryrun --all --mesh single --out runs/dryrun.jsonl

import argparse  # noqa: E402
import json
import sys
import time
import traceback

import jax  # noqa: E402  (after XLA_FLAGS on purpose)
import numpy as np  # noqa: E402

from .. import configs  # noqa: E402
from . import cells, mesh as mesh_lib, roofline, shapes as shapes_lib  # noqa: E402


def _cost_probe(arch, shape_name, mesh, remat, k, n_micro=1, **cellkw):
    """Compile a k-super-block reduced-depth variant with inner scans
    unrolled; returns its (flops, hbm_bytes, coll_bytes, coll_detail)."""
    from ..models import layers as layers_mod
    cfg = cells.reduced_depth_cfg(configs.get(arch), k)
    cell = cells.build_cell(arch, shape_name, mesh, remat=remat,
                            cfg_override=cfg, n_micro=n_micro, **cellkw)
    layers_mod.UNROLL_INNER_SCANS = True
    try:
        with mesh:
            compiled = cell.lower_fn().compile()
    finally:
        layers_mod.UNROLL_INNER_SCANS = False
    rl = roofline.analyze(compiled)
    return rl


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             remat: str = "full", verbose: bool = True,
             extrapolate: bool = True, n_micro: int = 1, **cellkw) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "multi" if multi_pod else "single", "chips": chips,
                 "n_micro": n_micro, **{k: v for k, v in cellkw.items() if v}}
    cell = cells.build_cell(arch, shape_name, mesh, remat=remat,
                            n_micro=n_micro, **cellkw)
    if cell is None:
        rec["status"] = "skipped"
        rec["why"] = shapes_lib.cell_supported(
            configs.get(arch), shapes_lib.SHAPES[shape_name])[1]
        return rec
    t0 = time.time()
    try:
        with mesh:
            lowered = cell.lower_fn()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            rl = roofline.analyze(compiled)
        shape = shapes_lib.SHAPES[shape_name]
        cfg = cell.cfg
        flops, hbm, coll = rl.flops, rl.hbm_bytes, rl.coll_bytes
        if extrapolate:
            # XLA cost analysis counts while-loop bodies once; recover
            # true totals from the depth-1/depth-2 reduced compiles:
            #   metric(k supers) = a + b·k  →  full = a + b·(L/|pat|)
            m1 = _cost_probe(arch, shape_name, mesh, remat, 1,
                             n_micro=n_micro, **cellkw)
            m2 = _cost_probe(arch, shape_name, mesh, remat, 2,
                             n_micro=n_micro, **cellkw)
            n_eff = cfg.n_layers / len(cfg.pattern)

            def extr(f1, f2, measured):
                # the single full-depth compile counts loop bodies once,
                # so it is a LOWER bound — never report below it
                return max((2 * f1 - f2) + (f2 - f1) * n_eff, measured, 0.0)

            flops = extr(m1.flops, m2.flops, rl.flops)
            hbm = extr(m1.hbm_bytes, m2.hbm_bytes, rl.hbm_bytes)
            coll = extr(m1.coll_bytes, m2.coll_bytes, rl.coll_bytes)
        tc = flops / roofline.PEAK_FLOPS
        tm = hbm / roofline.HBM_BW
        tl = coll / roofline.LINK_BW
        bottleneck = max([("compute", tc), ("memory", tm),
                          ("collective", tl)], key=lambda kv: kv[1])[0]
        mf = roofline.model_flops(cfg, shape, chips)
        if cell.kind == "decode":
            # decode roofline is HBM-bound: floor = (bf16 weights + KV/SSM
            # cache) read once per token, spread over the mesh
            from ..models import api as api_mod
            model_ = api_mod.build(cfg)
            with mesh_lib.make_production_mesh(multi_pod=multi_pod):
                a_cache = shapes_lib.abstract_cache(model_, cfg, shape)
            cache_bytes = sum(
                int(np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(a_cache))
            floor = (2.0 * cfg.n_params() + cache_bytes) / chips
            rec["decode_mem_floor_bytes"] = floor
            rec["decode_mem_fraction"] = round(floor / max(hbm, 1.0), 4)
        rec.update(
            status="ok", kind=cell.kind,
            t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
            flops_per_chip=flops, hbm_bytes_per_chip=hbm,
            coll_bytes_per_chip=coll,
            coll_detail={k: v for k, v in rl.coll_detail.items() if v},
            t_compute_s=tc, t_memory_s=tm, t_collective_s=tl,
            bottleneck=bottleneck,
            peak_memory_bytes=rl.peak_memory,
            model_flops_per_chip=mf,
            useful_flop_ratio=round(mf / max(flops, 1.0), 4),
            roofline_fraction=round(mf / roofline.PEAK_FLOPS
                                    / max(tc, tm, tl, 1e-12), 4),
            fits_hbm=bool(rl.peak_memory <= 16e9),
        )
        if verbose:
            print(f"--- {arch} × {shape_name} × {rec['mesh']} ---")
            print(compiled.memory_analysis())
            print({k: rec[k] for k in ("flops_per_chip",
                                       "hbm_bytes_per_chip",
                                       "coll_bytes_per_chip", "bottleneck",
                                       "roofline_fraction")})
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
        rec["trace"] = traceback.format_exc()[-2000:]
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(shapes_lib.SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--bf16-gather", action="store_true")
    ap.add_argument("--fast-attn", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    archs = configs.ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(shapes_lib.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, remat=args.remat,
                               verbose=not args.quiet, n_micro=args.micro,
                               bf16_weight_gather=args.bf16_gather,
                               fast_attn=args.fast_attn)
                line = json.dumps(rec)
                print(line if args.quiet else
                      f"[{rec['status']}] {arch} {shape} {rec['mesh']}")
                if out_f:
                    out_f.write(line + "\n")
                    out_f.flush()
                if rec["status"] == "fail":
                    n_fail += 1
                    print(rec["error"], file=sys.stderr)
    if out_f:
        out_f.close()
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
