"""Production mesh construction.

Single pod: 16×16 = 256 v5e chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — ``pod`` is
pure data parallelism over the (slow) inter-pod links; ``data`` carries
batch + FSDP; ``model`` carries tensor/expert parallelism over fast ICI.

Functions, not module constants: importing this module never touches
jax device state (required by the dry-run bootstrap ordering).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Small test mesh over however many (host) devices exist."""
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The pure-data-parallel axes (pod folds into DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)
