"""Batched greedy-decoding serving driver.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2_27b --smoke \
      --batch 8 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models import api


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_1p3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = api.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    if cfg.family == "encdec":
        from ..models import encdec
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.src_len, cfg.d_model),
            jnp.bfloat16)
        cache = encdec.init_cache(params, frames, cfg, max_len)
    else:
        cache = model.init_cache(args.batch, max_len)

    serve = jax.jit(api.make_serve_step(model), donate_argnums=(1,))
    prompt = jax.random.randint(jax.random.PRNGKey(2),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    tok = prompt[:, 0]
    t0 = time.time()
    out = []
    for pos in range(max_len - 1):
        nxt, cache = serve(params, cache, tok, pos)
        tok = jnp.where(pos + 1 < args.prompt_len, prompt[:, pos + 1], nxt)
        if pos + 1 >= args.prompt_len:
            out.append(nxt)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = args.batch * len(out)
    print(f"arch={cfg.name} generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, batch={args.batch})")
    seqs = jnp.stack(out, axis=1)
    print("sample:", seqs[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
