"""Render dry-run JSONL records into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.report runs/dryrun_baseline.jsonl
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    return f"{b / 1e9:.2f}G" if b >= 1e8 else f"{b / 1e6:.1f}M"


def fmt_t(s):
    if s <= 0:
        return "0"
    return f"{s * 1e3:.2f}ms" if s < 1 else f"{s:.2f}s"


def load(path):
    recs = [json.loads(line) for line in open(path)]
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def roofline_table(recs, mesh="single"):
    rows = [r for r in recs if r["mesh"] == mesh]
    out = ["| arch | shape | kind | t_comp | t_mem | t_coll | bound | "
           "useful | roofline | HBM/chip | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"[:-4]]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                       f"| — | — | — | skipped |")
            continue
        if r["status"] == "fail":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | "
                       f"{r.get('error', '')[:40]} | | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_t(r['t_compute_s'])} | {fmt_t(r['t_memory_s'])} | "
            f"{fmt_t(r['t_collective_s'])} | {r['bottleneck'][:4]} | "
            f"{r['useful_flop_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{fmt_bytes(r['peak_memory_bytes'])} | "
            f"{'y' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


def summary(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skipped"]
    fail = [r for r in recs if r["status"] == "fail"]
    lines = [f"cells: {len(ok)} ok, {len(skip)} skipped (documented), "
             f"{len(fail)} failed"]
    for r in fail:
        lines.append(f"  FAIL {r['arch']} {r['shape']} {r['mesh']}: "
                     f"{r.get('error', '')[:120]}")
    return "\n".join(lines)


def main():
    recs = load(sys.argv[1] if len(sys.argv) > 1
                else "runs/dryrun_baseline.jsonl")
    print(summary(recs))
    print("\n## single-pod (16×16 = 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## multi-pod (2×16×16 = 512 chips)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
