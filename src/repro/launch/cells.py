"""Dry-run cell construction: shardings + abstract inputs + lowering.

One "cell" = (architecture × input shape × mesh).  Everything here is
allocation-free: params/caches come from ``jax.eval_shape`` and inputs
are ``ShapeDtypeStruct``s, so a 480B-param cell lowers on a laptop.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import configs
from ..dist import sharding as shard_rules
from ..models import api, lm
from ..models.config import ModelConfig
from ..optim import adamw
from . import mesh as mesh_lib
from . import shapes as shapes_lib


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _div(n, size):
    return size > 1 and n % size == 0


def batch_shardings(cfg: ModelConfig, shape, mesh: Mesh):
    dp = mesh_lib.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh_lib.axis_size(mesh, a)
    b = shape.global_batch
    bspec = P(dp) if _div(b, dp_size) else P()
    out = {"tokens": _ns(mesh, P(*bspec, None))}
    if cfg.family == "vlm":
        out["img"] = _ns(mesh, P(*bspec, None, None))
    if cfg.family == "encdec":
        out["frames"] = _ns(mesh, P(*bspec, None, None))
    return out


def cache_specs(cfg: ModelConfig, shape, mesh: Mesh, cache_tree,
                cache_shard: str = "w"):
    """Sharding rules for decode caches (see DESIGN.md §4).

    Batch → data when divisible; otherwise the *length* axis of
    attention caches is sequence-sharded over data (long_500k, batch=1)
    — distributed flash-decode.  Head-like axes → model when divisible.
    """
    data = mesh_lib.axis_size(mesh, "data")
    model = mesh_lib.axis_size(mesh, "model")
    dp = mesh_lib.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh_lib.axis_size(mesh, a)
    b = shape.global_batch

    def stacked_spec(base, shp):
        """Spec for a layer-stacked cache leaf (leading L axis)."""
        nd = len(shp)
        bshard = dp if _div(b, dp_size) else None
        spec = [None] * nd
        if base in ("k", "v"):
            # (L, B, W, KV, hd)  (cross/self caches share the layout).
            # Batch → dp; then either the *length* axis → model (+ data
            # when batch can't shard) — flash-decode partial-softmax
            # combine — or, with cache_shard="hd", the head_dim axis →
            # model (keeps the ring-buffer write local; §Perf variant).
            spec[1] = bshard
            if cache_shard == "hd" and _div(shp[4], model):
                spec[4] = "model"
                if bshard is None and _div(shp[2], data):
                    spec[2] = "data"
                return spec
            w_axes = []
            if bshard is None and _div(shp[2], data):
                w_axes.append("data")
            if _div(shp[2], model):
                w_axes.append("model")
            if w_axes:
                spec[2] = tuple(w_axes) if len(w_axes) > 1 else w_axes[0]
            elif _div(shp[3], model):
                spec[3] = "model"
            return spec
        if base in ("state", "conv", "h"):
            # state: (L, B, H, S, P) — H → model;
            # conv:  (L, B, K, C)    — C → model;
            # h:     (L, B, W)       — W → model.
            spec[1] = bshard
            axis = 2 if base == "state" else nd - 1
            if _div(shp[axis], model):
                spec[axis] = "model"
            return spec
        return spec

    def leaf_spec(path, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        base = name.rsplit("/", 1)[-1]
        if name.startswith("rest/") or "/rest/" in name:
            # remainder layers are unstacked: rule shifts left by one
            return P(*stacked_spec(base, (1,) + leaf.shape)[1:])
        return P(*stacked_spec(base, leaf.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = [leaf_spec(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    cfg: ModelConfig
    kind: str
    lower_fn: object            # () -> jax.stages.Lowered


def reduced_depth_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """Same config at k super-blocks of depth (for cost extrapolation —
    XLA cost analysis counts while-loop bodies once, so per-layer costs
    are recovered from the depth-1/depth-2 delta)."""
    kw = dict(n_layers=k * len(cfg.pattern))
    if cfg.family == "encdec":
        kw["enc_layers"] = k
    return dataclasses.replace(cfg, **kw)


def build_cell(arch: str, shape_name: str, mesh: Mesh,
               remat: str = "full",
               opt_policy: str | None = None,
               cfg_override: ModelConfig | None = None,
               n_micro: int = 1,
               bf16_weight_gather: bool = False,
               fast_attn: bool = False,
               moe_local: bool = False,
               cache_shard: str = "w") -> Cell | None:
    from ..models import layers as layers_mod, moe as moe_mod
    layers_mod.FAST_ATTN = fast_attn
    cfg = cfg_override or configs.get(arch)
    shape = shapes_lib.SHAPES[shape_name]
    if moe_local and cfg.n_experts:
        moe_mod.set_local_moe((mesh, mesh_lib.dp_axes(mesh), "model",
                               "data"))
        # local-TP MoE wants F-sharded expert weights (see moe.py)
        cfg = dataclasses.replace(cfg, shard_experts=False)
    else:
        moe_mod.set_local_moe(None)
    ok, why = shapes_lib.cell_supported(cfg, shape)
    if not ok:
        return None
    model = api.build(cfg)
    dp0 = mesh_lib.dp_axes(mesh)
    dp0_size = 1
    for a in dp0:
        dp0_size *= mesh_lib.axis_size(mesh, a)
    if _div(shape.global_batch, dp0_size):
        lm.set_activation_spec(P(dp0, None, None))
    else:
        lm.set_activation_spec(None)
    pspecs = shard_rules.param_specs(
        model.init_params and shapes_lib.abstract_params(model),
        shard_experts=cfg.shard_experts, mesh=mesh)
    pshard = jax.tree.map(lambda s: _ns(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        policy = opt_policy or ("bf16_mv" if cfg.name == "arctic-480b"
                                else "fp32")
        opt_cfg = adamw.AdamWConfig(state_policy=policy)
        step = api.make_train_step(model, opt_cfg, remat=remat,
                                   n_micro=n_micro,
                                   bf16_weight_gather=bf16_weight_gather)
        a_state = jax.eval_shape(
            partial(api.init_train_state, model, opt_cfg=opt_cfg),
            jax.random.PRNGKey(0))
        s_shard = api.TrainState(
            params=pshard,
            opt=adamw.OptState(m=pshard, v=pshard,
                               step=_ns(mesh, P())),
            step=_ns(mesh, P()))
        b_shard = batch_shardings(cfg, shape, mesh)
        a_batch = shapes_lib.batch_specs(cfg, shape)

        def lower():
            jf = jax.jit(step, in_shardings=(s_shard, b_shard),
                         out_shardings=(s_shard, None),
                         donate_argnums=(0,))
            return jf.lower(a_state, a_batch)
        return Cell(arch, shape_name, cfg, "train", lower)

    if shape.kind == "prefill":
        step = api.make_prefill_step(model)
        a_params = shapes_lib.abstract_params(model)
        b_shard = batch_shardings(cfg, shape, mesh)
        a_batch = shapes_lib.batch_specs(cfg, shape)
        dp = mesh_lib.dp_axes(mesh)
        dp_size = 1
        for a in dp:
            dp_size *= mesh_lib.axis_size(mesh, a)
        ospec = [dp if _div(shape.global_batch, dp_size) else None, None]
        model_sz = mesh_lib.axis_size(mesh, "model")
        if _div(cfg.vocab_padded, model_sz):
            ospec[1] = "model"
        o_shard = _ns(mesh, P(*ospec))

        def lower():
            jf = jax.jit(step, in_shardings=(pshard, b_shard),
                         out_shardings=o_shard)
            return jf.lower(a_params, a_batch)
        return Cell(arch, shape_name, cfg, "prefill", lower)

    # decode
    step = api.make_serve_step(model)
    a_params = shapes_lib.abstract_params(model)
    with mesh:   # enc-dec cache init traces encode() → needs mesh context
        a_cache = shapes_lib.abstract_cache(model, cfg, shape)
    cspecs = cache_specs(cfg, shape, mesh, a_cache, cache_shard)
    cshard = jax.tree.map(lambda s: _ns(mesh, s), cspecs,
                          is_leaf=lambda x: isinstance(x, P))
    dp = mesh_lib.dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh_lib.axis_size(mesh, a)
    tok_spec = P(dp) if _div(shape.global_batch, dp_size) else P()
    tok_shard = _ns(mesh, tok_spec)
    a_tok = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    a_pos = jax.ShapeDtypeStruct((), jnp.int32)

    def lower():
        jf = jax.jit(step,
                     in_shardings=(pshard, cshard, tok_shard, None),
                     out_shardings=(tok_shard, cshard),
                     donate_argnums=(1,))     # cache is updated in place
        return jf.lower(a_params, a_cache, a_tok, a_pos)
    return Cell(arch, shape_name, cfg, "decode", lower)
