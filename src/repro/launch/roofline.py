"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all per-chip (the compiled SPMD
module is the per-device program, so its FLOPs/bytes/operand sizes are
already shard-local):

  compute    = HLO_FLOPs        / peak_FLOPs            [197e12 bf16]
  memory     = HLO_bytes        / HBM_bw                [819e9 B/s]
  collective = Σ link_bytes(op) / link_bw               [50e9 B/s]

link_bytes applies the ring cost model per op: all-reduce moves ~2×
its operand per link; all-gather / reduce-scatter / all-to-all /
collective-permute move ~1× their (shard) operand.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

PEAK_FLOPS = 197e12          # TPU v5e bf16
HBM_BW = 819e9               # B/s
LINK_BW = 50e9               # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# Per-chip link bytes under a ring algorithm, in terms of the op's
# RESULT size R and group size g (compiled HLO prints result types only;
# operands are bare SSA refs):
#   all-reduce:         operand==result==R; ring moves 2R(g−1)/g ≈ 2R
#   all-gather:         result R = g·operand; ring moves R(g−1)/g ≈ R
#   reduce-scatter:     result R = operand/g; ring moves R(g−1)
#   all-to-all:         moves R(g−1)/g ≈ R
#   collective-permute: moves R
_COLL_RESULT_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / max(g, 1),
    "all-gather": lambda g: 1.0 * (g - 1) / max(g, 1),
    "reduce-scatter": lambda g: float(g - 1),
    "all-to-all": lambda g: 1.0 * (g - 1) / max(g, 1),
    "collective-permute": lambda g: 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_LINE_RE = re.compile(
    r"=\s+((?:\([^)]*\)|\S+))\s+(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, default_group: int = 16) -> dict:
    """Per-op-kind per-chip link bytes (ring model) from compiled HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLL_RESULT_FACTOR}
    counts: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        result_type, kind = m.group(1), m.group(2)
        r = _type_bytes(result_type)
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else default_group
        out[kind] += r * _COLL_RESULT_FACTOR[kind](g)
        counts[kind] = counts.get(kind, 0) + 1
    out["total"] = sum(out.values())
    out["ops"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                # per-chip HLO flops
    hbm_bytes: float            # per-chip bytes accessed
    coll_bytes: float           # per-chip link bytes (ring model)
    coll_detail: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    peak_memory: int            # per-chip bytes (from memory_analysis)

    def dominant(self):
        return max(("compute", self.t_compute),
                   ("memory", self.t_memory),
                   ("collective", self.t_collective), key=lambda kv: kv[1])


def analyze(compiled) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    tc = flops / PEAK_FLOPS
    tm = hbm / HBM_BW
    tl = coll["total"] / LINK_BW
    ma = compiled.memory_analysis()
    peak = 0
    if ma is not None:
        peak = int(getattr(ma, "argument_size_in_bytes", 0)
                   + getattr(ma, "output_size_in_bytes", 0)
                   + getattr(ma, "temp_size_in_bytes", 0)
                   - getattr(ma, "alias_size_in_bytes", 0))
    name = max([("compute", tc), ("memory", tm), ("collective", tl)],
               key=lambda kv: kv[1])[0]
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll["total"],
                    coll_detail=coll, t_compute=tc, t_memory=tm,
                    t_collective=tl, bottleneck=name, peak_memory=peak)


def model_flops(cfg, shape, chips: int) -> float:
    """6·N_active·D per chip (dense: N_active = N)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * n * tokens / chips


def useful_ratio(cfg, shape, chips: int, rl: Roofline) -> float:
    return model_flops(cfg, shape, chips) / max(rl.flops, 1.0)
