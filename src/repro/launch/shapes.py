"""Assigned input shapes and abstract input specs (no allocation).

Four shapes per architecture:
  train_4k     seq 4096,   global_batch 256  → train_step
  prefill_32k  seq 32768,  global_batch 32   → prefill_step
  decode_32k   seq 32768 (KV cache), gb 128  → serve_step
  long_500k    seq 524288 (KV cache), gb 1   → serve_step (sub-quadratic
               archs only; skips recorded in DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.api import Model
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k policy: sub-quadratic attention only (see DESIGN.md)
LONG_OK = {"gemma2-27b", "mixtral-8x22b", "recurrentgemma-9b", "mamba2-1.3b"}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_OK:
        return False, "pure full-attention arch: long_500k skipped"
    return True, ""


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract ShapeDtypeStructs for the (train/prefill) batch."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        specs["img"] = jax.ShapeDtypeStruct(
            (b, cfg.vis_tokens, cfg.vis_dim), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.src_len, cfg.d_model), jnp.bfloat16)
    return specs


def abstract_params(model: Model, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(model.init_params, key)


def abstract_cache(model: Model, cfg: ModelConfig, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        from ..models import encdec
        params = abstract_params(model)
        frames = jax.ShapeDtypeStruct((b, cfg.src_len, cfg.d_model),
                                      jnp.bfloat16)
        return jax.eval_shape(
            partial(encdec.init_cache, cfg=cfg, max_len=s), params, frames)
    return jax.eval_shape(partial(lm.init_cache, cfg, b, s))
