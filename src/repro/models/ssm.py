"""Mamba2 (SSD) block — train via the chunked Pallas kernel, decode via
the O(1)-state recurrence.

Param/layout follows the paper (arXiv:2405.21060): in_proj → (z, x, B,
C, dt); causal depthwise conv on (x, B, C); SSD; gated RMSNorm; out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from ..kernels.ssd import ops as ssd_ops


def dims(cfg):
    din = cfg.ssm_expand * cfg.d_model
    h = din // cfg.ssm_head_dim
    return din, h, cfg.ssm_head_dim, cfg.ssm_groups, cfg.ssm_state


def init_params(key, cfg, n_stack):
    d = cfg.d_model
    din, h, p_, g, s = dims(cfg)
    conv_ch = din + 2 * g * s
    ks = jax.random.split(key, 5)
    return {
        "in_proj": layers.dense_init(
            ks[0], (n_stack, d, 2 * din + 2 * g * s + h), jnp.float32),
        "conv_w": layers.dense_init(
            ks[1], (n_stack, cfg.ssm_conv, conv_ch), jnp.float32),
        "a_log": jnp.zeros((n_stack, h), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_stack, h), jnp.float32),
        "d_skip": jnp.ones((n_stack, h), jnp.float32),
        "gnorm": jnp.zeros((n_stack, din), jnp.float32),
        "out_proj": layers.dense_init(ks[2], (n_stack, din, d), jnp.float32),
    }


def _causal_dconv(u, w):
    """u: (B, L, C), w: (K, C) depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i:i + u.shape[1]] * w[i]
    return out


def _split(proj, cfg):
    din, h, p_, g, s = dims(cfg)
    z = proj[..., :din]
    xbc = proj[..., din:din + din + 2 * g * s]
    dt = proj[..., -h:]
    return z, xbc, dt


def forward(x, p, cfg, chunk=128):
    """Train-time forward. x: (B, L, D) -> (B, L, D)."""
    b, l, d = x.shape
    din, h, hp, g, s = dims(cfg)
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split(proj, cfg)
    xbc = jax.nn.silu(_causal_dconv(xbc, p["conv_w"].astype(x.dtype)))
    xs = xbc[..., :din].reshape(b, l, h, hp)
    bmat = xbc[..., din:din + g * s].reshape(b, l, g, s)
    cmat = xbc[..., din + g * s:].reshape(b, l, g, s)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_log = -jnp.exp(p["a_log"])

    pad = (-l) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y = ssd_ops.ssd_forward(xs.astype(jnp.float32), dt, a_log,
                            bmat.astype(jnp.float32),
                            cmat.astype(jnp.float32), chunk=chunk)
    y = y[:, :l] + xs[:, :l].astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, l, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = layers.rms_norm(y, p["gnorm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype)


def init_cache(cfg, batch, dtype):
    din, h, hp, g, s = dims(cfg)
    conv_ch = din + 2 * g * s
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, h, s, hp), jnp.float32),
    }


def decode_step(x, cache, p, cfg):
    """x: (B, 1, D) -> (y, new_cache); O(1) in sequence length."""
    b = x.shape[0]
    din, h, hp, g, s = dims(cfg)
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split(proj, cfg)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xbc_c = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w))[:, None, :]
    new_conv = hist[:, 1:]
    xs = xbc_c[..., :din].reshape(b, h, hp)
    bmat = xbc_c[..., din:din + g * s].reshape(b, g, s)
    cmat = xbc_c[..., din + g * s:].reshape(b, g, s)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = jnp.exp(dtv * (-jnp.exp(p["a_log"])))                # (B, H)
    rep = h // g
    bh = jnp.repeat(bmat, rep, axis=1)                       # (B, H, S)
    ch = jnp.repeat(cmat, rep, axis=1)
    state = cache["state"] * a[..., None, None] + \
        dtv[..., None, None] * jnp.einsum("bhs,bhp->bhsp", bh,
                                          xs.astype(jnp.float32))
    y = jnp.einsum("bhs,bhsp->bhp", ch, state)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype) * jax.nn.silu(z)
    y = layers.rms_norm(y, p["gnorm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype), {
        "conv": new_conv, "state": state}
