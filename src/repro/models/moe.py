"""Top-k routed MoE FFN with sort-based capacity dispatch.

TPU-native formulation (no ragged shapes): token→expert assignment is a
single stable sort; each expert receives a fixed-capacity buffer; two
batched einsums run all experts; a gather + weighted sum combines.  This
is the paper's skewed-partition problem in router space — the capacity
bound is the payload bound ``b``, and dropped tokens are the analogue of
partition overflow (balance is reported with the same metrics module).

Sharding: expert-stacked weights (E, D, F) go ``E→model`` when
``shard_experts`` (arctic, 128 experts) or ``F→model`` when experts are
few (mixtral, 8 experts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers


def init_params(key, cfg, n_stack):
    d = cfg.d_model
    fe = cfg.moe_ff or cfg.d_ff
    e = cfg.n_experts
    keys = jax.random.split(key, 4)
    p = {
        "wr": layers.dense_init(keys[0], (n_stack, d, e), jnp.float32),
        "w1": layers.dense_init(keys[1], (n_stack, e, d, fe), jnp.float32),
        "w3": layers.dense_init(keys[2], (n_stack, e, d, fe), jnp.float32),
        "w2": layers.dense_init(keys[3], (n_stack, e, fe, d), jnp.float32),
    }
    return p


# §Perf: local-dispatch MoE (set by the launcher). GSPMD cannot prove
# locality of the data-dependent dispatch scatter/gather and falls back
# to replicating the (E, C, D) buffers across the mesh — the dominant
# collective cost of the MoE baselines. Under shard_map each device
# dispatches ONLY its own tokens into a local capacity buffer (classic
# local-capacity MoE), with FSDP weight all-gather + TP output psum as
# the only communication — the same bytes a dense TP MLP pays.
# Value: (mesh, dp_axes, tp_axis, fsdp_axis) or None.
_LOCAL_SPEC = None


def set_local_moe(spec) -> None:
    global _LOCAL_SPEC
    _LOCAL_SPEC = spec


def moe_ffn_local(x, p, cfg):
    """shard_map'd MoE: per-device dispatch, dense-TP-equivalent comm."""
    import jax.lax as lax
    from jax.sharding import PartitionSpec as P
    mesh, dp, tp, fsdp = _LOCAL_SPEC

    def local_fn(x_l, wr, w1, w3, w2):
        # gather FSDP (data-axis) weight shards: (E, D/f, F/t) -> (E, D, F/t)
        if fsdp:
            wr = lax.all_gather(wr, fsdp, axis=0, tiled=True)
            w1 = lax.all_gather(w1, fsdp, axis=1, tiled=True)
            w3 = lax.all_gather(w3, fsdp, axis=1, tiled=True)
            w2 = lax.all_gather(w2, fsdp, axis=2, tiled=True)
        y, aux = _moe_math(x_l, {"wr": wr, "w1": w1, "w3": w3, "w2": w2},
                           cfg)
        y = lax.psum(y, tp)          # TP combine over the F shards
        aux = {k: lax.pmean(lax.pmean(v, tp), dp) for k, v in aux.items()}
        return y, aux

    act = P(dp, None, None)
    from ..core.compat import shard_map
    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(act, P(fsdp, None), P(None, fsdp, tp), P(None, fsdp, tp),
                  P(None, tp, fsdp)),
        out_specs=(act, P()),
        check_vma=False,
    )(x, p["wr"].astype(x.dtype), p["w1"].astype(x.dtype),
      p["w3"].astype(x.dtype), p["w2"].astype(x.dtype))


def moe_ffn(x, p, cfg):
    """x: (B, S, D); p: one layer's params {wr, w1, w3, w2}.

    Returns (y, aux) with load-balance loss + expert-payload stats.
    """
    if _LOCAL_SPEC is not None:
        return moe_ffn_local(x, p, cfg)
    return _moe_math(x, p, cfg)


def _moe_math(x, p, cfg):
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    cap = max(1, int(cfg.capacity_factor * t * k / e))
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["wr"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate, eids = jax.lax.top_k(probs, k)                     # (T, k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # ---- dispatch: stable sort by expert id ----
    flat_e = eids.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e,
                                 num_segments=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(t * k) - starts[sorted_e]
    keep = rank_sorted < cap
    slot = jnp.where(keep, rank_sorted, cap)                 # cap = trash
    tok_sorted = order // k

    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[sorted_e, slot].set(xt[tok_sorted])
    buf = buf[:, :cap]                                       # (E, C, D)

    # ---- expert compute (batched over E) ----
    h = layers.act_fn(cfg.act)(
        jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype))
    ) * jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(x.dtype))
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    y_e = jnp.concatenate(
        [y_e, jnp.zeros((e, 1, d), y_e.dtype)], axis=1)      # trash row = 0

    # ---- combine ----
    inv = jnp.argsort(order, stable=True)                    # flat -> sorted
    rank_flat = jnp.where(keep, rank_sorted, cap)[inv]
    y_tk = y_e[flat_e, rank_flat].reshape(t, k, d)
    y = jnp.sum(y_tk * gate[..., None].astype(y_tk.dtype), axis=1)

    # aux: Switch-style load-balance loss + payload skew (paper metric)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(eids[:, 0], e, dtype=jnp.float32), axis=0)
    lb_loss = e * jnp.sum(me * ce)
    payload = counts.astype(jnp.float32)
    aux = {
        "lb_loss": lb_loss,
        "expert_skew": jnp.max(payload) / jnp.maximum(jnp.mean(payload), 1e-9),
        "drop_frac": 1.0 - jnp.sum(jnp.minimum(payload, cap)) / (t * k),
    }
    return y.reshape(b, s, d), aux
