"""Decoder-only LM assembly (dense / moe / ssm / hybrid / vlm families).

Params are nested dicts; every per-layer leaf is stacked over the
super-block axis and consumed by one ``lax.scan`` (plus unstacked
``rest`` remainder layers).  Works under ``jax.eval_shape`` for the
abstract dry-run path (no device allocation for 480B-param configs).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from . import blocks, layers
from .config import ModelConfig


def structure(cfg: ModelConfig):
    pat = cfg.pattern
    n_super = cfg.n_layers // len(pat)
    rest = cfg.n_layers - n_super * len(pat)
    return pat, n_super, rest


# Residual-stream sharding constraint (set by the launcher under a mesh
# context; None for single-device tests).  Pinning the layer-boundary
# activations to (batch→dp, seq→None, d→None) stops GSPMD from trading
# the batch sharding away for the FSDP weight sharding (see DESIGN.md).
_ACT_SPEC = None


def set_activation_spec(spec) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def constrain(x):
    if _ACT_SPEC is not None:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    pat, n_super, rest = structure(cfg)
    ks = jax.random.split(key, 8 + len(pat) + rest)
    d, v = cfg.d_model, cfg.vocab_padded
    params: dict[str, Any] = {
        "embed": layers.dense_init(ks[0], (v, d), jnp.float32),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.dense_init(ks[1], (v, d), jnp.float32)
    params["blocks"] = {
        f"p{i}": blocks.block_init(ks[2 + i], cfg, kind, n_super)
        for i, kind in enumerate(pat)
    }
    if rest:
        params["rest"] = {
            f"r{i}": jax.tree.map(
                lambda a: a[0],
                blocks.block_init(ks[2 + len(pat) + i], cfg, pat[i], 1))
            for i in range(rest)
        }
    if cfg.family == "vlm":
        params["vis_proj"] = layers.dense_init(
            ks[-1], (cfg.vis_dim, d), jnp.float32)
    return params


def _embed_in(params, tokens, cfg, img=None):
    x = params["embed"].astype(_dt(cfg))[tokens]
    if cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if img is not None:
        vis = img.astype(x.dtype) @ params["vis_proj"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
    return x


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _logits_of(x, params, cfg):
    w_out = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w_out.astype(x.dtype).T).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.vocab_padded != cfg.vocab:   # mask pad rows out of the softmax
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab, logits, -1e9)
    return logits


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            img: jax.Array | None = None, remat: str = "full",
            logits_mode: str = "all") -> tuple:
    """Teacher-forcing forward -> (logits fp32, aux).

    logits_mode="last" computes the unembed only for the final position
    (prefill path) — the (B, S, V) tensor never exists.
    """
    pat, n_super, rest = structure(cfg)
    x = _embed_in(params, tokens, cfg, img)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    x = constrain(x)

    def body(h, layer_params):
        auxes = {}
        for i, kind in enumerate(pat):
            h, aux = blocks.apply_block(h, layer_params[f"p{i}"], cfg, kind,
                                        positions)
            h = constrain(h)
            for k2, v2 in aux.items():
                auxes[f"{kind}{i}_{k2}"] = v2
        return h, auxes

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    if n_super > 0:
        x, auxes = lax.scan(body, x, params["blocks"],
                            unroll=n_super
                            if layers.UNROLL_INNER_SCANS else 1)
        aux = {k2: jnp.mean(v2) for k2, v2 in auxes.items()}
    else:
        aux = {}
    for i in range(rest):
        x, a = blocks.apply_block(x, params["rest"][f"r{i}"], cfg, pat[i],
                                  positions)
        aux.update({f"rest{i}_{k2}": v2 for k2, v2 in a.items()})

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "last":
        x = x[:, -1:]
    logits = _logits_of(x, params, cfg)
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, remat: str = "full"):
    """Next-token CE (+ MoE load-balance aux).  batch: {tokens, [img]}.

    Single-pass CE: nll = logsumexp(logits) − logits[label], so exactly
    one (B, S, V) buffer is live (log_softmax would make two)."""
    tokens = batch["tokens"]
    img = batch.get("img")
    logits, aux = forward(params, tokens, cfg, img=img, remat=remat)
    # image prefix (if any) carries no labels
    txt_logits = logits[:, -tokens.shape[1]:][:, :-1]
    tgt = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(txt_logits, axis=-1)
    true = jnp.take_along_axis(txt_logits, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - true)
    # z-loss keeps the softmax normalizer in check (production trick)
    loss = loss + 1e-4 * jnp.mean(lse ** 2)
    for k, v in aux.items():
        if k.endswith("lb_loss"):
            loss = loss + 0.01 * v
    return loss, aux


# ------------------------------ decode ------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    pat, n_super, rest = structure(cfg)
    dt = _dt(cfg)
    cache = {
        f"p{i}": blocks.block_cache_init(cfg, kind, batch, max_len, n_super, dt)
        for i, kind in enumerate(pat)
    }
    if rest:
        cache["rest"] = {
            f"r{i}": jax.tree.map(
                lambda a: a[0],
                blocks.block_cache_init(cfg, pat[i], batch, max_len, 1, dt))
            for i in range(rest)
        }
    return cache


def decode_step(params: dict, cache: dict, token: jax.Array, pos: jax.Array,
                cfg: ModelConfig):
    """One greedy decode step.  token: (B,) int32 -> (logits, new_cache)."""
    pat, n_super, rest = structure(cfg)
    x = _embed_in(params, token[:, None], cfg)

    def body(h, inp):
        layer_params, layer_cache = inp
        new_cache = {}
        for i, kind in enumerate(pat):
            h, nc = blocks.decode_block(h, layer_params[f"p{i}"],
                                        layer_cache[f"p{i}"], cfg, kind, pos)
            new_cache[f"p{i}"] = nc
        return h, new_cache

    blk_cache = {k: cache[k] for k in cache if k != "rest"}
    if n_super > 0:
        x, new_blk = lax.scan(body, x, (params["blocks"], blk_cache),
                              unroll=n_super
                              if layers.UNROLL_INNER_SCANS else 1)
    else:
        new_blk = blk_cache
    new_cache = dict(new_blk)
    if rest:
        new_cache["rest"] = {}
        for i in range(rest):
            x, nc = blocks.decode_block(x, params["rest"][f"r{i}"],
                                        cache["rest"][f"r{i}"], cfg, pat[i],
                                        pos)
            new_cache["rest"][f"r{i}"] = nc

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits_of(x[:, 0], params, cfg)
    return logits, new_cache
