"""Model configuration covering every assigned architecture family."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int | None = None

    # attention variants
    qkv_bias: bool = False
    logit_softcap: float | None = None    # gemma2 final-logit softcap
    attn_softcap: float | None = None     # gemma2 attention softcap
    window: int | None = None             # SWA (mixtral)
    local_global: bool = False            # gemma2 alternating local/global
    local_window: int = 4096
    post_norms: bool = False              # gemma2 sandwich norms
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False          # arctic dense+MoE parallel
    moe_ff: int | None = None             # expert hidden size if != d_ff
    shard_experts: bool = True            # EP over the model axis

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2

    # hybrid (recurrentgemma): repeating block pattern
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "local")
    rglru_width: int | None = None

    # encoder-decoder (whisper)
    enc_layers: int = 0
    src_len: int = 1500

    # vlm (internvl): stub frontend provides patch embeddings
    vis_tokens: int = 0
    vis_dim: int = 0

    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a 256 multiple so the unembed shards over the
        model axis (and rows align with the MXU); padded logit rows are
        masked to -1e9 in loss/decode."""
        return -(-self.vocab // 256) * 256

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        if self.family == "ssm":
            return ("ssm",)
        if self.local_global:
            return ("local", "global")
        if self.family == "moe":
            return ("moe",)
        return ("full",)

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND model-flops)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd, h, kv = self.hd, self.n_heads, self.n_kv
        total = v * d * (1 if self.tie_embeddings else 2)
        per = {}
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * f
        fe = self.moe_ff or f
        moe = self.n_experts * 3 * d * fe + d * self.n_experts
        din = self.ssm_expand * d
        ssm = d * (2 * din + 2 * self.ssm_groups * self.ssm_state
                   + self.ssm_heads) + din * d
        w = self.rglru_width or d
        rec = 2 * d * w + w * d + 3 * w
        per["full"] = per["local"] = per["global"] = attn + mlp
        per["moe"] = attn + moe + (mlp if self.dense_residual else 0)
        per["ssm"] = ssm
        per["rec"] = rec + mlp
        pat = self.pattern
        for i in range(self.n_layers):
            kind = pat[i % len(pat)]
            total += per.get(kind, attn + mlp)
        if self.family == "encdec":
            total += self.enc_layers * (2 * attn + mlp)  # self+cross approx
        if self.family == "vlm":
            total += self.vis_dim * self.d_model
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.n_params()
        d = self.d_model
        fe = self.moe_ff or self.d_ff
        full = self.n_params()
        inactive = (self.n_experts - self.top_k) * 3 * d * fe
        pat = self.pattern
        n_moe = sum(1 for i in range(self.n_layers)
                    if pat[i % len(pat)] == "moe")
        return full - n_moe * inactive
