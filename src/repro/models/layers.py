"""Shared neural layers: norms, RoPE, attention (train + decode), MLP.

Training attention is *KV-chunked online-softmax* (flash-attention
pattern in pure JAX): a ``lax.scan`` over KV chunks carrying running
(max, denom, acc), bounding activation memory at O(S·C) per head instead
of O(S²) while keeping the HLO small for scan-over-layers compilation.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

_INIT = jax.nn.initializers.normal(stddev=0.02)

# Roofline-accounting mode: XLA's cost analysis counts while-loop bodies
# once, so the dry-run's reduced-depth cost cells unroll the inner
# (KV-chunk) scans to make every FLOP visible in the HLO.
UNROLL_INNER_SCANS = False

# §Perf iteration: bf16 score/probability tensors in attention (fp32
# running max/denominator, MXU-native bf16 matmuls) — halves the
# dominant score-traffic term. Toggled by the launcher for A/B runs.
FAST_ATTN = False


def dense_init(key, shape, dtype):
    return _INIT(key, shape, dtype)


def rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta):
    """x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # ang: (..., S, 1, half), broadcast over the head axis
    ang = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:2 * half]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rest = x[..., 2 * half:]
    return jnp.concatenate([out1.astype(x.dtype), out2.astype(x.dtype), rest],
                           axis=-1)


def _softcap(x, cap):
    return cap * jnp.tanh(x / cap)


def chunked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      chunk=512, q_offset=0):
    """Online-softmax attention.

    q: (B, Sq, H, hd), k/v: (B, Sk, KV, hd) with H % KV == 0.
    ``window``: sliding-window size (None = full causal).
    ``q_offset``: absolute position of q[0] relative to k[0].
    Returns (B, Sq, H, hd) in q.dtype.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    score_dt = jnp.bfloat16 if FAST_ATTN else jnp.float32
    qf = (q.astype(score_dt) * jnp.asarray(scale, score_dt)
          ).reshape(b, sq, kv, rep, hd)
    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(kp.reshape(b, nchunks, chunk, kv, hd), 1, 0)
    vc = jnp.moveaxis(vp.reshape(b, nchunks, chunk, kv, hd), 1, 0)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc, cidx = carry
        k_blk, v_blk = inp
        s = jnp.einsum("bqgrh,bcgh->bqgrc", qf, k_blk.astype(score_dt),
                       preferred_element_type=jnp.float32)
        if softcap is not None:
            s = _softcap(s, softcap)
        k_pos = cidx * chunk + jnp.arange(chunk)
        valid = k_pos[None, :] < sk
        if causal:
            valid = valid & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            valid = valid & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(valid[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqgrc,bcgh->bqgrh", p.astype(score_dt),
            v_blk.astype(score_dt), preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new, cidx + 1), None

    m0 = jnp.full((b, sq, kv, rep), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, rep), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, rep, hd), jnp.float32)
    (m, l, acc, _), _ = lax.scan(step, (m0, l0, a0, 0), (kc, vc),
                                 unroll=nchunks if UNROLL_INNER_SCANS
                                 else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, *, window=None,
                     softcap=None):
    """Single-token attention against a (possibly ring-buffered) cache.

    q: (B, 1, H, hd); k/v_cache: (B, W, KV, hd); ``length`` = number of
    tokens written so far (ring wraps when length > W).  When the KV
    cache is sequence-sharded under pjit, the max/sum reductions lower
    to small all-reduces — distributed flash-decode for free.
    """
    b, w, kv, hd = k_cache.shape
    h = q.shape[2]
    rep = h // kv
    scale = hd ** -0.5
    # read the bf16 cache directly with fp32 accumulation — an explicit
    # fp32 cast would materialise a 2× copy of the (dominant) cache
    # traffic (§Perf decode iteration 1)
    qf = (q.astype(k_cache.dtype) * jnp.asarray(scale, k_cache.dtype)
          ).reshape(b, kv, rep, hd)
    s = jnp.einsum("bgrh,bwgh->bgrw", qf, k_cache,
                   preferred_element_type=jnp.float32)
    if softcap is not None:
        s = _softcap(s, softcap)
    idx = jnp.arange(w)
    valid = idx[None, :] < jnp.minimum(length, w)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrw,bwgh->bgrh", p.astype(k_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def gated_mlp(x, w1, w3, w2, act="silu"):
    h = act_fn(act)(x @ w1) * (x @ w3)
    return h @ w2
