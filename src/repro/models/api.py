"""Model factory: one entry point for every assigned architecture.

``build(cfg)`` returns a ``Model`` with init/loss/decode functions;
``make_train_step`` / ``make_prefill_step`` / ``make_serve_step`` build
the jittable step functions the launcher lowers for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, lm
from .config import ModelConfig
from ..optim import adamw


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable                 # (params, batch) -> (loss, aux)
    init_cache: Callable
    decode_step: Callable             # (params, cache, token, pos) -> ...


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init_params=lambda key: encdec.init_params(key, cfg),
            loss_fn=lambda p, b, remat="full": encdec.loss_fn(p, b, cfg, remat),
            init_cache=None,
            decode_step=lambda p, c, t, pos: encdec.decode_step(p, c, t, pos, cfg),
        )
    return Model(
        cfg=cfg,
        init_params=lambda key: lm.init_params(key, cfg),
        loss_fn=lambda p, b, remat="full": lm.loss_fn(p, b, cfg, remat),
        init_cache=lambda batch, max_len: lm.init_cache(cfg, batch, max_len),
        decode_step=lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: adamw.OptState
    step: jax.Array


def init_train_state(model: Model, key, opt_cfg: adamw.AdamWConfig):
    params = model.init_params(key)
    return TrainState(params=params, opt=adamw.init_state(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    remat: str = "full", n_micro: int = 1,
                    bf16_weight_gather: bool = False):
    """Jittable train step.

    ``n_micro`` > 1 accumulates gradients over sequential microbatches
    (scan), dividing peak activation/logit memory by ``n_micro`` at the
    cost of one fp32 gradient buffer — the standard HBM-fitting lever
    for the big train cells (see EXPERIMENTS.md §Perf).

    ``bf16_weight_gather`` casts fp32 master weights to bf16 *before*
    the per-layer FSDP all-gather (GSPMD pushes the elementwise cast
    below the gather), halving weight-gather + grad-reduce link bytes.
    """
    def _cast(params):
        if not bf16_weight_gather:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)

    def loss_fn(params, mb, remat_):
        return model.loss_fn(_cast(params), mb, remat_)

    def step(state: TrainState, batch):
        if n_micro == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch, remat)
        else:
            from ..models import layers as _layers
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def acc(gsum_loss, mb):
                gsum, lsum = gsum_loss
                (l, _), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb, remat)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            init = (jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params),
                jnp.zeros((), jnp.float32))
            (gsum, lsum), _ = jax.lax.scan(
                acc, init, mbs,
                unroll=n_micro if _layers.UNROLL_INNER_SCANS else 1)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss, aux = lsum / n_micro, {}
        new_params, new_opt, om = adamw.update(grads, state.opt,
                                               state.params, opt_cfg)
        metrics = {"loss": loss, **om}
        for k, v in aux.items():
            if "skew" in k or "drop" in k:
                metrics[k] = v
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics
    return step


def make_prefill_step(model: Model):
    """Inference prefill: no-grad forward, last-position logits."""
    cfg = model.cfg

    def step(params, batch):
        if cfg.family == "encdec":
            logits, _ = encdec.forward(params, batch["frames"],
                                       batch["tokens"], cfg,
                                       logits_mode="last")
        else:
            logits, _ = lm.forward(params, batch["tokens"], cfg,
                                   img=batch.get("img"), remat="none",
                                   logits_mode="last")
        return logits[:, -1]
    return step


def make_serve_step(model: Model):
    """One decode step (greedy): token + cache -> next token + cache."""
    def step(params, cache, token, pos):
        logits, new_cache = model.decode_step(params, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache
    return step
