"""RecurrentGemma / Griffin RG-LRU recurrent block (arXiv:2402.19427).

    r_t = σ(W_r x_t);  i_t = σ(W_i x_t);  a_t = a^(c·r_t)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``lax.associative_scan`` (log-depth, TPU-friendly); decode
is the one-step recurrence.  The surrounding block is Griffin's:
(linear → conv1d → RG-LRU) gated by (linear → gelu), then projected out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import layers

_C = 8.0


def init_params(key, cfg, n_stack):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 7)
    return {
        "in_x": layers.dense_init(ks[0], (n_stack, d, w), jnp.float32),
        "in_gate": layers.dense_init(ks[1], (n_stack, d, w), jnp.float32),
        "conv_w": layers.dense_init(ks[2], (n_stack, 4, w), jnp.float32),
        "w_r": layers.dense_init(ks[3], (n_stack, w, w), jnp.float32),
        "w_i": layers.dense_init(ks[4], (n_stack, w, w), jnp.float32),
        # Λ init so that a = σ(Λ) ∈ (0.9, 0.999)
        "lam": jnp.full((n_stack, w), 4.0, jnp.float32),
        "out": layers.dense_init(ks[5], (n_stack, w, d), jnp.float32),
    }


def _gates(u, p):
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_r"])
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_i"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])      # log a_t  (≤ 0)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return a, gated


def _conv(u, w):
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):
        out = out + pad[:, i:i + u.shape[1]] * w[i]
    return out


def forward(x, p, cfg):
    """x: (B, L, D) -> (B, L, D)."""
    u = x @ p["in_x"].astype(x.dtype)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    u = _conv(u, p["conv_w"].astype(x.dtype))
    a, b = _gates(u, p)

    def op(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, b1 * a2 + b2

    _, h = lax.associative_scan(op, (a, b), axis=1)
    y = (h.astype(x.dtype) * gate) @ p["out"].astype(x.dtype)
    return y


def init_cache(cfg, batch, dtype):
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def decode_step(x, cache, p, cfg):
    """x: (B, 1, D) -> (y, new_cache)."""
    u = x @ p["in_x"].astype(x.dtype)
    gate = jax.nn.gelu(x @ p["in_gate"].astype(x.dtype))
    hist = jnp.concatenate([cache["conv"], u], axis=1)       # (B, 4, W)
    w = p["conv_w"].astype(x.dtype)
    u_c = jnp.einsum("bkw,kw->bw", hist, w)[:, None, :]
    a, b = _gates(u_c, p)
    h = cache["h"] * a[:, 0] + b[:, 0]
    y = (h[:, None, :].astype(x.dtype) * gate) @ p["out"].astype(x.dtype)
    return y, {"conv": hist[:, 1:], "h": h}
