"""Decoder block assembly: attention/MoE/SSM/RG-LRU kinds, stacked for
scan-over-layers.

A model is ``n_super`` repetitions of ``cfg.pattern`` (super-blocks) plus
``rest`` remainder layers (patterns that don't divide n_layers, e.g.
recurrentgemma's 38 = 12×(rec,rec,local) + (rec,rec)).  All stacked
params carry a leading super-block axis so the forward pass is a single
``lax.scan`` — O(1-layer) HLO regardless of depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers, moe, rglru, ssm

ATTN_KINDS = ("full", "local", "global", "cross")


def window_for(kind, cfg, max_len=None):
    if kind == "local":
        w = cfg.local_window
    elif cfg.window is not None:
        w = cfg.window
    else:
        return None
    return w


# ----------------------------- init ---------------------------------------

def attn_init(key, cfg, n_stack):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 8)
    p = {
        "wq": layers.dense_init(ks[0], (n_stack, d, h * hd), jnp.float32),
        "wk": layers.dense_init(ks[1], (n_stack, d, kv * hd), jnp.float32),
        "wv": layers.dense_init(ks[2], (n_stack, d, kv * hd), jnp.float32),
        "wo": layers.dense_init(ks[3], (n_stack, h * hd, d), jnp.float32),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_stack, h * hd), jnp.float32)
        p["bk"] = jnp.zeros((n_stack, kv * hd), jnp.float32)
        p["bv"] = jnp.zeros((n_stack, kv * hd), jnp.float32)
    return p


def mlp_init(key, cfg, n_stack):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": layers.dense_init(ks[0], (n_stack, d, f), jnp.float32),
        "w3": layers.dense_init(ks[1], (n_stack, d, f), jnp.float32),
        "w2": layers.dense_init(ks[2], (n_stack, f, d), jnp.float32),
    }


def block_init(key, cfg, kind, n_stack):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    norm = lambda: jnp.zeros((n_stack, d), jnp.float32)  # noqa: E731
    if kind == "ssm":
        return {"norm1": norm(), "ssm": ssm.init_params(ks[0], cfg, n_stack)}
    if kind == "rec":
        return {"norm1": norm(), "rec": rglru.init_params(ks[0], cfg, n_stack),
                "norm2": norm(), "mlp": mlp_init(ks[1], cfg, n_stack)}
    p = {"norm1": norm(), "attn": attn_init(ks[0], cfg, n_stack),
         "norm2": norm()}
    if kind == "moe":
        p["moe"] = moe.init_params(ks[1], cfg, n_stack)
        if cfg.dense_residual:
            p["mlp"] = mlp_init(ks[2], cfg, n_stack)
    else:
        p["mlp"] = mlp_init(ks[1], cfg, n_stack)
    if cfg.post_norms:
        p["norm1b"] = norm()
        p["norm2b"] = norm()
    return p


# ---------------------------- forward -------------------------------------

def _attn_apply(x, p, cfg, kind, positions):
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = layers.rope(q.reshape(b, s, h, hd), positions, cfg.rope_theta)
    k = layers.rope(k.reshape(b, s, kv, hd), positions, cfg.rope_theta)
    v = v.reshape(b, s, kv, hd)
    out = layers.chunked_attention(
        q, k, v, causal=True, window=window_for(kind, cfg),
        softcap=cfg.attn_softcap)
    return out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)


def apply_block(x, p, cfg, kind, positions):
    """One block, training form. x: (B, S, D)."""
    eps = cfg.norm_eps
    aux = {}
    if kind == "ssm":
        return x + ssm.forward(
            layers.rms_norm(x, p["norm1"], eps), p["ssm"], cfg), aux
    if kind == "rec":
        x = x + rglru.forward(layers.rms_norm(x, p["norm1"], eps),
                              p["rec"], cfg)
        x = x + layers.gated_mlp(layers.rms_norm(x, p["norm2"], eps),
                                 p["mlp"]["w1"].astype(x.dtype),
                                 p["mlp"]["w3"].astype(x.dtype),
                                 p["mlp"]["w2"].astype(x.dtype), cfg.act)
        return x, aux

    a = _attn_apply(layers.rms_norm(x, p["norm1"], eps), p["attn"], cfg,
                    kind, positions)
    if cfg.post_norms:
        a = layers.rms_norm(a, p["norm1b"], eps)
    x = x + a
    hin = layers.rms_norm(x, p["norm2"], eps)
    if kind == "moe":
        m, aux = moe.moe_ffn(hin, p["moe"], cfg)
        if cfg.dense_residual:
            m = m + layers.gated_mlp(hin, p["mlp"]["w1"].astype(x.dtype),
                                     p["mlp"]["w3"].astype(x.dtype),
                                     p["mlp"]["w2"].astype(x.dtype), cfg.act)
    else:
        m = layers.gated_mlp(hin, p["mlp"]["w1"].astype(x.dtype),
                             p["mlp"]["w3"].astype(x.dtype),
                             p["mlp"]["w2"].astype(x.dtype), cfg.act)
    if cfg.post_norms:
        m = layers.rms_norm(m, p["norm2b"], eps)
    return x + m, aux


# ---------------------------- decode --------------------------------------

def attn_cache_init(cfg, kind, batch, max_len, n_stack, dtype):
    w = window_for(kind, cfg)
    wlen = min(max_len, w) if w else max_len
    kv, hd = cfg.n_kv, cfg.hd
    return {
        "k": jnp.zeros((n_stack, batch, wlen, kv, hd), dtype),
        "v": jnp.zeros((n_stack, batch, wlen, kv, hd), dtype),
    }


def block_cache_init(cfg, kind, batch, max_len, n_stack, dtype):
    if kind == "ssm":
        c = ssm.init_cache(cfg, batch, dtype)
    elif kind == "rec":
        c = rglru.init_cache(cfg, batch, dtype)
    else:
        return attn_cache_init(cfg, kind, batch, max_len, n_stack, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_stack,) + a.shape), c)


def _attn_decode(x, p, cache, cfg, kind, pos):
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    posv = jnp.full((b, 1), pos)
    q = layers.rope(q.reshape(b, 1, h, hd), posv, cfg.rope_theta)
    k = layers.rope(k.reshape(b, 1, kv, hd), posv, cfg.rope_theta)
    v = v.reshape(b, 1, kv, hd)
    wlen = cache["k"].shape[1]
    slot = pos % wlen
    # masked elementwise write instead of dynamic_update_slice: a DUS at
    # a traced offset on a length-sharded cache forces GSPMD into full
    # rematerialisation (cache all-gather per layer); the where() form
    # shards cleanly (§Perf decode iteration)
    hit = (jnp.arange(wlen) == slot)[None, :, None, None]
    kc = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
    vc = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
    out = layers.decode_attention(q, kc, vc, pos + 1,
                                  softcap=cfg.attn_softcap)
    y = out.reshape(b, 1, h * hd) @ p["wo"].astype(x.dtype)
    return y, {"k": kc, "v": vc}


def decode_block(x, p, cache, cfg, kind, pos):
    eps = cfg.norm_eps
    if kind == "ssm":
        y, nc = ssm.decode_step(layers.rms_norm(x, p["norm1"], eps),
                                cache, p["ssm"], cfg)
        return x + y, nc
    if kind == "rec":
        y, nc = rglru.decode_step(layers.rms_norm(x, p["norm1"], eps),
                                  cache, p["rec"], cfg)
        x = x + y
        x = x + layers.gated_mlp(layers.rms_norm(x, p["norm2"], eps),
                                 p["mlp"]["w1"].astype(x.dtype),
                                 p["mlp"]["w3"].astype(x.dtype),
                                 p["mlp"]["w2"].astype(x.dtype), cfg.act)
        return x, nc

    a, nc = _attn_decode(layers.rms_norm(x, p["norm1"], eps), p["attn"],
                         cache, cfg, kind, pos)
    if cfg.post_norms:
        a = layers.rms_norm(a, p["norm1b"], eps)
    x = x + a
    hin = layers.rms_norm(x, p["norm2"], eps)
    if kind == "moe":
        m, _ = moe.moe_ffn(hin, p["moe"], cfg)
        if cfg.dense_residual:
            m = m + layers.gated_mlp(hin, p["mlp"]["w1"].astype(x.dtype),
                                     p["mlp"]["w3"].astype(x.dtype),
                                     p["mlp"]["w2"].astype(x.dtype), cfg.act)
    else:
        m = layers.gated_mlp(hin, p["mlp"]["w1"].astype(x.dtype),
                             p["mlp"]["w3"].astype(x.dtype),
                             p["mlp"]["w2"].astype(x.dtype), cfg.act)
    if cfg.post_norms:
        m = layers.rms_norm(m, p["norm2b"], eps)
    return x + m, nc
