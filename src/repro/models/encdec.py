"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs``
feeds precomputed frame embeddings (B, src_len, d_model) straight into
the encoder.  Encoder = bidirectional attention blocks; decoder = causal
self-attention + cross-attention blocks.  Norm/MLP reuse the shared
layers (RMSNorm + gated-GELU; Whisper's LayerNorm/plain-GELU deviation is
noted in DESIGN.md — the backbone shapes/FLOPs are identical).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import blocks, layers
from .config import ModelConfig
from .lm import constrain


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_padded
    ks = jax.random.split(key, 8)
    enc = {
        "norm1": jnp.zeros((cfg.enc_layers, d), jnp.float32),
        "attn": blocks.attn_init(ks[0], cfg, cfg.enc_layers),
        "norm2": jnp.zeros((cfg.enc_layers, d), jnp.float32),
        "mlp": blocks.mlp_init(ks[1], cfg, cfg.enc_layers),
    }
    dec = {
        "norm1": jnp.zeros((cfg.n_layers, d), jnp.float32),
        "attn": blocks.attn_init(ks[2], cfg, cfg.n_layers),
        "normx": jnp.zeros((cfg.n_layers, d), jnp.float32),
        "xattn": blocks.attn_init(ks[3], cfg, cfg.n_layers),
        "norm2": jnp.zeros((cfg.n_layers, d), jnp.float32),
        "mlp": blocks.mlp_init(ks[4], cfg, cfg.n_layers),
    }
    return {
        "embed": layers.dense_init(ks[5], (v, d), jnp.float32),
        "enc": enc,
        "dec": dec,
        "enc_norm": jnp.zeros((d,), jnp.float32),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }


def _mha(x, kv_src, p, cfg, *, causal, positions, kv_positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (kv_src @ p["wk"].astype(x.dtype)).reshape(b, kv_src.shape[1], kv, hd)
    v = (kv_src @ p["wv"].astype(x.dtype)).reshape(b, kv_src.shape[1], kv, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, kv_positions, cfg.rope_theta)
    out = layers.chunked_attention(q, k, v, causal=causal)
    return out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)


def encode(params, frames, cfg: ModelConfig):
    x = frames.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(h, p):
        hn = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
        h = h + _mha(hn, hn, p["attn"], cfg, causal=False, positions=pos,
                     kv_positions=pos)
        h = h + layers.gated_mlp(
            layers.rms_norm(h, p["norm2"], cfg.norm_eps),
            p["mlp"]["w1"].astype(h.dtype), p["mlp"]["w3"].astype(h.dtype),
            p["mlp"]["w2"].astype(h.dtype), cfg.act)
        return constrain(h), None

    x, _ = lax.scan(jax.checkpoint(body), x, params["enc"],
                    unroll=cfg.enc_layers
                    if layers.UNROLL_INNER_SCANS else 1)
    return layers.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _logits_of(x, params, cfg):
    logits = (x @ params["embed"].astype(x.dtype).T).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab, logits, -1e9)
    return logits


def forward(params, frames, tokens, cfg: ModelConfig,
            logits_mode: str = "all"):
    """Teacher-forcing enc-dec forward -> (logits, aux)."""
    enc_out = encode(params, frames, cfg)
    x = params["embed"].astype(enc_out.dtype)[tokens]
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    src = enc_out.shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(src)[None], (b, src))

    def body(h, p):
        hn = layers.rms_norm(h, p["norm1"], cfg.norm_eps)
        h = h + _mha(hn, hn, p["attn"], cfg, causal=True, positions=pos,
                     kv_positions=pos)
        hx = layers.rms_norm(h, p["normx"], cfg.norm_eps)
        h = h + _mha(hx, enc_out, p["xattn"], cfg, causal=False,
                     positions=pos, kv_positions=kv_pos)
        h = h + layers.gated_mlp(
            layers.rms_norm(h, p["norm2"], cfg.norm_eps),
            p["mlp"]["w1"].astype(h.dtype), p["mlp"]["w3"].astype(h.dtype),
            p["mlp"]["w2"].astype(h.dtype), cfg.act)
        return constrain(h), None

    x, _ = lax.scan(jax.checkpoint(body), x, params["dec"],
                    unroll=cfg.n_layers
                    if layers.UNROLL_INNER_SCANS else 1)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if logits_mode == "last":
        x = x[:, -1:]
    return _logits_of(x, params, cfg), {}


def loss_fn(params, batch, cfg: ModelConfig, remat: str = "full"):
    logits, aux = forward(params, batch["frames"], batch["tokens"], cfg)
    tgt = batch["tokens"][:, 1:]
    txt = logits[:, :-1]
    lse = jax.scipy.special.logsumexp(txt, axis=-1)
    true = jnp.take_along_axis(txt, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - true) + 1e-4 * jnp.mean(lse ** 2), aux


# ------------------------------ decode ------------------------------------

def init_cache(params, frames, cfg: ModelConfig, max_len: int):
    """Prefill the cross-attention K/V from the encoder, allocate the
    decoder self-attention cache."""
    enc_out = encode(params, frames, cfg)
    b, src, _ = enc_out.shape
    kv, hd = cfg.n_kv, cfg.hd
    kv_pos = jnp.broadcast_to(jnp.arange(src)[None], (b, src))

    def per_layer(p):
        k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, src, kv, hd)
        k = layers.rope(k, kv_pos, cfg.rope_theta)
        v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, src, kv, hd)
        return {"k": k, "v": v}

    cross = jax.vmap(per_layer)(params["dec"]["xattn"])
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    self_c = {
        "k": jnp.zeros((cfg.n_layers, b, max_len, kv, hd), dt),
        "v": jnp.zeros((cfg.n_layers, b, max_len, kv, hd), dt),
    }
    return {"self": self_c, "cross": cross}


def decode_step(params, cache, token, pos, cfg: ModelConfig):
    x = params["embed"][token[:, None]].astype(
        jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv, cfg.hd

    def body(hcur, inp):
        p, selfc, crossc = inp
        hn = layers.rms_norm(hcur, p["norm1"], cfg.norm_eps)
        q = (hn @ p["attn"]["wq"].astype(hn.dtype)).reshape(b, 1, h, hd)
        k = (hn @ p["attn"]["wk"].astype(hn.dtype)).reshape(b, 1, kv, hd)
        v = (hn @ p["attn"]["wv"].astype(hn.dtype)).reshape(b, 1, kv, hd)
        posv = jnp.full((b, 1), pos)
        q = layers.rope(q, posv, cfg.rope_theta)
        k = layers.rope(k, posv, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(
            selfc["k"], k.astype(selfc["k"].dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            selfc["v"], v.astype(selfc["v"].dtype), (0, pos, 0, 0))
        a = layers.decode_attention(q, kc, vc, pos + 1)
        hcur = hcur + a.reshape(b, 1, h * hd) @ p["attn"]["wo"].astype(hn.dtype)
        hx = layers.rms_norm(hcur, p["normx"], cfg.norm_eps)
        qx = (hx @ p["xattn"]["wq"].astype(hx.dtype)).reshape(b, 1, h, hd)
        qx = layers.rope(qx, posv, cfg.rope_theta)
        ax = layers.decode_attention(qx, crossc["k"], crossc["v"],
                                     crossc["k"].shape[1])
        hcur = hcur + ax.reshape(b, 1, h * hd) @ p["xattn"]["wo"].astype(hx.dtype)
        hcur = hcur + layers.gated_mlp(
            layers.rms_norm(hcur, p["norm2"], cfg.norm_eps),
            p["mlp"]["w1"].astype(hcur.dtype), p["mlp"]["w3"].astype(hcur.dtype),
            p["mlp"]["w2"].astype(hcur.dtype), cfg.act)
        return hcur, {"k": kc, "v": vc}

    x, new_self = lax.scan(body, x, (params["dec"], cache["self"],
                                     cache["cross"]),
                           unroll=cfg.n_layers
                           if layers.UNROLL_INNER_SCANS else 1)
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits_of(x[:, 0], params, cfg)
    return logits, {"self": new_self, "cross": cache["cross"]}
