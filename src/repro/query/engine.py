"""Distributed spatial-join engine (the paper's Algorithm 1, SPMD form).

Pipeline (mirrors the paper's phases):
  A. partition      — any of the six layouts on the merged R ∪ S (§2.3)
  B. staging        — MASJ assignment into padded, masked device tiles
  C. planning       — cost-model LPT packing of tiles onto devices
  D. tile joins     — shard_map'd Pallas mbr_join per tile
  E. boundary fix   — reference-point ownership (default, zero-comm) or
                      paper-faithful all_gather + sort-unique dedup
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.compat import shard_map

from ..core import geometry
from ..core.partition import api, assign
from ..core.partition.assign import round_up as _round_up
from . import balance, join

_SENTINEL_BOX = np.array(geometry.SENTINEL_BOX, np.float32)


@dataclasses.dataclass
class JoinPlan:
    """Device-shaped staging of one co-partitioned join. All arrays are
    leading-axis-[D] numpy; D = number of devices in the mesh."""
    r_tiles: np.ndarray   # (D, Tpd, cap_r, 4)
    r_ids: np.ndarray     # (D, Tpd, cap_r)
    s_tiles: np.ndarray   # (D, Tpd, cap_s, 4)
    s_ids: np.ndarray     # (D, Tpd, cap_s)
    tile_boxes: np.ndarray  # (D, Tpd, 4)
    universe: np.ndarray  # (4,)
    stats: dict


def plan_join(method: str, r: jax.Array, s: jax.Array, payload: int,
              n_devices: int, packer: str = "lpt",
              parts: api.Partitioning | None = None) -> JoinPlan:
    """Host-side planning: layout, MASJ staging, LPT packing.

    r, s: (N, 4) / (M, 4) f32 MBRs -> ``JoinPlan`` with device-shaped
    ``(D, Tpd, cap, 4)`` tile arrays (sentinel-padded, id -1 in padding
    slots) and packing/λ stats.  Raises nothing on overflow: capacities
    are sized from the true max tile payload.
    """
    merged = jnp.concatenate([r, s], axis=0)
    if parts is None:
        parts = api.partition(method, merged, payload)
    uni = np.asarray(geometry.universe(merged))

    counts_r, _ = assign.partition_counts(r, parts)
    counts_s, _ = assign.partition_counts(s, parts)
    cap_r = _round_up(max(int(jnp.max(counts_r)), 1), 128)
    cap_s = _round_up(max(int(jnp.max(counts_s)), 1), 128)
    mem_r, mask_r, ovf_r = assign.assign_padded(r, parts, cap_r)
    mem_s, mask_s, ovf_s = assign.assign_padded(s, parts, cap_s)
    assert int(jnp.sum(ovf_r)) == 0 and int(jnp.sum(ovf_s)) == 0

    valid = np.asarray(parts.valid)
    keep = np.flatnonzero(valid)
    t = len(keep)
    nr = np.asarray(jnp.sum(mask_r, axis=1))[keep]
    ns = np.asarray(jnp.sum(mask_s, axis=1))[keep]
    costs = balance.tile_costs(nr, ns)
    pack = balance.lpt_pack if packer == "lpt" else balance.round_robin_pack
    dev, makespan, mean_load = pack(costs, n_devices)

    tpd = max(1, math.ceil(t / n_devices))
    shape_r = (n_devices, tpd, cap_r, 4)
    r_tiles = np.broadcast_to(_SENTINEL_BOX, shape_r).copy()
    s_tiles = np.broadcast_to(_SENTINEL_BOX,
                              (n_devices, tpd, cap_s, 4)).copy()
    r_ids = np.full((n_devices, tpd, cap_r), -1, np.int32)
    s_ids = np.full((n_devices, tpd, cap_s), -1, np.int32)
    tile_boxes = np.broadcast_to(_SENTINEL_BOX, (n_devices, tpd, 4)).copy()

    r_np, s_np = np.asarray(r), np.asarray(s)
    mem_r_np, mask_r_np = np.asarray(mem_r)[keep], np.asarray(mask_r)[keep]
    mem_s_np, mask_s_np = np.asarray(mem_s)[keep], np.asarray(mask_s)[keep]
    boxes_np = np.asarray(parts.boxes)[keep]
    slot = np.zeros(n_devices, np.int64)
    for i in range(t):
        d = dev[i]
        j = slot[d]
        if j >= tpd:   # LPT balances cost, not tile count; spill to min-slot
            d = int(np.argmin(slot))
            j = slot[d]
        m = mask_r_np[i]
        r_tiles[d, j, m] = r_np[mem_r_np[i][m]]
        r_ids[d, j, m] = mem_r_np[i][m]
        m = mask_s_np[i]
        s_tiles[d, j, m] = s_np[mem_s_np[i][m]]
        s_ids[d, j, m] = mem_s_np[i][m]
        tile_boxes[d, j] = boxes_np[i]
        slot[d] += 1

    stats = dict(
        k=t, cap_r=cap_r, cap_s=cap_s, tpd=tpd,
        makespan=makespan, mean_load=mean_load,
        skew=makespan / max(mean_load, 1e-9),
        lambda_r=float(jnp.sum(counts_r)) / r.shape[0] - 1.0,
        lambda_s=float(jnp.sum(counts_s)) / s.shape[0] - 1.0,
        method=method,
        overlapping=api.info(method).overlapping if method in api.methods()
        else True,
    )
    return JoinPlan(r_tiles, r_ids, s_tiles, s_ids, tile_boxes, uni, stats)


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------

def _device_count_fn(uni, dedup):
    def per_device(r_tiles, s_tiles, tile_boxes):
        def one_tile(args):
            rt, st, tb = args
            return join.tile_join_count(rt, st, tb, uni, dedup=dedup)
        counts = jax.lax.map(one_tile, (r_tiles, s_tiles, tile_boxes))
        return jnp.sum(counts)
    return per_device


def make_count_step(mesh: Mesh, axis: str, uni, dedup: str = "rp"):
    """Build the jitted SPMD join-count step over ``mesh[axis]``."""
    fn = _device_count_fn(jnp.asarray(uni), dedup)

    def step(r_tiles, s_tiles, tile_boxes):
        # shard_map keeps the leading (sharded) axis as size 1 — drop it
        local = fn(r_tiles[0], s_tiles[0], tile_boxes[0])
        return jax.lax.psum(local, axis)

    spec = P(axis)
    return jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=P(), check_vma=False))


def run_join_count(plan: JoinPlan, mesh: Mesh, axis: str = "d",
                   dedup: str = "rp") -> int:
    """Execute a planned join count SPMD.  With ``dedup='rp'`` the
    result is the exact duplicate-free pair count for non-overlapping
    layouts; ``dedup='none'`` returns the raw MASJ count (replicated
    pairs included)."""
    step = make_count_step(mesh, axis, plan.universe, dedup)
    sharding = NamedSharding(mesh, P(axis))
    args = [jax.device_put(jnp.asarray(x), sharding)
            for x in (plan.r_tiles, plan.s_tiles, plan.tile_boxes)]
    return int(step(*args))


def spatial_join_count(plan: JoinPlan, mesh: Mesh, axis: str = "d",
                       max_pairs_per_tile: int = 4096) -> int:
    """Dedup-mode-aware join count.

    Reference-point ownership is exact ONLY for non-overlapping layouts
    (Table 1: FG/BSP/SLC/BOS) — overlapping tight-MBR layouts (STR/HC)
    can own a pair's reference point in several tiles.  Those fall back
    to the paper-faithful MASJ materialise+dedup path.
    """
    if plan.stats.get("overlapping", True):
        return run_join_pairs_masj(plan, mesh, axis, max_pairs_per_tile)
    return run_join_count(plan, mesh, axis, dedup="rp")


def run_join_pairs_masj(plan: JoinPlan, mesh: Mesh, axis: str = "d",
                        max_pairs_per_tile: int = 4096):
    """Paper-faithful MASJ: materialise per-tile pairs (duplicates
    included), all_gather, global sort-unique dedup."""
    from . import dedup as dd

    def per_device(r_tiles, r_ids, s_tiles, s_ids, tile_boxes, uni):
        def one_tile(args):
            rt, rid, st, sid, tb = args
            pr, ps, _ = join.tile_join_pairs(
                rt, st, rid, sid, tb, uni, max_pairs_per_tile, dedup="none")
            return pr, ps
        pr, ps = jax.lax.map(
            one_tile,
            (r_tiles[0], r_ids[0], s_tiles[0], s_ids[0], tile_boxes[0]))
        pr, ps = pr.reshape(-1), ps.reshape(-1)
        pr = jax.lax.all_gather(pr, axis, tiled=True)
        ps = jax.lax.all_gather(ps, axis, tiled=True)
        n, _ = dd.unique_pairs(pr, ps)
        return n

    spec = P(axis)
    step = jax.jit(shard_map(
        per_device, mesh=mesh,
        in_specs=(spec,) * 5 + (P(),), out_specs=P(), check_vma=False))
    sharding = NamedSharding(mesh, P(axis))
    args = [jax.device_put(jnp.asarray(x), sharding)
            for x in (plan.r_tiles, plan.r_ids, plan.s_tiles, plan.s_ids,
                      plan.tile_boxes)]
    uni = jax.device_put(jnp.asarray(plan.universe),
                         NamedSharding(mesh, P()))
    return int(step(*args, uni))
