"""Tile-local spatial join (the paper's query phase D).

Filter step = MBR intersection via the Pallas ``mbr_join`` kernel.  The
refine step of Hadoop-GIS evaluates the exact geometry predicate; objects
here *are* MBRs, so refine degenerates to the filter predicate and its
cost is carried by the cost model's ``c_pair``.

Reference-point deduplication (beyond-paper optimisation): a duplicate
(r, s) hit appears in every tile both replicas share; exactly one tile
contains the *reference point* ``(max(r.xmin, s.xmin), max(r.ymin,
s.ymin))``, so counting only rp-owned hits yields the exact global count
with zero dedup communication.  Ownership is half-open on the high edge
(closed at the universe boundary) so edge-touching points count once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.mbr_join import ops as mops


def rp_own_mask(r: jax.Array, s: jax.Array, tile_box: jax.Array,
                uni: jax.Array) -> jax.Array:
    """(N, 4), (M, 4), (4,), (4,) -> (N, M) reference-point ownership."""
    rpx = jnp.maximum(r[:, None, 0], s[None, :, 0])
    rpy = jnp.maximum(r[:, None, 1], s[None, :, 1])
    hi_x = jnp.where(tile_box[2] >= uni[2], rpx <= tile_box[2],
                     rpx < tile_box[2])
    hi_y = jnp.where(tile_box[3] >= uni[3], rpy <= tile_box[3],
                     rpy < tile_box[3])
    return (rpx >= tile_box[0]) & hi_x & (rpy >= tile_box[1]) & hi_y


@functools.partial(jax.jit, static_argnames=("dedup",))
def tile_join_count(r: jax.Array, s: jax.Array, tile_box: jax.Array,
                    uni: jax.Array, dedup: str = "rp") -> jax.Array:
    """Count intersecting pairs in one padded tile.

    dedup="rp"   — reference-point-owned count (globally exact, no comm),
    dedup="none" — raw MASJ count (duplicates included; the paper-faithful
                   path subtracts them in ``dedup.py``).
    """
    if dedup == "none":
        return mops.join_count(r, s)
    hits = mops.join_mask(r, s)
    own = rp_own_mask(r, s, tile_box, uni)
    return jnp.sum((hits & own).astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("max_pairs", "dedup"))
def tile_join_pairs(r: jax.Array, s: jax.Array, r_ids: jax.Array,
                    s_ids: jax.Array, tile_box: jax.Array, uni: jax.Array,
                    max_pairs: int, dedup: str = "none"):
    """Materialise intersecting (r_id, s_id) pairs of one tile, padded to
    ``max_pairs`` with (-1, -1).  Padded tile slots carry id -1 and
    sentinel boxes, so they never match."""
    hits = mops.join_mask(r, s)
    if dedup == "rp":
        hits = hits & rp_own_mask(r, s, tile_box, uni)
    hits = hits & (r_ids[:, None] >= 0) & (s_ids[None, :] >= 0)
    ri, si = jnp.nonzero(hits, size=max_pairs, fill_value=-1)
    pr = jnp.where(ri >= 0, r_ids[jnp.maximum(ri, 0)], -1)
    ps = jnp.where(si >= 0, s_ids[jnp.maximum(si, 0)], -1)
    n = jnp.sum(hits.astype(jnp.int32))
    return pr, ps, n
