"""MASJ duplicate elimination (the paper's query phase E).

``unique_pairs`` is the paper-faithful global de-duplication: gather all
candidate (r, s) id pairs, lexicographically sort, and keep first
occurrences.  Runs in int32 via a two-pass stable argsort (no 64-bit
keys needed).  Cost is the β(|R|+|S|) term of the cost model.

The zero-communication alternative (reference-point ownership) lives in
``join.py``; both are benchmarked in §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lexsort_pairs(rid: jax.Array, sid: jax.Array) -> jax.Array:
    """(P,) x (P,) -> (P,) permutation sorting (rid, sid)
    lexicographically (stable, two-pass int32 argsort)."""
    o1 = jnp.argsort(sid, stable=True)
    o2 = jnp.argsort(rid[o1], stable=True)
    return o1[o2]


@jax.jit
def unique_pairs(rid: jax.Array, sid: jax.Array):
    """Count + mark unique non-padding pairs.

    rid, sid: (P,) int32 candidate pair ids, (-1, -1) in padding slots
    -> ``(n_unique scalar int32, uniq[P] bool)`` where ``uniq`` marks
    the first occurrence of each real pair in the original order.
    Exact global dedup: with every tile's candidates gathered, the
    count equals the duplicate-free join cardinality.
    """
    order = lexsort_pairs(rid, sid)
    r_s, s_s = rid[order], sid[order]
    first = jnp.concatenate([
        jnp.ones((1,), bool),
        (r_s[1:] != r_s[:-1]) | (s_s[1:] != s_s[:-1]),
    ])
    real = r_s >= 0
    uniq_sorted = first & real
    n_unique = jnp.sum(uniq_sorted.astype(jnp.int32))
    uniq = jnp.zeros_like(uniq_sorted).at[order].set(uniq_sorted)
    return n_unique, uniq
