"""MapReduce-style parallel spatial partitioning (paper §5.1, Algorithm 7).

TeraSort-analogue in SPMD form:
  sample  — host draws an anchor sample, takes Hilbert-key quantiles as
            the coarse splitters (the paper's anchor point list),
  map     — each device keys its local objects by Hilbert value and
            assigns a coarse bucket via searchsorted,
  shuffle — ``all_to_all`` exchanges padded per-bucket buffers,
  reduce  — each device runs a fine partitioner (masked SLC) on its
            bucket; the union of local layouts is the global layout.

Like the paper, the parallel layout differs from the single-threaded one
but is "reasonably well" — quality is re-measured by the same metrics.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.compat import shard_map

from ..core import geometry, hilbert
from ..core.partition.api import Partitioning

BIG = jnp.float32(3.4e38)


def coarse_splitters(key: jax.Array, mbrs: jax.Array, n_buckets: int,
                     sample: int = 4096) -> jax.Array:
    """Anchor-sample Hilbert quantiles -> (n_buckets-1,) uint32 splitters.

    Sampling is without replacement and quantile positions are rounded
    (not truncated) — with-replacement draws plus ``astype(int32)``
    floor both bias the splitters low on small samples.
    """
    n = mbrs.shape[0]
    idx = jax.random.choice(key, n, (min(sample, n),), replace=False)
    pts = geometry.centroids(mbrs[idx])
    keys = jnp.sort(hilbert.hilbert_keys(pts, geometry.universe(mbrs)))
    q = jnp.linspace(0, keys.shape[0] - 1, n_buckets + 1)[1:-1]
    return keys[jnp.round(q).astype(jnp.int32)]


def _slc_masked(local_mbrs, real, payload: int, kmax: int):
    """Masked strip partitioner for a padded reducer bucket.

    Sorts real objects by x-centroid (padding to +inf), slices strips of
    ``payload``; strip y-extent = bucket's tight y-range.
    """
    cx = jnp.where(real, (local_mbrs[:, 0] + local_mbrs[:, 2]) * 0.5, BIG)
    order = jnp.argsort(cx)
    cx_s = cx[order]
    m = jnp.sum(real.astype(jnp.int32))
    y0 = jnp.min(jnp.where(real, local_mbrs[:, 1], BIG))
    y1 = jnp.max(jnp.where(real, local_mbrs[:, 3], -BIG))
    x0 = jnp.min(jnp.where(real, local_mbrs[:, 0], BIG))
    x1 = jnp.max(jnp.where(real, local_mbrs[:, 2], -BIG))

    i = jnp.arange(kmax)
    nn = cx_s.shape[0]
    lo_i = jnp.clip(i * payload, 0, nn - 1)
    hi_i = jnp.clip((i + 1) * payload, 0, nn - 1)
    lo_v = jnp.where(i == 0, x0, (cx_s[lo_i] + cx_s[jnp.maximum(lo_i - 1, 0)]) * 0.5)
    is_last = (i + 1) * payload >= m
    hi_v = jnp.where(is_last, x1, (cx_s[hi_i] + cx_s[jnp.maximum(hi_i - 1, 0)]) * 0.5)
    valid = (i * payload) < m
    boxes = jnp.stack([lo_v, jnp.broadcast_to(y0, lo_v.shape),
                       hi_v, jnp.broadcast_to(y1, lo_v.shape)], axis=-1)
    boxes = jnp.where(valid[:, None], boxes, 0.0)
    return boxes.astype(jnp.float32), valid


def parallel_partition(key: jax.Array, mbrs: jax.Array, payload: int,
                       mesh: Mesh, axis: str = "d",
                       cap_factor: float = 2.0) -> tuple[Partitioning, dict]:
    """Distributed two-level partitioning over ``mesh[axis]``."""
    d = mesh.shape[axis]
    n = mbrs.shape[0]
    per_dev = math.ceil(n / d)
    cap = math.ceil(cap_factor * per_dev)
    kmax_local = max(1, math.ceil(cap / payload))

    splitters = coarse_splitters(key, mbrs, d)
    uni = geometry.universe(mbrs)

    pad = d * per_dev - n
    mbrs_p = jnp.concatenate(
        [mbrs, jnp.broadcast_to(jnp.array([9e9, 9e9, -9e9, -9e9]),
                                (pad, 4))], axis=0).astype(jnp.float32)
    real_p = jnp.concatenate([jnp.ones((n,), bool), jnp.zeros((pad,), bool)])

    def spmd(local, real, splitters, uni):
        # map: hilbert key -> coarse bucket
        pts = geometry.centroids(local)
        keys = hilbert.hilbert_keys(pts, uni)
        bucket = jnp.searchsorted(splitters, keys).astype(jnp.int32)
        bucket = jnp.where(real, bucket, -1)
        # build (D, cap) send buffers; slot `cap` is a discarded trash
        # column so masked-out scatter targets never collide with real ones
        send = jnp.broadcast_to(jnp.array([9e9, 9e9, -9e9, -9e9]),
                                (d, cap + 1, 4)).astype(jnp.float32)
        smask = jnp.zeros((d, cap + 1), bool)
        onehot = bucket[:, None] == jnp.arange(d)[None, :]     # (L, D)
        rank = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
        ok = onehot & (rank < cap)
        tgt = jnp.where(ok, jnp.arange(d)[None, :], 0)
        slot = jnp.where(ok, jnp.clip(rank, 0, cap - 1), cap)
        li = jnp.broadcast_to(jnp.arange(local.shape[0])[:, None], ok.shape)
        send = send.at[tgt.ravel(), slot.ravel()].set(local[li.ravel()])
        smask = smask.at[tgt.ravel(), slot.ravel()].max(ok.ravel())
        send, smask = send[:, :cap], smask[:, :cap]
        dropped = jnp.sum((onehot & ~ok).astype(jnp.int32))
        # shuffle
        recv = jax.lax.all_to_all(send, axis, split_axis=0, concat_axis=0,
                                  tiled=True)
        rmask = jax.lax.all_to_all(smask, axis, split_axis=0, concat_axis=0,
                                   tiled=True)
        recv = recv.reshape(-1, 4)
        rmask = rmask.reshape(-1)
        # reduce: fine partition of the local bucket
        boxes, valid = _slc_masked(recv, rmask, payload, kmax_local * d)
        return boxes, valid, jax.lax.psum(dropped, axis)

    spec = P(axis)
    fn = jax.jit(shard_map(
        partial(spmd),
        mesh=mesh,
        in_specs=(spec, spec, P(), P()),
        out_specs=(spec, spec, P()), check_vma=False))
    sharding = NamedSharding(mesh, spec)
    local = jax.device_put(mbrs_p, sharding)
    real = jax.device_put(real_p, sharding)
    boxes, valid, dropped = fn(local, real, splitters, uni)
    stats = dict(dropped=int(dropped), buckets=d, kmax_local=kmax_local)
    return Partitioning(boxes=boxes, valid=valid), stats
