"""Tile → device packing (SPMD straggler mitigation).

MapReduce absorbs stragglers with its dynamic task queue; lock-step SPMD
cannot, so the slowest device gates every step.  We therefore pack tiles
onto devices with the paper's cost model as the weight — greedy LPT
(longest-processing-time-first), a 4/3-approximation to makespan — at
plan time on the host.  This is where the paper's "partition balance
drives query performance" thesis becomes a scheduler, not just a metric.
"""
from __future__ import annotations

import numpy as np


def tile_costs(nr: np.ndarray, ns: np.ndarray) -> np.ndarray:
    """Per-tile join cost  c_i = |R_i|·|S_i|  (§2.3).

    nr, ns: (T,) per-tile payload counts -> (T,) float64 costs.
    """
    return nr.astype(np.float64) * ns.astype(np.float64)


def lpt_pack(costs: np.ndarray, n_devices: int):
    """Greedy LPT (longest-processing-time-first), a 4/3-approximation
    to minimum makespan.

    costs: (T,) non-negative weights -> ``(device[T] int32 assignment,
    makespan float, mean_load float)``.  Equal weights degrade to
    round-robin placement (ties broken by ascending device id); an
    all-zero vector leaves everything on device 0 — callers that need
    spreading regardless (e.g. ``serve.engine.pack_queries``)
    substitute uniform costs first.
    """
    t = costs.shape[0]
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_devices, np.float64)
    assignment = np.zeros(t, np.int32)
    counts = np.zeros(n_devices, np.int64)
    for i in order:
        d = int(np.argmin(loads))
        assignment[i] = d
        loads[d] += costs[i]
        counts[d] += 1
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def round_robin_pack(costs: np.ndarray, n_devices: int):
    """Baseline packing (what a naive tile→mapper hash gives you).

    Same return contract as ``lpt_pack``; ignores the weights when
    placing, so the makespan gap to LPT *is* the straggler cost.
    """
    t = costs.shape[0]
    assignment = (np.arange(t) % n_devices).astype(np.int32)
    loads = np.zeros(n_devices, np.float64)
    np.add.at(loads, assignment, costs)
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean
