"""Tile → device packing (SPMD straggler mitigation) — compat shim.

The LPT scheduler family moved to ``repro.core.placement`` when tile
*sharding* made it a three-way shared concern (join tiles → devices,
query batches → devices, tile shards → owner devices).  This module
keeps the historical import path for the join engine and downstream
users; new code should import ``repro.core.placement`` directly.
"""
from __future__ import annotations

from ..core.placement import (  # noqa: F401
    lpt_pack,
    lpt_pack_capped,
    round_robin_pack,
    shard_tiles,
    tile_costs,
)
