"""Batched k-nearest-neighbour queries over staged layouts.

kNN is built as **iterative-deepening range probes** (LocationSpark's
local strategy): each query point grows an L∞ box until it provably
contains ≥ k distinct objects, then one refinement pass extracts the
candidates within radius ``r·√2`` (the Euclidean guarantee: d∞ ≤ r ⇒
d₂ ≤ r·√2, so the √2-inflated box contains every true neighbour) and
takes an exact top-k by ``(distance, id)`` — ties broken by id, fully
deterministic.

Counting during deepening runs against the *canonical-copy* tiles (see
``query.range``), so counts are unique-object counts — raw MASJ counts
would overcount replicas and stop the deepening too early, which is a
correctness bug, not a tuning knob.

The layout's kNN quality metric is MINDIST fan-out: the number of
partitions a best-first search (ordered by MINDIST, à la R*-Grove /
classic R-tree NN) must visit before the kth distance prunes the rest.
``serve.router.route_knn`` produces that ordering; ``knn_fanout`` turns
an answered batch into the per-query metric.

``pruned_knn`` is the routed executor: deepening and refinement touch
only each query's ``(Q, F)`` MINDIST-frontier candidate tiles
(``serve.router.candidate_knn``), with a provable miss check — if the
final refinement radius reaches the nearest *excluded* tile, the query
is flagged instead of silently answered, and the server widens the
frontier and retries.  Exactness is checkable, never assumed.

Like ``query.range``, these are pure functions of staged arrays —
the ``TileLayout`` placements (``repro.serve.layout``) call them
without the executors knowing which placement is running.  Under tile
sharding (``repro.serve.exchange``) each owner device runs
``knn_partial`` — deepening counts and a local top-k over its shard —
and the home device reduces with ``merge_knn_partials``: a k-way merge
keyed by the same ``(distance, id)`` tie-break (``_refine_topk`` is the
single definition), so sharded answers are bit-identical to the dense
oracle.  The frontier-miss check is unchanged: the excluded distance
is global, computed at routing time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.range_probe import ops as rops

_INF = jnp.float32(jnp.inf)
_BIG_ID = jnp.int32(2**30)


def mindist2(pts: jax.Array, boxes: jax.Array) -> jax.Array:
    """Squared Euclidean MINDIST, point to closed box.

    pts: (..., 2), boxes: (K, 4) -> (..., K); 0 inside the box.
    """
    x, y = pts[..., None, 0], pts[..., None, 1]
    dx = jnp.maximum(jnp.maximum(boxes[..., 0] - x, x - boxes[..., 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(boxes[..., 1] - y, y - boxes[..., 3]), 0.0)
    return dx * dx + dy * dy


def knn_ref(mbrs: np.ndarray, pts: np.ndarray, k: int
            ) -> tuple[np.ndarray, np.ndarray]:
    """Numpy brute-force oracle: (Q, k) ids and squared distances,
    ordered by (distance, id)."""
    px, py = pts[:, None, 0], pts[:, None, 1]
    dx = np.maximum(np.maximum(mbrs[None, :, 0] - px, px - mbrs[None, :, 2]),
                    0.0)
    dy = np.maximum(np.maximum(mbrs[None, :, 1] - py, py - mbrs[None, :, 3]),
                    0.0)
    d2 = dx * dx + dy * dy
    ids = np.broadcast_to(np.arange(mbrs.shape[0]), d2.shape)
    order = np.lexsort((ids, d2), axis=1)[:, :k]
    return order.astype(np.int32), np.take_along_axis(d2, order, axis=1)


def _qboxes(pts: jax.Array, r: jax.Array) -> jax.Array:
    rr = r[:, None]
    return jnp.concatenate([pts - rr, pts + rr], axis=-1)


def initial_radius(diag, k: int, n_slots):
    """Density-based first deepening radius: the L∞ half-width at which
    a box is expected to hold ~k of ``n_slots`` uniformly spread
    objects, floored at diag·1e-6.  Shared by the executors and the
    server's LPT cost proxy (``serve.engine``) so packing weights match
    the radius the kernel actually starts from.

    ``n_slots`` must be the number of *live canonical members* (the
    dataset size ``n``), not the padded ``T·cap`` slot count — sentinel
    slots hold nothing, so counting them biases the density high, the
    radius low, and every high-padding layout burns extra deepening
    rounds doubling back up (the ``n_live`` parameter of the executors
    exists for exactly this).  Accepts a python int or a traced scalar
    — the executors take ``n_live`` as a *dynamic* argument so a
    streaming append (which changes ``n`` every batch) never forces a
    re-trace.
    """
    n = jnp.maximum(jnp.asarray(n_slots, jnp.float32), 1.0)
    r = diag * 0.5 * jnp.sqrt(k / n)
    return jnp.maximum(r, diag * 1e-6)


def _refine_topk(k: int, pt: jax.Array, hit: jax.Array,
                 boxes_row: jax.Array, ids_row: jax.Array, max_cand: int
                 ) -> tuple[jax.Array, jax.Array]:
    """One query's exact top-k by ``(distance, id)`` from a hit mask.

    hit: (S,) candidate mask over ``boxes_row``/``ids_row`` (S slots);
    at most ``max_cand`` set slots are extracted (callers flag the
    excess) -> ``(ids[k], d2[k])``, missing entries -1 / +inf.  The
    single definition of the deterministic tie-break shared by the
    dense, pruned, and sharded-partial executors — bit-identical
    answers across them hinge on this ordering being one function.
    """
    slots = jnp.nonzero(hit, size=max_cand, fill_value=-1)[0]
    live = slots >= 0
    boxes = boxes_row[jnp.maximum(slots, 0)]
    cid = jnp.where(live, ids_row[jnp.maximum(slots, 0)], _BIG_ID)
    d2 = jnp.where(live, mindist2(pt, boxes), _INF)
    o1 = jnp.argsort(cid)
    o2 = jnp.argsort(d2[o1], stable=True)
    order = o1[o2][:k]
    return jnp.where(d2[order] < _INF, cid[order], -1), d2[order]


@functools.partial(jax.jit, static_argnames=("k", "max_rounds", "max_cand"))
def batched_knn(pts: jax.Array, k: int, canon_tiles: jax.Array,
                ids: jax.Array, uni: jax.Array, r0: float | None = None,
                max_rounds: int = 32, max_cand: int = 1024,
                n_live=None, alive: jax.Array | None = None):
    """Exact batched kNN against a staged layout.

    pts: (Q, 2) query points; canon_tiles/ids: staging from
    ``serve.engine`` — canonical copies only, so deepening counts are
    unique-object counts.  ``n_live`` is the live canonical member
    count (the dataset size) the initial radius is density-sized from;
    ``None`` falls back to the padded ``T·cap`` slot count, which
    undersizes the radius on high-padding layouts (see
    ``initial_radius``) — callers that know ``n`` should pass it.
    Returns ``(nn_ids[Q, k] int32, nn_d2[Q, k] f32, radius[Q] f32,
    overflow[Q] bool, rounds[Q] int32)``; overflow marks queries whose
    refinement box held more than ``max_cand`` candidates (re-run with
    a bigger ``max_cand`` — exactness is flagged, never silently
    lost); rounds counts each query's radius doublings (the deepening
    cost the initial radius is meant to minimise).  ``alive``: (T, cap)
    tombstone mask — deleted objects neither count during deepening nor
    appear as neighbours (pass the matching live ``n_live``).
    """
    q = pts.shape[0]
    diag = jnp.sqrt(jnp.sum((uni[2:] - uni[:2]) ** 2))
    if r0 is None:
        n_slots = (n_live if n_live is not None
                   else canon_tiles.shape[0] * canon_tiles.shape[1])
        r_init = initial_radius(diag, k, n_slots)
    else:
        r_init = jnp.maximum(jnp.float32(r0), diag * 1e-6)

    # per-query L∞ radius at which the box provably covers the universe
    # (query points may lie outside it), so deepening always terminates
    # with >= min(k, n) unique hits
    r_cover = jnp.maximum(
        jnp.maximum(pts[:, 0] - uni[0], uni[2] - pts[:, 0]),
        jnp.maximum(pts[:, 1] - uni[1], uni[3] - pts[:, 1]))
    r_cover = jnp.maximum(r_cover, diag * 1e-6)

    def counts_at(r):
        return jnp.sum(rops.probe_counts(_qboxes(pts, r), canon_tiles,
                                         alive=alive), axis=1)

    def cond(state):
        r, counts, rounds, i = state
        return jnp.any((counts < k) & (r < r_cover)) & (i < max_rounds)

    def body(state):
        r, counts, rounds, i = state
        grow = (counts < k) & (r < r_cover)
        r = jnp.where(counts < k, jnp.minimum(r * 2.0, r_cover), r)
        return r, counts_at(r), rounds + grow.astype(jnp.int32), i + 1

    r = jnp.full((q,), r_init, jnp.float32)
    counts = counts_at(r)
    r, counts, rounds, _ = jax.lax.while_loop(
        cond, body, (r, counts, jnp.zeros((q,), jnp.int32), jnp.int32(0)))

    # refinement: the √2-inflated box provably contains all true kNN
    re = r * jnp.sqrt(jnp.float32(2.0))
    mask = rops.probe_mask(_qboxes(pts, re), canon_tiles,
                           alive=alive)                     # (Q, T, cap)
    ids_flat = ids.reshape(-1)
    flat = mask.reshape(q, -1) & (ids_flat >= 0)[None, :]
    n_cand = jnp.sum(flat, axis=1, dtype=jnp.int32)

    tiles_flat = canon_tiles.reshape(-1, 4)
    nn_ids, nn_d2 = jax.vmap(
        lambda pt, hit: _refine_topk(k, pt, hit, tiles_flat, ids_flat,
                                     max_cand))(pts, flat)
    return nn_ids, nn_d2, r, n_cand > max_cand, rounds


@functools.partial(jax.jit, static_argnames=("k", "max_rounds", "max_cand"))
def pruned_knn(pts: jax.Array, k: int, canon_tiles: jax.Array,
               ids: jax.Array, uni: jax.Array, cand: jax.Array,
               excluded: jax.Array, r0: float | None = None,
               max_rounds: int = 32, max_cand: int = 1024,
               n_live=None,
               chunk_boxes: jax.Array | None = None,
               alive: jax.Array | None = None):
    """Exact batched kNN probing only each query's candidate tiles.

    Same contract as ``batched_knn`` (including ``n_live`` for the
    density-sized initial radius and the per-query ``rounds`` output)
    with two extra inputs from ``serve.router.candidate_knn`` over the
    layout's canonical probe boxes: ``cand`` (Q, F) int32 frontier tile
    indices (-1 padding) and ``excluded`` (Q,) f32, the L∞ distance of
    the nearest tile *not* in the frontier (+inf when the frontier
    holds every tile).  ``chunk_boxes`` (T, C, 4), when given, runs
    deepening counts and refinement through the chunk-skipping kernels
    (indexed staging, ``local_index="x"``/``"hilbert"``) — same bits,
    dead chunks skipped.

    Returns ``(nn_ids[Q, k] int32, nn_d2[Q, k] f32, radius[Q] f32,
    overflow[Q] bool, rounds[Q] int32)``.  ``overflow`` flags a query
    when (a) its refinement box held more than ``max_cand`` candidates,
    or (b) its final L∞ refinement radius reached ``excluded`` — a tile
    outside the frontier could hold a true neighbour.  Non-flagged
    answers are exact (ties by id, like the dense path); the server
    retries flagged queries with a wider frontier.

    Rows with an all ``-1`` candidate list (SPMD padding slots) can
    never reach k hits; they start at the covering radius so they don't
    drive the deepening loop, and answer all -1 / +inf.
    """
    q = pts.shape[0]
    dead = jnp.all(cand < 0, axis=1)
    diag = jnp.sqrt(jnp.sum((uni[2:] - uni[:2]) ** 2))
    if r0 is None:
        n_slots = (n_live if n_live is not None
                   else canon_tiles.shape[0] * canon_tiles.shape[1])
        r_init = initial_radius(diag, k, n_slots)
    else:
        r_init = jnp.maximum(jnp.float32(r0), diag * 1e-6)

    r_cover = jnp.maximum(
        jnp.maximum(pts[:, 0] - uni[0], uni[2] - pts[:, 0]),
        jnp.maximum(pts[:, 1] - uni[1], uni[3] - pts[:, 1]))
    r_cover = jnp.maximum(r_cover, diag * 1e-6)

    def counts_at(r):
        qb = _qboxes(pts, r)
        if chunk_boxes is None:
            return jnp.sum(rops.gathered_counts(qb, canon_tiles, cand,
                                                alive=alive), axis=1)
        return jnp.sum(rops.gathered_counts_skip(qb, canon_tiles,
                                                 chunk_boxes, cand,
                                                 alive=alive), axis=1)

    def cond(state):
        r, counts, rounds, i = state
        return jnp.any((counts < k) & (r < r_cover)) & (i < max_rounds)

    def body(state):
        r, counts, rounds, i = state
        grow = (counts < k) & (r < r_cover)
        r = jnp.where(counts < k, jnp.minimum(r * 2.0, r_cover), r)
        return r, counts_at(r), rounds + grow.astype(jnp.int32), i + 1

    r = jnp.where(dead, r_cover, jnp.full((q,), r_init, jnp.float32))
    counts = counts_at(r)
    r, counts, rounds, _ = jax.lax.while_loop(
        cond, body, (r, counts, jnp.zeros((q,), jnp.int32), jnp.int32(0)))

    # refinement over the frontier only; the √2-inflated box provably
    # contains all true kNN *unless* it reaches an excluded tile —
    # the same local extraction the sharded owners run
    re = r * jnp.sqrt(jnp.float32(2.0))
    nn_ids, nn_d2, n_cand = knn_partial(pts, canon_tiles, ids, cand, re,
                                        k=k, max_cand=max_cand,
                                        chunk_boxes=chunk_boxes,
                                        alive=alive)
    overflow = (n_cand > max_cand) | (excluded <= re)
    return nn_ids, nn_d2, r, overflow, rounds


# --------------------------------------------------------------------------
# sharded executor pieces: owner-side partial top-k + home-side k-way merge
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "max_cand"))
def knn_partial(pts: jax.Array, canon_tiles: jax.Array, ids: jax.Array,
                cand: jax.Array, re: jax.Array, k: int,
                max_cand: int = 1024,
                chunk_boxes: jax.Array | None = None,
                alive: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Owner-side refinement: local top-k within ``[pt ± re]``.

    pts: (Q, 2) received query points; canon_tiles/ids: this owner's
    *local* shard; cand: (Q, F) local candidate tile indices (-1
    padding); re: (Q,) final L∞ refinement radii (already √2-inflated
    by the caller); chunk_boxes: this shard's (T, C, 4) local index or
    None (selects the chunk-skipping mask kernel — same bits)
    -> ``(nn_ids[Q, k], nn_d2[Q, k], n_cand[Q])``.

    Because the true global top-k is contained in the union of
    per-owner top-k's, exchanging only ``k`` rows per (query, owner)
    pair loses nothing — ``merge_knn_partials`` re-sorts the union with
    the same ``(distance, id)`` key, so the merged answer is
    bit-identical to the dense single-device refinement.  ``n_cand``
    is this owner's candidate count: local extraction truncates past
    ``max_cand``, so the caller must flag those queries.
    """
    q = pts.shape[0]
    if chunk_boxes is None:
        mask = rops.gathered_mask(_qboxes(pts, re), canon_tiles, cand,
                                  alive=alive)
    else:
        mask = rops.gathered_mask_skip(_qboxes(pts, re), canon_tiles,
                                       chunk_boxes, cand, alive=alive)
    gids = rops.gathered_ids(ids, cand).reshape(q, -1)
    gboxes = rops.gathered_rows(canon_tiles, cand).reshape(q, -1, 4)
    flat = mask.reshape(q, -1) & (gids >= 0)
    n_cand = jnp.sum(flat, axis=1, dtype=jnp.int32)
    nn_ids, nn_d2 = jax.vmap(
        lambda pt, hit, br, ir: _refine_topk(k, pt, hit, br, ir, max_cand)
    )(pts, flat, gboxes, gids)
    return nn_ids, nn_d2, n_cand


def merge_knn_partials(pids: jax.Array, pd2: jax.Array, slots: jax.Array,
                       qpd: int, k: int) -> tuple[jax.Array, jax.Array]:
    """K-way merge of per-owner top-k frontiers by ``(distance, id)``.

    pids/pd2: (D, M, k) per-owner partial answers (entry (o, m) is
    owner ``o``'s local top-k for this home's ``m``-th message to it);
    slots: (D, M) home query slot per message (-1 padding)
    -> ``(nn_ids[qpd, k], nn_d2[qpd, k])``.

    Each query meets each owner at most once and each canonical id
    lives on exactly one owner, so scattering the ≤ D partial lists
    into a per-query ``(D, k)`` table and re-sorting by the shared
    ``(distance, id)`` key (same two-pass sort as ``_refine_topk``)
    reproduces the dense tie-break exactly — ids are distinct, the
    total order is unique, and distances are computed from identical
    f32 inputs on owners, so the merge is bit-identical to the oracle.
    """
    d = pids.shape[0]
    live = slots >= 0
    idx = jnp.where(live, slots, qpd)
    col = jnp.arange(d)[:, None]
    keyed = jnp.where(live[..., None] & (pids >= 0), pids, _BIG_ID)
    dk = jnp.where(live[..., None], pd2, _INF)
    tid = jnp.full((qpd + 1, d, k), _BIG_ID, jnp.int32).at[idx, col].set(keyed)
    td2 = jnp.full((qpd + 1, d, k), _INF, jnp.float32).at[idx, col].set(dk)
    fid = tid[:qpd].reshape(qpd, d * k)
    fd2 = td2[:qpd].reshape(qpd, d * k)
    o1 = jnp.argsort(fid, axis=1)
    o2 = jnp.argsort(jnp.take_along_axis(fd2, o1, axis=1), axis=1,
                     stable=True)
    order = jnp.take_along_axis(o1, o2, axis=1)[:, :k]
    d2 = jnp.take_along_axis(fd2, order, axis=1)
    cid = jnp.take_along_axis(fid, order, axis=1)
    return jnp.where(d2 < _INF, cid, -1), d2


def knn_fanout(pts: jax.Array, kth_d2: jax.Array, part_boxes: jax.Array,
               valid: jax.Array) -> jax.Array:
    """Per-query MINDIST fan-out: partitions a best-first search must
    visit, i.e. valid partitions with MINDIST² ≤ kth distance²."""
    d2 = mindist2(pts, part_boxes)
    return jnp.sum((d2 <= kth_d2[:, None]) & valid[None, :], axis=1,
                   dtype=jnp.int32)
