"""Batched k-nearest-neighbour queries over staged layouts.

kNN is built as **iterative-deepening range probes** (LocationSpark's
local strategy): each query point grows an L∞ box until it provably
contains ≥ k distinct objects, then one refinement pass extracts the
candidates within radius ``r·√2`` (the Euclidean guarantee: d∞ ≤ r ⇒
d₂ ≤ r·√2, so the √2-inflated box contains every true neighbour) and
takes an exact top-k by ``(distance, id)`` — ties broken by id, fully
deterministic.

Counting during deepening runs against the *canonical-copy* tiles (see
``query.range``), so counts are unique-object counts — raw MASJ counts
would overcount replicas and stop the deepening too early, which is a
correctness bug, not a tuning knob.

The layout's kNN quality metric is MINDIST fan-out: the number of
partitions a best-first search (ordered by MINDIST, à la R*-Grove /
classic R-tree NN) must visit before the kth distance prunes the rest.
``serve.router.route_knn`` produces that ordering; ``knn_fanout`` turns
an answered batch into the per-query metric.

``pruned_knn`` is the routed executor: deepening and refinement touch
only each query's ``(Q, F)`` MINDIST-frontier candidate tiles
(``serve.router.candidate_knn``), with a provable miss check — if the
final refinement radius reaches the nearest *excluded* tile, the query
is flagged instead of silently answered, and the server widens the
frontier and retries.  Exactness is checkable, never assumed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.range_probe import ops as rops

_INF = jnp.float32(jnp.inf)
_BIG_ID = jnp.int32(2**30)


def mindist2(pts: jax.Array, boxes: jax.Array) -> jax.Array:
    """Squared Euclidean MINDIST, point to closed box.

    pts: (..., 2), boxes: (K, 4) -> (..., K); 0 inside the box.
    """
    x, y = pts[..., None, 0], pts[..., None, 1]
    dx = jnp.maximum(jnp.maximum(boxes[..., 0] - x, x - boxes[..., 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(boxes[..., 1] - y, y - boxes[..., 3]), 0.0)
    return dx * dx + dy * dy


def knn_ref(mbrs: np.ndarray, pts: np.ndarray, k: int
            ) -> tuple[np.ndarray, np.ndarray]:
    """Numpy brute-force oracle: (Q, k) ids and squared distances,
    ordered by (distance, id)."""
    px, py = pts[:, None, 0], pts[:, None, 1]
    dx = np.maximum(np.maximum(mbrs[None, :, 0] - px, px - mbrs[None, :, 2]),
                    0.0)
    dy = np.maximum(np.maximum(mbrs[None, :, 1] - py, py - mbrs[None, :, 3]),
                    0.0)
    d2 = dx * dx + dy * dy
    ids = np.broadcast_to(np.arange(mbrs.shape[0]), d2.shape)
    order = np.lexsort((ids, d2), axis=1)[:, :k]
    return order.astype(np.int32), np.take_along_axis(d2, order, axis=1)


def _qboxes(pts: jax.Array, r: jax.Array) -> jax.Array:
    rr = r[:, None]
    return jnp.concatenate([pts - rr, pts + rr], axis=-1)


def initial_radius(diag, k: int, n_slots: int):
    """Density-based first deepening radius: the L∞ half-width at which
    a box is expected to hold ~k of ``n_slots`` uniformly spread
    objects, floored at diag·1e-6.  Shared by the executors and the
    server's LPT cost proxy (``serve.engine``) so packing weights match
    the radius the kernel actually starts from.
    """
    r = diag * 0.5 * jnp.sqrt(k / jnp.float32(max(n_slots, 1)))
    return jnp.maximum(r, diag * 1e-6)


@functools.partial(jax.jit, static_argnames=("k", "max_rounds", "max_cand"))
def batched_knn(pts: jax.Array, k: int, canon_tiles: jax.Array,
                ids: jax.Array, uni: jax.Array, r0: float | None = None,
                max_rounds: int = 32, max_cand: int = 1024):
    """Exact batched kNN against a staged layout.

    pts: (Q, 2) query points; canon_tiles/ids: staging from
    ``serve.engine`` — canonical copies only, so deepening counts are
    unique-object counts.  Returns ``(nn_ids[Q, k] int32,
    nn_d2[Q, k] f32, radius[Q] f32, overflow[Q] bool)``; overflow marks
    queries whose refinement box held more than ``max_cand`` candidates
    (re-run with a bigger ``max_cand`` — exactness is flagged, never
    silently lost).
    """
    q = pts.shape[0]
    diag = jnp.sqrt(jnp.sum((uni[2:] - uni[:2]) ** 2))
    if r0 is None:
        r_init = initial_radius(
            diag, k, canon_tiles.shape[0] * canon_tiles.shape[1])
    else:
        r_init = jnp.maximum(jnp.float32(r0), diag * 1e-6)

    # per-query L∞ radius at which the box provably covers the universe
    # (query points may lie outside it), so deepening always terminates
    # with >= min(k, n) unique hits
    r_cover = jnp.maximum(
        jnp.maximum(pts[:, 0] - uni[0], uni[2] - pts[:, 0]),
        jnp.maximum(pts[:, 1] - uni[1], uni[3] - pts[:, 1]))
    r_cover = jnp.maximum(r_cover, diag * 1e-6)

    def counts_at(r):
        return jnp.sum(rops.probe_counts(_qboxes(pts, r), canon_tiles),
                       axis=1)

    def cond(state):
        r, counts, i = state
        return jnp.any((counts < k) & (r < r_cover)) & (i < max_rounds)

    def body(state):
        r, counts, i = state
        r = jnp.where(counts < k, jnp.minimum(r * 2.0, r_cover), r)
        return r, counts_at(r), i + 1

    r = jnp.full((q,), r_init, jnp.float32)
    counts = counts_at(r)
    r, counts, _ = jax.lax.while_loop(cond, body, (r, counts, jnp.int32(0)))

    # refinement: the √2-inflated box provably contains all true kNN
    re = r * jnp.sqrt(jnp.float32(2.0))
    mask = rops.probe_mask(_qboxes(pts, re), canon_tiles)   # (Q, T, cap)
    ids_flat = ids.reshape(-1)
    flat = mask.reshape(q, -1) & (ids_flat >= 0)[None, :]
    n_cand = jnp.sum(flat, axis=1, dtype=jnp.int32)

    tiles_flat = canon_tiles.reshape(-1, 4)

    def refine(pt, hit):
        slots = jnp.nonzero(hit, size=max_cand, fill_value=-1)[0]
        live = slots >= 0
        boxes = tiles_flat[jnp.maximum(slots, 0)]
        cid = jnp.where(live, ids_flat[jnp.maximum(slots, 0)], _BIG_ID)
        d2 = jnp.where(live, mindist2(pt, boxes), _INF)
        o1 = jnp.argsort(cid)
        o2 = jnp.argsort(d2[o1], stable=True)
        order = o1[o2][:k]
        return jnp.where(d2[order] < _INF, cid[order], -1), d2[order]

    nn_ids, nn_d2 = jax.vmap(refine)(pts, flat)
    return nn_ids, nn_d2, r, n_cand > max_cand


@functools.partial(jax.jit, static_argnames=("k", "max_rounds", "max_cand"))
def pruned_knn(pts: jax.Array, k: int, canon_tiles: jax.Array,
               ids: jax.Array, uni: jax.Array, cand: jax.Array,
               excluded: jax.Array, r0: float | None = None,
               max_rounds: int = 32, max_cand: int = 1024):
    """Exact batched kNN probing only each query's candidate tiles.

    Same contract as ``batched_knn`` with two extra inputs from
    ``serve.router.candidate_knn`` over the layout's canonical probe
    boxes: ``cand`` (Q, F) int32 frontier tile indices (-1 padding) and
    ``excluded`` (Q,) f32, the L∞ distance of the nearest tile *not* in
    the frontier (+inf when the frontier holds every tile).

    Returns ``(nn_ids[Q, k] int32, nn_d2[Q, k] f32, radius[Q] f32,
    overflow[Q] bool)``.  ``overflow`` flags a query when (a) its
    refinement box held more than ``max_cand`` candidates, or (b) its
    final L∞ refinement radius reached ``excluded`` — a tile outside
    the frontier could hold a true neighbour.  Non-flagged answers are
    exact (ties by id, like the dense path); the server retries flagged
    queries with a wider frontier.

    Rows with an all ``-1`` candidate list (SPMD padding slots) can
    never reach k hits; they start at the covering radius so they don't
    drive the deepening loop, and answer all -1 / +inf.
    """
    q = pts.shape[0]
    dead = jnp.all(cand < 0, axis=1)
    diag = jnp.sqrt(jnp.sum((uni[2:] - uni[:2]) ** 2))
    if r0 is None:
        r_init = initial_radius(
            diag, k, canon_tiles.shape[0] * canon_tiles.shape[1])
    else:
        r_init = jnp.maximum(jnp.float32(r0), diag * 1e-6)

    r_cover = jnp.maximum(
        jnp.maximum(pts[:, 0] - uni[0], uni[2] - pts[:, 0]),
        jnp.maximum(pts[:, 1] - uni[1], uni[3] - pts[:, 1]))
    r_cover = jnp.maximum(r_cover, diag * 1e-6)

    def counts_at(r):
        return jnp.sum(
            rops.gathered_counts(_qboxes(pts, r), canon_tiles, cand), axis=1)

    def cond(state):
        r, counts, i = state
        return jnp.any((counts < k) & (r < r_cover)) & (i < max_rounds)

    def body(state):
        r, counts, i = state
        r = jnp.where(counts < k, jnp.minimum(r * 2.0, r_cover), r)
        return r, counts_at(r), i + 1

    r = jnp.where(dead, r_cover, jnp.full((q,), r_init, jnp.float32))
    counts = counts_at(r)
    r, counts, _ = jax.lax.while_loop(cond, body, (r, counts, jnp.int32(0)))

    # refinement over the frontier only; the √2-inflated box provably
    # contains all true kNN *unless* it reaches an excluded tile
    re = r * jnp.sqrt(jnp.float32(2.0))
    mask = rops.gathered_mask(_qboxes(pts, re), canon_tiles, cand)
    gids = rops.gathered_ids(ids, cand).reshape(q, -1)          # (Q, F·cap)
    gboxes = rops.gathered_rows(canon_tiles, cand).reshape(q, -1, 4)
    flat = mask.reshape(q, -1) & (gids >= 0)
    n_cand = jnp.sum(flat, axis=1, dtype=jnp.int32)

    def refine(pt, hit, boxes_row, ids_row):
        slots = jnp.nonzero(hit, size=max_cand, fill_value=-1)[0]
        live = slots >= 0
        boxes = boxes_row[jnp.maximum(slots, 0)]
        cid = jnp.where(live, ids_row[jnp.maximum(slots, 0)], _BIG_ID)
        d2 = jnp.where(live, mindist2(pt, boxes), _INF)
        o1 = jnp.argsort(cid)
        o2 = jnp.argsort(d2[o1], stable=True)
        order = o1[o2][:k]
        return jnp.where(d2[order] < _INF, cid[order], -1), d2[order]

    nn_ids, nn_d2 = jax.vmap(refine)(pts, flat, gboxes, gids)
    overflow = (n_cand > max_cand) | (excluded <= re)
    return nn_ids, nn_d2, r, overflow


def knn_fanout(pts: jax.Array, kth_d2: jax.Array, part_boxes: jax.Array,
               valid: jax.Array) -> jax.Array:
    """Per-query MINDIST fan-out: partitions a best-first search must
    visit, i.e. valid partitions with MINDIST² ≤ kth distance²."""
    d2 = mindist2(pts, part_boxes)
    return jnp.sum((d2 <= kth_d2[:, None]) & valid[None, :], axis=1,
                   dtype=jnp.int32)
