"""Batched range (box-containment/overlap) queries over staged layouts.

A range query is a box; its answer is the set of dataset objects whose
MBR intersects it (closed-box ``st_intersects``, matching the join
path).  Queries run against the ``repro.serve.layout`` staging format
(``stage_tiles``): ``(T, cap, 4)`` member-box tiles built by MASJ
assignment — once per dataset, then kept current by the streaming
append path (which only ever grows canonical membership and the boxes
that summarise it, so everything here stays exact on a moving
dataset).

Replication makes dedup the correctness crux (same problem as the join,
§2.2), solved two ways, mirroring the join engine:

- **canonical-copy** (primary, all layouts): staging marks exactly one
  copy of every object as canonical; probing only canonical copies
  yields exact unique counts *and* exact unique id sets with zero dedup
  work, because a hit test against a member's full MBR is
  tile-independent.  This is the dense throughput path — one
  ``range_probe`` kernel sweep over all local tiles.
- **reference-point** (zero-extra-state, non-overlapping covering
  layouts only): a (query, object) hit is owned by the tile containing
  the intersection's low corner, so owned counts are exact without any
  canonical marking.  Overlapping tight-MBR layouts (HC/STR) can own a
  hit in several tiles — those must use the canonical path (same
  Table-1 split as the join's dedup-mode choice).

The global index (``repro.serve.router``) prunes which tiles a query
*must* visit, and per-query fan-out is the paper's boundary-object cost
metric for selection workloads.  Three pruned executors exploit it:

- ``pruned_range_counts`` / ``pruned_range_ids`` (primary): probe only
  each query's ``(Q, F)`` candidate tiles with the gathered
  ``range_probe`` kernel, against **canonical** tiles routed on
  canonical probe boxes — exact unique answers on *all six layouts*
  (see ``serve.router``), O(Q·F·cap) work instead of O(Q·T·cap).
- ``routed_range_counts`` (rp variant): candidate gather with
  reference-point ownership over the *full* tiles — exact for
  non-overlapping covering layouts without any canonical marking.

These executors are placement-agnostic — pure functions of staged
arrays, consumed through the ``TileLayout`` protocol
(``repro.serve.layout``) by both data placements.  When tiles are
*sharded* across devices (``repro.serve.exchange``), each owner runs
the pruned executors above on its local shard only and the home device
reduces the partials: ``merge_owner_counts`` (plain
integer sum — canonical copies make hits owner-disjoint) and
``merge_owner_ids`` (duplicate-free union by one ascending sort).
Merged answers are bit-identical to the single-device dense sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import geometry
from ..kernels.range_probe import ops as rops
from .join import rp_own_mask

_BIG_ID = jnp.int32(2**30)


# --------------------------------------------------------------------------
# brute-force reference (numpy, host)
# --------------------------------------------------------------------------

def range_query_ref(mbrs: np.ndarray, qboxes: np.ndarray) -> list[np.ndarray]:
    """Per-query sorted hit-id arrays, numpy brute force (oracle)."""
    out = []
    for q in qboxes:
        hit = ((q[0] <= mbrs[:, 2]) & (mbrs[:, 0] <= q[2])
               & (q[1] <= mbrs[:, 3]) & (mbrs[:, 1] <= q[3]))
        out.append(np.flatnonzero(hit).astype(np.int32))
    return out


# --------------------------------------------------------------------------
# canonical-copy path (exact for every layout)
# --------------------------------------------------------------------------

@jax.jit
def range_counts(qboxes: jax.Array, canon_tiles: jax.Array,
                 alive: jax.Array | None = None) -> jax.Array:
    """Exact per-query unique hit counts.

    qboxes: (Q, 4); canon_tiles: (T, cap, 4) canonical-copy member boxes
    (non-canonical slots sentineled) -> (Q,) int32.  ``alive``: (T, cap)
    bool tombstone mask — deleted objects stop answering.
    """
    return jnp.sum(rops.probe_counts(qboxes, canon_tiles, alive=alive),
                   axis=1)


@functools.partial(jax.jit, static_argnames=("max_hits",))
def range_ids(qboxes: jax.Array, canon_tiles: jax.Array, ids: jax.Array,
              max_hits: int, alive: jax.Array | None = None
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Exact per-query unique hit-id sets, ascending, padded with -1.

    ids: (T, cap) int32 member ids (-1 in padding slots).  Returns
    ``(hit_ids[Q, max_hits], counts[Q], overflow[Q])``; ids beyond
    ``max_hits`` are dropped and flagged.  ``alive`` as in
    ``range_counts``.
    """
    q = qboxes.shape[0]
    mask = rops.probe_mask(qboxes, canon_tiles, alive=alive)  # (Q, T, cap)
    flat = mask.reshape(q, -1) & (ids.reshape(-1) >= 0)[None, :]
    keyed = jnp.where(flat, ids.reshape(-1)[None, :], _BIG_ID)
    if keyed.shape[1] < max_hits:          # small layout, wide id budget
        keyed = jnp.pad(keyed, ((0, 0), (0, max_hits - keyed.shape[1])),
                        constant_values=_BIG_ID)
    top = jax.lax.sort(keyed, dimension=1)[:, :max_hits]
    hit_ids = jnp.where(top < _BIG_ID, top, -1)
    counts = jnp.sum(flat, axis=1, dtype=jnp.int32)
    return hit_ids, counts, counts > max_hits


# --------------------------------------------------------------------------
# pruned canonical path (exact for every layout, routed work only)
# --------------------------------------------------------------------------

@jax.jit
def pruned_range_counts(qboxes: jax.Array, canon_tiles: jax.Array,
                        cand: jax.Array,
                        chunk_boxes: jax.Array | None = None,
                        alive: jax.Array | None = None) -> jax.Array:
    """Exact per-query unique hit counts, probing candidate tiles only.

    qboxes: (Q, 4); canon_tiles: (T, cap, 4) canonical-copy member
    boxes; cand: (Q, F) int32 from ``serve.router.candidate_range``
    over the layout's canonical probe boxes (-1 = padding slot)
    -> (Q,) int32.  ``chunk_boxes`` (T, C, 4), when given (indexed
    staging, ``local_index="x"``/``"hilbert"``), switches to the
    chunk-skipping kernel — same bits, dead 128-member chunks skipped.

    Exactness: every canonical copy an un-pruned sweep would hit lives
    in a tile whose probe box the query overlaps, so a candidate list
    without overflow loses nothing; padded (-1) candidates gather an
    all-sentinel tile and contribute zero.  Chunk boxes bound their
    chunks' canonical members (a staging invariant), so a skipped
    chunk provably holds no hit.  ``alive``: (T, cap) tombstone mask.
    """
    if chunk_boxes is None:
        return jnp.sum(rops.gathered_counts(qboxes, canon_tiles, cand,
                                            alive=alive), axis=1)
    return jnp.sum(rops.gathered_counts_skip(qboxes, canon_tiles,
                                             chunk_boxes, cand,
                                             alive=alive), axis=1)


@functools.partial(jax.jit, static_argnames=("max_hits",))
def pruned_range_ids(qboxes: jax.Array, canon_tiles: jax.Array,
                     ids: jax.Array, cand: jax.Array, max_hits: int,
                     chunk_boxes: jax.Array | None = None,
                     alive: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Exact per-query unique hit-id sets from candidate tiles only.

    Same contract as ``range_ids`` (ascending ids, -1 padded, overflow
    flagged past ``max_hits``) at O(Q·F·cap) instead of O(Q·T·cap):
    ids: (T, cap) int32 (-1 padding); cand: (Q, F) int32 (-1 padding)
    -> ``(hit_ids[Q, max_hits], counts[Q], overflow[Q])``.
    ``chunk_boxes`` selects the chunk-skipping mask kernel (see
    ``pruned_range_counts``).

    Uniqueness is free: each object has exactly one canonical slot
    repo-wide, and a candidate list names distinct tiles, so no id can
    appear twice in the gathered hit table.
    """
    q = qboxes.shape[0]
    if chunk_boxes is None:
        mask = rops.gathered_mask(qboxes, canon_tiles, cand,
                                  alive=alive)                # (Q, F, cap)
    else:
        mask = rops.gathered_mask_skip(qboxes, canon_tiles, chunk_boxes,
                                       cand, alive=alive)
    gids = rops.gathered_ids(ids, cand)                    # (Q, F, cap)
    flat = mask.reshape(q, -1) & (gids.reshape(q, -1) >= 0)
    keyed = jnp.where(flat, gids.reshape(q, -1), _BIG_ID)
    if keyed.shape[1] < max_hits:          # narrow gather, wide id budget
        keyed = jnp.pad(keyed, ((0, 0), (0, max_hits - keyed.shape[1])),
                        constant_values=_BIG_ID)
    top = jax.lax.sort(keyed, dimension=1)[:, :max_hits]
    hit_ids = jnp.where(top < _BIG_ID, top, -1)
    counts = jnp.sum(flat, axis=1, dtype=jnp.int32)
    return hit_ids, counts, counts > max_hits


# --------------------------------------------------------------------------
# owner-partial merges (the sharded executor's home-side reduce)
# --------------------------------------------------------------------------

def merge_owner_counts(partials: jax.Array, slots: jax.Array,
                       qpd: int) -> jax.Array:
    """Sum per-owner partial counts back onto home query slots.

    partials: (D, M) int32 — entry (o, m) is owner ``o``'s count for
    this home's ``m``-th message to it; slots: (D, M) int32 home query
    slot each message carries (-1 = padding) -> (qpd,) int32.

    Exact because canonical copies partition the id space across tiles
    and the placement partitions tiles across owners: every hit is
    counted by exactly one owner, so the merge is a plain integer sum
    (associative — deterministic under any scatter order).  Dead
    messages land in a trash row that is sliced off.
    """
    live = slots >= 0
    idx = jnp.where(live, slots, qpd)
    return jnp.zeros((qpd + 1,), jnp.int32).at[idx].add(
        jnp.where(live, partials, 0))[:qpd]


def merge_owner_ids(pids: jax.Array, pcounts: jax.Array, slots: jax.Array,
                    qpd: int, max_hits: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Union per-owner sorted id partials into the ``range_ids`` contract.

    pids: (D, M, mh) ascending local hit ids (-1 padded) from each
    owner; pcounts: (D, M) true (untruncated) local counts; slots:
    (D, M) home query slots (-1 padding) -> ``(hit_ids[qpd, max_hits],
    counts[qpd], overflow[qpd])``.

    Each query reaches each owner at most once and each canonical id
    lives on exactly one owner, so the union is duplicate-free: scatter
    the ≤ D partial lists into a per-query table and one ascending sort
    yields exactly the dense path's id set.  Local truncation (an owner
    holding more than ``mh`` hits) implies ``counts > max_hits`` when
    ``mh == max_hits``, so it is always flagged, never silent.
    """
    d, _, mh = pids.shape
    live = slots >= 0
    idx = jnp.where(live, slots, qpd)
    col = jnp.arange(d)[:, None]
    keyed = jnp.where(live[..., None] & (pids >= 0), pids, _BIG_ID)
    tbl = jnp.full((qpd + 1, d, mh), _BIG_ID, jnp.int32).at[idx, col].set(keyed)
    flat = tbl[:qpd].reshape(qpd, d * mh)
    if flat.shape[1] < max_hits:
        flat = jnp.pad(flat, ((0, 0), (0, max_hits - flat.shape[1])),
                       constant_values=_BIG_ID)
    top = jax.lax.sort(flat, dimension=1)[:, :max_hits]
    hit_ids = jnp.where(top < _BIG_ID, top, -1)
    counts = merge_owner_counts(pcounts, slots, qpd)
    return hit_ids, counts, counts > max_hits


# --------------------------------------------------------------------------
# reference-point path (non-overlapping covering layouts)
# --------------------------------------------------------------------------

@jax.jit
# reprolint: disable=kernel-twin-parity -- reference-point research path
# over full MASJ tiles of a static layout; not part of the tombstone
# serving surface (serving goes through range_counts/pruned_*)
def range_counts_rp(qboxes: jax.Array, tiles: jax.Array,
                    tile_boxes: jax.Array, uni: jax.Array) -> jax.Array:
    """Exact unique counts via reference-point ownership (FG/BSP/SLC/BOS).

    tiles: the *full* MASJ tiles — no canonical marking needed; each hit
    is counted only in the tile owning the intersection's low corner.
    """
    hits = rops.probe_mask(qboxes, tiles)                 # (Q, T, cap)
    own = jax.vmap(
        lambda member_boxes, tb: rp_own_mask(qboxes, member_boxes, tb, uni)
    )(tiles, tile_boxes)                                  # (T, Q, cap)
    own = jnp.swapaxes(own, 0, 1)
    return jnp.sum(hits & own, axis=(1, 2), dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_fanout",))
# reprolint: disable=kernel-twin-parity -- reference-point research path
# (see range_counts_rp): static layouts only, outside the tombstone
# serving surface
def routed_range_counts(qboxes: jax.Array, tiles: jax.Array,
                        tile_boxes: jax.Array, uni: jax.Array,
                        route_mask: jax.Array, max_fanout: int) -> jax.Array:
    """Pruned probe: each query gathers only its routed tiles.

    ``route_mask``: (Q, T) bool from ``serve.router.route_range``.  Work
    is O(Q · max_fanout · cap) instead of O(Q · T · cap) — the win the
    paper's fan-out metric predicts.  Exact for non-overlapping covering
    layouts (rp ownership).  Returns ``(counts[Q], overflow[Q])``;
    queries routed to more than ``max_fanout`` tiles undercount and are
    flagged, never silently truncated.
    """
    fanout = jnp.sum(route_mask, axis=1, dtype=jnp.int32)
    order = jnp.argsort(~route_mask, axis=1, stable=True)  # routed first
    routed = order[:, :max_fanout]                         # (Q, F)
    live = jnp.take_along_axis(route_mask, routed, axis=1)  # (Q, F)

    def per_query(qbox, tidx, tlive):
        tb = tile_boxes[tidx]                              # (F, 4)
        mb = tiles[tidx]                                   # (F, cap, 4)
        hits = jax.vmap(
            lambda boxes, box: (rp_own_mask(qbox[None], boxes, box, uni)[0]
                                & geometry.intersects(qbox[None], boxes))
        )(mb, tb)
        return jnp.sum(hits & tlive[:, None], dtype=jnp.int32)

    return jax.vmap(per_query)(qboxes, routed, live), fanout > max_fanout
