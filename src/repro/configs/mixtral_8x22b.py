"""Mixtral 8x22B [arXiv:2401.04088]: 8-expert top-2 MoE with SWA.
Experts are few (8 < model-axis 16), so TP shards the expert hidden dim
rather than the expert axis (shard_experts=False)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv=8, d_ff=16384,
    vocab=32768, head_dim=128, window=4096,
    n_experts=8, top_k=2, shard_experts=False,
)
