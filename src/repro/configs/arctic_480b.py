"""Snowflake Arctic 480B [hf:Snowflake]: 128-expert top-2 MoE with a
parallel dense residual MLP; experts sharded over the model axis (EP)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, head_dim=128,
    n_experts=128, top_k=2, moe_ff=4864, dense_residual=True,
    shard_experts=True,
)
