"""RecurrentGemma 9B [arXiv:2402.19427]: Griffin — RG-LRU recurrent
blocks and local attention in a 2:1 pattern (rec, rec, local)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288,
    vocab=256000, head_dim=256,
    block_pattern=("rec", "rec", "local"), local_window=2048,
    rglru_width=4096, tie_embeddings=True, act="gelu",
)
