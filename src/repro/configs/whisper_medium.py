"""Whisper medium [arXiv:2212.04356]: enc-dec; conv/mel frontend is a
stub — input_specs feeds precomputed frame embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv=16,
    d_ff=4096, vocab=51865, head_dim=64, src_len=1500,
    act="gelu", tie_embeddings=True,
)
