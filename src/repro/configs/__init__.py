"""Assigned-architecture registry (+ the paper's own workload config).

Every module defines ``CONFIG`` (the exact published configuration) —
``get(name)`` returns it, ``smoke(name)`` returns a reduced same-family
config for CPU tests (small dims, same block pattern / features).
"""
from __future__ import annotations

import dataclasses
import importlib

from ..models.config import ModelConfig

ARCHS = [
    "gemma2_27b", "stablelm_12b", "qwen15_4b", "command_r_35b",
    "whisper_medium", "mixtral_8x22b", "arctic_480b", "internvl2_26b",
    "recurrentgemma_9b", "mamba2_1p3b",
]

# canonical dashed ids used by the assignment table
ALIASES = {
    "gemma2-27b": "gemma2_27b", "stablelm-12b": "stablelm_12b",
    "qwen1.5-4b": "qwen15_4b", "command-r-35b": "command_r_35b",
    "whisper-medium": "whisper_medium", "mixtral-8x22b": "mixtral_8x22b",
    "arctic-480b": "arctic_480b", "internvl2-26b": "internvl2_26b",
    "recurrentgemma-9b": "recurrentgemma_9b", "mamba2-1.3b": "mamba2_1p3b",
}


def get(name: str) -> ModelConfig:
    mod = ALIASES.get(name, name)
    return importlib.import_module(f".{mod}", __package__).CONFIG


def smoke(name: str) -> ModelConfig:
    """Reduced same-family config: tiny dims, identical structure."""
    cfg = get(name)
    pat_len = len(cfg.pattern)
    n_layers = pat_len * 2 + (1 if cfg.block_pattern else 0)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        moe_ff=128 if cfg.n_experts else None,
        ssm_state=32 if cfg.ssm_state else 0,
        ssm_head_dim=16,
        rglru_width=64 if cfg.rglru_width else None,
        local_window=32,
        window=32 if cfg.window else None,
        enc_layers=2 if cfg.enc_layers else 0,
        src_len=24 if cfg.enc_layers else cfg.src_len,
        vis_tokens=8 if cfg.vis_tokens else 0,
        vis_dim=48 if cfg.vis_dim else 0,
    )
