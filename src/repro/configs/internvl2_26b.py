"""InternVL2 26B [arXiv:2404.16821]: InternViT frontend (stub — patch
embeddings arrive precomputed) + InternLM2-style dense backbone."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384,
    vocab=92553, head_dim=128,
    vis_tokens=256, vis_dim=3200,
)
