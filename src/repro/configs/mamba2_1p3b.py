"""Mamba2 1.3B [arXiv:2405.21060]: attention-free SSD (state-space
duality), state 128, 48 mixer blocks."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv=1, d_ff=0,
    vocab=50280, head_dim=64,
    ssm_state=128, ssm_head_dim=64, ssm_groups=1, ssm_expand=2,
    tie_embeddings=True,
)
