"""Command-R 35B [hf:CohereForAI]: wide dense GQA, no biases."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv=8, d_ff=22528,
    vocab=256000, head_dim=128,
)
