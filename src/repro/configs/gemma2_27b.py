"""Gemma 2 27B [arXiv:2408.00118]: local+global alternating attention,
logit/attention softcaps, sandwich norms, tied embeddings."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv=16, d_ff=36864,
    vocab=256000, head_dim=128,
    local_global=True, local_window=4096,
    logit_softcap=30.0, attn_softcap=50.0, post_norms=True,
    tie_embeddings=True, act="gelu", rope_theta=10000.0,
)
