"""Version compatibility shims for jax API drift.

The repo targets the container's pinned jax; newer/older releases moved
``shard_map`` (``jax.experimental.shard_map`` → ``jax.shard_map``) and
renamed its replication-check kwarg (``check_rep`` → ``check_vma``).
Everything in-repo imports ``shard_map`` from here so call sites can use
the modern spelling regardless of the installed version.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, kwarg is check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # jax 0.4.x: experimental module, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on any supported jax."""
    kw = {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def all_to_all(x, axis_name: str):
    """Device transpose: ``x[(D, ...)] -> (D, ...)`` where output row
    ``j`` is what device ``j`` held in *its* row for this device.

    The one exchange shape the serving stack uses (leading axis =
    mesh-axis size, ``split_axis=concat_axis=0``), wrapped here next to
    ``shard_map`` so collective call sites survive jax API drift in one
    place.  ``tiled=True`` keeps the leading axis in place (row ``j``
    of the result came from device ``j``).
    """
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0,
                              tiled=True)
