"""The paper's query-processing cost model (§2.3).

    C(R ⋈ S) = (1+α)² · |R||S| / k  +  β(|R| + |S|)

α — boundary-object replication fraction (a function of k and the layout),
β — per-object de-duplication cost, k — partition count.  The model says
granularity is a double-edged sword: larger k parallelises the join but
inflates α.  ``optimal_k`` sweeps the trade-off given an empirical α(k).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CostParams:
    beta: float = 1.0          # dedup cost per object, in pair-test units
    c_pair: float = 1.0        # cost of one pair predicate test


def join_cost(n_r, n_s, k, alpha, params: CostParams = CostParams()):
    part = params.c_pair * (1.0 + alpha) ** 2 * n_r * n_s / jnp.maximum(k, 1)
    dedup = params.beta * (n_r + n_s)
    return part + dedup


def straggler_cost(n_r, n_s, k, alpha, skew, params: CostParams = CostParams()):
    """SPMD refinement (beyond-paper): lock-step time is gated by the
    *largest* tile, i.e. the mean per-tile cost times the skew ratio."""
    return join_cost(n_r, n_s, k, alpha, params) * jnp.maximum(skew, 1.0)


def optimal_k(n_r, n_s, ks, alphas, params: CostParams = CostParams()):
    costs = join_cost(jnp.float32(n_r), jnp.float32(n_s),
                      jnp.asarray(ks, jnp.float32),
                      jnp.asarray(alphas, jnp.float32), params)
    i = jnp.argmin(costs)
    return i, costs
