"""Hilbert space-filling-curve encoding (pure-jnp reference).

Maps 2-D grid coordinates to positions along a Hilbert curve of a given
order.  Used by the HC partitioner and as the oracle for the Pallas kernel
in ``repro.kernels.hilbert``.

Algorithm: the classic iterative xy->d transform (Wikipedia / Hacker's
Delight), vectorised over arrays with ``lax.fori_loop`` over bit planes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

DEFAULT_ORDER = 16  # 2^16 x 2^16 grid -> 32-bit curve index


def xy2d(x: jax.Array, y: jax.Array, order: int = DEFAULT_ORDER) -> jax.Array:
    """Vectorised Hilbert encode: uint32 grid coords -> uint32 curve index.

    ``x``/``y`` must be in ``[0, 2**order)``.
    """
    x = x.astype(jnp.uint32)
    y = y.astype(jnp.uint32)
    d = jnp.zeros_like(x)

    def body(i, carry):
        x, y, d = carry
        s = jnp.uint32(1) << jnp.uint32(order - 1 - i)
        rx = ((x & s) > 0).astype(jnp.uint32)
        ry = ((y & s) > 0).astype(jnp.uint32)
        d = d + s * s * ((jnp.uint32(3) * rx) ^ ry)
        # rotate quadrant
        swap = ry == 0
        flip = swap & (rx == 1)
        x_f = jnp.where(flip, s - jnp.uint32(1) - x, x)
        y_f = jnp.where(flip, s - jnp.uint32(1) - y, y)
        x, y = jnp.where(swap, y_f, x_f), jnp.where(swap, x_f, y_f)
        return x, y, d

    _, _, d = lax.fori_loop(0, order, body, (x, y, d))
    return d


def quantize(pts: jax.Array, bounds: jax.Array, order: int = DEFAULT_ORDER) -> tuple[jax.Array, jax.Array]:
    """(N, 2) float points + (4,) universe box -> uint32 grid coords."""
    n = jnp.uint32(1) << jnp.uint32(order)
    span = jnp.maximum(bounds[2:] - bounds[:2], 1e-30)
    f = (pts - bounds[:2]) / span
    g = jnp.clip((f * n.astype(jnp.float32)).astype(jnp.uint32), 0, n - 1)
    return g[:, 0], g[:, 1]


def hilbert_keys(pts: jax.Array, bounds: jax.Array, order: int = DEFAULT_ORDER) -> jax.Array:
    """Float points -> uint32 Hilbert keys (the HC partitioner sort key)."""
    gx, gy = quantize(pts, bounds, order)
    return xy2d(gx, gy, order)
