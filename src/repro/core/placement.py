"""Item → device placement (the shared LPT scheduler family).

Two consumers, one cost story: partitions are the paper's unit of
parallelism, so both the join engine (tiles → devices, §2.3 cost) and
the serving stack (queries → devices by routed fan-out, and now *tile
shards* → owner devices by member count) place work with greedy LPT
(longest-processing-time-first, a 4/3-approximation to makespan) at
plan time on the host.  Lock-step SPMD cannot absorb stragglers the
way MapReduce's dynamic task queue does, so the slowest device gates
every step — balance is a scheduler here, not just a metric.

Tile *sharding* adds a second constraint LPT alone does not give:
per-device memory.  ``lpt_pack_capped`` bounds the number of items per
device (R*-Grove's balanced-partition goal applied to placement), and
``shard_tiles`` uses it with a ``ceil(T/D)`` cap so every device holds
at most one tile more than an even split — per-device staged memory is
O(total/D), the property the distributed server's tests assert.

``repro.query.balance`` re-exports the join-facing names for
compatibility; new code should import from here.
"""
from __future__ import annotations

import numpy as np


def tile_costs(nr: np.ndarray, ns: np.ndarray) -> np.ndarray:
    """Per-tile join cost  c_i = |R_i|·|S_i|  (§2.3).

    nr, ns: (T,) per-tile payload counts -> (T,) float64 costs.
    """
    return nr.astype(np.float64) * ns.astype(np.float64)


def lpt_pack(costs: np.ndarray, n_devices: int):
    """Greedy LPT (longest-processing-time-first), a 4/3-approximation
    to minimum makespan.

    costs: (T,) non-negative weights -> ``(device[T] int32 assignment,
    makespan float, mean_load float)``.  Equal weights degrade to
    round-robin placement (ties broken by ascending device id); an
    all-zero vector leaves everything on device 0 — callers that need
    spreading regardless (e.g. ``serve.engine.pack_queries``)
    substitute uniform costs first.
    """
    t = costs.shape[0]
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_devices, np.float64)
    assignment = np.zeros(t, np.int32)
    for i in order:
        d = int(np.argmin(loads))
        assignment[i] = d
        loads[d] += costs[i]
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def lpt_pack_capped(costs: np.ndarray, n_devices: int, max_per_device: int):
    """LPT under a per-device item-count cap (capacitated scheduling).

    Same contract as ``lpt_pack`` but no device receives more than
    ``max_per_device`` items: each item goes to the least-loaded device
    that still has a free slot.  Raises if ``n_devices·max_per_device``
    cannot hold every item.  The cap is what turns cost balancing into
    a *memory* guarantee — with ``max_per_device = ceil(T/D)`` no
    device stores more than one item over an even split.
    """
    t = costs.shape[0]
    if n_devices * max_per_device < t:
        raise ValueError(
            f"cannot place {t} items on {n_devices} devices with "
            f"cap {max_per_device}")
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_devices, np.float64)
    counts = np.zeros(n_devices, np.int64)
    assignment = np.zeros(t, np.int32)
    for i in order:
        open_ = np.flatnonzero(counts < max_per_device)
        d = int(open_[np.argmin(loads[open_])])
        assignment[i] = d
        loads[d] += costs[i]
        counts[d] += 1
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def round_robin_pack(costs: np.ndarray, n_devices: int):
    """Baseline packing (what a naive tile→mapper hash gives you).

    Same return contract as ``lpt_pack``; ignores the weights when
    placing, so the makespan gap to LPT *is* the straggler cost.
    """
    t = costs.shape[0]
    assignment = (np.arange(t) % n_devices).astype(np.int32)
    loads = np.zeros(n_devices, np.float64)
    np.add.at(loads, assignment, costs)
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def shard_tiles(costs: np.ndarray, n_devices: int,
                prev_owner: np.ndarray | None = None,
                cooc: np.ndarray | None = None,
                balance_tol: float = 1.25,
                ) -> tuple[np.ndarray, np.ndarray, int, dict]:
    """Assign tiles to owner devices and local shard slots.

    costs: (T,) per-tile weights (member counts for serving shards)
    -> ``(owner[T] int32, local[T] int32, t_local, stats)``.

    ``owner[t]`` is the device holding tile ``t``; ``local[t]`` its
    row in that device's ``(t_local, ...)`` shard.  Placement is
    cost-balanced LPT capped at ``t_local = ceil(T/D)`` items per
    device, so per-device shard memory is at most one tile over an
    even split regardless of the cost distribution (an uncapped LPT
    piles all zero-cost tiles onto one device).  Local slots are
    assigned in ascending global-tile order per device, so the
    global → (owner, local) map is deterministic.

    ``prev_owner`` (the map being replaced, on a streaming re-balance)
    is reporting-only: ``stats['moved']`` counts tiles whose owner
    changed — the data-movement cost of the re-balance — without
    biasing the placement itself (the memory cap, not placement
    stickiness, is the guarantee re-staging relies on).

    ``cooc`` (a ``(T, T)`` tile-pair co-occurrence weight matrix from
    the router heat tracker) switches placement to the heat-aware
    co-locating refinement ``colocate_tiles``: tiles that co-occur in
    candidate lists land on the same owner so exchange fan-out stops
    crossing devices, still under the same ``ceil(T/D)`` cap.  With
    ``cooc`` a valid ``prev_owner`` additionally *seeds* the plan
    (move-minimising local search) rather than only scoring it.
    """
    t = costs.shape[0]
    d = max(1, n_devices)
    t_local = -(-t // d)                       # ceil(T/D)
    if cooc is not None and t > 0:
        owner, makespan, mean, cstats = colocate_tiles(
            costs, cooc, d, t_local, prev_owner=prev_owner,
            balance_tol=balance_tol)
    else:
        owner, makespan, mean = lpt_pack_capped(costs, d, t_local)
        cstats = {}
    local = np.zeros(t, np.int32)
    for dev in range(d):
        mine = np.flatnonzero(owner == dev)
        local[mine] = np.arange(mine.size, dtype=np.int32)
    stats = dict(t_local=t_local, makespan=makespan, mean_load=mean,
                 skew=makespan / max(mean, 1e-9), **cstats)
    if prev_owner is not None and prev_owner.shape[0] == t:
        stats["moved"] = int(np.sum(owner != prev_owner))
    return owner.astype(np.int32), local, t_local, stats


def colocate_tiles(costs: np.ndarray, cooc: np.ndarray, n_devices: int,
                   max_per_device: int,
                   prev_owner: np.ndarray | None = None,
                   balance_tol: float = 1.25, sweeps: int = 4):
    """Capped placement that minimises the co-occurrence cut.

    costs: (T,) per-tile weights; cooc: (T, T) symmetric-ish pair
    weights (``cooc[i, j]`` ≈ how often tiles i and j appear in the
    same query's candidate list) -> ``(owner[T] int32, makespan,
    mean_load, stats)``.

    This is the serving-side version of Kolb et al.'s hot-block
    grouping: the objective is the weighted *cut* — co-occurrence mass
    between tiles on different owners — because every cut pair is a
    query that must message two devices through the exchange.  Greedy
    local search (single moves, then pairwise swaps once devices fill
    up) from either the previous plan (move-minimising: tiles only
    move when the cut pays for it) or a fresh capped LPT.  Moves keep
    the per-device item cap and a load tolerance — a move may not push
    a device's cost load past ``balance_tol ×`` the mean unless it
    stays below the source device's load, so makespan stays bounded
    while the cut drops.  Deterministic: fixed sweep order (descending
    cost, stable), ties to the lowest device id.
    """
    t = costs.shape[0]
    d = max(1, n_devices)
    costs = np.asarray(costs, np.float64)
    w = np.asarray(cooc, np.float64)
    w = w + w.T                                # symmetrise
    np.fill_diagonal(w, 0.0)

    if (prev_owner is not None and prev_owner.shape[0] == t
            and np.all((prev_owner >= 0) & (prev_owner < d))
            and np.all(np.bincount(prev_owner, minlength=d)
                       <= max_per_device)):
        owner = prev_owner.astype(np.int32).copy()
    else:
        owner, _, _ = lpt_pack_capped(costs, d, max_per_device)
        owner = owner.astype(np.int32)

    loads = np.zeros(d, np.float64)
    np.add.at(loads, owner, costs)
    counts = np.bincount(owner, minlength=d).astype(np.int64)
    mean = float(costs.sum() / d)

    def onehot(o):
        e = np.zeros((t, d), np.float64)
        e[np.arange(t), o] = 1.0
        return e

    def cut(o):
        same = o[:, None] == o[None, :]
        return float(w[~same].sum() / 2.0)

    cut_before = cut(owner)
    order = np.argsort(-costs, kind="stable")
    for _ in range(max(1, sweeps)):
        moved_any = False
        # affinity[i, dev] = co-occurrence mass tile i shares with dev
        aff = w @ onehot(owner)
        for i in order:
            src = owner[i]
            gain = aff[i] - aff[i, src]        # cut reduction per target
            gain[src] = 0.0
            for dst in np.argsort(-gain, kind="stable"):
                if gain[dst] <= 0.0:
                    break
                if dst == src or counts[dst] >= max_per_device:
                    continue
                new_load = loads[dst] + costs[i]
                if new_load > balance_tol * max(mean, 1e-9) and \
                        new_load > loads[src]:
                    continue
                aff -= np.outer(w[:, i], onehot(owner)[i])
                owner[i] = dst
                aff += np.outer(w[:, i], onehot(owner)[i])
                loads[src] -= costs[i]; loads[dst] += costs[i]
                counts[src] -= 1; counts[dst] += 1
                moved_any = True
                break
        # swap pass: when devices are full, single moves stall — trade
        # pairs across the heaviest cut edges instead.
        aff = w @ onehot(owner)
        ii, jj = np.nonzero(np.triu(w, 1))
        edge_order = np.argsort(-w[ii, jj], kind="stable")
        for e in edge_order[:4 * t]:
            i, j = int(ii[e]), int(jj[e])
            oi, oj = owner[i], owner[j]
            if oi == oj:
                continue
            gain = (aff[i, oj] + aff[j, oi] - aff[i, oi] - aff[j, oj]
                    - 2.0 * w[i, j])
            if gain <= 0.0:
                continue
            di, dj = costs[i] - costs[j], costs[j] - costs[i]
            if max(loads[oi] + dj, loads[oj] + di) > \
                    balance_tol * max(mean, 1e-9) and \
                    max(loads[oi] + dj, loads[oj] + di) > \
                    max(loads[oi], loads[oj]):
                continue
            owner[i], owner[j] = oj, oi
            loads[oi] += dj; loads[oj] += di
            aff = w @ onehot(owner)
            moved_any = True
        if not moved_any:
            break

    cut_after = cut(owner)
    stats = dict(cut_before=cut_before, cut_after=cut_after)
    return owner, float(loads.max()), mean, stats
