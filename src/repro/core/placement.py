"""Item → device placement (the shared LPT scheduler family).

Two consumers, one cost story: partitions are the paper's unit of
parallelism, so both the join engine (tiles → devices, §2.3 cost) and
the serving stack (queries → devices by routed fan-out, and now *tile
shards* → owner devices by member count) place work with greedy LPT
(longest-processing-time-first, a 4/3-approximation to makespan) at
plan time on the host.  Lock-step SPMD cannot absorb stragglers the
way MapReduce's dynamic task queue does, so the slowest device gates
every step — balance is a scheduler here, not just a metric.

Tile *sharding* adds a second constraint LPT alone does not give:
per-device memory.  ``lpt_pack_capped`` bounds the number of items per
device (R*-Grove's balanced-partition goal applied to placement), and
``shard_tiles`` uses it with a ``ceil(T/D)`` cap so every device holds
at most one tile more than an even split — per-device staged memory is
O(total/D), the property the distributed server's tests assert.

``repro.query.balance`` re-exports the join-facing names for
compatibility; new code should import from here.
"""
from __future__ import annotations

import numpy as np


def tile_costs(nr: np.ndarray, ns: np.ndarray) -> np.ndarray:
    """Per-tile join cost  c_i = |R_i|·|S_i|  (§2.3).

    nr, ns: (T,) per-tile payload counts -> (T,) float64 costs.
    """
    return nr.astype(np.float64) * ns.astype(np.float64)


def lpt_pack(costs: np.ndarray, n_devices: int):
    """Greedy LPT (longest-processing-time-first), a 4/3-approximation
    to minimum makespan.

    costs: (T,) non-negative weights -> ``(device[T] int32 assignment,
    makespan float, mean_load float)``.  Equal weights degrade to
    round-robin placement (ties broken by ascending device id); an
    all-zero vector leaves everything on device 0 — callers that need
    spreading regardless (e.g. ``serve.engine.pack_queries``)
    substitute uniform costs first.
    """
    t = costs.shape[0]
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_devices, np.float64)
    assignment = np.zeros(t, np.int32)
    for i in order:
        d = int(np.argmin(loads))
        assignment[i] = d
        loads[d] += costs[i]
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def lpt_pack_capped(costs: np.ndarray, n_devices: int, max_per_device: int):
    """LPT under a per-device item-count cap (capacitated scheduling).

    Same contract as ``lpt_pack`` but no device receives more than
    ``max_per_device`` items: each item goes to the least-loaded device
    that still has a free slot.  Raises if ``n_devices·max_per_device``
    cannot hold every item.  The cap is what turns cost balancing into
    a *memory* guarantee — with ``max_per_device = ceil(T/D)`` no
    device stores more than one item over an even split.
    """
    t = costs.shape[0]
    if n_devices * max_per_device < t:
        raise ValueError(
            f"cannot place {t} items on {n_devices} devices with "
            f"cap {max_per_device}")
    order = np.argsort(-costs, kind="stable")
    loads = np.zeros(n_devices, np.float64)
    counts = np.zeros(n_devices, np.int64)
    assignment = np.zeros(t, np.int32)
    for i in order:
        open_ = np.flatnonzero(counts < max_per_device)
        d = int(open_[np.argmin(loads[open_])])
        assignment[i] = d
        loads[d] += costs[i]
        counts[d] += 1
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def round_robin_pack(costs: np.ndarray, n_devices: int):
    """Baseline packing (what a naive tile→mapper hash gives you).

    Same return contract as ``lpt_pack``; ignores the weights when
    placing, so the makespan gap to LPT *is* the straggler cost.
    """
    t = costs.shape[0]
    assignment = (np.arange(t) % n_devices).astype(np.int32)
    loads = np.zeros(n_devices, np.float64)
    np.add.at(loads, assignment, costs)
    mean = float(loads.mean()) if n_devices else 0.0
    return assignment, float(loads.max()), mean


def shard_tiles(costs: np.ndarray, n_devices: int,
                prev_owner: np.ndarray | None = None
                ) -> tuple[np.ndarray, np.ndarray, int, dict]:
    """Assign tiles to owner devices and local shard slots.

    costs: (T,) per-tile weights (member counts for serving shards)
    -> ``(owner[T] int32, local[T] int32, t_local, stats)``.

    ``owner[t]`` is the device holding tile ``t``; ``local[t]`` its
    row in that device's ``(t_local, ...)`` shard.  Placement is
    cost-balanced LPT capped at ``t_local = ceil(T/D)`` items per
    device, so per-device shard memory is at most one tile over an
    even split regardless of the cost distribution (an uncapped LPT
    piles all zero-cost tiles onto one device).  Local slots are
    assigned in ascending global-tile order per device, so the
    global → (owner, local) map is deterministic.

    ``prev_owner`` (the map being replaced, on a streaming re-balance)
    is reporting-only: ``stats['moved']`` counts tiles whose owner
    changed — the data-movement cost of the re-balance — without
    biasing the placement itself (the memory cap, not placement
    stickiness, is the guarantee re-staging relies on).
    """
    t = costs.shape[0]
    d = max(1, n_devices)
    t_local = -(-t // d)                       # ceil(T/D)
    owner, makespan, mean = lpt_pack_capped(costs, d, t_local)
    local = np.zeros(t, np.int32)
    for dev in range(d):
        mine = np.flatnonzero(owner == dev)
        local[mine] = np.arange(mine.size, dtype=np.int32)
    stats = dict(t_local=t_local, makespan=makespan, mean_load=mean,
                 skew=makespan / max(mean, 1e-9))
    if prev_owner is not None and prev_owner.shape[0] == t:
        stats["moved"] = int(np.sum(owner != prev_owner))
    return owner.astype(np.int32), local, t_local, stats
