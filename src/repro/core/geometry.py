"""MBR (minimum bounding rectangle) geometry primitives.

Conventions
-----------
An MBR is a float32 vector ``[xmin, ymin, xmax, ymax]``; a dataset is an
``(N, 4)`` array.  All predicates use *closed* boxes (touching boundaries
intersect), matching ``st_intersects`` semantics used by the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

XMIN, YMIN, XMAX, YMAX = 0, 1, 2, 3

# inverted box (xmin > xmax): intersects nothing under the closed-box
# predicates below — the padding sentinel shared by kernels and staging
SENTINEL_BOX = (9e9, 9e9, -9e9, -9e9)


def centroids(mbrs: jax.Array) -> jax.Array:
    """(N, 4) -> (N, 2) box centers."""
    return (mbrs[..., :2] + mbrs[..., 2:]) * 0.5


def areas(mbrs: jax.Array) -> jax.Array:
    """(N, 4) -> (N,) box areas (degenerate boxes have area 0)."""
    w = jnp.maximum(mbrs[..., XMAX] - mbrs[..., XMIN], 0.0)
    h = jnp.maximum(mbrs[..., YMAX] - mbrs[..., YMIN], 0.0)
    return w * h


def universe(mbrs: jax.Array, valid: jax.Array | None = None) -> jax.Array:
    """Tight bounding box of the whole dataset -> (4,).

    ``valid`` optionally masks out padding rows.
    """
    if valid is not None:
        big = jnp.float32(jnp.inf)
        lo = jnp.where(valid[:, None], mbrs[:, :2], big)
        hi = jnp.where(valid[:, None], mbrs[:, 2:], -big)
    else:
        lo, hi = mbrs[:, :2], mbrs[:, 2:]
    return jnp.concatenate([jnp.min(lo, axis=0), jnp.max(hi, axis=0)])


def intersects(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise closed-box intersection: (..., 4) x (..., 4) -> (...,) bool."""
    return (
        (a[..., XMIN] <= b[..., XMAX])
        & (b[..., XMIN] <= a[..., XMAX])
        & (a[..., YMIN] <= b[..., YMAX])
        & (b[..., YMIN] <= a[..., YMAX])
    )


def intersect_matrix(r: jax.Array, s: jax.Array) -> jax.Array:
    """(N, 4) x (M, 4) -> (N, M) bool intersect table (reference path).

    The Pallas kernel ``repro.kernels.mbr_join`` is the blocked production
    implementation; this is the small-input / oracle path.
    """
    return intersects(r[:, None, :], s[None, :, :])


def contains_point(boxes: jax.Array, pts: jax.Array) -> jax.Array:
    """(K, 4) boxes x (N, 2) points -> (N, K) bool containment (closed)."""
    x, y = pts[:, None, 0], pts[:, None, 1]
    return (
        (boxes[None, :, XMIN] <= x)
        & (x <= boxes[None, :, XMAX])
        & (boxes[None, :, YMIN] <= y)
        & (y <= boxes[None, :, YMAX])
    )


def box_union(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.concatenate(
        [jnp.minimum(a[..., :2], b[..., :2]), jnp.maximum(a[..., 2:], b[..., 2:])],
        axis=-1,
    )


def clip_box(inner: jax.Array, outer: jax.Array) -> jax.Array:
    lo = jnp.clip(inner[..., :2], outer[..., :2], outer[..., 2:])
    hi = jnp.clip(inner[..., 2:], outer[..., :2], outer[..., 2:])
    return jnp.concatenate([lo, hi], axis=-1)
