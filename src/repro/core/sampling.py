"""Sampling-based partitioning (§5.2).

Partition a γ-sample with a proportionally scaled payload (γ·b), then map
the resulting layout back onto the full dataset.  For universe-covering
methods (FG/BSP/SLC/BOS) the layout transfers directly; for tight-MBR
methods (HC/STR) the sampled layout may leave gaps — the paper flags this
as an open problem, and ``uncovered`` in the diagnostics quantifies it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import geometry
from .partition import api
from .partition.assign import partition_counts


@dataclasses.dataclass(frozen=True)
class SampledResult:
    parts: api.Partitioning
    sample_size: int
    sample_payload: int


def sampled_partition(method: str, mbrs: jax.Array, payload: int,
                      gamma: float, key: jax.Array) -> SampledResult:
    n = mbrs.shape[0]
    s = max(2, int(round(gamma * n)))
    payload_s = max(1, int(round(gamma * payload)))
    perm = jax.random.permutation(key, n)[:s]
    sample = mbrs[perm]
    parts = api.partition(method, sample, payload_s)
    if api.info(method).covers_universe:
        # the sampled layout covers the SAMPLE's universe; snap its rim
        # outward to the full-data universe so the transfer stays gap-free
        parts = _extend_rim(parts, geometry.universe(sample),
                            geometry.universe(mbrs))
    return SampledResult(parts=parts, sample_size=s, sample_payload=payload_s)


def _extend_rim(parts: api.Partitioning, uni_s: jax.Array,
                uni_f: jax.Array) -> api.Partitioning:
    """Stretch boxes touching the sample-universe rim to the full one."""
    eps = 1e-6 * jnp.maximum(uni_s[2:] - uni_s[:2], 1e-9)
    b = parts.boxes
    lo = jnp.where(b[:, :2] <= uni_s[:2] + eps,
                   jnp.minimum(b[:, :2], uni_f[:2]), b[:, :2])
    hi = jnp.where(b[:, 2:] >= uni_s[2:] - eps,
                   jnp.maximum(b[:, 2:], uni_f[2:]), b[:, 2:])
    boxes = jnp.where(parts.valid[:, None],
                      jnp.concatenate([lo, hi], axis=-1), b)
    return api.Partitioning(boxes=boxes.astype(jnp.float32),
                            valid=parts.valid)


def evaluate_on_full(res: SampledResult, mbrs: jax.Array):
    """Map a sampled layout back to the full dataset; returns metrics dict
    inputs (counts, copies) — ``copies == 0`` rows are the HC/STR gap
    objects the paper describes."""
    counts, copies = partition_counts(mbrs, res.parts)
    return counts, copies


def nearest_box_fallback(mbrs: jax.Array, parts: api.Partitioning) -> jax.Array:
    """For gap objects (no intersecting partition): index of the partition
    whose box center is nearest to the object centroid.  Used by the
    engine so HC/STR sampled layouts remain runnable (DESIGN.md §7)."""
    c = geometry.centroids(mbrs)
    bc = (parts.boxes[:, :2] + parts.boxes[:, 2:]) * 0.5
    d2 = jnp.sum((c[:, None, :] - bc[None, :, :]) ** 2, axis=-1)
    d2 = jnp.where(parts.valid[None, :], d2, jnp.inf)
    return jnp.argmin(d2, axis=1).astype(jnp.int32)
