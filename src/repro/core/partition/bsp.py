"""Binary Split Partitioning (BSP) — Algorithm 3.

Top-down, data-oriented, non-overlapping.  A node whose payload exceeds
``b`` is split at the member-centroid median; the split dimension is the
one maximising the product of children areas (the paper's probabilistic
area-balance criterion).

Implementation: level-synchronous kd construction.  Instead of recursion
(which does not jit), each level splits *all* oversized nodes at once with
segment ops over a (node, coord)-sorted order.  Child membership is
assigned by rank (robust to ties); the cut coordinate is the midpoint of
the two middle order statistics, so children boxes tile the parent
exactly and the layout is non-overlapping with full universe coverage.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import geometry
from .api import Partitioning, register


def _per_node_median(coord, node, num_nodes, counts, starts):
    """Per-node median cut + per-object rank in node, along one dim.

    Returns (cut[num_nodes], pos_in_node[N]) where ``cut`` is the midpoint
    of the two middle member coords.
    """
    n = coord.shape[0]
    order_c = jnp.argsort(coord)                 # stable
    order = order_c[jnp.argsort(node[order_c], stable=True)]
    sorted_coord = coord[order]
    sorted_node = node[order]
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_node]
    pos_in_node = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    half = counts // 2
    lo_idx = jnp.clip(starts + jnp.maximum(half - 1, 0), 0, n - 1)
    hi_idx = jnp.clip(starts + half, 0, n - 1)
    cut = (sorted_coord[lo_idx] + sorted_coord[hi_idx]) * 0.5
    return cut, pos_in_node


@register("bsp", overlapping=False, search="top-down", criterion="data",
          covers_universe=True)
def bsp_partition(mbrs: jax.Array, payload: int) -> Partitioning:
    n = mbrs.shape[0]
    depth = max(0, math.ceil(math.log2(max(n / payload, 1.0))))
    kmax = 1 << depth
    bounds = geometry.universe(mbrs)
    cx, cy = geometry.centroids(mbrs).T

    node = jnp.zeros((n,), jnp.int32)
    obox = jnp.broadcast_to(bounds, (n, 4))      # per-object node box

    for level in range(depth):
        num_nodes = 1 << level
        ones = jnp.ones((n,), jnp.int32)
        counts = jax.ops.segment_sum(ones, node, num_segments=num_nodes)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1].astype(jnp.int32)])
        cut_x, pos_x = _per_node_median(cx, node, num_nodes, counts, starts)
        cut_y, pos_y = _per_node_median(cy, node, num_nodes, counts, starts)

        # area products for the split-dimension criterion (per node)
        nbox = jnp.zeros((num_nodes, 4), obox.dtype).at[node].set(obox)
        w, h = nbox[:, 2] - nbox[:, 0], nbox[:, 3] - nbox[:, 1]
        px = jnp.maximum(cut_x - nbox[:, 0], 0) * jnp.maximum(nbox[:, 2] - cut_x, 0) * h * h
        py = jnp.maximum(cut_y - nbox[:, 1], 0) * jnp.maximum(nbox[:, 3] - cut_y, 0) * w * w
        use_x = px >= py

        split = counts > payload
        half = counts // 2
        o_split = split[node]
        o_use_x = use_x[node]
        o_left = jnp.where(o_use_x, pos_x, pos_y) < half[node]
        child = 2 * node + jnp.where(o_split & ~o_left, 1, 0)

        o_cut = jnp.where(o_use_x, cut_x[node], cut_y[node])
        xm0, ym0, xm1, ym1 = obox[:, 0], obox[:, 1], obox[:, 2], obox[:, 3]
        nx1 = jnp.where(o_split & o_use_x & o_left, o_cut, xm1)
        nx0 = jnp.where(o_split & o_use_x & ~o_left, o_cut, xm0)
        ny1 = jnp.where(o_split & ~o_use_x & o_left, o_cut, ym1)
        ny0 = jnp.where(o_split & ~o_use_x & ~o_left, o_cut, ym0)
        obox = jnp.stack([nx0, ny0, nx1, ny1], axis=-1)
        node = child

    boxes = jnp.broadcast_to(bounds, (kmax, 4)).astype(jnp.float32)
    boxes = boxes.at[node].set(obox)
    valid = jnp.zeros((kmax,), bool).at[node].set(True)
    return Partitioning(boxes=boxes, valid=valid)
