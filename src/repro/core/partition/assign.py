"""MASJ assignment: replicate every object to every partition it touches.

This is the paper's multi-assignment/single-join strategy (§2.2): after a
layout is computed, each object is assigned to *all* partitions whose
region intersects its MBR; duplicates produced by the replication are
removed after the query (``repro.query.dedup``).

Outputs are padded/masked so the whole pipeline stays statically shaped
(SPMD requirement — see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import geometry
from .api import Partitioning


def round_up(x: int, m: int) -> int:
    """Round ``x`` up to a multiple of ``m`` (capacity lane alignment)."""
    return int(-(-x // m) * m)


def partition_counts(mbrs: jax.Array, parts: Partitioning,
                     block: int = 8192) -> tuple[jax.Array, jax.Array]:
    """Per-partition payload counts and per-object copy counts.

    Returns ``(counts[kmax], copies[N])`` where ``counts`` includes MASJ
    replication (so ``sum(counts)/N - 1`` is the paper's λ).
    Memory: O(block * kmax).
    """
    n = mbrs.shape[0]
    kmax = parts.kmax
    counts = jnp.zeros((kmax,), jnp.int32)
    copies = jnp.zeros((n,), jnp.int32)
    nblocks = -(-n // block)
    for i in range(nblocks):
        sl = slice(i * block, min((i + 1) * block, n))
        hit = geometry.intersect_matrix(mbrs[sl], parts.boxes)
        hit = hit & parts.valid[None, :]
        counts = counts + jnp.sum(hit, axis=0, dtype=jnp.int32)
        copies = copies.at[sl].set(jnp.sum(hit, axis=1, dtype=jnp.int32))
    return counts, copies


def assign_padded(mbrs: jax.Array, parts: Partitioning, capacity: int
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Build padded per-partition member lists.

    Returns ``(members[kmax, capacity] int32 indices, mask[kmax, capacity],
    overflow[kmax])``.  Objects beyond ``capacity`` in a partition are
    dropped and counted in ``overflow`` (the engine sizes ``capacity``
    from the cost model so overflow is an error signal, not a silent
    truncation).
    """
    hit = geometry.intersect_matrix(mbrs, parts.boxes) & parts.valid[None, :]
    return assign_from_hit(hit, capacity)


def assign_from_hit(hit: jax.Array, capacity: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``assign_padded`` from a precomputed membership matrix.

    hit: (N, kmax) bool — object n is a member of partition k.  Callers
    that amend the geometric membership (e.g. the serving layer's
    nearest-tile adoption of objects that intersect no region on
    non-covering layouts) build ``hit`` themselves and share this
    scatter; ``assign_padded`` is the intersect-and-assign composition.
    """
    n, kmax = hit.shape
    rank = jnp.cumsum(hit.astype(jnp.int32), axis=0) - 1      # (N, k)
    obj = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, kmax))
    part = jnp.broadcast_to(jnp.arange(kmax, dtype=jnp.int32)[None, :],
                            (n, kmax))
    ok = hit & (rank < capacity)
    # every real (part, slot) target is unique (rank is a per-partition
    # running index); all masked-out entries collapse onto (0, 0) with
    # identity values under `max`, so a single scatter-max builds the table.
    p = jnp.where(ok, part, 0).ravel()
    s = jnp.where(ok, jnp.clip(rank, 0, capacity - 1), 0).ravel()
    members = jnp.full((kmax, capacity), -1, jnp.int32).at[p, s].max(
        jnp.where(ok, obj, -1).ravel())
    mask = jnp.zeros((kmax, capacity), bool).at[p, s].max(ok.ravel())
    members = jnp.maximum(members, 0)
    counts = jnp.sum(hit, axis=0, dtype=jnp.int32)
    overflow = jnp.maximum(counts - capacity, 0)
    return members, mask, overflow
