"""Hilbert-Curve partitioning (HC).

Bottom-up packing, data-oriented, *overlapping* (tight member MBRs).
Centroids are mapped to Hilbert curve indices (order-16 grid), the
dataset is sorted by curve value, and every consecutive run of ``b``
objects forms a partition whose region is the tight union of member
extents — exactly the Hilbert R-tree bulk-load leaf level.

The curve encode itself is the compute hot spot for large N; the
production path uses the Pallas kernel (``repro.kernels.hilbert``) and
falls back to the pure-jnp reference here.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from .. import geometry, hilbert
from .api import Partitioning, register
from .str_ import tight_group_boxes

# injected by repro.kernels at import time to avoid a core->kernels dep
_KEY_FN: Callable | None = None


def set_key_fn(fn: Callable | None) -> None:
    global _KEY_FN
    _KEY_FN = fn


@register("hc", overlapping=True, search="bottom-up", criterion="data",
          covers_universe=False)
def hc_partition(mbrs: jax.Array, payload: int,
                 order: int = hilbert.DEFAULT_ORDER) -> Partitioning:
    n = mbrs.shape[0]
    k = max(1, math.ceil(n / payload))
    bounds = geometry.universe(mbrs)
    pts = geometry.centroids(mbrs)
    key_fn = _KEY_FN or hilbert.hilbert_keys
    keys = key_fn(pts, bounds, order)
    perm = jnp.argsort(keys)

    pad = k * payload - n
    idx = jnp.pad(perm, (0, pad))
    real = jnp.pad(jnp.ones((n,), bool), (0, pad))
    member_boxes = mbrs[idx.reshape(k, payload)]
    mask = real.reshape(k, payload)
    boxes, valid = tight_group_boxes(member_boxes, mask)
    return Partitioning(boxes=boxes.astype(jnp.float32), valid=valid)
