"""Strip partitioning (SLC) — Algorithm 4.

Data-oriented, non-overlapping.  Objects are sorted by centroid along one
dimension and sliced into strips of ``b`` objects; each strip spans the
full universe in the other dimension.  Fully vectorised: one sort plus a
gather of the cut positions.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import geometry
from .api import Partitioning, register


def strip_cuts(coord_sorted: jax.Array, payload: int, lo, hi) -> jax.Array:
    """Cut positions between consecutive strips of ``payload`` objects.

    Returns (k+1,) edges with edges[0]=lo, edges[k]=hi.
    """
    n = coord_sorted.shape[0]
    k = max(1, math.ceil(n / payload))
    idx = jnp.arange(1, k) * payload          # first object of strip i
    right = coord_sorted[jnp.clip(idx, 0, n - 1)]
    left = coord_sorted[jnp.clip(idx - 1, 0, n - 1)]
    cuts = (left + right) * 0.5
    return jnp.concatenate([jnp.array([lo], coord_sorted.dtype), cuts,
                            jnp.array([hi], coord_sorted.dtype)])


@register("slc", overlapping=False, search="bottom-up", criterion="data",
          covers_universe=True)
def slc_partition(mbrs: jax.Array, payload: int, dim: int = 0) -> Partitioning:
    n = mbrs.shape[0]
    k = max(1, math.ceil(n / payload))
    bounds = geometry.universe(mbrs)
    c = geometry.centroids(mbrs)[:, dim]
    c_sorted = jnp.sort(c)
    edges = strip_cuts(c_sorted, payload, bounds[dim], bounds[dim + 2])
    if dim == 0:
        boxes = jnp.stack(
            [edges[:-1], jnp.full((k,), bounds[1]),
             edges[1:], jnp.full((k,), bounds[3])], axis=-1)
    else:
        boxes = jnp.stack(
            [jnp.full((k,), bounds[0]), edges[:-1],
             jnp.full((k,), bounds[2]), edges[1:]], axis=-1)
    return Partitioning(boxes=boxes.astype(jnp.float32),
                        valid=jnp.ones((k,), bool))
