"""Fixed Grid partitioning (FG) — Algorithm 2.

Space-oriented, non-overlapping: the universe is split into an m x m grid
with ``m = ceil(sqrt(N / b))``.  The grid is computed in O(1); objects are
assigned later by MASJ box intersection (``partition/assign.py``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import geometry
from .api import Partitioning, register


def grid_boxes(bounds: jax.Array, mx: int, my: int) -> jax.Array:
    """Tile ``bounds`` into an (mx*my, 4) grid of boxes (row-major in y)."""
    xs = jnp.linspace(bounds[0], bounds[2], mx + 1)
    ys = jnp.linspace(bounds[1], bounds[3], my + 1)
    x0, x1 = xs[:-1], xs[1:]
    y0, y1 = ys[:-1], ys[1:]
    bx0 = jnp.repeat(x0, my)
    bx1 = jnp.repeat(x1, my)
    by0 = jnp.tile(y0, mx)
    by1 = jnp.tile(y1, mx)
    return jnp.stack([bx0, by0, bx1, by1], axis=-1).astype(jnp.float32)


@register("fg", overlapping=False, search="na", criterion="space",
          covers_universe=True)
def fg_partition(mbrs: jax.Array, payload: int) -> Partitioning:
    n = mbrs.shape[0]
    m = max(1, math.ceil(math.sqrt(n / payload)))
    bounds = geometry.universe(mbrs)
    boxes = grid_boxes(bounds, m, m)
    return Partitioning(boxes=boxes, valid=jnp.ones((m * m,), bool))
