"""Sort-Tile-Recursive partitioning (STR) — Algorithm 6.

Bottom-up packing, data-oriented, *overlapping* (tight member MBRs).
``m = ceil(sqrt(N/b))`` vertical slabs by x-centroid, each slab sliced
into runs of ``b`` by y-centroid; the partition region is the tight MBR
of the run's members, as in R-tree bulk loading.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .. import geometry
from .api import Partitioning, register


def tight_group_boxes(mbrs_grouped: jax.Array, mask: jax.Array):
    """(..., G, 4) member boxes + (..., G) mask -> (..., 4) tight MBR."""
    big = jnp.float32(3.4e38)
    lo = jnp.where(mask[..., None], mbrs_grouped[..., :2], big)
    hi = jnp.where(mask[..., None], mbrs_grouped[..., 2:], -big)
    out = jnp.concatenate([jnp.min(lo, axis=-2), jnp.max(hi, axis=-2)],
                          axis=-1)
    any_valid = jnp.any(mask, axis=-1)
    return jnp.where(any_valid[..., None], out, jnp.zeros_like(out)), any_valid


@register("str", overlapping=True, search="bottom-up", criterion="data",
          covers_universe=False)
def str_partition(mbrs: jax.Array, payload: int) -> Partitioning:
    n = mbrs.shape[0]
    m = max(1, math.ceil(math.sqrt(n / payload)))
    slab = math.ceil(n / m)
    kper = max(1, math.ceil(slab / payload))

    c = geometry.centroids(mbrs)
    pad = m * slab - n
    big = jnp.float32(3.4e38)
    cx = jnp.concatenate([c[:, 0], jnp.full((pad,), big)])
    order_x = jnp.argsort(cx)
    idx = jnp.where(order_x < n, order_x, 0).reshape(m, slab)
    real = (order_x < n).reshape(m, slab)
    cy = jnp.where(real, c[:, 1][idx], big)

    order_y = jnp.argsort(cy, axis=1)
    idx = jnp.take_along_axis(idx, order_y, axis=1)
    real = jnp.take_along_axis(real, order_y, axis=1)

    pad2 = kper * payload - slab
    if pad2:
        idx = jnp.pad(idx, ((0, 0), (0, pad2)))
        real = jnp.pad(real, ((0, 0), (0, pad2)))
    member_boxes = mbrs[idx.reshape(m, kper, payload)]
    mask = real.reshape(m, kper, payload)
    boxes, valid = tight_group_boxes(member_boxes, mask)
    return Partitioning(boxes=boxes.reshape(-1, 4).astype(jnp.float32),
                        valid=valid.reshape(-1))
