"""Boundary-Optimized Strip partitioning (BOS) — Algorithm 5.

Data-oriented, non-overlapping.  Like SLC it slices strips of ``b``
objects off the remaining universe, but at every step it evaluates the
induced cut in *both* dimensions and takes the one crossing fewer object
MBRs (``getCost``), directly minimising boundary objects.

Implementation: a ``lax.scan`` over the (static) strip count.  Each step
is O(N) masked vector work against precomputed per-dimension sort orders,
so the whole partitioner is a single fused scan — no host loop.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .. import geometry
from .api import Partitioning, register


@register("bos", overlapping=False, search="bottom-up", criterion="data",
          covers_universe=True)
def bos_partition(mbrs: jax.Array, payload: int) -> Partitioning:
    n = mbrs.shape[0]
    kmax = max(1, math.ceil(n / payload))
    bounds = geometry.universe(mbrs)
    c = geometry.centroids(mbrs)
    cx, cy = c[:, 0], c[:, 1]
    ox = jnp.argsort(cx)
    oy = jnp.argsort(cy)
    cx_s, cy_s = cx[ox], cy[oy]

    def cut_and_cost(alive, order, coord_sorted, lo_ext, hi_ext, take):
        """b-th remaining order statistic as a cut + boundary-cross cost."""
        alive_s = alive[order]
        csum = jnp.cumsum(alive_s.astype(jnp.int32))
        pos_b = jnp.searchsorted(csum, take, side="left")
        pos_b1 = jnp.searchsorted(csum, take + 1, side="left")
        nn = coord_sorted.shape[0]
        v_b = coord_sorted[jnp.clip(pos_b, 0, nn - 1)]
        v_b1 = coord_sorted[jnp.clip(pos_b1, 0, nn - 1)]
        cut = (v_b + v_b1) * 0.5
        cost = jnp.sum(alive & (lo_ext < cut) & (cut < hi_ext))
        take_mask_s = alive_s & (csum <= take)
        removed = jnp.zeros_like(alive).at[order].set(take_mask_s)
        return cut, cost, removed

    def step(carry, _):
        alive, rem = carry
        n_alive = jnp.sum(alive.astype(jnp.int32))
        has = n_alive > 0
        take = jnp.minimum(payload, n_alive)
        last = n_alive <= payload

        cut_x, cost_x, rm_x = cut_and_cost(
            alive, ox, cx_s, mbrs[:, 0], mbrs[:, 2], take)
        cut_y, cost_y, rm_y = cut_and_cost(
            alive, oy, cy_s, mbrs[:, 1], mbrs[:, 3], take)
        cut_x = jnp.where(last, rem[2], cut_x)
        cut_y = jnp.where(last, rem[3], cut_y)
        use_x = cost_x <= cost_y

        box_x = jnp.stack([rem[0], rem[1], cut_x, rem[3]])
        box_y = jnp.stack([rem[0], rem[1], rem[2], cut_y])
        box = jnp.where(use_x, box_x, box_y)
        rem_x = jnp.stack([cut_x, rem[1], rem[2], rem[3]])
        rem_y = jnp.stack([rem[0], cut_y, rem[2], rem[3]])
        new_rem = jnp.where(has, jnp.where(use_x, rem_x, rem_y), rem)
        removed = jnp.where(use_x, rm_x, rm_y)
        new_alive = alive & ~(removed & has)
        return (new_alive, new_rem), (jnp.where(has, box, rem), has)

    alive0 = jnp.ones((n,), bool)
    (_, _), (boxes, valid) = lax.scan(step, (alive0, bounds), None,
                                      length=kmax)
    return Partitioning(boxes=boxes.astype(jnp.float32), valid=valid)
