"""Partitioner registry and the common result structure.

Every partitioner is a function ``(mbrs, payload, **kw) -> Partitioning``
with a *static* maximum partition count so the whole thing jits.  The
paper's Table-1 classification is attached as registry metadata.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Partitioning:
    """A set of (possibly padded) partition regions.

    boxes : (kmax, 4) float32 partition boundaries
    valid : (kmax,)  bool — real partitions vs padding rows
    """

    boxes: jax.Array
    valid: jax.Array

    @property
    def kmax(self) -> int:
        return self.boxes.shape[0]

    def k(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


@dataclasses.dataclass(frozen=True)
class MethodInfo:
    fn: Callable
    overlapping: bool          # Table 1: partition-boundary dimension
    search: str                # "top-down" | "bottom-up" | "na"
    criterion: str             # "space" | "data"
    covers_universe: bool      # tight-MBR methods may leave gaps


_REGISTRY: dict[str, MethodInfo] = {}


def register(name: str, *, overlapping: bool, search: str, criterion: str,
             covers_universe: bool):
    def deco(fn):
        _REGISTRY[name] = MethodInfo(fn, overlapping, search, criterion,
                                     covers_universe)
        return fn
    return deco


def methods() -> dict[str, MethodInfo]:
    return dict(_REGISTRY)


def info(name: str) -> MethodInfo:
    return _REGISTRY[name]


def partition(method: str, mbrs: jax.Array, payload: int, **kw) -> Partitioning:
    """Run a registered partitioner. ``payload`` is the paper's ``b``."""
    if method not in _REGISTRY:
        raise KeyError(f"unknown partition method {method!r}; "
                       f"have {sorted(_REGISTRY)}")
    return _REGISTRY[method].fn(mbrs, payload, **kw)
