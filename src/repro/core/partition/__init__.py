"""The paper's six spatial partitioning algorithms + MASJ assignment."""
from . import api, assign, bos, bsp, fg, hc, slc, str_  # noqa: F401  (registration)
from .api import Partitioning, info, methods, partition  # noqa: F401
from .assign import assign_padded, partition_counts  # noqa: F401
