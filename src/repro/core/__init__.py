"""Core library: the paper's contribution (partitioning, metrics, cost model)."""
from . import cost_model, geometry, hilbert, metrics, sampling  # noqa: F401
from .partition import Partitioning, partition  # noqa: F401
