"""Partition-quality metrics from the paper (§6.3, §6.4).

- ``balance_stddev``  — Fig 3's skewness measure,
- ``boundary_ratio``  — λ (eq. 2),
- ``skew_ratio``      — max/mean payload (the SPMD straggler factor:
  in lock-step execution the slowest shard gates the step, so this is
  the *direct* slowdown multiplier — see DESIGN.md §2),
- ``coverage``        — fraction of objects assigned to ≥1 partition.
"""
from __future__ import annotations

import jax.numpy as jnp


def balance_stddev(counts, valid):
    c = counts.astype(jnp.float32)
    k = jnp.maximum(jnp.sum(valid), 1)
    mean = jnp.sum(jnp.where(valid, c, 0.0)) / k
    var = jnp.sum(jnp.where(valid, (c - mean) ** 2, 0.0)) / k
    return jnp.sqrt(var)


def boundary_ratio(counts, valid, n_objects):
    """λ = Σ|p_i| / |R| − 1 (0 when no boundary objects)."""
    total = jnp.sum(jnp.where(valid, counts, 0))
    return total.astype(jnp.float32) / jnp.float32(n_objects) - 1.0


def skew_ratio(counts, valid):
    c = counts.astype(jnp.float32)
    k = jnp.maximum(jnp.sum(valid), 1)
    mean = jnp.sum(jnp.where(valid, c, 0.0)) / k
    mx = jnp.max(jnp.where(valid, c, 0.0))
    return mx / jnp.maximum(mean, 1e-9)


def coverage(copies):
    covered = jnp.sum((copies > 0).astype(jnp.int32))
    return covered.astype(jnp.float32) / jnp.float32(copies.shape[0])


def padding_waste(counts, valid, capacity):
    """Fraction of padded-tile slots that are padding (SPMD-specific)."""
    c = jnp.where(valid, counts, 0)
    used = jnp.sum(jnp.minimum(c, capacity))
    slots = jnp.maximum(jnp.sum(valid) * capacity, 1)
    return 1.0 - used.astype(jnp.float32) / slots.astype(jnp.float32)
