"""range_probe: batched query-box vs tiled-layout Pallas kernels.

Dense (``probe_counts`` / ``probe_mask``: every query vs every tile)
and routed (``gathered_counts`` / ``gathered_mask``: every query vs
only its ``(Q, F)`` candidate tiles) variants, each with a
chunk-skipping ``*_skip`` twin that consumes the staging's per-tile
local index (one MBR per 128-member chunk) and predicates dead chunks
away; ``ops`` is the public jit'd surface, ``ref`` the pure-jnp
oracle, ``kernel`` the raw ``pallas_call`` layer.  Padding everywhere
is the inverted sentinel box (xmin > xmax), which intersects nothing.
"""
from . import kernel, ops, ref  # noqa: F401
