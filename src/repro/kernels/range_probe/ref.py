"""Pure-jnp oracles for the range_probe kernels.

Shapes mirror the kernels' logical outputs before the ops-layer
transposes: dense oracles are tile-major, gathered oracles are
query-major.  Sentinel boxes (xmin > xmax) intersect nothing, so
padding contributes zero hits by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def probe_mask(qboxes: jax.Array, tiles: jax.Array) -> jax.Array:
    """(Q, 4) x (T, cap, 4) -> (T, Q, cap) closed-box intersection."""
    q = qboxes[None, :, None, :]
    s = tiles[:, None, :, :]
    return (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )


def probe_counts(qboxes: jax.Array, tiles: jax.Array) -> jax.Array:
    """(Q, 4) x (T, cap, 4) -> (Q, T) per-(query, tile) hit counts."""
    return jnp.sum(probe_mask(qboxes, tiles).astype(jnp.int32), axis=2).T


def gathered_mask(qboxes: jax.Array, gtiles: jax.Array) -> jax.Array:
    """(Q, 4) x (Q, F, cap, 4) -> (Q, F, cap): query j vs ITS OWN
    gathered candidate tiles (row-major gather)."""
    q = qboxes[:, None, None, :]
    s = gtiles
    return (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )


def gathered_counts(qboxes: jax.Array, gtiles: jax.Array) -> jax.Array:
    """(Q, 4) x (Q, F, cap, 4) -> (Q, F) per-candidate hit counts."""
    return jnp.sum(gathered_mask(qboxes, gtiles).astype(jnp.int32), axis=2)
