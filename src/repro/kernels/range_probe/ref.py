"""Pure-jnp oracles for the range_probe kernels.

Shapes mirror the kernels' logical outputs before the ops-layer
transposes: dense oracles are tile-major, gathered oracles are
query-major.  Sentinel boxes (xmin > xmax) intersect nothing, so
padding contributes zero hits by construction.

The ``*_skip`` oracles define the chunk-masked semantics of the
local-index kernels: a member hit only counts if the query also hits
the member's 128-lane chunk box.  When chunk boxes bound their members
(the staging invariant) this equals the unmasked result; when they
don't, the kernels must still match these oracles bit-for-bit.  They
double as the fused off-TPU executors — the chunk bookkeeping is
O(work / CHUNK), so the masked path costs within noise of the
unmasked one on backends that cannot skip.

Every oracle takes an optional per-slot **alive mask** (``alive``:
``(T, cap)`` dense, ``(Q, F, cap)`` gathered): a hit survives only if
its member slot is alive.  This is the tombstone-delete semantics of
the ingest engine (``serve.layout``): deleted members keep their slot
(and their contribution to the routing boxes, which stay exact
supersets) but stop answering.  ``alive=None`` is the all-live
fast path — bit-identical to passing an all-``True`` mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import CHUNK


def probe_mask(qboxes: jax.Array, tiles: jax.Array,
               alive: jax.Array | None = None) -> jax.Array:
    """(Q, 4) x (T, cap, 4) -> (T, Q, cap) closed-box intersection;
    ``alive`` (T, cap) masks dead member slots out of the hit table."""
    q = qboxes[None, :, None, :]
    s = tiles[:, None, :, :]
    hit = (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )
    if alive is not None:
        hit = hit & alive[:, None, :]
    return hit


def probe_counts(qboxes: jax.Array, tiles: jax.Array,
                 alive: jax.Array | None = None) -> jax.Array:
    """(Q, 4) x (T, cap, 4) -> (Q, T) per-(query, tile) hit counts."""
    return jnp.sum(probe_mask(qboxes, tiles, alive).astype(jnp.int32),
                   axis=2).T


def gathered_mask(qboxes: jax.Array, gtiles: jax.Array,
                  galive: jax.Array | None = None) -> jax.Array:
    """(Q, 4) x (Q, F, cap, 4) -> (Q, F, cap): query j vs ITS OWN
    gathered candidate tiles (row-major gather); ``galive`` (Q, F, cap)
    is the matching gathered alive mask."""
    q = qboxes[:, None, None, :]
    s = gtiles
    hit = (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )
    if galive is not None:
        hit = hit & galive
    return hit


def gathered_counts(qboxes: jax.Array, gtiles: jax.Array,
                    galive: jax.Array | None = None) -> jax.Array:
    """(Q, 4) x (Q, F, cap, 4) -> (Q, F) per-candidate hit counts."""
    return jnp.sum(gathered_mask(qboxes, gtiles, galive).astype(jnp.int32),
                   axis=2)


# --------------------------------------------------------------------------
# chunk-masked (local-index) oracles
# --------------------------------------------------------------------------

def _pad_lanes(mask: jax.Array, n_chunks: int) -> jax.Array:
    """Pad a (..., cap) hit table with False up to n_chunks * CHUNK."""
    pad = n_chunks * CHUNK - mask.shape[-1]
    if pad:
        mask = jnp.pad(mask, [(0, 0)] * (mask.ndim - 1) + [(0, pad)])
    return mask


def chunk_hits(qboxes: jax.Array, cboxes: jax.Array) -> jax.Array:
    """(Q, 4) x (T, C, 4) -> (Q, T, C) query-vs-chunk-box intersection."""
    q = qboxes[:, None, None, :]
    s = cboxes[None]
    return (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )


def probe_mask_skip(qboxes: jax.Array, tiles: jax.Array,
                    cboxes: jax.Array,
                    alive: jax.Array | None = None) -> jax.Array:
    """Chunk-masked ``probe_mask``: -> (T, Q, cap); a hit survives only
    if the query also hits the member's chunk box (and the member slot
    is alive, when ``alive`` is given)."""
    live = jnp.swapaxes(chunk_hits(qboxes, cboxes), 0, 1)  # (T, Q, C)
    lanes = jnp.repeat(live, CHUNK, axis=-1)[..., :tiles.shape[1]]
    return probe_mask(qboxes, tiles, alive) & lanes


def probe_counts_skip(qboxes: jax.Array, tiles: jax.Array,
                      cboxes: jax.Array,
                      alive: jax.Array | None = None) -> jax.Array:
    """Chunk-masked ``probe_counts``: -> (Q, T).  Sums per-chunk
    partials, then zeroes chunks the query's box cannot reach."""
    n_chunks = cboxes.shape[1]
    m = _pad_lanes(probe_mask(qboxes, tiles, alive), n_chunks)  # (T,Q,cap_p)
    part = jnp.sum(m.reshape(m.shape[0], m.shape[1], n_chunks, CHUNK)
                   .astype(jnp.int32), axis=3)              # (T, Q, C)
    live = jnp.swapaxes(chunk_hits(qboxes, cboxes), 0, 1)   # (T, Q, C)
    return jnp.sum(part * live, axis=2).T


def gathered_chunk_hits(qboxes: jax.Array, gcboxes: jax.Array) -> jax.Array:
    """(Q, 4) x (Q, F, C, 4) -> (Q, F, C): query j vs ITS OWN gathered
    candidates' chunk boxes."""
    q = qboxes[:, None, None, :]
    s = gcboxes
    return (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )


def gathered_mask_skip(qboxes: jax.Array, gtiles: jax.Array,
                       gcboxes: jax.Array,
                       galive: jax.Array | None = None) -> jax.Array:
    """Chunk-masked ``gathered_mask``: -> (Q, F, cap)."""
    live = gathered_chunk_hits(qboxes, gcboxes)             # (Q, F, C)
    lanes = jnp.repeat(live, CHUNK, axis=-1)[..., :gtiles.shape[2]]
    return gathered_mask(qboxes, gtiles, galive) & lanes


def gathered_counts_skip(qboxes: jax.Array, gtiles: jax.Array,
                         gcboxes: jax.Array,
                         galive: jax.Array | None = None) -> jax.Array:
    """Chunk-masked ``gathered_counts``: -> (Q, F)."""
    n_chunks = gcboxes.shape[2]
    m = _pad_lanes(gathered_mask(qboxes, gtiles, galive), n_chunks)
    part = jnp.sum(m.reshape(m.shape[0], m.shape[1], n_chunks, CHUNK)
                   .astype(jnp.int32), axis=3)               # (Q, F, C)
    return jnp.sum(part * gathered_chunk_hits(qboxes, gcboxes), axis=2)
