"""Pure-jnp oracle for the range_probe kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def probe_mask(qboxes: jax.Array, tiles: jax.Array) -> jax.Array:
    """(Q, 4) x (T, cap, 4) -> (T, Q, cap) closed-box intersection."""
    q = qboxes[None, :, None, :]
    s = tiles[:, None, :, :]
    return (
        (q[..., 0] <= s[..., 2])
        & (s[..., 0] <= q[..., 2])
        & (q[..., 1] <= s[..., 3])
        & (s[..., 1] <= q[..., 3])
    )


def probe_counts(qboxes: jax.Array, tiles: jax.Array) -> jax.Array:
    """(Q, 4) x (T, cap, 4) -> (Q, T) per-(query, tile) hit counts."""
    return jnp.sum(probe_mask(qboxes, tiles).astype(jnp.int32), axis=2).T
