"""Blocked range-probe kernel (TPU Pallas): query boxes vs tiled layout.

The serving hot spot: a (Q, 4) batch of range-query boxes is tested
against a (T, cap, 4) partitioned layout (T tiles of cap member slots,
the staging format of ``serve.engine``).  Like ``mbr_join`` this is a
VPU problem — a (BQ, cap) block of boolean closed-box compares from
rank-1 broadcasts; the member axis is the 128-lane axis.

Layout: queries arrive component-major (4, Q); tiles arrive per-tile
component-major (T, 4, cap) so grid cell (t, i) streams one tile's
coordinate block and one query block through VMEM.

Four entry points:
- ``count``: grid cell (t, i) reduces its (BQ, cap) hit block over the
  member axis — per-(tile, query) hit counts, O(T×Q) output.  This is
  the dense throughput path (count/selectivity queries, kNN deepening).
- ``mask``: writes the full (BQ, cap) boolean block — used for hit-id
  extraction on moderate tile counts.
- ``gather_count`` / ``gather_mask``: the **routed** variants.  The
  caller has already gathered each query's candidate tiles (router
  output) into a per-query ``(Q, F, 4, cap)`` stack, so grid cell
  (f, i) streams a (BQ, 1, 4, cap) slab where query row j carries *its
  own* f-th candidate tile.  Work drops from O(Q·T·cap) to
  O(Q·F·cap) — the partition-pruning win the paper's fan-out metric
  predicts, realised as compute instead of a report.
- ``*_skip``: the **local-index** variants (LocationSpark's second,
  intra-partition index layer).  Staging sorts each tile's members
  along x and summarises every ``CHUNK``-lane (128-member) slot group
  with one MBR ("chunk box"); the kernels test the query block against
  a tile's C chunk boxes first and only run the full (BQ, CHUNK)
  member compare for chunks some query in the block can hit
  (``pl.when``) — dead chunks cost C scalar compares instead of
  CHUNK·4 member compares.  Per-query predication (``hits & live``)
  keeps the output bit-identical to the unindexed kernels whenever the
  chunk boxes bound their members, and identical to the ``ref``
  chunk-masked oracles unconditionally.

Padding contract (same as mbr_join): callers pad query slots, member
slots, and absent candidate tiles with *inverted* sentinel boxes
(xmin > xmax), which intersect nothing, so no validity mask is
streamed through VMEM.  All-sentinel chunks get inverted chunk boxes
and are always skipped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
CHUNK = 128  # members summarised per chunk box (the VPU lane width)


def _block_hits(q_ref, t_ref):
    qx0 = q_ref[0, :][:, None]   # (BQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = t_ref[0, 0, :][None, :]   # (1, cap)
    sy0 = t_ref[0, 1, :][None, :]
    sx1 = t_ref[0, 2, :][None, :]
    sy1 = t_ref[0, 3, :][None, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _count_kernel(q_ref, t_ref, out_ref):
    hits = _block_hits(q_ref, t_ref)
    out_ref[0, :] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_kernel(q_ref, t_ref, out_ref):
    out_ref[0, ...] = _block_hits(q_ref, t_ref)


def count_pallas(q4: jax.Array, tiles: jax.Array, bq: int = DEFAULT_BQ,
                 interpret: bool = False) -> jax.Array:
    """q4: (4, Q), tiles: (T, 4, cap); Q % bq == 0, cap % 128 == 0
    -> (T, Q) int32 per-(tile, query) hit counts."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
            pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda ti, i: (ti, i)),
        out_shape=jax.ShapeDtypeStruct((t, q), jnp.int32),
        interpret=interpret,
    )(q4, tiles)


def mask_pallas(q4: jax.Array, tiles: jax.Array, bq: int = DEFAULT_BQ,
                interpret: bool = False) -> jax.Array:
    """q4: (4, Q), tiles: (T, 4, cap) -> (T, Q, cap) bool hit table."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
            pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, cap), lambda ti, i: (ti, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, q, cap), jnp.bool_),
        interpret=interpret,
    )(q4, tiles)


def _gather_block_hits(q_ref, g_ref):
    # query row j of the block is compared against its OWN gathered tile:
    # g_ref block is (BQ, 1, 4, cap), so every coordinate slab below is
    # (BQ, cap) with per-row tile data — still rank-1-broadcast VPU work.
    qx0 = q_ref[0, :][:, None]   # (BQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = g_ref[:, 0, 0, :]      # (BQ, cap)
    sy0 = g_ref[:, 0, 1, :]
    sx1 = g_ref[:, 0, 2, :]
    sy1 = g_ref[:, 0, 3, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _gather_count_kernel(q_ref, g_ref, out_ref):
    hits = _gather_block_hits(q_ref, g_ref)
    out_ref[:, 0] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_kernel(q_ref, g_ref, out_ref):
    out_ref[:, 0, :] = _gather_block_hits(q_ref, g_ref)


def gather_count_pallas(q4: jax.Array, gtiles: jax.Array,
                        bq: int = DEFAULT_BQ,
                        interpret: bool = False) -> jax.Array:
    """Routed probe, count form.

    q4: (4, Q) component-major queries; gtiles: (Q, F, 4, cap) each
    query's gathered candidate tiles (absent candidates = sentinel
    tiles).  Q % bq == 0, cap % 128 == 0 -> (Q, F) int32 per-(query,
    candidate) hit counts.
    """
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    return pl.pallas_call(
        _gather_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
            pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda fi, i: (i, fi)),
        out_shape=jax.ShapeDtypeStruct((q, f), jnp.int32),
        interpret=interpret,
    )(q4, gtiles)


def gather_mask_pallas(q4: jax.Array, gtiles: jax.Array,
                       bq: int = DEFAULT_BQ,
                       interpret: bool = False) -> jax.Array:
    """Routed probe, mask form: (4, Q) x (Q, F, 4, cap) -> (Q, F, cap)
    bool hit table (hit-id extraction over candidate tiles only)."""
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    return pl.pallas_call(
        _gather_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
            pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, f, cap), jnp.bool_),
        interpret=interpret,
    )(q4, gtiles)


# --------------------------------------------------------------------------
# chunk-skipping (local-index) variants
# --------------------------------------------------------------------------

def _chunk_live_dense(q_ref, cb_ref, c: int):
    """(BQ,) bool: which queries of the block hit chunk ``c``'s box.
    cb_ref: (1, C, 4) this tile's chunk boxes."""
    x0, y0 = cb_ref[0, c, 0], cb_ref[0, c, 1]
    x1, y1 = cb_ref[0, c, 2], cb_ref[0, c, 3]
    return ((q_ref[0, :] <= x1) & (x0 <= q_ref[2, :])
            & (q_ref[1, :] <= y1) & (y0 <= q_ref[3, :]))


def _block_hits_chunk(q_ref, t_ref, c: int):
    """(BQ, CHUNK) member compare restricted to chunk ``c``."""
    sl = slice(c * CHUNK, (c + 1) * CHUNK)
    qx0 = q_ref[0, :][:, None]
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = t_ref[0, 0, sl][None, :]
    sy0 = t_ref[0, 1, sl][None, :]
    sx1 = t_ref[0, 2, sl][None, :]
    sy1 = t_ref[0, 3, sl][None, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _count_skip_kernel(q_ref, t_ref, cb_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = t_ref.shape[2] // CHUNK
    out_ref[0, :] = jnp.zeros((bq,), jnp.int32)
    for c in range(n_chunks):
        live = _chunk_live_dense(q_ref, cb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            hits = _block_hits_chunk(q_ref, t_ref, c) & live[:, None]
            out_ref[0, :] += jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_skip_kernel(q_ref, t_ref, cb_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = t_ref.shape[2] // CHUNK
    out_ref[0, ...] = jnp.zeros((bq, t_ref.shape[2]), jnp.bool_)
    for c in range(n_chunks):
        live = _chunk_live_dense(q_ref, cb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            out_ref[0, :, c * CHUNK:(c + 1) * CHUNK] = (
                _block_hits_chunk(q_ref, t_ref, c) & live[:, None])


def count_skip_pallas(q4: jax.Array, tiles: jax.Array, cboxes: jax.Array,
                      bq: int = DEFAULT_BQ,
                      interpret: bool = False) -> jax.Array:
    """Dense probe with chunk skipping.

    q4: (4, Q), tiles: (T, 4, cap), cboxes: (T, C, 4) per-chunk MBRs
    (C == cap // CHUNK); Q % bq == 0, cap % CHUNK == 0 -> (T, Q) int32.
    """
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    c = cboxes.shape[1]
    return pl.pallas_call(
        _count_skip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
            pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
            pl.BlockSpec((1, c, 4), lambda ti, i: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda ti, i: (ti, i)),
        out_shape=jax.ShapeDtypeStruct((t, q), jnp.int32),
        interpret=interpret,
    )(q4, tiles, cboxes)


def mask_skip_pallas(q4: jax.Array, tiles: jax.Array, cboxes: jax.Array,
                     bq: int = DEFAULT_BQ,
                     interpret: bool = False) -> jax.Array:
    """Dense mask with chunk skipping: -> (T, Q, cap) bool (skipped
    chunks read False)."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    c = cboxes.shape[1]
    return pl.pallas_call(
        _mask_skip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
            pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
            pl.BlockSpec((1, c, 4), lambda ti, i: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, cap), lambda ti, i: (ti, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, q, cap), jnp.bool_),
        interpret=interpret,
    )(q4, tiles, cboxes)


def _chunk_live_gather(q_ref, gcb_ref, c: int):
    """(BQ,) bool: row j's query vs row j's OWN candidate's chunk-c box.
    gcb_ref: (BQ, 1, C, 4) gathered chunk boxes."""
    x0, y0 = gcb_ref[:, 0, c, 0], gcb_ref[:, 0, c, 1]
    x1, y1 = gcb_ref[:, 0, c, 2], gcb_ref[:, 0, c, 3]
    return ((q_ref[0, :] <= x1) & (x0 <= q_ref[2, :])
            & (q_ref[1, :] <= y1) & (y0 <= q_ref[3, :]))


def _gather_block_hits_chunk(q_ref, g_ref, c: int):
    """(BQ, CHUNK) per-row member compare restricted to chunk ``c``."""
    sl = slice(c * CHUNK, (c + 1) * CHUNK)
    qx0 = q_ref[0, :][:, None]
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = g_ref[:, 0, 0, sl]
    sy0 = g_ref[:, 0, 1, sl]
    sx1 = g_ref[:, 0, 2, sl]
    sy1 = g_ref[:, 0, 3, sl]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _gather_count_skip_kernel(q_ref, g_ref, gcb_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = g_ref.shape[3] // CHUNK
    out_ref[:, 0] = jnp.zeros((bq,), jnp.int32)
    for c in range(n_chunks):
        live = _chunk_live_gather(q_ref, gcb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            hits = _gather_block_hits_chunk(q_ref, g_ref, c) & live[:, None]
            out_ref[:, 0] += jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_skip_kernel(q_ref, g_ref, gcb_ref, out_ref):
    bq = q_ref.shape[1]
    cap = g_ref.shape[3]
    n_chunks = cap // CHUNK
    out_ref[:, 0, :] = jnp.zeros((bq, cap), jnp.bool_)
    for c in range(n_chunks):
        live = _chunk_live_gather(q_ref, gcb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            out_ref[:, 0, c * CHUNK:(c + 1) * CHUNK] = (
                _gather_block_hits_chunk(q_ref, g_ref, c) & live[:, None])


def gather_count_skip_pallas(q4: jax.Array, gtiles: jax.Array,
                             gcboxes: jax.Array, bq: int = DEFAULT_BQ,
                             interpret: bool = False) -> jax.Array:
    """Routed probe with chunk skipping, count form.

    q4: (4, Q); gtiles: (Q, F, 4, cap); gcboxes: (Q, F, C, 4) each
    query's gathered candidate chunk boxes (C == cap // CHUNK)
    -> (Q, F) int32.
    """
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    c = gcboxes.shape[2]
    return pl.pallas_call(
        _gather_count_skip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
            pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
            pl.BlockSpec((bq, 1, c, 4), lambda fi, i: (i, fi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda fi, i: (i, fi)),
        out_shape=jax.ShapeDtypeStruct((q, f), jnp.int32),
        interpret=interpret,
    )(q4, gtiles, gcboxes)


def gather_mask_skip_pallas(q4: jax.Array, gtiles: jax.Array,
                            gcboxes: jax.Array, bq: int = DEFAULT_BQ,
                            interpret: bool = False) -> jax.Array:
    """Routed mask with chunk skipping: -> (Q, F, cap) bool (skipped
    chunks read False)."""
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    c = gcboxes.shape[2]
    return pl.pallas_call(
        _gather_mask_skip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
            pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
            pl.BlockSpec((bq, 1, c, 4), lambda fi, i: (i, fi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, f, cap), jnp.bool_),
        interpret=interpret,
    )(q4, gtiles, gcboxes)
