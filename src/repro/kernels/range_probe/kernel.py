"""Blocked range-probe kernel (TPU Pallas): query boxes vs tiled layout.

The serving hot spot: a (Q, 4) batch of range-query boxes is tested
against a (T, cap, 4) partitioned layout (T tiles of cap member slots,
the staging format of ``serve.engine``).  Like ``mbr_join`` this is a
VPU problem — a (BQ, cap) block of boolean closed-box compares from
rank-1 broadcasts; the member axis is the 128-lane axis.

Layout: queries arrive component-major (4, Q); tiles arrive per-tile
component-major (T, 4, cap) so grid cell (t, i) streams one tile's
coordinate block and one query block through VMEM.

Four entry points:
- ``count``: grid cell (t, i) reduces its (BQ, cap) hit block over the
  member axis — per-(tile, query) hit counts, O(T×Q) output.  This is
  the dense throughput path (count/selectivity queries, kNN deepening).
- ``mask``: writes the full (BQ, cap) boolean block — used for hit-id
  extraction on moderate tile counts.
- ``gather_count`` / ``gather_mask``: the **routed** variants.  The
  caller has already gathered each query's candidate tiles (router
  output) into a per-query ``(Q, F, 4, cap)`` stack, so grid cell
  (f, i) streams a (BQ, 1, 4, cap) slab where query row j carries *its
  own* f-th candidate tile.  Work drops from O(Q·T·cap) to
  O(Q·F·cap) — the partition-pruning win the paper's fan-out metric
  predicts, realised as compute instead of a report.
- ``*_skip``: the **local-index** variants (LocationSpark's second,
  intra-partition index layer).  Staging sorts each tile's members
  along x and summarises every ``CHUNK``-lane (128-member) slot group
  with one MBR ("chunk box"); the kernels test the query block against
  a tile's C chunk boxes first and only run the full (BQ, CHUNK)
  member compare for chunks some query in the block can hit
  (``pl.when``) — dead chunks cost C scalar compares instead of
  CHUNK·4 member compares.  Per-query predication (``hits & live``)
  keeps the output bit-identical to the unindexed kernels whenever the
  chunk boxes bound their members, and identical to the ``ref``
  chunk-masked oracles unconditionally.

Padding contract (same as mbr_join): callers pad query slots, member
slots, and absent candidate tiles with *inverted* sentinel boxes
(xmin > xmax), which intersect nothing, so no validity mask is
streamed through VMEM.  All-sentinel chunks get inverted chunk boxes
and are always skipped.

Every entry point takes an optional **alive mask** (keyword-only
``alive``; dense: (T, cap) bool, gathered: (Q, F, cap) bool) — the
tombstone-delete layer of the ingest engine (``serve.layout``).  A hit
counts only if its member slot is alive; the ``*_skip`` variants
additionally ``pl.when`` a whole chunk away when none of its slots is
alive, so a tombstone-riddled chunk costs one scalar reduce even when
its (stale, superset) chunk box still overlaps the query.
``alive=None`` compiles the original mask-free kernels — the all-live
fast path, bit-identical to an all-``True`` mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
CHUNK = 128  # members summarised per chunk box (the VPU lane width)


def _block_hits(q_ref, t_ref):
    qx0 = q_ref[0, :][:, None]   # (BQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = t_ref[0, 0, :][None, :]   # (1, cap)
    sy0 = t_ref[0, 1, :][None, :]
    sx1 = t_ref[0, 2, :][None, :]
    sy1 = t_ref[0, 3, :][None, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _count_kernel(q_ref, t_ref, out_ref):
    hits = _block_hits(q_ref, t_ref)
    out_ref[0, :] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_kernel(q_ref, t_ref, out_ref):
    out_ref[0, ...] = _block_hits(q_ref, t_ref)


def _count_alive_kernel(q_ref, t_ref, a_ref, out_ref):
    hits = _block_hits(q_ref, t_ref) & a_ref[0, :][None, :]
    out_ref[0, :] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_alive_kernel(q_ref, t_ref, a_ref, out_ref):
    out_ref[0, ...] = _block_hits(q_ref, t_ref) & a_ref[0, :][None, :]


def _dense_specs(bq: int, cap: int, alive) -> list:
    """Input specs shared by the dense kernels: query block, one tile's
    component block, and (when masking) that tile's alive row."""
    specs = [
        pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
        pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
    ]
    if alive is not None:
        specs.append(pl.BlockSpec((1, cap), lambda ti, i: (ti, 0)))
    return specs


def count_pallas(q4: jax.Array, tiles: jax.Array, bq: int = DEFAULT_BQ,
                 interpret: bool = False, *,
                 alive: jax.Array | None = None) -> jax.Array:
    """q4: (4, Q), tiles: (T, 4, cap); Q % bq == 0, cap % 128 == 0
    -> (T, Q) int32 per-(tile, query) hit counts.  ``alive``: (T, cap)
    bool — dead member slots never count."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    args = (q4, tiles) if alive is None else (q4, tiles, alive)
    return pl.pallas_call(
        _count_kernel if alive is None else _count_alive_kernel,
        grid=grid,
        in_specs=_dense_specs(bq, cap, alive),
        out_specs=pl.BlockSpec((1, bq), lambda ti, i: (ti, i)),
        out_shape=jax.ShapeDtypeStruct((t, q), jnp.int32),
        interpret=interpret,
    )(*args)


def mask_pallas(q4: jax.Array, tiles: jax.Array, bq: int = DEFAULT_BQ,
                interpret: bool = False, *,
                alive: jax.Array | None = None) -> jax.Array:
    """q4: (4, Q), tiles: (T, 4, cap) -> (T, Q, cap) bool hit table
    (dead slots read False under ``alive``)."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    args = (q4, tiles) if alive is None else (q4, tiles, alive)
    return pl.pallas_call(
        _mask_kernel if alive is None else _mask_alive_kernel,
        grid=grid,
        in_specs=_dense_specs(bq, cap, alive),
        out_specs=pl.BlockSpec((1, bq, cap), lambda ti, i: (ti, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, q, cap), jnp.bool_),
        interpret=interpret,
    )(*args)


def _gather_block_hits(q_ref, g_ref):
    # query row j of the block is compared against its OWN gathered tile:
    # g_ref block is (BQ, 1, 4, cap), so every coordinate slab below is
    # (BQ, cap) with per-row tile data — still rank-1-broadcast VPU work.
    qx0 = q_ref[0, :][:, None]   # (BQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = g_ref[:, 0, 0, :]      # (BQ, cap)
    sy0 = g_ref[:, 0, 1, :]
    sx1 = g_ref[:, 0, 2, :]
    sy1 = g_ref[:, 0, 3, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _gather_count_kernel(q_ref, g_ref, out_ref):
    hits = _gather_block_hits(q_ref, g_ref)
    out_ref[:, 0] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_kernel(q_ref, g_ref, out_ref):
    out_ref[:, 0, :] = _gather_block_hits(q_ref, g_ref)


def _gather_count_alive_kernel(q_ref, g_ref, ga_ref, out_ref):
    hits = _gather_block_hits(q_ref, g_ref) & ga_ref[:, 0, :]
    out_ref[:, 0] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_alive_kernel(q_ref, g_ref, ga_ref, out_ref):
    out_ref[:, 0, :] = _gather_block_hits(q_ref, g_ref) & ga_ref[:, 0, :]


def _gather_specs(bq: int, cap: int, alive) -> list:
    """Input specs shared by the gathered kernels: query block, per-row
    candidate-f slab, and (when masking) the matching alive slab."""
    specs = [
        pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
        pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
    ]
    if alive is not None:
        specs.append(pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)))
    return specs


def gather_count_pallas(q4: jax.Array, gtiles: jax.Array,
                        bq: int = DEFAULT_BQ,
                        interpret: bool = False, *,
                        alive: jax.Array | None = None) -> jax.Array:
    """Routed probe, count form.

    q4: (4, Q) component-major queries; gtiles: (Q, F, 4, cap) each
    query's gathered candidate tiles (absent candidates = sentinel
    tiles).  Q % bq == 0, cap % 128 == 0 -> (Q, F) int32 per-(query,
    candidate) hit counts.  ``alive``: (Q, F, cap) gathered alive mask.
    """
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    args = (q4, gtiles) if alive is None else (q4, gtiles, alive)
    return pl.pallas_call(
        _gather_count_kernel if alive is None else _gather_count_alive_kernel,
        grid=grid,
        in_specs=_gather_specs(bq, cap, alive),
        out_specs=pl.BlockSpec((bq, 1), lambda fi, i: (i, fi)),
        out_shape=jax.ShapeDtypeStruct((q, f), jnp.int32),
        interpret=interpret,
    )(*args)


def gather_mask_pallas(q4: jax.Array, gtiles: jax.Array,
                       bq: int = DEFAULT_BQ,
                       interpret: bool = False, *,
                       alive: jax.Array | None = None) -> jax.Array:
    """Routed probe, mask form: (4, Q) x (Q, F, 4, cap) -> (Q, F, cap)
    bool hit table (hit-id extraction over candidate tiles only)."""
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    args = (q4, gtiles) if alive is None else (q4, gtiles, alive)
    return pl.pallas_call(
        _gather_mask_kernel if alive is None else _gather_mask_alive_kernel,
        grid=grid,
        in_specs=_gather_specs(bq, cap, alive),
        out_specs=pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, f, cap), jnp.bool_),
        interpret=interpret,
    )(*args)


# --------------------------------------------------------------------------
# chunk-skipping (local-index) variants
# --------------------------------------------------------------------------

def _chunk_live_dense(q_ref, cb_ref, c: int):
    """(BQ,) bool: which queries of the block hit chunk ``c``'s box.
    cb_ref: (1, C, 4) this tile's chunk boxes."""
    x0, y0 = cb_ref[0, c, 0], cb_ref[0, c, 1]
    x1, y1 = cb_ref[0, c, 2], cb_ref[0, c, 3]
    return ((q_ref[0, :] <= x1) & (x0 <= q_ref[2, :])
            & (q_ref[1, :] <= y1) & (y0 <= q_ref[3, :]))


def _block_hits_chunk(q_ref, t_ref, c: int):
    """(BQ, CHUNK) member compare restricted to chunk ``c``."""
    sl = slice(c * CHUNK, (c + 1) * CHUNK)
    qx0 = q_ref[0, :][:, None]
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = t_ref[0, 0, sl][None, :]
    sy0 = t_ref[0, 1, sl][None, :]
    sx1 = t_ref[0, 2, sl][None, :]
    sy1 = t_ref[0, 3, sl][None, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _count_skip_kernel(q_ref, t_ref, cb_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = t_ref.shape[2] // CHUNK
    out_ref[0, :] = jnp.zeros((bq,), jnp.int32)
    for c in range(n_chunks):
        live = _chunk_live_dense(q_ref, cb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            hits = _block_hits_chunk(q_ref, t_ref, c) & live[:, None]
            out_ref[0, :] += jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_skip_kernel(q_ref, t_ref, cb_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = t_ref.shape[2] // CHUNK
    out_ref[0, ...] = jnp.zeros((bq, t_ref.shape[2]), jnp.bool_)
    for c in range(n_chunks):
        live = _chunk_live_dense(q_ref, cb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            out_ref[0, :, c * CHUNK:(c + 1) * CHUNK] = (
                _block_hits_chunk(q_ref, t_ref, c) & live[:, None])


def _count_skip_alive_kernel(q_ref, t_ref, cb_ref, a_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = t_ref.shape[2] // CHUNK
    out_ref[0, :] = jnp.zeros((bq,), jnp.int32)
    for c in range(n_chunks):
        live = _chunk_live_dense(q_ref, cb_ref, c)
        alive_c = a_ref[0, c * CHUNK:(c + 1) * CHUNK]

        @pl.when(jnp.any(live) & jnp.any(alive_c))
        def _(c=c, live=live, alive_c=alive_c):
            hits = (_block_hits_chunk(q_ref, t_ref, c)
                    & live[:, None] & alive_c[None, :])
            out_ref[0, :] += jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_skip_alive_kernel(q_ref, t_ref, cb_ref, a_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = t_ref.shape[2] // CHUNK
    out_ref[0, ...] = jnp.zeros((bq, t_ref.shape[2]), jnp.bool_)
    for c in range(n_chunks):
        live = _chunk_live_dense(q_ref, cb_ref, c)
        alive_c = a_ref[0, c * CHUNK:(c + 1) * CHUNK]

        @pl.when(jnp.any(live) & jnp.any(alive_c))
        def _(c=c, live=live, alive_c=alive_c):
            out_ref[0, :, c * CHUNK:(c + 1) * CHUNK] = (
                _block_hits_chunk(q_ref, t_ref, c)
                & live[:, None] & alive_c[None, :])


def _dense_skip_specs(bq: int, cap: int, c: int, alive) -> list:
    specs = [
        pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
        pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
        pl.BlockSpec((1, c, 4), lambda ti, i: (ti, 0, 0)),
    ]
    if alive is not None:
        specs.append(pl.BlockSpec((1, cap), lambda ti, i: (ti, 0)))
    return specs


def count_skip_pallas(q4: jax.Array, tiles: jax.Array, cboxes: jax.Array,
                      bq: int = DEFAULT_BQ,
                      interpret: bool = False, *,
                      alive: jax.Array | None = None) -> jax.Array:
    """Dense probe with chunk skipping.

    q4: (4, Q), tiles: (T, 4, cap), cboxes: (T, C, 4) per-chunk MBRs
    (C == cap // CHUNK); Q % bq == 0, cap % CHUNK == 0 -> (T, Q) int32.
    ``alive``: (T, cap) bool — all-dead chunks are skipped entirely.
    """
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    c = cboxes.shape[1]
    args = (q4, tiles, cboxes) if alive is None else (q4, tiles, cboxes, alive)
    return pl.pallas_call(
        _count_skip_kernel if alive is None else _count_skip_alive_kernel,
        grid=grid,
        in_specs=_dense_skip_specs(bq, cap, c, alive),
        out_specs=pl.BlockSpec((1, bq), lambda ti, i: (ti, i)),
        out_shape=jax.ShapeDtypeStruct((t, q), jnp.int32),
        interpret=interpret,
    )(*args)


def mask_skip_pallas(q4: jax.Array, tiles: jax.Array, cboxes: jax.Array,
                     bq: int = DEFAULT_BQ,
                     interpret: bool = False, *,
                     alive: jax.Array | None = None) -> jax.Array:
    """Dense mask with chunk skipping: -> (T, Q, cap) bool (skipped
    chunks and dead slots read False)."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    c = cboxes.shape[1]
    args = (q4, tiles, cboxes) if alive is None else (q4, tiles, cboxes, alive)
    return pl.pallas_call(
        _mask_skip_kernel if alive is None else _mask_skip_alive_kernel,
        grid=grid,
        in_specs=_dense_skip_specs(bq, cap, c, alive),
        out_specs=pl.BlockSpec((1, bq, cap), lambda ti, i: (ti, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, q, cap), jnp.bool_),
        interpret=interpret,
    )(*args)


def _chunk_live_gather(q_ref, gcb_ref, c: int):
    """(BQ,) bool: row j's query vs row j's OWN candidate's chunk-c box.
    gcb_ref: (BQ, 1, C, 4) gathered chunk boxes."""
    x0, y0 = gcb_ref[:, 0, c, 0], gcb_ref[:, 0, c, 1]
    x1, y1 = gcb_ref[:, 0, c, 2], gcb_ref[:, 0, c, 3]
    return ((q_ref[0, :] <= x1) & (x0 <= q_ref[2, :])
            & (q_ref[1, :] <= y1) & (y0 <= q_ref[3, :]))


def _gather_block_hits_chunk(q_ref, g_ref, c: int):
    """(BQ, CHUNK) per-row member compare restricted to chunk ``c``."""
    sl = slice(c * CHUNK, (c + 1) * CHUNK)
    qx0 = q_ref[0, :][:, None]
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = g_ref[:, 0, 0, sl]
    sy0 = g_ref[:, 0, 1, sl]
    sx1 = g_ref[:, 0, 2, sl]
    sy1 = g_ref[:, 0, 3, sl]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _gather_count_skip_kernel(q_ref, g_ref, gcb_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = g_ref.shape[3] // CHUNK
    out_ref[:, 0] = jnp.zeros((bq,), jnp.int32)
    for c in range(n_chunks):
        live = _chunk_live_gather(q_ref, gcb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            hits = _gather_block_hits_chunk(q_ref, g_ref, c) & live[:, None]
            out_ref[:, 0] += jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_skip_kernel(q_ref, g_ref, gcb_ref, out_ref):
    bq = q_ref.shape[1]
    cap = g_ref.shape[3]
    n_chunks = cap // CHUNK
    out_ref[:, 0, :] = jnp.zeros((bq, cap), jnp.bool_)
    for c in range(n_chunks):
        live = _chunk_live_gather(q_ref, gcb_ref, c)

        @pl.when(jnp.any(live))
        def _(c=c, live=live):
            out_ref[:, 0, c * CHUNK:(c + 1) * CHUNK] = (
                _gather_block_hits_chunk(q_ref, g_ref, c) & live[:, None])


def _gather_count_skip_alive_kernel(q_ref, g_ref, gcb_ref, ga_ref, out_ref):
    bq = q_ref.shape[1]
    n_chunks = g_ref.shape[3] // CHUNK
    out_ref[:, 0] = jnp.zeros((bq,), jnp.int32)
    for c in range(n_chunks):
        live = _chunk_live_gather(q_ref, gcb_ref, c)
        alive_c = ga_ref[:, 0, c * CHUNK:(c + 1) * CHUNK]

        @pl.when(jnp.any(live) & jnp.any(alive_c))
        def _(c=c, live=live, alive_c=alive_c):
            hits = (_gather_block_hits_chunk(q_ref, g_ref, c)
                    & live[:, None] & alive_c)
            out_ref[:, 0] += jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_skip_alive_kernel(q_ref, g_ref, gcb_ref, ga_ref, out_ref):
    bq = q_ref.shape[1]
    cap = g_ref.shape[3]
    n_chunks = cap // CHUNK
    out_ref[:, 0, :] = jnp.zeros((bq, cap), jnp.bool_)
    for c in range(n_chunks):
        live = _chunk_live_gather(q_ref, gcb_ref, c)
        alive_c = ga_ref[:, 0, c * CHUNK:(c + 1) * CHUNK]

        @pl.when(jnp.any(live) & jnp.any(alive_c))
        def _(c=c, live=live, alive_c=alive_c):
            out_ref[:, 0, c * CHUNK:(c + 1) * CHUNK] = (
                _gather_block_hits_chunk(q_ref, g_ref, c)
                & live[:, None] & alive_c)


def _gather_skip_specs(bq: int, cap: int, c: int, alive) -> list:
    specs = [
        pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
        pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
        pl.BlockSpec((bq, 1, c, 4), lambda fi, i: (i, fi, 0, 0)),
    ]
    if alive is not None:
        specs.append(pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)))
    return specs


def gather_count_skip_pallas(q4: jax.Array, gtiles: jax.Array,
                             gcboxes: jax.Array, bq: int = DEFAULT_BQ,
                             interpret: bool = False, *,
                             alive: jax.Array | None = None) -> jax.Array:
    """Routed probe with chunk skipping, count form.

    q4: (4, Q); gtiles: (Q, F, 4, cap); gcboxes: (Q, F, C, 4) each
    query's gathered candidate chunk boxes (C == cap // CHUNK)
    -> (Q, F) int32.  ``alive``: (Q, F, cap) gathered alive mask —
    all-dead chunk blocks are skipped entirely.
    """
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    c = gcboxes.shape[2]
    args = ((q4, gtiles, gcboxes) if alive is None
            else (q4, gtiles, gcboxes, alive))
    return pl.pallas_call(
        (_gather_count_skip_kernel if alive is None
         else _gather_count_skip_alive_kernel),
        grid=grid,
        in_specs=_gather_skip_specs(bq, cap, c, alive),
        out_specs=pl.BlockSpec((bq, 1), lambda fi, i: (i, fi)),
        out_shape=jax.ShapeDtypeStruct((q, f), jnp.int32),
        interpret=interpret,
    )(*args)


def gather_mask_skip_pallas(q4: jax.Array, gtiles: jax.Array,
                            gcboxes: jax.Array, bq: int = DEFAULT_BQ,
                            interpret: bool = False, *,
                            alive: jax.Array | None = None) -> jax.Array:
    """Routed mask with chunk skipping: -> (Q, F, cap) bool (skipped
    chunks and dead slots read False)."""
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    c = gcboxes.shape[2]
    args = ((q4, gtiles, gcboxes) if alive is None
            else (q4, gtiles, gcboxes, alive))
    return pl.pallas_call(
        (_gather_mask_skip_kernel if alive is None
         else _gather_mask_skip_alive_kernel),
        grid=grid,
        in_specs=_gather_skip_specs(bq, cap, c, alive),
        out_specs=pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, f, cap), jnp.bool_),
        interpret=interpret,
    )(*args)
