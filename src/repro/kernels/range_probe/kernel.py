"""Blocked range-probe kernel (TPU Pallas): query boxes vs tiled layout.

The serving hot spot: a (Q, 4) batch of range-query boxes is tested
against a (T, cap, 4) partitioned layout (T tiles of cap member slots,
the staging format of ``serve.engine``).  Like ``mbr_join`` this is a
VPU problem — a (BQ, cap) block of boolean closed-box compares from
rank-1 broadcasts; the member axis is the 128-lane axis.

Layout: queries arrive component-major (4, Q); tiles arrive per-tile
component-major (T, 4, cap) so grid cell (t, i) streams one tile's
coordinate block and one query block through VMEM.

Four entry points:
- ``count``: grid cell (t, i) reduces its (BQ, cap) hit block over the
  member axis — per-(tile, query) hit counts, O(T×Q) output.  This is
  the dense throughput path (count/selectivity queries, kNN deepening).
- ``mask``: writes the full (BQ, cap) boolean block — used for hit-id
  extraction on moderate tile counts.
- ``gather_count`` / ``gather_mask``: the **routed** variants.  The
  caller has already gathered each query's candidate tiles (router
  output) into a per-query ``(Q, F, 4, cap)`` stack, so grid cell
  (f, i) streams a (BQ, 1, 4, cap) slab where query row j carries *its
  own* f-th candidate tile.  Work drops from O(Q·T·cap) to
  O(Q·F·cap) — the partition-pruning win the paper's fan-out metric
  predicts, realised as compute instead of a report.

Padding contract (same as mbr_join): callers pad query slots, member
slots, and absent candidate tiles with *inverted* sentinel boxes
(xmin > xmax), which intersect nothing, so no validity mask is
streamed through VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128


def _block_hits(q_ref, t_ref):
    qx0 = q_ref[0, :][:, None]   # (BQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = t_ref[0, 0, :][None, :]   # (1, cap)
    sy0 = t_ref[0, 1, :][None, :]
    sx1 = t_ref[0, 2, :][None, :]
    sy1 = t_ref[0, 3, :][None, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _count_kernel(q_ref, t_ref, out_ref):
    hits = _block_hits(q_ref, t_ref)
    out_ref[0, :] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _mask_kernel(q_ref, t_ref, out_ref):
    out_ref[0, ...] = _block_hits(q_ref, t_ref)


def count_pallas(q4: jax.Array, tiles: jax.Array, bq: int = DEFAULT_BQ,
                 interpret: bool = False) -> jax.Array:
    """q4: (4, Q), tiles: (T, 4, cap); Q % bq == 0, cap % 128 == 0
    -> (T, Q) int32 per-(tile, query) hit counts."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
            pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq), lambda ti, i: (ti, i)),
        out_shape=jax.ShapeDtypeStruct((t, q), jnp.int32),
        interpret=interpret,
    )(q4, tiles)


def mask_pallas(q4: jax.Array, tiles: jax.Array, bq: int = DEFAULT_BQ,
                interpret: bool = False) -> jax.Array:
    """q4: (4, Q), tiles: (T, 4, cap) -> (T, Q, cap) bool hit table."""
    q = q4.shape[1]
    t, _, cap = tiles.shape
    grid = (t, q // bq)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda ti, i: (0, i)),
            pl.BlockSpec((1, 4, cap), lambda ti, i: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, cap), lambda ti, i: (ti, i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, q, cap), jnp.bool_),
        interpret=interpret,
    )(q4, tiles)


def _gather_block_hits(q_ref, g_ref):
    # query row j of the block is compared against its OWN gathered tile:
    # g_ref block is (BQ, 1, 4, cap), so every coordinate slab below is
    # (BQ, cap) with per-row tile data — still rank-1-broadcast VPU work.
    qx0 = q_ref[0, :][:, None]   # (BQ, 1)
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    sx0 = g_ref[:, 0, 0, :]      # (BQ, cap)
    sy0 = g_ref[:, 0, 1, :]
    sx1 = g_ref[:, 0, 2, :]
    sy1 = g_ref[:, 0, 3, :]
    return (qx0 <= sx1) & (sx0 <= qx1) & (qy0 <= sy1) & (sy0 <= qy1)


def _gather_count_kernel(q_ref, g_ref, out_ref):
    hits = _gather_block_hits(q_ref, g_ref)
    out_ref[:, 0] = jnp.sum(hits.astype(jnp.int32), axis=1)


def _gather_mask_kernel(q_ref, g_ref, out_ref):
    out_ref[:, 0, :] = _gather_block_hits(q_ref, g_ref)


def gather_count_pallas(q4: jax.Array, gtiles: jax.Array,
                        bq: int = DEFAULT_BQ,
                        interpret: bool = False) -> jax.Array:
    """Routed probe, count form.

    q4: (4, Q) component-major queries; gtiles: (Q, F, 4, cap) each
    query's gathered candidate tiles (absent candidates = sentinel
    tiles).  Q % bq == 0, cap % 128 == 0 -> (Q, F) int32 per-(query,
    candidate) hit counts.
    """
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    return pl.pallas_call(
        _gather_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
            pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1), lambda fi, i: (i, fi)),
        out_shape=jax.ShapeDtypeStruct((q, f), jnp.int32),
        interpret=interpret,
    )(q4, gtiles)


def gather_mask_pallas(q4: jax.Array, gtiles: jax.Array,
                       bq: int = DEFAULT_BQ,
                       interpret: bool = False) -> jax.Array:
    """Routed probe, mask form: (4, Q) x (Q, F, 4, cap) -> (Q, F, cap)
    bool hit table (hit-id extraction over candidate tiles only)."""
    q = q4.shape[1]
    _, f, _, cap = gtiles.shape
    grid = (f, q // bq)
    return pl.pallas_call(
        _gather_mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, bq), lambda fi, i: (0, i)),
            pl.BlockSpec((bq, 1, 4, cap), lambda fi, i: (i, fi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, cap), lambda fi, i: (i, fi, 0)),
        out_shape=jax.ShapeDtypeStruct((q, f, cap), jnp.bool_),
        interpret=interpret,
    )(q4, gtiles)
