"""Public jit'd wrappers for the range_probe kernel.

Handles padding to block multiples (with never-intersecting sentinel
boxes), the component-major layouts the kernel wants, and CPU fallback
to interpret mode.  The natural caller is ``repro.serve.engine``, whose
staged layouts are already sentinel-padded and 128-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.geometry import SENTINEL_BOX
from . import kernel

_SENTINEL = jnp.array(SENTINEL_BOX, jnp.float32)
_LANE = 128


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_queries_cm(qboxes: jax.Array, bq: int) -> jax.Array:
    """(Q, 4) -> component-major (4, Q_pad) with sentinel padding."""
    q = qboxes.shape[0]
    pad = (-q) % bq
    if pad:
        qboxes = jnp.concatenate(
            [qboxes, jnp.broadcast_to(_SENTINEL, (pad, 4))], axis=0)
    return qboxes.T


def _pad_tiles_cm(tiles: jax.Array) -> jax.Array:
    """(T, cap, 4) -> per-tile component-major (T, 4, cap_pad)."""
    cap = tiles.shape[1]
    pad = (-cap) % _LANE
    if pad:
        tiles = jnp.concatenate(
            [tiles, jnp.broadcast_to(_SENTINEL, (tiles.shape[0], pad, 4))],
            axis=1)
    return jnp.swapaxes(tiles, 1, 2)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def probe_counts(qboxes: jax.Array, tiles: jax.Array,
                 bq: int = kernel.DEFAULT_BQ,
                 interpret: bool | None = None) -> jax.Array:
    """Per-(query, tile) hit counts.

    qboxes: (Q, 4), tiles: (T, cap, 4) sentinel-padded member boxes
    -> (Q, T) int32.
    """
    if interpret is None:
        interpret = _interpret_default()
    q = qboxes.shape[0]
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    t3 = _pad_tiles_cm(tiles.astype(jnp.float32))
    counts = kernel.count_pallas(q4, t3, bq, interpret=interpret)
    return counts.T[:q]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def probe_mask(qboxes: jax.Array, tiles: jax.Array,
               bq: int = kernel.DEFAULT_BQ,
               interpret: bool | None = None) -> jax.Array:
    """Full hit table for id extraction.

    qboxes: (Q, 4), tiles: (T, cap, 4) -> (Q, T, cap) bool (un-padded
    view).  O(Q·T·cap) output — the count path is the throughput path.
    """
    if interpret is None:
        interpret = _interpret_default()
    q, cap = qboxes.shape[0], tiles.shape[1]
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    t3 = _pad_tiles_cm(tiles.astype(jnp.float32))
    full = kernel.mask_pallas(q4, t3, bq, interpret=interpret)
    return jnp.swapaxes(full, 0, 1)[:q, :, :cap]
