"""Public jit'd wrappers for the range_probe kernels.

Handles padding to block multiples (with never-intersecting sentinel
boxes), the component-major layouts the kernels want, and CPU fallback
to interpret mode.  The natural caller is ``repro.serve.engine``, whose
staged layouts are already sentinel-padded and 128-aligned.

Candidate-list contract (``gathered_*``): ``cand`` is (Q, F) int32 tile
indices from ``repro.serve.router`` — entries in [0, T) are real tiles,
``-1`` marks padding slots and is remapped to an all-sentinel tile, so
padded candidates contribute exactly zero hits and no validity mask is
needed downstream.

Local-index contract (``*_skip``): ``cboxes`` is the staging's
``(T, C, 4)`` chunk-box summary (``C == ceil(cap / CHUNK)``, chunk c
bounding member slots ``[c*CHUNK, (c+1)*CHUNK)``; all-sentinel chunks
carry inverted boxes).  Answers equal the unindexed variants whenever
the chunk boxes bound their members; on TPU dead chunks are skipped,
off-TPU the fused jnp path masks per-chunk partials (same O(1/CHUNK)
bookkeeping cost, same bits).

Tombstone contract (keyword-only ``alive``): an optional (T, cap) bool
per-slot alive mask — a hit counts only if its member slot is alive.
Wrappers pad it with False (dead) and gather it alongside the member
boxes, so padded slots and padded candidates stay inert.  ``alive=None``
is the all-live fast path, bit-identical to an all-``True`` mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.geometry import SENTINEL_BOX
from . import kernel
from .kernel import CHUNK  # noqa: F401  (re-export: staging chunks on this)

_SENTINEL = jnp.array(SENTINEL_BOX, jnp.float32)
_LANE = 128


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_queries_cm(qboxes: jax.Array, bq: int) -> jax.Array:
    """(Q, 4) -> component-major (4, Q_pad) with sentinel padding."""
    q = qboxes.shape[0]
    pad = (-q) % bq
    if pad:
        qboxes = jnp.concatenate(
            [qboxes, jnp.broadcast_to(_SENTINEL, (pad, 4))], axis=0)
    return qboxes.T


def _pad_tiles_cm(tiles: jax.Array) -> jax.Array:
    """(T, cap, 4) -> per-tile component-major (T, 4, cap_pad)."""
    cap = tiles.shape[1]
    pad = (-cap) % _LANE
    if pad:
        tiles = jnp.concatenate(
            [tiles, jnp.broadcast_to(_SENTINEL, (tiles.shape[0], pad, 4))],
            axis=1)
    return jnp.swapaxes(tiles, 1, 2)


def _pad_alive(alive: jax.Array) -> jax.Array:
    """(T, cap) bool -> (T, cap_pad) with False (dead) padding."""
    cap = alive.shape[1]
    pad = (-cap) % _LANE
    if pad:
        alive = jnp.pad(alive, ((0, 0), (0, pad)))
    return alive


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def probe_counts(qboxes: jax.Array, tiles: jax.Array,
                 bq: int = kernel.DEFAULT_BQ,
                 interpret: bool | None = None, *,
                 alive: jax.Array | None = None) -> jax.Array:
    """Per-(query, tile) hit counts.

    qboxes: (Q, 4), tiles: (T, cap, 4) sentinel-padded member boxes
    -> (Q, T) int32.  ``alive``: (T, cap) bool — dead slots never count.
    """
    if interpret is None:
        interpret = _interpret_default()
    q = qboxes.shape[0]
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    t3 = _pad_tiles_cm(tiles.astype(jnp.float32))
    a = None if alive is None else _pad_alive(alive)
    counts = kernel.count_pallas(q4, t3, bq, interpret=interpret, alive=a)
    return counts.T[:q]


def _append_pad_row(table: jax.Array, pad_value) -> tuple[jax.Array, int]:
    """Append one row of ``pad_value`` to ``table``'s leading axis; the
    single definition of the '-1 candidate -> pad row' remap target.
    -> ``(table_p[T+1, ...], t)`` where remapping is
    ``where(cand >= 0, cand, t)``."""
    t = table.shape[0]
    row = jnp.broadcast_to(jnp.asarray(pad_value, table.dtype),
                           (1,) + table.shape[1:])
    return jnp.concatenate([table, row], axis=0), t


# reprolint: disable=kernel-twin-parity -- pure data mover: gathers raw
# member boxes for downstream twins; tombstones are enforced where the
# hits are computed, via the parallel gathered_alive mask
def gathered_rows(tiles: jax.Array, cand: jax.Array) -> jax.Array:
    """Row-major candidate gather: (T, cap, 4) x (Q, F) -> (Q, F, cap, 4)
    with -1 candidates remapped to an appended all-sentinel tile (the
    shared ``SENTINEL_BOX`` contract).  XLA fuses this into a consuming
    compare, so nothing materialises — the fast non-TPU executor for
    the gathered probe, also reused by ``query.knn`` for candidate
    member boxes."""
    tiles_p, t = _append_pad_row(tiles.astype(jnp.float32), _SENTINEL)
    return tiles_p[jnp.where(cand >= 0, cand, t)]


def gathered_ids(ids: jax.Array, cand: jax.Array) -> jax.Array:
    """Candidate gather of member ids: (T, cap) int32 x (Q, F) ->
    (Q, F, cap) with -1 candidates remapped to an appended all ``-1``
    row — the id-side companion of ``gathered_rows``, so padded
    candidates read as padding slots downstream."""
    ids_p, t = _append_pad_row(ids, -1)
    return ids_p[jnp.where(cand >= 0, cand, t)]


def gathered_alive(alive: jax.Array, cand: jax.Array) -> jax.Array:
    """Candidate gather of the alive mask: (T, cap) bool x (Q, F) ->
    (Q, F, cap) with -1 candidates remapped to an appended all-``False``
    (dead) row — the tombstone companion of ``gathered_rows``, so padded
    candidates never answer."""
    alive_p, t = _append_pad_row(alive, False)
    return alive_p[jnp.where(cand >= 0, cand, t)]


def _gather_cm(qboxes: jax.Array, tiles: jax.Array, cand: jax.Array,
               bq: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Shared gathered-probe staging: pad queries to a block multiple,
    remap -1 candidates to an appended all-sentinel tile, and gather the
    component-major candidate stack.

    -> ``(q4[4, Q_pad], gtiles[Q_pad, F, 4, cap_pad], cidx[Q_pad, F])``
    (``cidx`` is the padded, remapped candidate index — reused to
    gather per-candidate chunk boxes for the ``*_skip`` kernels).
    """
    tiles_p, t = _append_pad_row(tiles.astype(jnp.float32), _SENTINEL)
    t3 = _pad_tiles_cm(tiles_p)                    # (T+1, 4, cap_pad)
    q = qboxes.shape[0]
    pad = (-q) % bq
    cidx = jnp.where(cand >= 0, cand, t)
    if pad:
        cidx = jnp.concatenate(
            [cidx, jnp.full((pad, cand.shape[1]), t, cidx.dtype)], axis=0)
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    return q4, t3[cidx], cidx


def _gather_alive_cm(alive: jax.Array | None,
                     cidx: jax.Array) -> jax.Array | None:
    """Kernel-path companion of ``gathered_alive``: lane-pad with False,
    append the all-dead pad row, gather by the already-remapped ``cidx``
    -> (Q_pad, F, cap_pad) bool (or None passthrough)."""
    if alive is None:
        return None
    alive_p, _ = _append_pad_row(_pad_alive(alive), False)
    return alive_p[cidx]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_counts(qboxes: jax.Array, tiles: jax.Array, cand: jax.Array,
                    bq: int = kernel.DEFAULT_BQ,
                    interpret: bool | None = None, *,
                    alive: jax.Array | None = None) -> jax.Array:
    """Routed probe: per-(query, candidate) hit counts.

    qboxes: (Q, 4); tiles: (T, cap, 4) sentinel-padded member boxes;
    cand: (Q, F) int32 candidate tile indices (-1 = padding)
    -> (Q, F) int32.  O(Q·F·cap) work vs the dense O(Q·T·cap).

    ``interpret=None`` picks the backend's best executor: the Pallas
    kernel on TPU, the fused-jnp gather+compare off-TPU (the gathered
    layout's blocked interpret-mode kernel is slow on CPU, unlike the
    dense one).  Pass ``interpret=True`` to force the interpret-mode
    kernel (validation path); results are identical either way.
    """
    if interpret is None and _interpret_default():
        from . import ref
        return ref.gathered_counts(
            qboxes.astype(jnp.float32), gathered_rows(tiles, cand),
            None if alive is None else gathered_alive(alive, cand))
    if interpret is None:
        interpret = False
    q = qboxes.shape[0]
    q4, gt, cidx = _gather_cm(qboxes, tiles, cand, bq)
    ga = _gather_alive_cm(alive, cidx)
    return kernel.gather_count_pallas(q4, gt, bq, interpret=interpret,
                                      alive=ga)[:q]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_mask(qboxes: jax.Array, tiles: jax.Array, cand: jax.Array,
                  bq: int = kernel.DEFAULT_BQ,
                  interpret: bool | None = None, *,
                  alive: jax.Array | None = None) -> jax.Array:
    """Routed probe, full hit table over candidate tiles only.

    qboxes: (Q, 4); tiles: (T, cap, 4); cand: (Q, F) int32 (-1 padding)
    -> (Q, F, cap) bool (un-padded view); slot (j, f, c) is True iff
    query j intersects member c of its f-th candidate tile.  Executor
    selection as in ``gathered_counts``.
    """
    if interpret is None and _interpret_default():
        from . import ref
        return ref.gathered_mask(
            qboxes.astype(jnp.float32), gathered_rows(tiles, cand),
            None if alive is None else gathered_alive(alive, cand))
    if interpret is None:
        interpret = False
    q, cap = qboxes.shape[0], tiles.shape[1]
    q4, gt, cidx = _gather_cm(qboxes, tiles, cand, bq)
    ga = _gather_alive_cm(alive, cidx)
    full = kernel.gather_mask_pallas(q4, gt, bq, interpret=interpret,
                                     alive=ga)
    return full[:q, :, :cap]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def probe_mask(qboxes: jax.Array, tiles: jax.Array,
               bq: int = kernel.DEFAULT_BQ,
               interpret: bool | None = None, *,
               alive: jax.Array | None = None) -> jax.Array:
    """Full hit table for id extraction.

    qboxes: (Q, 4), tiles: (T, cap, 4) -> (Q, T, cap) bool (un-padded
    view).  O(Q·T·cap) output — the count path is the throughput path.
    """
    if interpret is None:
        interpret = _interpret_default()
    q, cap = qboxes.shape[0], tiles.shape[1]
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    t3 = _pad_tiles_cm(tiles.astype(jnp.float32))
    a = None if alive is None else _pad_alive(alive)
    full = kernel.mask_pallas(q4, t3, bq, interpret=interpret, alive=a)
    return jnp.swapaxes(full, 0, 1)[:q, :, :cap]


# --------------------------------------------------------------------------
# chunk-skipping (local-index) variants
# --------------------------------------------------------------------------

def gathered_chunk_boxes(cboxes: jax.Array, cand: jax.Array) -> jax.Array:
    """Candidate gather of chunk boxes: (T, C, 4) x (Q, F) ->
    (Q, F, C, 4) with -1 candidates remapped to an appended all-sentinel
    chunk row — the chunk-box companion of ``gathered_rows``, so padded
    candidates' chunks never test live."""
    cb_p, t = _append_pad_row(cboxes.astype(jnp.float32), _SENTINEL)
    return cb_p[jnp.where(cand >= 0, cand, t)]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def probe_counts_skip(qboxes: jax.Array, tiles: jax.Array,
                      cboxes: jax.Array, bq: int = kernel.DEFAULT_BQ,
                      interpret: bool | None = None, *,
                      alive: jax.Array | None = None) -> jax.Array:
    """Dense per-(query, tile) hit counts with chunk skipping.

    qboxes: (Q, 4); tiles: (T, cap, 4); cboxes: (T, C, 4) chunk boxes
    (``C == ceil(cap / CHUNK)``) -> (Q, T) int32, equal to
    ``probe_counts`` whenever each chunk box bounds the members of
    *this* ``tiles`` array in its slot range.  NB the staging's
    ``chunk_boxes`` bound **canonical** members only — pair them with
    ``canon_tiles``; probing the full member tiles needs chunk boxes
    built over the full tiles.  Executor selection as in
    ``gathered_counts``: the Pallas skip kernel on TPU (or
    ``interpret=True``), the fused chunk-masked jnp path off-TPU.
    """
    if interpret is None and _interpret_default():
        from . import ref
        return ref.probe_counts_skip(qboxes.astype(jnp.float32),
                                     tiles.astype(jnp.float32),
                                     cboxes.astype(jnp.float32), alive)
    if interpret is None:
        interpret = False
    q = qboxes.shape[0]
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    t3 = _pad_tiles_cm(tiles.astype(jnp.float32))
    a = None if alive is None else _pad_alive(alive)
    counts = kernel.count_skip_pallas(q4, t3, cboxes.astype(jnp.float32),
                                      bq, interpret=interpret, alive=a)
    return counts.T[:q]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def probe_mask_skip(qboxes: jax.Array, tiles: jax.Array,
                    cboxes: jax.Array, bq: int = kernel.DEFAULT_BQ,
                    interpret: bool | None = None, *,
                    alive: jax.Array | None = None) -> jax.Array:
    """Dense hit table with chunk skipping: -> (Q, T, cap) bool
    (un-padded view); same chunk-box contract (boxes must bound the
    probed ``tiles`` — staged boxes pair with ``canon_tiles``) and
    executor selection as ``probe_counts_skip``."""
    if interpret is None and _interpret_default():
        from . import ref
        return jnp.swapaxes(
            ref.probe_mask_skip(qboxes.astype(jnp.float32),
                                tiles.astype(jnp.float32),
                                cboxes.astype(jnp.float32), alive), 0, 1)
    if interpret is None:
        interpret = False
    q, cap = qboxes.shape[0], tiles.shape[1]
    q4 = _pad_queries_cm(qboxes.astype(jnp.float32), bq)
    t3 = _pad_tiles_cm(tiles.astype(jnp.float32))
    a = None if alive is None else _pad_alive(alive)
    full = kernel.mask_skip_pallas(q4, t3, cboxes.astype(jnp.float32),
                                   bq, interpret=interpret, alive=a)
    return jnp.swapaxes(full, 0, 1)[:q, :, :cap]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_counts_skip(qboxes: jax.Array, tiles: jax.Array,
                         cboxes: jax.Array, cand: jax.Array,
                         bq: int = kernel.DEFAULT_BQ,
                         interpret: bool | None = None, *,
                         alive: jax.Array | None = None) -> jax.Array:
    """Routed per-(query, candidate) hit counts with chunk skipping.

    qboxes: (Q, 4); tiles: (T, cap, 4); cboxes: (T, C, 4); cand:
    (Q, F) int32 (-1 padding) -> (Q, F) int32, equal to
    ``gathered_counts`` whenever the chunk boxes bound their members —
    the serving hot path's local-index executor.
    """
    if interpret is None and _interpret_default():
        from . import ref
        return ref.gathered_counts_skip(
            qboxes.astype(jnp.float32), gathered_rows(tiles, cand),
            gathered_chunk_boxes(cboxes, cand),
            None if alive is None else gathered_alive(alive, cand))
    if interpret is None:
        interpret = False
    q = qboxes.shape[0]
    q4, gt, cidx = _gather_cm(qboxes, tiles, cand, bq)
    cb_p, _ = _append_pad_row(cboxes.astype(jnp.float32), _SENTINEL)
    ga = _gather_alive_cm(alive, cidx)
    out = kernel.gather_count_skip_pallas(q4, gt, cb_p[cidx], bq,
                                          interpret=interpret, alive=ga)
    return out[:q]


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def gathered_mask_skip(qboxes: jax.Array, tiles: jax.Array,
                       cboxes: jax.Array, cand: jax.Array,
                       bq: int = kernel.DEFAULT_BQ,
                       interpret: bool | None = None, *,
                       alive: jax.Array | None = None) -> jax.Array:
    """Routed hit table with chunk skipping: -> (Q, F, cap) bool
    (un-padded view); executor selection as in ``gathered_counts_skip``."""
    if interpret is None and _interpret_default():
        from . import ref
        return ref.gathered_mask_skip(
            qboxes.astype(jnp.float32), gathered_rows(tiles, cand),
            gathered_chunk_boxes(cboxes, cand),
            None if alive is None else gathered_alive(alive, cand))
    if interpret is None:
        interpret = False
    q, cap = qboxes.shape[0], tiles.shape[1]
    q4, gt, cidx = _gather_cm(qboxes, tiles, cand, bq)
    cb_p, _ = _append_pad_row(cboxes.astype(jnp.float32), _SENTINEL)
    ga = _gather_alive_cm(alive, cidx)
    full = kernel.gather_mask_skip_pallas(q4, gt, cb_p[cidx], bq,
                                          interpret=interpret, alive=ga)
    return full[:q, :, :cap]


@jax.jit
def chunk_skip_rate(qboxes: jax.Array, cboxes: jax.Array,
                    cand: jax.Array) -> jax.Array:
    """Fraction of (query, live candidate) chunk probes the local index
    skips: chunks whose box the query misses, over all chunks of all
    non-padding candidates.  All-sentinel chunks (pure padding past a
    tile's canonical members) count as skipped — an unindexed probe
    would have swept them.  -> () f32 in [0, 1].
    """
    from . import ref
    live_cand = cand >= 0                                   # (Q, F)
    hit = ref.gathered_chunk_hits(qboxes.astype(jnp.float32),
                                  gathered_chunk_boxes(cboxes, cand))
    total = jnp.sum(live_cand) * cboxes.shape[1]
    skipped = jnp.sum(~hit & live_cand[..., None])
    return skipped / jnp.maximum(total, 1)
