"""Oracle for the Hilbert kernel — the core pure-jnp implementation."""
from ...core.hilbert import hilbert_keys, quantize, xy2d  # noqa: F401
