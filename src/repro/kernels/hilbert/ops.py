"""Public jit'd wrappers for the Hilbert encode kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import hilbert as core_hilbert
from . import kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("order", "interpret"))
def encode(gx: jax.Array, gy: jax.Array,
           order: int = core_hilbert.DEFAULT_ORDER,
           interpret: bool | None = None) -> jax.Array:
    """(N,) uint32 grid coords -> (N,) uint32 curve index via the kernel."""
    if interpret is None:
        interpret = _interpret_default()
    n = gx.shape[0]
    tile = kernel.DEFAULT_ROWS * kernel.LANES
    pad = (-n) % tile
    gx_p = jnp.pad(gx.astype(jnp.uint32), (0, pad)).reshape(-1, kernel.LANES)
    gy_p = jnp.pad(gy.astype(jnp.uint32), (0, pad)).reshape(-1, kernel.LANES)
    d = kernel.encode_pallas(gx_p, gy_p, order, interpret=interpret)
    return d.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("order", "interpret"))
def hilbert_keys(pts: jax.Array, bounds: jax.Array,
                 order: int = core_hilbert.DEFAULT_ORDER,
                 interpret: bool | None = None) -> jax.Array:
    """Drop-in replacement for ``core.hilbert.hilbert_keys`` (kernel path)."""
    gx, gy = core_hilbert.quantize(pts, bounds, order)
    return encode(gx, gy, order, interpret=interpret)
