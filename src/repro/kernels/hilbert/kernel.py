"""Hilbert xy→d encode kernel (TPU Pallas).

Pure integer/VPU bit transform, vectorised over (BR, 128) blocks of
points (the lane axis holds 128 points, the sublane axis BR rows).  The
bit-plane loop is a ``lax.fori_loop`` so the kernel body is O(order)
instructions regardless of block size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_ROWS = 8
LANES = 128


def _hilbert_kernel(order: int, x_ref, y_ref, out_ref):
    x = x_ref[...].astype(jnp.uint32)
    y = y_ref[...].astype(jnp.uint32)
    d = jnp.zeros_like(x)

    def body(i, carry):
        x, y, d = carry
        s = jnp.uint32(1) << jnp.uint32(order - 1 - i)
        rx = ((x & s) > 0).astype(jnp.uint32)
        ry = ((y & s) > 0).astype(jnp.uint32)
        d = d + s * s * ((jnp.uint32(3) * rx) ^ ry)
        swap = ry == 0
        flip = swap & (rx == 1)
        x_f = jnp.where(flip, s - jnp.uint32(1) - x, x)
        y_f = jnp.where(flip, s - jnp.uint32(1) - y, y)
        x, y = jnp.where(swap, y_f, x_f), jnp.where(swap, x_f, y_f)
        return x, y, d

    _, _, d = lax.fori_loop(0, order, body, (x, y, d))
    out_ref[...] = d


def encode_pallas(gx: jax.Array, gy: jax.Array, order: int,
                  rows: int = DEFAULT_ROWS,
                  interpret: bool = False) -> jax.Array:
    """gx, gy: (R, 128) uint32 grids, R % rows == 0 -> (R, 128) uint32."""
    import functools
    r = gx.shape[0]
    grid = (r // rows,)
    return pl.pallas_call(
        functools.partial(_hilbert_kernel, order),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, LANES), jnp.uint32),
        interpret=interpret,
    )(gx, gy)
