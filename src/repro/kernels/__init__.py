"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package has ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper), and ``ref.py`` (pure-jnp oracle).
Kernels target TPU (VMEM tiling, 128-aligned blocks) and are validated on
CPU with ``interpret=True``.

- ``mbr_join``: blocked pairwise MBR-intersection counting — the per-tile
  spatial-join hot spot (the paper's query phase D).
- ``range_probe``: batched query-box vs tiled-layout probe — the range/kNN
  serving hot spot (``repro.serve``).
- ``hilbert``: Hilbert-curve xy→d bit transform — the HC partitioner and
  MapReduce-shuffle anchor-key hot spot (paper §5.1).
- ``ssd``: Mamba2 state-space-duality intra-chunk block — the assigned
  arch pool's kernel-level hot spot.
"""
from . import hilbert, mbr_join, range_probe, ssd  # noqa: F401

# wire the Hilbert kernel into the HC partitioner (core has no kernels dep)
from ..core.partition import hc as _hc
from .hilbert import ops as _hops

_hc.set_key_fn(_hops.hilbert_keys)
