"""Mamba2 SSD intra-chunk kernel (TPU Pallas).

State-space duality (arXiv:2405.21060) computes attention-like chunked
matmuls instead of a sequential scan.  The intra-chunk block is the MXU
hot spot:

    G     = C @ Bᵀ                      (Q, Q)   MXU
    M_ij  = G_ij · exp(cl_i − cl_j) · dt_j  for i ≥ j else 0
    Y     = M @ X                       (Q, P)   MXU

where ``cl`` is the within-chunk cumulative log-decay (cumsum of dt·A).
Chunk length Q = 128 and state S = 128 align both matmuls with the MXU;
inter-chunk state passing is cheap jnp around the kernel (see ops.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, cl_ref, b_ref, c_ref, out_ref):
    x = x_ref[0]                       # (Q, P)
    dt = dt_ref[0]                     # (Q,)
    cl = cl_ref[0]                     # (Q,)
    b = b_ref[0]                       # (Q, S)
    c = c_ref[0]                       # (Q, S)
    q = x.shape[0]
    g = jnp.dot(c, b.T, preferred_element_type=jnp.float32)     # (Q, Q)
    decay = jnp.exp(cl[:, None] - cl[None, :])
    i = lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j = lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(i >= j, g * decay, 0.0) * dt[None, :]
    out_ref[0] = jnp.dot(m.astype(x.dtype), x,
                         preferred_element_type=jnp.float32).astype(out_ref.dtype)


def intra_chunk_pallas(x: jax.Array, dt: jax.Array, cl: jax.Array,
                       b: jax.Array, c: jax.Array,
                       interpret: bool = False) -> jax.Array:
    """All args flattened over (batch·head·chunk) instances.

    x: (I, Q, P), dt/cl: (I, Q), b/c: (I, Q, S) -> (I, Q, P) float32.
    """
    inst, q, p = x.shape
    s = b.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(inst,),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
            pl.BlockSpec((1, q), lambda i: (i, 0)),
            pl.BlockSpec((1, q, s), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, s), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((inst, q, p), jnp.float32),
        interpret=interpret,
    )(x, dt, cl, b, c)
