"""Pure-jnp oracles for the SSD kernel.

``intra_chunk_ref`` mirrors the kernel contract exactly;
``ssd_scan_ref`` is the sequential state-space recurrence the chunked
algorithm must reproduce end-to-end:

    h_t = exp(dt_t A) · h_{t−1} + dt_t · B_t ⊗ x_t
    y_t = C_t · h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def intra_chunk_ref(x, dt, cl, b, c):
    """x: (I, Q, P), dt/cl: (I, Q), b/c: (I, Q, S) -> (I, Q, P)."""
    g = jnp.einsum("iqs,iks->iqk", c, b)
    decay = jnp.exp(cl[:, :, None] - cl[:, None, :])
    q = x.shape[1]
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(mask[None], g * decay, 0.0) * dt[:, None, :]
    return jnp.einsum("iqk,ikp->iqp", m, x)


def ssd_scan_ref(x, dt, a_log, b, c, h0=None):
    """Sequential oracle.  x: (L, P), dt: (L,), a_log: scalar (=A<0),
    b/c: (L, S) -> y: (L, P), h_final: (S, P)."""
    s, p = b.shape[-1], x.shape[-1]
    h0 = jnp.zeros((s, p), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * a_log)
        h = decay * h + dt_t * jnp.outer(b_t, x_t)
        y_t = c_t @ h
        return h, y_t

    h, y = lax.scan(step, h0, (x, dt, b, c))
    return y, h
