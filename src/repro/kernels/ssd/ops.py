"""Chunked SSD forward: Pallas intra-chunk kernel + jnp inter-chunk carry.

The full SSD output decomposes per chunk c as

    Y_c = intra(X_c)  +  C_c · exp(cl) · H_{c−1}

with the chunk-final states H_c computed by a (cheap, O(L/Q)) scan:

    H_c = exp(cl_last) · H_{c−1} + (dt·exp(cl_last − cl) B)ᵀ X_c
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# Pallas kernels are forward-only; differentiate through the pure-jnp
# oracle formulas instead (kernel forward, oracle-derived backward).
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _intra_chunk(x, dt, cl, b, c, interpret):
    return kernel.intra_chunk_pallas(x, dt, cl, b, c, interpret=interpret)


def _intra_fwd(x, dt, cl, b, c, interpret):
    return _intra_chunk(x, dt, cl, b, c, interpret), (x, dt, cl, b, c)


def _intra_bwd(interpret, res, g):
    from . import ref
    _, vjp = jax.vjp(ref.intra_chunk_ref, *res)
    return vjp(g)


_intra_chunk.defvjp(_intra_fwd, _intra_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd_forward(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b: jax.Array, c: jax.Array, chunk: int = kernel.CHUNK,
                interpret: bool | None = None,
                use_kernel: bool | None = None):
    """Multi-head chunked SSD.

    x: (B, L, H, P), dt: (B, L, H), a_log: (H,) (negative),
    b, c: (B, L, G, S) with H % G == 0.  Returns (B, L, H, P) float32.

    ``use_kernel=None`` resolves to "Pallas on a single device, einsum
    under GSPMD": pallas_call is an opaque custom-call to the SPMD
    partitioner, so inside a multi-device jit the mathematically
    identical einsum form (which GSPMD shards) is used; the kernel is
    the per-shard hot-spot path (shard_map / single-device / TPU core).
    """
    if interpret is None:
        interpret = _interpret_default()
    if use_kernel is None:
        use_kernel = jax.device_count() == 1
    bs, l, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    rep = h // g
    assert l % chunk == 0, "sequence must be chunk-padded"
    nc = l // chunk

    bh = jnp.repeat(b, rep, axis=2)  # (B, L, H, S)
    ch = jnp.repeat(c, rep, axis=2)

    # per-step log decay and within-chunk cumulative
    ld = dt * a_log[None, None, :]                      # (B, L, H)
    ldc = ld.reshape(bs, nc, chunk, h)
    cl = jnp.cumsum(ldc, axis=2)                        # inclusive cumsum

    xc = x.reshape(bs, nc, chunk, h, p)
    dtc = dt.reshape(bs, nc, chunk, h)
    bc = bh.reshape(bs, nc, chunk, h, s)
    cc = ch.reshape(bs, nc, chunk, h, s)

    # ---- intra-chunk ----
    if use_kernel:
        # Pallas path: flatten (B, H, nc) into the kernel grid axis
        def flat(t, feat):
            return jnp.moveaxis(t, 3, 1).reshape(bs * h * nc, chunk, *feat)

        xi, bi, ci = flat(xc, (p,)), flat(bc, (s,)), flat(cc, (s,))
        dti = jnp.moveaxis(dtc, 3, 1).reshape(bs * h * nc, chunk)
        cli = jnp.moveaxis(cl, 3, 1).reshape(bs * h * nc, chunk)
        y_intra = _intra_chunk(xi, dti, cli, bi, ci, interpret)
        y_intra = jnp.moveaxis(
            y_intra.reshape(bs, h, nc, chunk, p), 1, 3)  # (B, nc, Q, H, P)
    else:
        # GSPMD path: batched einsums, batch/head axes kept separate so
        # data/model shardings propagate without gathers
        g = jnp.einsum("bnqhs,bnkhs->bnhqk", cc, bc)
        # decay[b,n,h,q,k] = exp(cl[b,n,q,h] - cl[b,n,k,h])
        clh = cl.transpose(0, 1, 3, 2)                   # (B, nc, H, Q)
        decay = jnp.exp(clh[..., :, None] - clh[..., None, :])
        q_i = jnp.arange(chunk)
        mask = q_i[:, None] >= q_i[None, :]
        m = jnp.where(mask[None, None, None], g * decay, 0.0) \
            * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
        y_intra = jnp.einsum("bnhqk,bnkhp->bnqhp", m, xc)

    # ---- inter-chunk state scan (jnp) ----
    cl_last = cl[:, :, -1, :]                            # (B, nc, H)
    # contribution of chunk c to its final state:
    #   S_c = Σ_t dt_t · exp(cl_last − cl_t) · B_t ⊗ X_t
    w = dtc * jnp.exp(cl_last[:, :, None, :] - cl)       # (B, nc, Q, H)
    s_c = jnp.einsum("bnqh,bnqhs,bnqhp->bnhsp", w, bc, xc)

    def carry(hprev, inp):
        s_chunk, decay = inp                             # (B,H,S,P), (B,H)
        hnew = hprev * decay[..., None, None] + s_chunk
        return hnew, hprev

    decays = jnp.exp(cl_last)                            # (B, nc, H)
    h0 = jnp.zeros((bs, h, s, p), jnp.float32)
    from ...models import layers as _layers
    _unroll = nc if _layers.UNROLL_INNER_SCANS else 1
    _, h_prevs = lax.scan(
        carry, h0,
        (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(decays, 1, 0)),
        unroll=_unroll)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # (B, nc, H, S, P)

    # inter-chunk output: y_t += C_t · exp(cl_t) · H_{c−1}
    y_inter = jnp.einsum("bnqhs,bnhsp->bnqhp",
                         cc * jnp.exp(cl)[..., None], h_prevs)

    y = (y_intra + y_inter).reshape(bs, l, h, p)
    return y
