"""Blocked pairwise MBR-intersection kernel (TPU Pallas).

The per-tile spatial join tests every (r, s) MBR pair in a tile for
closed-box intersection.  On TPU this is a VPU problem: a (BR, BS) block
of boolean compares from rank-1 broadcasts.  Layout: coordinates arrive
as (4, N) — component-major — so the object axis is the 128-lane axis.

Two entry points:
- ``count``: grid cell (i, j) reduces its (BR, BS) block to one int32 —
  O(Nb×Mb) output, used for selectivity/λ statistics and join counting.
- ``mask``:  writes the full boolean block — used for pair extraction on
  moderate tile sizes.

Padding contract: callers pad with *inverted* sentinel boxes
(xmin > xmax) which intersect nothing, so no separate validity mask is
streamed through VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 256
DEFAULT_BS = 128


def _block_hits(r_ref, s_ref):
    rx0 = r_ref[0, :][:, None]   # (BR, 1)
    ry0 = r_ref[1, :][:, None]
    rx1 = r_ref[2, :][:, None]
    ry1 = r_ref[3, :][:, None]
    sx0 = s_ref[0, :][None, :]   # (1, BS)
    sy0 = s_ref[1, :][None, :]
    sx1 = s_ref[2, :][None, :]
    sy1 = s_ref[3, :][None, :]
    return (rx0 <= sx1) & (sx0 <= rx1) & (ry0 <= sy1) & (sy0 <= ry1)


def _count_kernel(r_ref, s_ref, out_ref):
    hits = _block_hits(r_ref, s_ref)
    out_ref[0, 0] = jnp.sum(hits.astype(jnp.int32))


def _mask_kernel(r_ref, s_ref, out_ref):
    out_ref[...] = _block_hits(r_ref, s_ref)


def count_pallas(r4: jax.Array, s4: jax.Array, br: int = DEFAULT_BR,
                 bs: int = DEFAULT_BS, interpret: bool = False) -> jax.Array:
    """r4: (4, N), s4: (4, M), N % br == 0, M % bs == 0 -> (N/br, M/bs) int32."""
    n, m = r4.shape[1], s4.shape[1]
    grid = (n // br, m // bs)
    return pl.pallas_call(
        _count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, br), lambda i, j: (0, i)),
            pl.BlockSpec((4, bs), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.int32),
        interpret=interpret,
    )(r4, s4)


def mask_pallas(r4: jax.Array, s4: jax.Array, br: int = DEFAULT_BR,
                bs: int = DEFAULT_BS, interpret: bool = False) -> jax.Array:
    """r4: (4, N), s4: (4, M) -> (N, M) bool intersection table."""
    n, m = r4.shape[1], s4.shape[1]
    grid = (n // br, m // bs)
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, br), lambda i, j: (0, i)),
            pl.BlockSpec((4, bs), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((br, bs), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.bool_),
        interpret=interpret,
    )(r4, s4)
