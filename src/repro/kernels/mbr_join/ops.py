"""Public jit'd wrappers for the mbr_join kernel.

Handles padding to block multiples (with never-intersecting sentinel
boxes), component-major layout, and CPU fallback to interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.geometry import SENTINEL_BOX
from . import kernel

_SENTINEL = jnp.array(SENTINEL_BOX, jnp.float32)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_cm(mbrs: jax.Array, block: int) -> jax.Array:
    """(N, 4) -> component-major (4, N_pad) with sentinel padding."""
    n = mbrs.shape[0]
    pad = (-n) % block
    if pad:
        mbrs = jnp.concatenate(
            [mbrs, jnp.broadcast_to(_SENTINEL, (pad, 4))], axis=0)
    return mbrs.T


@functools.partial(jax.jit, static_argnames=("br", "bs", "interpret"))
def join_count(r: jax.Array, s: jax.Array, br: int = kernel.DEFAULT_BR,
               bs: int = kernel.DEFAULT_BS,
               interpret: bool | None = None) -> jax.Array:
    """Total intersecting (r, s) pairs. r: (N, 4), s: (M, 4)."""
    if interpret is None:
        interpret = _interpret_default()
    r4 = _pad_cm(r.astype(jnp.float32), br)
    s4 = _pad_cm(s.astype(jnp.float32), bs)
    parts = kernel.count_pallas(r4, s4, br, bs, interpret=interpret)
    return jnp.sum(parts)


@functools.partial(jax.jit, static_argnames=("br", "bs", "interpret"))
def join_mask(r: jax.Array, s: jax.Array, br: int = kernel.DEFAULT_BR,
              bs: int = kernel.DEFAULT_BS,
              interpret: bool | None = None) -> jax.Array:
    """(N, M) boolean intersection table (un-padded view)."""
    if interpret is None:
        interpret = _interpret_default()
    n, m = r.shape[0], s.shape[0]
    r4 = _pad_cm(r.astype(jnp.float32), br)
    s4 = _pad_cm(s.astype(jnp.float32), bs)
    full = kernel.mask_pallas(r4, s4, br, bs, interpret=interpret)
    return full[:n, :m]
