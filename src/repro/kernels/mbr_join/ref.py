"""Pure-jnp oracle for the mbr_join kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def intersect_mask(r: jax.Array, s: jax.Array) -> jax.Array:
    """(N, 4) x (M, 4) -> (N, M) closed-box intersection."""
    return (
        (r[:, None, 0] <= s[None, :, 2])
        & (s[None, :, 0] <= r[:, None, 2])
        & (r[:, None, 1] <= s[None, :, 3])
        & (s[None, :, 1] <= r[:, None, 3])
    )


def intersect_count(r: jax.Array, s: jax.Array) -> jax.Array:
    return jnp.sum(intersect_mask(r, s).astype(jnp.int32))
