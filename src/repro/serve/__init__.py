"""Batched spatial query serving over partitioned layouts.

- ``config``: ``ServeConfig`` — the one frozen description of how a
  server serves (placement, probe mode, local-index mode, chunk
  granularity, capacity/slack policy).
- ``router``: the global index — jit-compatible query→partition
  routing and fixed-width ``(Q, F)`` candidate-tile emission (box
  overlap for range, L∞-MINDIST frontier for kNN) plus the per-query
  partition fan-out metric, and the host-side owner translation
  (``owner_split``) that re-expresses candidate lists in sharded
  ``(owner device, local tile)`` coordinates.
- ``layout``: the ``TileLayout`` protocol and its three placements —
  ``ReplicatedTiles`` (full staging everywhere, queries shard),
  ``ShardedTiles`` (tiles shard across owners, queries travel through
  the exchange), and ``HeatSharded`` (sharded with query-heat-aware
  co-location + hot-tile replicas) — plus ``stage_tiles`` (MASJ tiles
  + canonical marks + canonical probe boxes + the configurable
  intra-tile local index) and the streaming append lifecycle (slack
  inserts with dead-slot reuse, incremental probe/chunk-box refresh,
  overflow re-stage with owner re-balancing).
- ``engine``: ``SpatialServer`` — routing, LPT query packing, the kNN
  widen-and-retry exactness ladder, and the adaptive ``WidthPolicy``,
  written once against the protocol.
- ``exchange``: the owner-routed ``all_to_all`` serving step — scatter
  queries to candidate-tile owners, probe local shards, merge partials
  deterministically; runs under a mesh or in vmap simulation.
- ``frontend``: the async request plane — single-query requests in,
  deadline-or-full padded batches out, with admission control,
  per-tenant fairness, and tail-latency metrics (``ServeFrontend``,
  ``FrontendConfig``, the sans-IO ``RequestPlane``, and the
  deterministic open-loop simulator).

See ``docs/ARCHITECTURE.md`` for the full pipeline.
"""
from . import config, engine, exchange, frontend, layout, router  # noqa: F401
from .config import PlacementPolicy, ServeConfig  # noqa: F401
from .engine import SpatialServer, WidthPolicy  # noqa: F401
from .frontend import (  # noqa: F401
    FrontendConfig,
    ServeFrontend,
)
from .layout import (  # noqa: F401
    HeatSharded,
    ReplicatedTiles,
    ShardedLayout,
    ShardedTiles,
    StagedLayout,
    TileLayout,
    build_tiles,
    shard_staged,
    stage_tiles,
)
from .router import HeatTracker  # noqa: F401
