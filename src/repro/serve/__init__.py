"""Batched spatial query serving over partitioned layouts.

- ``router``: the global index — jit-compatible query→partition
  routing and fixed-width ``(Q, F)`` candidate-tile emission (box
  overlap for range, L∞-MINDIST frontier for kNN) plus the per-query
  partition fan-out metric.
- ``engine``: stage a dataset once under any ``Partitioning`` (MASJ
  tiles + canonical marks + canonical probe boxes), then answer
  streams of range/kNN batches with an SPMD ``shard_map`` step:
  fan-out-weighted LPT query packing and pruned candidate-tile probing
  (dense all-tile sweep kept as the oracle, ``pruned=False``).

See ``docs/ARCHITECTURE.md`` for the full pipeline.
"""
from . import engine, router  # noqa: F401
from .engine import SpatialServer, stage  # noqa: F401
