"""Batched spatial query serving over partitioned layouts.

- ``config``: ``ServeConfig`` — the one frozen description of how a
  server serves (placement, probe mode, local-index mode, chunk
  granularity, capacity/slack policy).
- ``router``: the global index — jit-compatible query→partition
  routing and fixed-width ``(Q, F)`` candidate-tile emission (box
  overlap for range, L∞-MINDIST frontier for kNN) plus the per-query
  partition fan-out metric, and the host-side owner translation
  (``owner_split``) that re-expresses candidate lists in sharded
  ``(owner device, local tile)`` coordinates.
- ``layout``: the ``TileLayout`` protocol and its two placements —
  ``ReplicatedTiles`` (full staging everywhere, queries shard) and
  ``ShardedTiles`` (tiles shard across owners, queries travel through
  the exchange) — plus ``stage_tiles`` (MASJ tiles + canonical marks +
  canonical probe boxes + the configurable intra-tile local index) and
  the streaming append lifecycle (slack inserts, incremental probe/
  chunk-box refresh, overflow re-stage with owner re-balancing).
- ``engine``: ``SpatialServer`` — routing, LPT query packing, the kNN
  widen-and-retry exactness ladder, and the adaptive ``WidthPolicy``,
  written once against the protocol; plus the deprecated PR-4 shims
  (``stage``, ``stage_sharded``, boolean kwargs — one release,
  ``LegacyServeWarning``).
- ``exchange``: the owner-routed ``all_to_all`` serving step — scatter
  queries to candidate-tile owners, probe local shards, merge partials
  deterministically; runs under a mesh or in vmap simulation.

See ``docs/ARCHITECTURE.md`` for the full pipeline and the old→new
API migration table.
"""
from . import config, engine, exchange, layout, router  # noqa: F401
from .config import LegacyServeWarning, ServeConfig  # noqa: F401
from .engine import (  # noqa: F401
    SpatialServer,
    WidthPolicy,
    stage,
    stage_sharded,
)
from .layout import (  # noqa: F401
    ReplicatedTiles,
    ShardedLayout,
    ShardedTiles,
    StagedLayout,
    TileLayout,
    build_tiles,
    shard_staged,
    stage_tiles,
)
