"""Batched spatial query serving over partitioned layouts.

- ``router``: the global index — jit-compatible query→partition routing
  (box overlap for range, MINDIST best-first order for kNN) and the
  per-query partition fan-out metric.
- ``engine``: stage a dataset once under any ``Partitioning``, then
  answer streams of range/kNN batches with an SPMD ``shard_map`` step
  and LPT query packing.
"""
from . import engine, router  # noqa: F401
from .engine import SpatialServer, stage  # noqa: F401
