"""Batched spatial query serving over partitioned layouts.

- ``router``: the global index — jit-compatible query→partition
  routing and fixed-width ``(Q, F)`` candidate-tile emission (box
  overlap for range, L∞-MINDIST frontier for kNN) plus the per-query
  partition fan-out metric, and the host-side owner translation
  (``owner_split``) that re-expresses candidate lists in sharded
  ``(owner device, local tile)`` coordinates.
- ``engine``: stage a dataset once under any ``Partitioning`` (MASJ
  tiles + canonical marks + canonical probe boxes + the intra-tile
  local index: x-sorted members and per-128-slot chunk boxes,
  ``local_index=True``), then answer streams of range/kNN batches with
  an SPMD ``shard_map`` step: fan-out-weighted LPT query packing and
  pruned candidate-tile probing with chunk-skipping kernels (dense
  all-tile sweep kept as the oracle, ``pruned=False``; unindexed
  staging via ``local_index=False``).
  ``sharded=True`` shards the tiles themselves across devices
  (``stage_sharded`` — capped-LPT placement, O(total/D) per-device
  memory) and serves through the exchange layer.
- ``exchange``: the owner-routed ``all_to_all`` serving step — scatter
  queries to candidate-tile owners, probe local shards, merge partials
  deterministically; runs under a mesh or in vmap simulation.

See ``docs/ARCHITECTURE.md`` for the full pipeline.
"""
from . import engine, exchange, router  # noqa: F401
from .engine import (  # noqa: F401
    ShardedLayout,
    SpatialServer,
    StagedLayout,
    WidthPolicy,
    stage,
    stage_sharded,
)
