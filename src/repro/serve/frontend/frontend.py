"""``ServeFrontend``: the asyncio wrapper around the sans-IO request
plane.

Callers await single-query coroutines (``range_counts`` /
``range_ids`` / ``knn``); one background dispatcher task forms batches
by the plane's deadline-or-full rule and runs them on a single worker
thread (``execute_batch`` calls block on device sync, and the engine's
width-policy cache is not thread-safe — one executor thread is the
concurrency model, same as the closed-loop bench).  Results come back
as ``Response`` objects; rejected and timed-out requests resolve with
their outcome instead of raising, so SLO handling is explicit at the
call site.

The wrapper adds *only* IO: futures, a wake event, the worker thread,
and wall-clock ``now``.  All policy (admission, fairness, deadlines,
batch shapes) lives in ``RequestPlane`` and is covered by the
virtual-clock tests.
"""
from __future__ import annotations

import asyncio
import concurrent.futures

import numpy as np

from .clock import MonotonicClock
from .config import FrontendConfig
from .executor import execute_batch
from .metrics import FrontendMetrics
from .plane import Outcome, RequestPlane, Request, Response


class ServeFrontend:
    """Async facade over one ``SpatialServer`` (any ``TileLayout``
    placement).  Use as an async context manager, or call ``start()`` /
    ``await close()`` explicitly."""

    def __init__(self, server, config: FrontendConfig | None = None):
        self.server = server
        self.config = config or FrontendConfig()
        self.metrics = FrontendMetrics()
        self.plane = RequestPlane(self.config, self.metrics)
        self.clock = MonotonicClock()
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self._pool: concurrent.futures.ThreadPoolExecutor | None = None
        self._closing = False

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "ServeFrontend":
        if self._task is not None:
            return self
        self._closing = False
        self._wake = asyncio.Event()
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-frontend")
        self._task = asyncio.get_running_loop().create_task(self._run())
        return self

    async def close(self) -> None:
        """Drain pending requests, then stop the dispatcher."""
        if self._task is None:
            return
        self._closing = True
        self._wake.set()
        await self._task
        self._task = None
        self._pool.shutdown(wait=True)
        self._pool = None

    async def __aenter__(self) -> "ServeFrontend":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- submission -------------------------------------------------------

    async def _submit(self, kind: str, payload: np.ndarray, params: tuple,
                      tenant: str, deadline: float | None) -> Response:
        if self._task is None:
            raise RuntimeError("ServeFrontend is not started")
        now = self.clock.now()
        req = Request(kind=kind, payload=payload, params=params,
                      tenant=tenant,
                      deadline=now + deadline if deadline is not None
                      else float("inf"))
        req.future = asyncio.get_running_loop().create_future()
        if not self.plane.submit(req, now):
            return Response(Outcome.REJECTED)
        self._wake.set()
        return await req.future

    async def range_counts(self, qbox, *, tenant: str = "default",
                           deadline: float | None = None) -> Response:
        """Count objects intersecting one (4,) query box.
        ``Response.value`` is an int."""
        return await self._submit(
            "range_counts", np.asarray(qbox, np.float32).reshape(4), (),
            tenant, deadline)

    async def range_ids(self, qbox, max_hits: int = 1024, *,
                        tenant: str = "default",
                        deadline: float | None = None) -> Response:
        """Ids of objects intersecting one (4,) query box.
        ``Response.value`` is ``(ids, count, overflow)``."""
        return await self._submit(
            "range_ids", np.asarray(qbox, np.float32).reshape(4),
            (int(max_hits),), tenant, deadline)

    async def knn(self, pt, k: int, max_cand: int = 1024, *,
                  tenant: str = "default",
                  deadline: float | None = None) -> Response:
        """k nearest objects to one (2,) point.  ``Response.value`` is
        ``(nn_ids, nn_d2, overflow)``."""
        return await self._submit(
            "knn", np.asarray(pt, np.float32).reshape(2),
            (int(k), int(max_cand)), tenant, deadline)

    # -- reporting --------------------------------------------------------

    def placement_stats(self) -> dict:
        """The served placement's heat view, as plain host values: what
        an operator of the async plane watches to decide (or audit) a
        ``server.rebalance()`` without reaching into the engine.
        Traffic through this frontend feeds the tracker exactly like
        direct batched calls — heat is observed at routing time."""
        srv = self.server
        stats = srv.stats
        out = dict(placement=stats.get("placement"),
                   shards=getattr(srv, "shards", 1),
                   heat_batches=srv.heat.batches,
                   heat_decay=srv.heat.decay)
        for key in ("replicated_tiles", "moved_tiles", "cut_before",
                    "cut_after", "placement_skew", "t_local"):
            if key in stats:
                out[key] = stats[key]
        return out

    # -- dispatcher -------------------------------------------------------

    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            now = self.clock.now()
            due = self.plane.next_due(now)
            if due is None:
                if self._closing:
                    return
                self._wake.clear()
                # re-check under the cleared event: a submit between
                # next_due() and clear() would otherwise be missed
                if self.plane.next_due(self.clock.now()) is None:
                    await self._wake.wait()
                continue
            if due > now:
                try:
                    await asyncio.wait_for(self._wake.wait(), due - now)
                    self._wake.clear()
                except asyncio.TimeoutError:
                    pass
                continue
            batch, expired = self.plane.form_batch(now, force=self._closing)
            self._finish_expired(expired, self.clock.now())
            if batch is None:
                continue
            try:
                results = await loop.run_in_executor(
                    self._pool, execute_batch, self.server, batch)
            except Exception as e:  # surface executor faults to callers
                for req in batch.requests:
                    if req.future is not None and not req.future.done():
                        req.future.set_exception(e)
                continue
            done = self.clock.now()
            for req, val in zip(batch.requests, results):
                queue_s = batch.formed_at - req.arrival
                execute_s = done - batch.formed_at
                self.metrics.on_complete(req.tenant, queue_s, execute_s,
                                         done - req.arrival)
                if req.future is not None and not req.future.done():
                    req.future.set_result(Response(
                        Outcome.OK, value=val, queue_s=queue_s,
                        execute_s=execute_s, total_s=done - req.arrival))

    def _finish_expired(self, expired, now: float) -> None:
        for req in expired:
            if req.future is not None and not req.future.done():
                req.future.set_result(Response(
                    Outcome.TIMED_OUT, queue_s=now - req.arrival,
                    total_s=now - req.arrival))
