"""Request-plane observability: counters, per-tenant accounting, and
latency histograms, surfaced as one plain dict (``snapshot()``).

The metrics answer the three questions an operator of the serving
front-end asks:

- **admission** — how much traffic is being turned away (``rejected``
  backpressure, ``timed_out`` SLO misses) and who it belongs to
  (per-tenant counters);
- **batching efficiency** — batch fill ratio (admitted requests per
  compiled batch slot) and padded-slot waste, the cost of the fixed
  batch-shape ladder;
- **latency** — per-request queue / execute / total histograms with
  p50/p90/p99, the open-loop numbers ``bench_serve_frontend`` reports
  next to the closed-loop throughput rows.

Everything is plain Python on the host — metrics never touch the
jitted path.
"""
from __future__ import annotations

import dataclasses


class Histogram:
    """Latency histogram with exact percentiles.

    Raw samples are kept (seconds, float) up to ``cap`` and then
    reservoir-subsampled by simple decimation (every other sample is
    dropped and the stride doubles), so long benches stay O(cap) memory
    while percentiles remain representative; ``count``/``total`` are
    always exact.
    """

    def __init__(self, cap: int = 100_000):
        self._cap = cap
        self._stride = 1
        self._tick = 0
        self.samples: list[float] = []
        self.count = 0
        self.total = 0.0
        self.max = 0.0

    def record(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v > self.max:
            self.max = v
        self._tick += 1
        if self._tick >= self._stride:
            self._tick = 0
            self.samples.append(v)
            if len(self.samples) >= self._cap:
                self.samples = self.samples[::2]
                self._stride *= 2

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the kept samples (0 when
        empty)."""
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * len(s))) - 1))
        return s[idx]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return dict(count=self.count, mean=self.mean, max=self.max,
                    p50=self.percentile(50), p90=self.percentile(90),
                    p99=self.percentile(99))


@dataclasses.dataclass
class _TenantCounters:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    timed_out: int = 0
    completed: int = 0


class FrontendMetrics:
    """One mutable metrics sink per frontend (see module docstring)."""

    def __init__(self):
        self.submitted = 0
        self.admitted = 0
        self.rejected = 0
        self.timed_out = 0
        self.completed = 0
        self.batches = 0
        self.batch_slots = 0        # sum of padded batch widths
        self.batch_fill = 0         # sum of real requests per batch
        self.queue_depth = 0        # live gauge, mirrors the plane
        self.queue_depth_max = 0
        self.tenants: dict[str, _TenantCounters] = {}

        self.queue_s = Histogram()      # arrival -> batch formed
        self.execute_s = Histogram()    # batch formed -> results ready
        self.total_s = Histogram()      # arrival -> response

    def _tenant(self, tenant: str) -> _TenantCounters:
        tc = self.tenants.get(tenant)
        if tc is None:
            tc = self.tenants[tenant] = _TenantCounters()
        return tc

    # -- admission --------------------------------------------------------

    def on_submit(self, tenant: str, admitted: bool, depth: int) -> None:
        self.submitted += 1
        tc = self._tenant(tenant)
        tc.submitted += 1
        if admitted:
            self.admitted += 1
            tc.admitted += 1
            self.queue_depth = depth
            if depth > self.queue_depth_max:
                self.queue_depth_max = depth
        else:
            self.rejected += 1
            tc.rejected += 1

    def on_timeout(self, tenant: str) -> None:
        self.timed_out += 1
        self._tenant(tenant).timed_out += 1

    # -- batching ---------------------------------------------------------

    def on_batch(self, width: int, fill: int, depth: int) -> None:
        self.batches += 1
        self.batch_slots += width
        self.batch_fill += fill
        self.queue_depth = depth

    def on_complete(self, tenant: str, queue_s: float, execute_s: float,
                    total_s: float) -> None:
        self.completed += 1
        self._tenant(tenant).completed += 1
        self.queue_s.record(queue_s)
        self.execute_s.record(execute_s)
        self.total_s.record(total_s)

    # -- reporting --------------------------------------------------------

    @property
    def batch_fill_ratio(self) -> float:
        return self.batch_fill / self.batch_slots if self.batch_slots else 0.0

    @property
    def padded_slots(self) -> int:
        return self.batch_slots - self.batch_fill

    def snapshot(self) -> dict:
        """Everything as one plain dict (bench JSON embeds it)."""
        return dict(
            submitted=self.submitted, admitted=self.admitted,
            rejected=self.rejected, timed_out=self.timed_out,
            completed=self.completed, batches=self.batches,
            batch_slots=self.batch_slots, batch_fill=self.batch_fill,
            batch_fill_ratio=round(self.batch_fill_ratio, 4),
            padded_slots=self.padded_slots,
            queue_depth_max=self.queue_depth_max,
            queue_s=self.queue_s.snapshot(),
            execute_s=self.execute_s.snapshot(),
            total_s=self.total_s.snapshot(),
            tenants={t: dataclasses.asdict(c)
                     for t, c in sorted(self.tenants.items())},
        )
