"""Execute one closed batch against the batched ``SpatialServer``.

The bridge between the request plane and the existing serving engine:
a ``Batch`` of single-query requests becomes ONE padded call to the
server's batched API — the same call a closed-loop caller would make —
so the front-end inherits every exactness guarantee (routing, the kNN
widen-and-retry ladder, canonical dedup) without re-implementing any
of it.  The server is used strictly through its public batched surface
and the ``TileLayout`` protocol underneath it, so replicated and
sharded placements are interchangeable backends here.

Padding: a batch of ``n`` requests runs at ladder width ``w >= n``.
Range pad rows are the sentinel box (intersects nothing: zero fan-out,
zero hits); kNN pad rows are the dataset-universe centre (the same pad
point the engine's own LPT packing uses).  Pad rows are sliced off
before responses are built.  Every per-request answer is a function of
that request's query alone — counts are exact sums, id lists are exact
ascending sets, kNN is exact with the (distance, id) tie-break — so a
padded batched response is **bit-identical** to calling the batched
API directly with the same queries, which the frontend tests assert
per placement.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...core import geometry
from .plane import Batch

_SENTINEL = np.asarray(geometry.SENTINEL_BOX, np.float32)


def _padded(batch: Batch, pad_row: np.ndarray) -> np.ndarray:
    dim = pad_row.shape[0]
    out = np.broadcast_to(pad_row, (batch.width, dim)).copy()
    for i, req in enumerate(batch.requests):
        out[i] = np.asarray(req.payload, np.float32).reshape(dim)
    return out


def execute_batch(server, batch: Batch) -> list:
    """Run ``batch`` through ``server``'s batched API; return one
    result per request (batch order).

    Per-request results: ``range_counts`` -> int count; ``range_ids``
    -> (ids (max_hits,) int32 ascending -1-padded, count, overflow
    bool); ``knn`` -> (nn_ids (k,) int32, nn_d2 (k,) f32, overflow
    bool).  Everything is host numpy — responses never hold live
    device buffers.
    """
    n = len(batch.requests)
    if batch.kind == "knn":
        k, max_cand = batch.params
        uni = np.asarray(server.uni, np.float32)
        centre = (uni[:2] + uni[2:]) * 0.5
        pts = _padded(batch, centre)
        nn_ids, nn_d2, overflow, _ = server.knn(
            jnp.asarray(pts), k, max_cand=max_cand)
        nn_ids, nn_d2 = np.asarray(nn_ids), np.asarray(nn_d2)
        overflow = np.asarray(overflow)
        return [(nn_ids[i], nn_d2[i], bool(overflow[i])) for i in range(n)]

    qboxes = jnp.asarray(_padded(batch, _SENTINEL))
    if batch.kind == "range_counts":
        counts, _ = server.range_counts(qboxes)
        counts = np.asarray(counts)
        return [int(counts[i]) for i in range(n)]
    if batch.kind == "range_ids":
        (max_hits,) = batch.params
        hit_ids, counts, overflow, _ = server.range_ids(
            qboxes, max_hits=max_hits)
        hit_ids, counts = np.asarray(hit_ids), np.asarray(counts)
        overflow = np.asarray(overflow)
        return [(hit_ids[i], int(counts[i]), bool(overflow[i]))
                for i in range(n)]
    raise ValueError(f"unknown batch kind {batch.kind!r}")
