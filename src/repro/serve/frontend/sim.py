"""Deterministic open-loop driver: seeded arrivals through the request
plane on a virtual clock.

Open-loop load (arrivals keep coming regardless of completions — the
production regime, where millions of users don't wait for each other)
is awkward to measure reliably on a shared CI machine with real
sleeps.  This driver makes the queueing math exact instead: arrivals
follow a *seeded* Poisson process on a ``VirtualClock``, the plane's
admission/batching/timeout decisions replay bit-for-bit run over run,
and only batch *service* times come from the real machine (measured
around ``execute_batch`` and injected into virtual time — the
single-server model: while a batch executes, arrivals queue).  Tests
swap the executor for a fixed-service-time stub and become fully
deterministic end to end.

``simulate_open_loop`` returns per-request ``Response``s (submission
order) plus the metrics sink — p50/p99 queue/total latency and
sustained QPS under a given offered load, the numbers
``benchmarks/bench_serve_frontend.py`` reports next to the closed-loop
rows.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .clock import VirtualClock
from .config import FrontendConfig
from .executor import execute_batch
from .metrics import FrontendMetrics
from .plane import Outcome, RequestPlane, Request, Response


@dataclasses.dataclass
class Arrival:
    """One scheduled request of the open-loop workload."""
    t: float
    kind: str
    payload: np.ndarray
    params: tuple = ()
    tenant: str = "default"
    deadline: float | None = None     # relative budget (seconds)


def poisson_workload(rate: float, duration: float, make_request,
                     seed: int = 0) -> list[Arrival]:
    """Seeded Poisson arrivals at ``rate``/s over ``duration`` s.

    ``make_request(rng, i)`` -> ``(kind, payload, params, tenant)`` for
    the i-th arrival — the workload mix (query kinds, tenant skew) is
    the caller's, the arrival process is exponential inter-arrivals
    from one seeded generator, so a given (rate, duration, seed) is one
    reproducible trace.
    """
    rng = np.random.default_rng(seed)
    out: list[Arrival] = []
    t = 0.0
    i = 0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration:
            return out
        kind, payload, params, tenant = make_request(rng, i)
        out.append(Arrival(t=t, kind=kind, payload=payload,
                           params=tuple(params), tenant=tenant))
        i += 1


def simulate_open_loop(server, workload: list[Arrival],
                       config: FrontendConfig | None = None,
                       execute=None, clock: VirtualClock | None = None
                       ) -> tuple[list[Response], FrontendMetrics]:
    """Drive ``workload`` through a ``RequestPlane`` in virtual time.

    ``execute(server, batch) -> (results, service_s)`` defaults to the
    real ``execute_batch`` with wall-clock-measured service time; pass
    a stub for fully deterministic tests.  Returns one ``Response``
    per workload entry (same order; rejected/timed-out entries carry
    their outcome and no value).
    """
    config = config or FrontendConfig()
    clock = clock or VirtualClock()
    metrics = FrontendMetrics()
    plane = RequestPlane(config, metrics)
    if execute is None:
        def execute(srv, batch):
            t0 = time.perf_counter()
            results = execute_batch(srv, batch)
            return results, time.perf_counter() - t0

    responses: list[Response | None] = [None] * len(workload)
    index_of: dict[int, int] = {}          # plane seq -> workload index
    i = 0
    inf = float("inf")

    def submit_due():
        nonlocal i
        now = clock.now()
        while i < len(workload) and workload[i].t <= now:
            a = workload[i]
            req = Request(kind=a.kind, payload=a.payload, params=a.params,
                          tenant=a.tenant)
            if a.deadline is not None:
                req.deadline = a.t + a.deadline
            # submit at the arrival's own timestamp: queueing delay is
            # measured from when the request arrived, not from when the
            # simulation loop got around to it
            if plane.submit(req, a.t):
                index_of[req.seq] = i
            else:
                responses[i] = Response(Outcome.REJECTED)
            i += 1

    def resolve_expired(expired):
        for r in expired:
            responses[index_of[r.seq]] = Response(
                Outcome.TIMED_OUT, queue_s=clock.now() - r.arrival,
                total_s=clock.now() - r.arrival)

    while i < len(workload) or plane.pending:
        submit_due()
        next_arrival = workload[i].t if i < len(workload) else inf
        due = plane.next_due(clock.now())
        next_event = min(next_arrival, due if due is not None else inf)
        if next_event > clock.now():
            if next_event == inf:      # arrivals done, queue not due yet
                batch, expired = plane.form_batch(clock.now(), force=True)
                resolve_expired(expired)
                if batch is None:
                    break
                _run_batch(server, batch, execute, clock, metrics,
                           responses, index_of)
                continue
            clock.advance_to(next_event)
            submit_due()
        batch, expired = plane.form_batch(clock.now())
        resolve_expired(expired)
        if batch is not None:
            _run_batch(server, batch, execute, clock, metrics,
                       responses, index_of)
    return [r if r is not None else Response(Outcome.TIMED_OUT)
            for r in responses], metrics


def _run_batch(server, batch, execute, clock, metrics, responses,
               index_of) -> None:
    results, service_s = execute(server, batch)
    clock.advance(max(float(service_s), 0.0))
    done = clock.now()
    for req, val in zip(batch.requests, results):
        queue_s = batch.formed_at - req.arrival
        execute_s = done - batch.formed_at
        metrics.on_complete(req.tenant, queue_s, execute_s,
                            done - req.arrival)
        responses[index_of[req.seq]] = Response(
            Outcome.OK, value=val, queue_s=queue_s, execute_s=execute_s,
            total_s=done - req.arrival)
