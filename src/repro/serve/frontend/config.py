"""`FrontendConfig`: the one frozen description of how the request
plane admits, batches, and schedules single-query traffic.

The batched ``SpatialServer`` wants fixed query-batch shapes (each
shape is one compiled step); production traffic arrives one query at a
time.  The config names the knobs that bridge the two:

- ``ladder`` — the compiled batch-shape ladder, ascending (default
  64/128/256/512).  A closing batch pads up to the smallest rung that
  holds its requests, so a steady stream touches at most
  ``len(ladder)`` compiled widths per query kind — the same
  recompile-guard idea as the server's ``WidthPolicy``, applied to the
  batch axis.
- ``max_delay`` — the batch-forming window in seconds: a batch closes
  when it reaches the top rung ("full") or when its oldest request has
  waited ``max_delay`` ("deadline"), whichever is first.  Small values
  trade fill ratio for latency.
- ``queue_limit`` — admission control: the total number of requests
  the plane will hold across all tenants and query kinds.  A submit
  past the limit is **rejected** immediately (explicit backpressure,
  never unbounded buffering).
- ``quantum`` — deficit-round-robin fairness: each tenant may place at
  most ``quantum`` requests into a forming batch per rotation turn, so
  one hot tenant cannot starve the rest — cold tenants keep landing in
  every batch.
- ``default_deadline`` — per-request latency budget in seconds
  (``None`` = no budget).  A request still queued past its deadline is
  **timed out** (never executed) with an explicit outcome; per-request
  ``deadline=`` overrides.

Frozen and hashable, like ``ServeConfig``: a frontend's behaviour is
one immutable, loggable value.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Frozen request-plane configuration (see module docstring)."""

    ladder: tuple = (64, 128, 256, 512)
    max_delay: float = 0.002
    queue_limit: int = 4096
    quantum: int = 16
    default_deadline: float | None = None

    def __post_init__(self):
        ladder = tuple(int(w) for w in self.ladder)
        object.__setattr__(self, "ladder", ladder)
        if not ladder or any(w < 1 for w in ladder):
            raise ValueError(f"ladder must be non-empty positive widths, "
                             f"got {ladder}")
        if list(ladder) != sorted(set(ladder)):
            raise ValueError(f"ladder must be strictly ascending, "
                             f"got {ladder}")
        if self.max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {self.max_delay}")
        if self.queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, "
                             f"got {self.queue_limit}")
        if self.quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {self.quantum}")
        if self.default_deadline is not None and self.default_deadline <= 0:
            raise ValueError(f"default_deadline must be positive, "
                             f"got {self.default_deadline}")

    @property
    def max_batch(self) -> int:
        return self.ladder[-1]

    def width_for(self, n: int) -> int:
        """Smallest ladder rung holding ``n`` requests (n <= top rung;
        the plane never forms a batch past ``max_batch``)."""
        for w in self.ladder:
            if n <= w:
                return w
        raise ValueError(f"batch of {n} exceeds the ladder top rung "
                         f"{self.ladder[-1]}")

    def replace(self, **changes) -> "FrontendConfig":
        return dataclasses.replace(self, **changes)
