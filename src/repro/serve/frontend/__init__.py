"""Async request plane in front of ``SpatialServer``.

Single-query requests go in; deadline-or-full padded batches come out
the back into the server's batched API, with admission control and
per-tenant fairness in between.  The policy core (``RequestPlane``) is
sans-IO and clock-explicit; ``ServeFrontend`` is the asyncio wrapper,
``sim`` the deterministic open-loop driver.  See
``docs/ARCHITECTURE.md`` ("Request plane").
"""
from .clock import MonotonicClock, VirtualClock
from .config import FrontendConfig
from .executor import execute_batch
from .frontend import ServeFrontend
from .metrics import FrontendMetrics, Histogram
from .plane import KINDS, Batch, Outcome, Request, RequestPlane, Response
from .sim import Arrival, poisson_workload, simulate_open_loop

__all__ = [
    "Arrival",
    "Batch",
    "FrontendConfig",
    "FrontendMetrics",
    "Histogram",
    "KINDS",
    "MonotonicClock",
    "Outcome",
    "Request",
    "RequestPlane",
    "Response",
    "ServeFrontend",
    "VirtualClock",
    "execute_batch",
    "poisson_workload",
    "simulate_open_loop",
]
