"""The sans-IO request plane: admission control, per-tenant deficit
round robin, and deadline-or-full batch forming.

This is the deterministic core of the async front-end.  It owns no
clock, no event loop, and no executor — every method takes ``now``
explicitly, so the same state machine runs under asyncio against wall
time (``frontend.ServeFrontend``), under the open-loop simulation
driver (``frontend.sim``), and under the virtual-clock unit tests,
with identical behaviour.

Lifecycle of a request:

1. ``submit(req, now)`` — admission control.  The plane holds at most
   ``config.queue_limit`` requests across all tenants and query
   classes; past that a submit is **rejected** immediately (explicit
   backpressure — the caller sees the overload instead of an unbounded
   queue hiding it).  Admitted requests join their (kind, params)
   class queue under their tenant.
2. batch forming — a class closes a batch when it holds a full top
   rung of requests, or when its oldest request has waited
   ``config.max_delay``; ``next_due(now)`` tells the driver when to
   wake.  ``form_batch(now)`` pops requests by **deficit round robin**
   over tenants (at most ``config.quantum`` per tenant per rotation
   turn, rotation persists across batches), so one hot tenant cannot
   starve the rest.  Requests whose deadline already passed are
   **timed out** at pop time — returned separately, never executed.
   The batch is padded up to the smallest ladder rung that holds it
   (``config.ladder``), so executors reuse warm compiled steps.
3. execution and response delivery belong to the driver
   (``executor.execute_batch`` + the asyncio wrapper or simulator).

Query classes: requests only batch with requests of the same kind
*and* static params (``max_hits`` / ``(k, max_cand)``), because those
are compile-time constants of the batched server call.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from collections import deque
from typing import Any

from .config import FrontendConfig
from .metrics import FrontendMetrics

KINDS = ("range_counts", "range_ids", "knn")


class Outcome(enum.Enum):
    """Terminal state of one request."""
    OK = "ok"                # executed; ``Response.value`` holds the answer
    REJECTED = "rejected"    # admission control: queue full at submit
    TIMED_OUT = "timed_out"  # deadline expired while queued; not executed


@dataclasses.dataclass
class Request:
    """One single-query request (kind-specific payload + params).

    payload: (4,) f32 query box for range kinds, (2,) f32 point for
    knn.  params: () | (max_hits,) | (k, max_cand) — the static values
    a batch must share.  ``deadline`` is absolute (``inf`` = none).
    ``future`` is an opaque slot for the asyncio wrapper; the plane
    never touches it.
    """
    kind: str
    payload: Any
    params: tuple
    tenant: str = "default"
    arrival: float = 0.0
    deadline: float = float("inf")
    seq: int = -1
    future: Any = None
    formed: float = 0.0       # set when its batch closes


@dataclasses.dataclass
class Batch:
    """A closed batch: ``len(requests)`` real queries padded to
    ``width`` slots (a ladder rung) at execution time."""
    kind: str
    params: tuple
    requests: list
    width: int
    formed_at: float


@dataclasses.dataclass
class Response:
    """What a caller gets back for one request."""
    outcome: Outcome
    value: Any = None            # kind-specific answer when OK
    queue_s: float = 0.0         # arrival -> batch formed
    execute_s: float = 0.0       # batch formed -> results ready
    total_s: float = 0.0         # arrival -> response

    @property
    def ok(self) -> bool:
        return self.outcome is Outcome.OK


class _ClassQueue:
    """Pending requests of one (kind, params) class: FIFO per tenant
    plus the DRR rotation state."""

    def __init__(self):
        self.by_tenant: dict[str, deque] = {}
        self.rotation: deque = deque()       # tenant visit order (DRR)
        self.count = 0

    def push(self, req: Request) -> None:
        q = self.by_tenant.get(req.tenant)
        if q is None:
            q = self.by_tenant[req.tenant] = deque()
            self.rotation.append(req.tenant)
        q.append(req)
        self.count += 1

    def oldest_arrival(self) -> float:
        """Earliest arrival among per-tenant FIFO heads (== the
        earliest pending arrival, since each deque is FIFO)."""
        return min(q[0].arrival for q in self.by_tenant.values() if q)

    def take(self, n_max: int, quantum: int, now: float,
             expired: list) -> list:
        """Pop up to ``n_max`` live requests by deficit round robin:
        each rotation turn grants one tenant up to ``quantum``
        requests; already-expired requests are diverted to ``expired``
        and don't consume the grant.  The rotation deque persists
        across batches, so fairness holds stream-wide, not just within
        one batch."""
        take: list = []
        turns_left = len(self.rotation)
        while len(take) < n_max and self.count and turns_left:
            tenant = self.rotation[0]
            self.rotation.rotate(-1)
            q = self.by_tenant.get(tenant)
            granted = 0
            while q and granted < quantum and len(take) < n_max:
                req = q.popleft()
                self.count -= 1
                if req.deadline < now:
                    expired.append(req)
                else:
                    take.append(req)
                    granted += 1
            # a tenant that still has backlog stays in rotation and
            # will be revisited after everyone else had a turn
            turns_left = turns_left - 1 if granted < quantum or not q \
                else len(self.rotation)
        self.rotation = deque(t for t in self.rotation if self.by_tenant[t])
        for t in [t for t, q in self.by_tenant.items() if not q]:
            del self.by_tenant[t]
        return take


class RequestPlane:
    """The deterministic admission + batching state machine (see
    module docstring).  Not thread-safe by design: drive it from one
    thread/loop and hand closed batches to an executor."""

    def __init__(self, config: FrontendConfig | None = None,
                 metrics: FrontendMetrics | None = None):
        self.config = config or FrontendConfig()
        self.metrics = metrics or FrontendMetrics()
        self._classes: dict[tuple, _ClassQueue] = {}
        self._seq = itertools.count()

    # -- introspection ----------------------------------------------------

    @property
    def pending(self) -> int:
        return sum(c.count for c in self._classes.values())

    # -- admission --------------------------------------------------------

    def submit(self, req: Request, now: float) -> bool:
        """Admit ``req`` (True) or reject it under backpressure
        (False).  Fills ``arrival``/``seq``; applies the config's
        default deadline budget when the request carries none."""
        if req.kind not in KINDS:
            raise ValueError(f"unknown request kind {req.kind!r}; "
                             f"expected one of {KINDS}")
        req.arrival = now
        req.seq = next(self._seq)
        if req.deadline == float("inf") and \
                self.config.default_deadline is not None:
            req.deadline = now + self.config.default_deadline
        if self.pending >= self.config.queue_limit:
            self.metrics.on_submit(req.tenant, False, self.pending)
            return False
        key = (req.kind, tuple(req.params))
        cq = self._classes.get(key)
        if cq is None:
            cq = self._classes[key] = _ClassQueue()
        cq.push(req)
        self.metrics.on_submit(req.tenant, True, self.pending)
        return True

    # -- batch forming ----------------------------------------------------

    def _due(self, cq: _ClassQueue, now: float) -> bool:
        # the same expression next_due() reports, so a driver that
        # sleeps until next_due() always finds the batch formable
        # (now - oldest >= max_delay differs from this by 1 ulp)
        return cq.count >= self.config.max_batch or (
            cq.count > 0
            and cq.oldest_arrival() + self.config.max_delay <= now)

    def next_due(self, now: float) -> float | None:
        """Earliest instant a batch will be due (<= now when one is
        already formable; None when the plane is empty)."""
        t = None
        for cq in self._classes.values():
            if not cq.count:
                continue
            if cq.count >= self.config.max_batch:
                return now
            due = cq.oldest_arrival() + self.config.max_delay
            t = due if t is None else min(t, due)
        return t

    def form_batch(self, now: float, force: bool = False
                   ) -> tuple[Batch | None, list]:
        """Close and return the most overdue due batch, plus every
        request that timed out on the way into it.

        Returns ``(batch, expired)``; batch is None when nothing is
        due (``force=True`` closes the oldest non-empty class
        regardless — the drain path).  Expired requests have been
        counted in metrics; the caller owns responding to them.
        """
        due = [(key, cq) for key, cq in self._classes.items()
               if cq.count and (force or self._due(cq, now))]
        expired: list = []
        while due:
            due.sort(key=lambda kc: kc[1].oldest_arrival())
            key, cq = due[0]
            take = cq.take(self.config.max_batch, self.config.quantum,
                           now, expired)
            if not cq.count:
                del self._classes[key]
                due.pop(0)
            if take:
                for r in take:
                    r.formed = now
                for r in expired:
                    self.metrics.on_timeout(r.tenant)
                batch = Batch(kind=key[0], params=key[1], requests=take,
                              width=self.config.width_for(len(take)),
                              formed_at=now)
                self.metrics.on_batch(batch.width, len(take), self.pending)
                return batch, expired
            # every popped request of this class had expired: move on
            # to the next due class rather than returning empty-handed
            if cq.count:
                due[0] = (key, cq)
        for r in expired:
            self.metrics.on_timeout(r.tenant)
        return None, expired
