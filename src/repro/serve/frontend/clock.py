"""Clocks for the request plane.

Every time the plane reads comes through one of these, so the whole
request lifecycle — arrival, batch-forming deadlines, SLO budgets,
latency accounting — runs identically against wall time
(``MonotonicClock``, production/asyncio) or a manually-advanced
``VirtualClock`` (deterministic tests and the open-loop simulation
driver, where queueing math is exact and repeatable).
"""
from __future__ import annotations

import time


class MonotonicClock:
    """Wall time via ``time.monotonic`` (seconds, arbitrary epoch)."""

    def now(self) -> float:
        return time.monotonic()


class VirtualClock:
    """Deterministic manually-advanced clock.  Never moves on its own;
    ``advance`` / ``advance_to`` are the only mutators and time never
    goes backwards."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by {dt} (time is monotonic)")
        self._now += dt
        return self._now

    def advance_to(self, t: float) -> float:
        if t < self._now:
            raise ValueError(f"cannot rewind {self._now} -> {t}")
        self._now = float(t)
        return self._now
