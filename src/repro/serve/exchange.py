"""Owner-routed query exchange over sharded tile layouts.

The distributed serving step — the machinery behind
``serve.layout.ShardedTiles``, the sharded implementation of the
``TileLayout`` protocol (callers never build these steps directly; the
server reaches them through the protocol).  Tiles are placed on owner
devices (``core.placement.shard_tiles``, re-balanced on streaming
re-stages); queries are LPT-packed onto *home* devices exactly as in
the replicated path; and every batch runs as one SPMD step built from
three moves:

  scatter — each home gathers, per owner, the queries whose candidate
            lists touch that owner's tiles (``router.owner_split``
            translated them to local coordinates on the host) and
            ``all_to_all``s query payloads + local candidate lists to
            the owners,
  probe   — each owner runs the existing gathered ``range_probe``
            executors (``query.range`` / ``query.knn``) against its
            local shard only — O(local candidates · cap) work, with
            per-device memory O(total/D),
  reduce  — partial counts / id lists / top-k frontiers ``all_to_all``
            back to the homes, which merge deterministically
            (``merge_owner_counts`` / ``merge_owner_ids`` /
            ``merge_knn_partials``): canonical copies make hits
            owner-disjoint, so merged answers are bit-identical to the
            dense single-device oracle.

Under ``serve.layout.HeatSharded`` the same steps serve heat-aware
placement unchanged: replicated hot tiles occupy extra shard rows past
``t_local`` as bit-exact copies, and ``router.owner_split`` already
resolved each candidate to exactly *one* resident copy — whichever
owner saves a message or carries less probe load — so the tables this
module consumes still name each candidate once and the owner-disjoint
merge argument is untouched.  The steps are shape-polymorphic in the
shard row count and cache across re-plans (``rebalance`` moves owner
maps, never shard shapes).

kNN deepening is lock-step: the radius state lives at home, each round
exchanges deepening boxes out and partial unique-counts back, and the
loop's continue flag is a ``psum``-reduced global — every device runs
the same number of rounds, so collectives inside the loop can never
deadlock.  The frontier-miss check is unchanged from the replicated
path (the excluded distance is global, computed at routing time), so
the server's widen-and-retry ladder still guarantees exactness.

Every orchestration is written once against a tiny ``_Comm`` seam and
runs in two modes:

- **SPMD** (``mesh`` given): ``shard_map`` over the mesh axis with
  ``all_to_all`` exchanges (``core.compat`` shims) — the production
  path; per-device arrays, collective transposes.
- **in-process simulation** (``mesh=None``): the same math over full
  ``(D, ...)`` arrays, with ``jax.vmap`` standing in for "each device"
  and axis transposes standing in for ``all_to_all`` — the oracle for
  the exchange itself, testable on one device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import geometry
from ..core.compat import all_to_all, shard_map
from ..query import knn as knn_mod, range as range_mod

_SENTINEL = jnp.array(geometry.SENTINEL_BOX, jnp.float32)


class _Comm:
    """The sharded/simulated seam: apply-per-device, exchange, reduce.

    ``axis=None`` selects in-process simulation: per-device functions
    are ``vmap``-ped over a leading device axis and the device
    transpose is a plain ``swapaxes`` — bit-identical math, no mesh.
    """

    def __init__(self, axis: str | None):
        self.axis = axis

    def apply(self, f, *xs):
        """Run a per-device function (sim: vmap over the device axis)."""
        return f(*xs) if self.axis else jax.vmap(f)(*xs)

    def exchange(self, x):
        """Device transpose: row o of the result came from device o."""
        if self.axis is None:
            return jnp.swapaxes(x, 0, 1)
        return all_to_all(x, self.axis)

    def any(self, x) -> jax.Array:
        """Global any() — uniform across devices (psum under SPMD), so
        it can steer a lock-step loop containing collectives."""
        if self.axis is None:
            return jnp.any(x)
        return jax.lax.psum(jnp.any(x).astype(jnp.int32), self.axis) > 0


def _gather_send(x: jax.Array, slots: jax.Array, pad) -> jax.Array:
    """Home-side send buffer: (Qpd, ...) x (D, M) slots -> (D, M, ...),
    padding element where a message slot is -1."""
    out = x[jnp.maximum(slots, 0)]
    live = (slots >= 0).reshape(slots.shape + (1,) * (out.ndim - 2))
    return jnp.where(live, out, jnp.asarray(pad, out.dtype))


# --------------------------------------------------------------------------
# orchestrations (one definition, both modes)
# --------------------------------------------------------------------------

def serve_range_counts(comm: _Comm, q: jax.Array, sl: jax.Array,
                       sc: jax.Array, tiles: jax.Array, alive: jax.Array,
                       cboxes: jax.Array | None = None) -> jax.Array:
    """Sharded exact range counts: scatter -> local probe -> sum merge.

    Per-device view: q (Qpd, 4) home query shard, sl (D, M) message
    slots, sc (D, M, Fl) local candidate lists, tiles (Tl, cap, 4)
    owner shard, alive (Tl, cap) the owner shard's tombstone mask
    (dead member slots answer nothing), cboxes (Tl, C, 4) owner-local
    chunk boxes or None (selects the chunk-skipping probe — same bits)
    -> (Qpd,) int32.
    """
    d, m = sl.shape[-2], sl.shape[-1]
    fl = sc.shape[-1]
    qpd = q.shape[-2]
    qs = comm.apply(lambda qq, ss: _gather_send(qq, ss, _SENTINEL), q, sl)
    qr, cr = comm.exchange(qs), comm.exchange(sc)

    def owner_probe(t, al, cb, qrr, crr):
        return range_mod.pruned_range_counts(
            qrr.reshape(d * m, 4), t, crr.reshape(d * m, fl),
            chunk_boxes=cb, alive=al).reshape(d, m)

    pb = comm.exchange(comm.apply(owner_probe, tiles, alive, cboxes,
                                  qr, cr))
    return comm.apply(
        lambda p, s: range_mod.merge_owner_counts(p, s, qpd), pb, sl)


def serve_range_ids(comm: _Comm, q: jax.Array, sl: jax.Array, sc: jax.Array,
                    tiles: jax.Array, ids: jax.Array, alive: jax.Array,
                    cboxes: jax.Array | None = None, *, max_hits: int,
                    mh_local: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Sharded exact unique id sets: scatter -> local ids -> union merge.

    Same per-device view as ``serve_range_counts`` plus ids (Tl, cap);
    ``mh_local`` bounds each owner's partial list (callers pass
    ``min(max_hits, Fl·cap)`` — an owner can never hold more) ->
    ``(hit_ids[Qpd, max_hits], counts[Qpd], overflow[Qpd])``.
    """
    d, m = sl.shape[-2], sl.shape[-1]
    fl = sc.shape[-1]
    qpd = q.shape[-2]
    qs = comm.apply(lambda qq, ss: _gather_send(qq, ss, _SENTINEL), q, sl)
    qr, cr = comm.exchange(qs), comm.exchange(sc)

    def owner_ids(t, i, al, cb, qrr, crr):
        hids, counts, _ = range_mod.pruned_range_ids(
            qrr.reshape(d * m, 4), t, i, crr.reshape(d * m, fl),
            max_hits=mh_local, chunk_boxes=cb, alive=al)
        return hids.reshape(d, m, mh_local), counts.reshape(d, m)

    pids, pcounts = comm.apply(owner_ids, tiles, ids, alive, cboxes,
                               qr, cr)
    bids, bcounts = comm.exchange(pids), comm.exchange(pcounts)
    return comm.apply(
        lambda pi, pc, s: range_mod.merge_owner_ids(pi, pc, s, qpd, max_hits),
        bids, bcounts, sl)


def serve_knn(comm: _Comm, pts: jax.Array, sl: jax.Array, sc: jax.Array,
              dead: jax.Array, tiles: jax.Array, ids: jax.Array,
              alive: jax.Array, cboxes: jax.Array | None, uni: jax.Array,
              n_live: jax.Array,
              *, k: int, max_cand: int, max_rounds: int = 32
              ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                         jax.Array]:
    """Sharded exact kNN: lock-step deepening + top-k frontier merge.

    Per-device view: pts (Qpd, 2) home shard, sl/sc as in the range
    steps (kNN frontier candidates in owner-local coordinates), dead
    (Qpd,) marks padding slots, tiles/ids the owner shard, cboxes the
    owner's (Tl, C, 4) local index (or None — ``serve_knn_unindexed``
    is the oracle arg-order wrapper), uni the (replicated) dataset
    universe; ``n_live`` is the *global* live canonical member count
    (the dataset size) so the density-based initial radius matches the
    single-device paths — a replicated traced scalar, not a baked-in
    static, so streaming appends (which change ``n`` every batch) keep
    the compiled step warm -> ``(nn_ids[Qpd, k], nn_d2[Qpd, k],
    radius[Qpd], overflow[Qpd], rounds[Qpd])``.

    The radius state lives at home.  Each deepening round exchanges
    radii to owners, sums per-owner unique-candidate counts back, and
    doubles the radius of unconverged queries — identical count totals
    and identical radius trajectories to ``pruned_knn`` (``rounds``
    counts each home query's doublings).  ``overflow`` flags owner-side
    candidate extraction past ``max_cand``; the frontier-miss flag is
    the caller's (it holds the global excluded distance).
    """
    d, m = sl.shape[-2], sl.shape[-1]
    fl = sc.shape[-1]
    qpd = pts.shape[-2]
    pad_pt = (uni[:2] + uni[2:]) * 0.5
    ps = comm.apply(lambda p, s: _gather_send(p, s, pad_pt), pts, sl)
    pr, cr = comm.exchange(ps), comm.exchange(sc)

    diag = jnp.sqrt(jnp.sum((uni[2:] - uni[:2]) ** 2))
    r_init = knn_mod.initial_radius(diag, k, n_live)
    r_cover = jnp.maximum(
        jnp.maximum(pts[..., 0] - uni[0], uni[2] - pts[..., 0]),
        jnp.maximum(pts[..., 1] - uni[1], uni[3] - pts[..., 1]))
    r_cover = jnp.maximum(r_cover, diag * 1e-6)

    def owner_counts(t, al, cb, p, c, rad):
        qb = jnp.concatenate([p - rad[..., None], p + rad[..., None]], -1)
        return range_mod.pruned_range_counts(
            qb.reshape(d * m, 4), t, c.reshape(d * m, fl),
            chunk_boxes=cb, alive=al).reshape(d, m)

    def counts_at(r):
        rr = comm.exchange(comm.apply(
            lambda r_, s: _gather_send(r_, s, jnp.float32(0.0)), r, sl))
        pb = comm.exchange(comm.apply(owner_counts, tiles, alive, cboxes,
                                      pr, cr, rr))
        return comm.apply(
            lambda p, s: range_mod.merge_owner_counts(p, s, qpd), pb, sl)

    r0 = jnp.where(dead, r_cover, jnp.full(pts.shape[:-1], r_init,
                                           jnp.float32))
    c0 = counts_at(r0)
    rounds0 = jnp.zeros(pts.shape[:-1], jnp.int32)

    def cont(r, c):
        return comm.any((c < k) & (r < r_cover))

    def body(state):
        r, c, rounds, i, _ = state
        grow = (c < k) & (r < r_cover)
        r = jnp.where(c < k, jnp.minimum(r * 2.0, r_cover), r)
        c = counts_at(r)
        return r, c, rounds + grow.astype(jnp.int32), i + 1, cont(r, c)

    r, counts, rounds, _, _ = jax.lax.while_loop(
        lambda s: s[4] & (s[3] < max_rounds), body,
        (r0, c0, rounds0, jnp.int32(0), cont(r0, c0)))

    # refinement: owners extract local top-k within the √2-inflated box
    re = r * jnp.sqrt(jnp.float32(2.0))
    rr = comm.exchange(comm.apply(
        lambda r_, s: _gather_send(r_, s, jnp.float32(0.0)), re, sl))

    def owner_refine(t, i, al, cb, p, c, rad):
        nn_i, nn_d, nc = knn_mod.knn_partial(
            p.reshape(d * m, 2), t, i, c.reshape(d * m, fl),
            rad.reshape(d * m), k=k, max_cand=max_cand, chunk_boxes=cb,
            alive=al)
        return (nn_i.reshape(d, m, k), nn_d.reshape(d, m, k),
                nc.reshape(d, m))

    pid, pd2, pnc = comm.apply(owner_refine, tiles, ids, alive, cboxes,
                               pr, cr, rr)
    bid, bd2, bnc = (comm.exchange(pid), comm.exchange(pd2),
                     comm.exchange(pnc))
    nn_ids, nn_d2 = comm.apply(
        lambda a, b, s: knn_mod.merge_knn_partials(a, b, s, qpd, k),
        bid, bd2, sl)
    over = comm.apply(
        lambda nc, s: range_mod.merge_owner_counts(
            (nc > max_cand).astype(jnp.int32), s, qpd) > 0, bnc, sl)
    return nn_ids, nn_d2, r, over, rounds


def serve_knn_unindexed(comm: _Comm, pts: jax.Array, sl: jax.Array,
                        sc: jax.Array, dead: jax.Array, tiles: jax.Array,
                        ids: jax.Array, alive: jax.Array, uni: jax.Array,
                        n_live: jax.Array, **static):
    """``serve_knn`` without the local-index chunk shards — the oracle
    arg order (no ``cboxes`` slot), so the ``local_index="off"`` server
    can build the step with one fewer sharded input."""
    return serve_knn(comm, pts, sl, sc, dead, tiles, ids, alive, None,
                     uni, n_live, **static)


# --------------------------------------------------------------------------
# step builders (jitted executors for the server)
# --------------------------------------------------------------------------

def build_step(orch, mesh, axis: str, n_sharded: int, n_replicated: int = 0,
               **static):
    """Jit an orchestration for a mesh (SPMD) or for in-process sim.

    With a mesh: ``shard_map`` over ``axis``; the first ``n_sharded``
    arguments are device-sharded on their leading axis (the per-device
    block's unit leading dim is stripped before the orchestration runs
    and restored on the way out), the trailing ``n_replicated`` are
    replicated (``P()``).  Without a mesh the same orchestration runs
    in simulation over the full arrays.  ``static`` kwargs (k,
    max_hits, ...) are baked into the jitted callable — the server
    caches one step per shape/static bucket.
    """
    if mesh is None:
        return jax.jit(functools.partial(orch, _Comm(None), **static))
    specs = (P(axis),) * n_sharded + (P(),) * n_replicated

    def spmd(*args):
        local = tuple(a[0] for a in args[:n_sharded]) + args[n_sharded:]
        out = orch(_Comm(axis), *local, **static)
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(shard_map(spmd, mesh=mesh, in_specs=specs,
                             out_specs=P(axis), check_vma=False))
