"""The batched spatial query server (stage once, serve a moving dataset).

LocationSpark's architecture in SPMD form: a dataset is staged under
any of the six layouts — MASJ assignment into padded ``(T, cap, 4)``
member tiles plus a canonical-copy mark so selection queries dedup for
free (see ``query.range``) — then streams of query batches are
answered by a jitted step:

  route   — the global index maps the batch to partitions, yielding the
            per-query fan-out metric *and* a fixed-width ``(Q, F)``
            candidate-tile index over the layout's canonical probe
            boxes (``router.candidate_range`` / ``candidate_knn``),
  pack    — queries are LPT-packed onto devices with routed fan-out as
            the cost (the join engine's straggler story, applied to the
            query side: a batch of hotspot queries must not serialise
            on one device),
  probe   — the ``TileLayout`` executes the batch against its
            placement: candidate tiles only via the gathered
            ``range_probe`` Pallas kernel, with the intra-tile local
            index predicating dead chunks away,
  gather  — results come back query-sharded and are unpermuted.

How the server serves is one frozen value, ``ServeConfig``
(``serve.config``): data placement (``replicated`` | ``sharded``),
default probe (``pruned`` | the ``dense`` all-tile oracle), local-index
mode (``off`` | ``x`` | ``hilbert``), chunk granularity, and the
capacity/slack policy.  The server itself is written once against the
``TileLayout`` protocol (``serve.layout``) — there is no placement
branch anywhere in the query paths; ``ReplicatedTiles`` and
``ShardedTiles`` implement the same contract (the latter through the
owner-routed ``all_to_all`` exchange, ``serve.exchange``).

The dataset *moves*: ``append(mbrs)`` streams new objects into the
slack slots staging reserved (``config.slack``), scattering only the
touched ``(tile, slot)`` cells to device — append cost tracks the
batch, not the layout; a tile overflow re-stages the layout at a grown
capacity (re-balancing owners under sharding) and resets the
``WidthPolicy``.  ``delete(ids)`` tombstones objects by flipping their
slots' alive bits (``update`` moves them), and the ``ServeConfig``
compaction policy reclaims dead slots — tile-locally past
``compact_dead_frac``, by full re-stage past ``restage_dead_frac``.
Answers after any ingest sequence are bit-identical to re-staging the
live set from scratch — and to the dense oracle — because every answer
is a function of the live canonical membership sets alone.

Exactness of the pruned path is never assumed: range candidate lists
are sized from the batch's true max fan-out, and kNN flags any query
whose refinement radius reaches a tile outside its frontier, which the
server retries with a doubled frontier until exact (worst case the
frontier is every tile — the dense sweep).  Converged candidate widths
are remembered per query kind (``WidthPolicy``), so steady query
streams pay recompiles and kNN widening ladders once.

Single-process use passes ``mesh=None`` and gets the same jitted maths
without the collective plumbing (sharded placement then runs the
exchange in vmap simulation — same answers, one device).

For serving streams of *single* requests (an online workload rather
than pre-formed batches), ``serve.frontend`` puts an async request
plane in front of this server: admission control, per-tenant fairness,
and deadline-or-full batch forming onto a fixed compiled-shape ladder.
"""
from __future__ import annotations

import logging

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core.partition import api
from ..core.partition.assign import round_up
from ..kernels.range_probe import ops as rops
from ..query import knn as knn_mod
from . import router
from .config import ServeConfig
from .layout import (  # noqa: F401  (re-exports: the staging surface)
    HeatSharded,
    ReplicatedTiles,
    ShardedLayout,
    ShardedTiles,
    StagedLayout,
    TileLayout,
    build_tiles,
    pack_queries,
    shard_staged,
    stage_tiles,
)

log = logging.getLogger(__name__)


def _f_width(fanout_max: int, t: int) -> int:
    """Candidate-list width: max batch fan-out rounded up to 8 (bounds
    jit recompiles to one per width bucket), capped at the tile count."""
    return min(max(t, 1), round_up(max(fanout_max, 1), 8))


class WidthPolicy:
    """Adaptive candidate-width cache (ROADMAP: adaptive ``f_max``).

    One policy per server, hence per (layout, dataset); keys are query
    kinds (``"range"`` or ``("knn", k, max_cand)``).  Widths only move
    up (``observe`` keeps the max — wider is always exact), and two
    lookup flavours serve the two consumers:

    - ``at_least(key, floor)`` — range batches: the answer must cover
      this batch's true fan-out, so return ``max(cached, floor)``; a
      narrow batch after a wide one reuses the already-compiled wider
      step instead of recompiling.
    - ``start(key, default)`` — kNN batches: any width is *correct*
      (the frontier-miss check widens until exact), so start straight
      from the converged width of earlier batches and skip their
      widening ladder; fall back to the density ``default`` cold.

    Cached widths are clamped to ``cap`` (the server passes its
    ``t_live`` — no candidate list can usefully exceed the live tile
    count), so one pathological batch can never inflate later batches'
    gather width and memory past the layout itself; ``reset()`` drops
    the cache entirely when a stream's width profile changes — the
    server hooks it on every streaming re-stage, where the layout the
    widths converged against no longer exists.

    ``hits``/``misses`` count cache effectiveness; ``seed`` force-sets
    a width unclamped (tests use it to exercise the widen-and-retry
    path).
    """

    def __init__(self, cap: int | None = None):
        self.cap = cap
        self._w: dict = {}
        self.hits = 0
        self.misses = 0

    def _clamp(self, w: int) -> int:
        return w if self.cap is None else min(w, self.cap)

    def at_least(self, key, floor: int) -> int:
        w = self._w.get(key)
        if w is not None and w >= floor:
            self.hits += 1
            return w
        self.misses += 1
        return floor

    def start(self, key, default: int) -> int:
        w = self._w.get(key)
        if w is not None:
            self.hits += 1
            return w
        self.misses += 1
        return default

    def observe(self, key, width: int) -> None:
        self._w[key] = self._clamp(max(self._w.get(key, 0), width))

    def reset(self) -> None:
        """Forget every cached width (the next batch of each kind pays
        one recompile / widening ladder again, at its natural width)."""
        self._w.clear()

    def seed(self, key, width: int) -> None:
        self._w[key] = width


class SpatialServer:
    """Stage once, then serve batched range / kNN queries — and keep
    serving as the dataset grows.

    ``config`` (a frozen ``ServeConfig``) picks the placement
    (``replicated`` | ``sharded``), the default probe (``pruned``
    routed candidates | the ``dense`` all-tile oracle — also a per-call
    ``pruned=`` override), the intra-tile local index (``off`` | ``x``
    | ``hilbert``), chunk granularity, and the capacity/slack policy
    for streaming ``append``.  ``mesh=None`` serves in-process; with a
    mesh every batch runs as an SPMD step over ``mesh[config.axis]``.

    The server is placement-agnostic: it routes, packs, and enforces
    exactness (the kNN widen-and-retry ladder), delegating execution to
    its ``TileLayout`` (``self.tiles``).  Answers are bit-identical
    across placements, probe modes, and local-index modes on all six
    layouts (tested), including after any sequence of ``append`` calls.
    """

    def __init__(self, parts: api.Partitioning, mbrs: jax.Array,
                 config: ServeConfig | None = None, *,
                 mesh: Mesh | None = None, method: str | None = None):
        self.config = config = config if config is not None else ServeConfig()
        self.parts = parts
        self.mesh = mesh
        self.tiles: TileLayout = build_tiles(parts, mbrs, config, mesh)
        self.stats = self.tiles.stats      # one dict, shared — appends
        self.stats["method"] = method      # mutate it in place
        self.widths = WidthPolicy(cap=self.stats["t_live"])
        # query-heat signals for heat-aware placement: every routed
        # batch's candidate lists fold in (O(Q·F) numpy, no device
        # work); ``rebalance()`` turns them into a placement plan
        self.heat = router.HeatTracker(self.stats["t"],
                                       decay=config.policy.heat_decay)
        self._batches_since_rebalance = 0

    @classmethod
    def from_method(cls, method: str, mbrs: jax.Array, payload: int,
                    config: ServeConfig | None = None, *,
                    mesh: Mesh | None = None) -> "SpatialServer":
        """Partition ``mbrs`` with ``method`` at ``payload`` and serve.

        Everything after ``payload`` — ``config`` included — reaches
        the constructor verbatim, so staging knobs like
        ``ServeConfig.capacity`` are honoured here exactly as on the
        direct path.
        """
        parts = api.partition(method, mbrs, payload)
        return cls(parts, mbrs, config, mesh=mesh, method=method)

    # -- shared accessors -------------------------------------------------

    @property
    def probe_boxes(self) -> jax.Array:
        return self.tiles.probe_boxes

    @property
    def uni(self) -> jax.Array:
        return self.tiles.uni

    @property
    def chunk_boxes(self) -> jax.Array | None:
        """The (T, C, 4) global local index (None when unindexed)."""
        return self.tiles.chunk_boxes

    @property
    def layout(self) -> StagedLayout | None:
        """The replicated staging (None under ``placement='sharded'``)."""
        return getattr(self.tiles, "staged", None)

    @property
    def slayout(self) -> ShardedLayout | None:
        """The sharded staging (None under ``placement='replicated'``)."""
        return getattr(self.tiles, "slayout", None)

    @property
    def shards(self) -> int:
        return self.tiles.shards

    @property
    def n_devices(self) -> int:
        return self.tiles.n_devices

    @property
    def _oracle_np(self):
        return self.tiles.oracle_np

    def chunk_skip_rate(self, qboxes: jax.Array) -> float:
        """Measured local-index effectiveness for one batch: the
        fraction of per-candidate 128-member chunks whose box the query
        misses (work the ``*_skip`` kernels drop).  0.0 when staged
        with ``local_index="off"``.  Pure measurement — does not touch
        the width cache."""
        if self.chunk_boxes is None:
            return 0.0
        hit = router.probe_overlap(self.probe_boxes, qboxes)
        # reprolint: disable=host-sync -- routing is host-side by design:
        # one fold of the overlap matrix feeds the width ratchet + packing
        pf = np.asarray(jnp.sum(hit, axis=1, dtype=jnp.int32))
        f = _f_width(int(pf.max(initial=0)), self.stats["t_live"])
        cand, _, _ = router.candidates_from_overlap(hit, f)
        return float(rops.chunk_skip_rate(qboxes, self.chunk_boxes, cand))

    def resident_tile_bytes(self) -> int:
        """Per-device bytes of device-resident staged member data —
        the O(N) (replicated) vs O(N/D) (sharded) axis the benchmarks
        report."""
        return self.tiles.resident_tile_bytes()

    # -- streaming --------------------------------------------------------

    def append(self, mbrs) -> dict:
        """Stream new objects into the served layout.

        mbrs: (M, 4) f32 MBRs; ids continue the running numbering.
        Inserts into each tile's reserved slack (probe/chunk boxes
        refresh incrementally, compiled steps stay warm); a tile
        overflow re-stages the layout at a grown capacity — owners
        re-balance under sharding — and resets the width cache, whose
        converged widths described the old staging.  Returns the append
        report (``appended``, ``restaged``, ``n``, ``cap``,
        ``free_slots_min``).  Answers after any append sequence are
        bit-identical to a from-scratch staging of the full dataset.
        """
        report = self.tiles.append(mbrs)
        self.widths.cap = self.stats["t_live"]
        if report["restaged"]:
            self.widths.reset()
        return report

    def delete(self, ids) -> dict:
        """Tombstone objects by id: their slots' alive bits flip off (a
        few-byte scatter — member boxes stay put as routing supersets)
        and every query path stops counting them.  Unknown, repeated,
        or already-deleted ids raise ``ValueError`` naming them.  May
        trigger the config's compaction policy (``compact_dead_frac`` /
        ``restage_dead_frac``); the report carries ``deleted``, ``n``,
        ``dead_frac``, ``compacted_tiles``, ``restaged``.
        """
        return self._after_maintenance(self.tiles.delete(ids))

    def update(self, ids, mbrs) -> dict:
        """Move objects: tombstone each id's current canonical slot and
        re-insert its new MBR under the same id (delete + append in one
        scatter).  A tile overflow re-stages like ``append``; otherwise
        the compaction policy applies as in ``delete``.
        """
        return self._after_maintenance(self.tiles.update(ids, mbrs))

    def compact(self) -> dict:
        """Force tile-local compaction of every tile holding dead
        slots, regardless of the config thresholds (re-sorts survivors,
        tightens probe/chunk boxes, zeroes the dead counts)."""
        return self._after_maintenance(self.tiles.compact())

    def _after_maintenance(self, report: dict) -> dict:
        """Shared post-ingest bookkeeping: live-tile count may move
        (compaction empties tiles, re-stage rebuilds them), and a
        re-stage invalidates the width cache's converged widths."""
        self.widths.cap = self.stats["t_live"]
        if report.get("restaged"):
            self.widths.reset()
        return report

    # -- heat-aware placement ---------------------------------------------

    def rebalance(self) -> dict:
        """Apply a heat-aware placement plan under traffic.

        Snapshots the heat tracker and hands it to the layout: owners
        re-plan co-locating co-occurring tiles (move-minimised from the
        current plan) and, under ``placement="heat"``, the hottest
        ``config.policy.replicate_top`` tiles refresh their replicas.
        Tile contents never move logically — answers are bit-identical
        before and after — only the owner maps and shard scatter
        change.  No-op report under ``placement="replicated"``.
        """
        heat, cooc = self.heat.snapshot()
        report = self.tiles.rebalance(heat, cooc)
        self._batches_since_rebalance = 0
        return report

    def _observe(self, cand) -> None:
        """Fold one routed batch into the heat tracker; auto-rebalance
        every ``config.policy.rebalance_every`` observed batches."""
        self.heat.observe(np.asarray(cand))
        self._batches_since_rebalance += 1
        every = self.config.policy.rebalance_every
        if every is not None and self._batches_since_rebalance >= every:
            self.rebalance()

    # -- routing helpers (host side, per batch) ---------------------------

    def _use_pruned(self, pruned: bool | None) -> bool:
        return (self.config.probe == "pruned") if pruned is None else pruned

    def _route_batch(self, qboxes: jax.Array):
        """Candidate-tile index for one range batch.  ``f_max`` covers
        the batch's true max probe fan-out — never truncating — and is
        ratcheted through the width cache so narrower follow-up batches
        reuse the compiled step.  Returns ``(cand[Q, F], costs[Q], F)``.
        """
        hit = router.probe_overlap(self.probe_boxes, qboxes)
        # reprolint: disable=host-sync -- routing is host-side by design:
        # one fold of the overlap matrix feeds the width ratchet + packing
        pf = np.asarray(jnp.sum(hit, axis=1, dtype=jnp.int32))
        floor = _f_width(int(pf.max(initial=0)), self.stats["t_live"])
        f = self.widths.at_least("range", floor)
        cand, _, _ = router.candidates_from_overlap(hit, f)
        self.widths.observe("range", f)
        self._observe(cand)
        return cand, pf.astype(np.float64), f

    def _fanout_stats(self, qboxes: jax.Array) -> dict:
        """The paper's reported metric: region fan-out from the global
        index (independent of the executor's probe-box routing)."""
        _, fanout = router.route_range(self.parts, qboxes)
        fanout_np = np.asarray(fanout)
        return dict(fanout_mean=float(fanout_np.mean()),
                    fanout_max=int(fanout_np.max()))

    # -- queries ----------------------------------------------------------

    def range_counts(self, qboxes: jax.Array, pruned: bool | None = None):
        """Exact unique hit counts -> ``((Q,) int32, stats)``.

        stats carry the region fan-out metric, the packing skew, and
        ``mode``/``f_max`` describing the executor that ran.
        """
        stats = self._fanout_stats(qboxes)
        if self._use_pruned(pruned):
            cand, costs, f = self._route_batch(qboxes)
            counts, xstats = self.tiles.range_counts(qboxes, cand, costs)
            stats.update(mode=self.tiles.mode, f_max=f, **xstats)
        else:
            counts, xstats = self.tiles.dense_range_counts(qboxes)
            stats.update(mode="dense", **xstats)
        return counts, stats

    def range_ids(self, qboxes: jax.Array, max_hits: int = 1024,
                  pruned: bool | None = None):
        """Exact unique hit-id sets (ascending, -1 padded) + overflow
        -> ``(hit_ids[Q, max_hits], counts[Q], overflow[Q], stats)``."""
        stats = self._fanout_stats(qboxes)
        if self._use_pruned(pruned):
            cand, costs, f = self._route_batch(qboxes)
            hit_ids, counts, overflow, xstats = self.tiles.range_ids(
                qboxes, cand, costs, max_hits)
            stats.update(mode=self.tiles.mode, f_max=f, **xstats)
        else:
            hit_ids, counts, overflow, xstats = self.tiles.dense_range_ids(
                qboxes, max_hits)
            stats.update(mode="dense", **xstats)
        return hit_ids, counts, overflow, stats

    def knn(self, pts: jax.Array, k: int, max_cand: int = 1024,
            pruned: bool | None = None):
        """Exact batched kNN -> ``(nn_ids[Q, k], nn_d2[Q, k],
        overflow[Q], stats)``; reported fan-out = MINDIST partitions a
        best-first search would visit given the answered kth distance.

        The pruned executor starts from a density-sized MINDIST
        frontier (or the width cache's converged start) and doubles it
        for any batch whose refinement radius reached an excluded tile
        — logged and counted in ``stats['retries']`` — so returned
        answers match the dense oracle exactly.
        """
        if self._use_pruned(pruned):
            nn_ids, nn_d2, overflow, mode_stats = self._knn_retry_loop(
                pts, k, max_cand)
            mode_stats = dict(mode=self.tiles.mode, **mode_stats)
        else:
            nn_ids, nn_d2, overflow, xstats = self.tiles.dense_knn(
                pts, k, max_cand)
            mode_stats = dict(mode="dense", **xstats)
        fanout = knn_mod.knn_fanout(jnp.asarray(pts),
                                    jnp.asarray(nn_d2[:, -1]),
                                    self.parts.boxes, self.parts.valid)
        fanout_np = np.asarray(fanout)
        stats = dict(fanout_mean=float(fanout_np.mean()),
                     fanout_max=int(fanout_np.max()), **mode_stats)
        return nn_ids, nn_d2, overflow, stats

    def _knn_retry_loop(self, pts: jax.Array, k: int, max_cand: int):
        """The exactness-critical widen-and-retry ladder, written once
        against the protocol.

        ``tiles.knn_attempt(pts, k, max_cand, f)`` answers the batch
        with frontier width ``f``.  Any query whose √2-inflated
        refinement radius reaches its nearest excluded tile may have
        missed a true neighbour, so the frontier doubles (logged) until
        no query can miss or the frontier holds every live tile.
        Converged widths feed the width cache so a steady stream pays
        the ladder once.
        """
        t_live, n = self.stats["t_live"], self.stats["n"]
        wkey = ("knn", k, max_cand)
        f = self.widths.start(
            wkey, _f_width(4 * k * t_live // max(n, 1) + 3, t_live))
        retries = 0
        while True:
            nn_ids, nn_d2, radius, overflow, excl, xstats = \
                self.tiles.knn_attempt(pts, k, max_cand, f)
            miss = np.asarray(excl) <= np.asarray(radius) * np.sqrt(2.0)
            if not miss.any() or f >= t_live:
                break
            new_f = _f_width(2 * f, t_live)
            log.info("kNN frontier miss on %d/%d queries: widening "
                     "f_max %d -> %d (retry %d)",
                     int(miss.sum()), pts.shape[0], f, new_f, retries + 1)
            f = new_f
            retries += 1
        self.widths.observe(wkey, f)
        # heat sees the *converged* frontier — the tiles this batch
        # actually probed at its final width
        cand, _, _ = router.candidate_knn(self.probe_boxes, pts, f)
        self._observe(cand)
        overflow = np.asarray(overflow) | miss
        return (jnp.asarray(nn_ids), jnp.asarray(nn_d2),
                jnp.asarray(overflow),
                dict(f_max=f, retries=retries, **xstats))
