"""The batched spatial query server (stage once, serve forever).

LocationSpark's architecture in SPMD form: a dataset is staged **once**
under any of the six layouts — MASJ assignment into padded
``(T, cap, 4)`` member tiles (reusing ``assign.assign_padded``) plus a
canonical-copy mark so selection queries dedup for free (see
``query.range``) — then streams of query batches are answered by a
jitted ``shard_map`` step:

  route   — the global index maps the batch to partitions and yields
            per-query fan-out (the layout-quality metric reported with
            every answer),
  pack    — queries are LPT-packed onto devices with fan-out as the
            cost (the join engine's straggler story, applied to the
            query side: a batch of hotspot queries must not serialise
            on one device),
  probe   — each device sweeps its query shard over the replicated
            tile set with the ``range_probe`` Pallas kernel (dense
            local probe; per-partition local indexes are a later PR),
  gather  — results come back query-sharded and are unpermuted.

Single-process use passes ``mesh=None`` and gets the same jitted maths
without the collective plumbing.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import geometry
from ..core.compat import shard_map
from ..core.partition import api, assign
from ..core.partition.assign import round_up
from ..query import balance, knn as knn_mod, range as range_mod
from . import router

_SENTINEL = np.array(geometry.SENTINEL_BOX, np.float32)


@partial(jax.tree_util.register_dataclass,
         data_fields=("tiles", "ids", "canon_tiles", "tile_boxes", "uni"),
         meta_fields=())
@dataclasses.dataclass(frozen=True)
class StagedLayout:
    """Device-resident staging of one partitioned dataset.

    tiles       : (T, cap, 4) member MBRs, sentinel-padded (all copies)
    ids         : (T, cap) int32 member ids, -1 in padding slots
    canon_tiles : (T, cap, 4) canonical copies only (others sentineled)
    tile_boxes  : (T, 4) partition regions (sentinel for invalid rows)
    uni         : (4,) dataset universe
    """

    tiles: jax.Array
    ids: jax.Array
    canon_tiles: jax.Array
    tile_boxes: jax.Array
    uni: jax.Array


def stage(parts: api.Partitioning, mbrs: jax.Array,
          capacity: int | None = None) -> tuple[StagedLayout, dict]:
    """MASJ-stage ``mbrs`` under ``parts``; 128-aligned, overflow-checked."""
    n = mbrs.shape[0]
    counts, copies = assign.partition_counts(mbrs, parts)
    if capacity is None:
        capacity = round_up(max(int(jnp.max(counts)), 1), 128)
    members, mask, overflow = assign.assign_padded(mbrs, parts, capacity)
    if int(jnp.sum(overflow)) > 0:
        raise ValueError(f"staging overflow: capacity {capacity} too small")

    sentinel = jnp.asarray(_SENTINEL)
    tiles = jnp.where(mask[..., None], mbrs[members], sentinel)
    ids = jnp.where(mask, members, -1).astype(jnp.int32)

    # canonical mark: first copy of each id in tile-major order wins,
    # so every object has exactly one canonical slot
    flat = ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    s = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    canon = jnp.zeros_like(flat, bool).at[order].set(first & (s >= 0))
    canon = canon.reshape(ids.shape)
    canon_tiles = jnp.where(canon[..., None], tiles, sentinel)

    tile_boxes = jnp.where(parts.valid[:, None], parts.boxes, sentinel)
    layout = StagedLayout(tiles=tiles, ids=ids, canon_tiles=canon_tiles,
                          tile_boxes=tile_boxes,
                          uni=geometry.universe(mbrs))
    stats = dict(
        n=n, t=int(parts.k()), cap=capacity,
        replication=float(jnp.sum(counts)) / n - 1.0,
    )
    return layout, stats


# --------------------------------------------------------------------------
# query packing (host): fan-out-weighted LPT onto devices
# --------------------------------------------------------------------------

def pack_queries(costs: np.ndarray, n_devices: int
                 ) -> tuple[np.ndarray, dict]:
    """LPT-pack queries onto devices by per-query cost.

    Returns ``(slots[D, Qpd] int32 query indices, stats)``; -1 slots are
    padding.  Qpd is the max per-device group size, so one straggler
    hotspot group bounds the step — exactly what LPT minimises.
    """
    d = max(1, n_devices)
    dev, makespan, mean_load = balance.lpt_pack(
        costs.astype(np.float64), d)
    groups = [np.flatnonzero(dev == i) for i in range(d)]
    qpd = max(1, max(len(g) for g in groups))
    slots = np.full((d, qpd), -1, np.int32)
    for i, g in enumerate(groups):
        slots[i, :len(g)] = g
    stats = dict(makespan=makespan, mean_load=mean_load,
                 skew=makespan / max(mean_load, 1e-9), qpd=qpd)
    return slots, stats


class SpatialServer:
    """Stage once, then serve batched range / kNN queries.

    ``mesh=None`` serves in-process; with a mesh, every batch runs as a
    query-sharded SPMD step over ``mesh[axis]`` with the staged layout
    replicated (it was built once; queries are the streaming side).
    """

    def __init__(self, parts: api.Partitioning, mbrs: jax.Array,
                 mesh: Mesh | None = None, axis: str = "d",
                 capacity: int | None = None, method: str | None = None):
        self.parts = parts
        self.layout, self.stats = stage(parts, mbrs, capacity)
        self.stats["method"] = method
        self.mesh, self.axis = mesh, axis
        self.n_devices = int(mesh.shape[axis]) if mesh is not None else 1
        self._steps: dict = {}

    @classmethod
    def from_method(cls, method: str, mbrs: jax.Array, payload: int,
                    mesh: Mesh | None = None, axis: str = "d",
                    **kw) -> "SpatialServer":
        parts = api.partition(method, mbrs, payload)
        return cls(parts, mbrs, mesh=mesh, axis=axis, method=method, **kw)

    # -- SPMD plumbing ----------------------------------------------------

    def _sharded_call(self, name: str, fn, queries: jax.Array,
                      costs: np.ndarray, pad_query: np.ndarray):
        """Run ``fn(local_queries) -> pytree`` query-sharded over the mesh."""
        if self.mesh is None:
            return fn(queries), dict(skew=1.0)
        slots, pstats = pack_queries(costs, self.n_devices)
        q_np = np.asarray(queries)
        packed = np.broadcast_to(
            pad_query, (slots.shape[0], slots.shape[1]) + pad_query.shape
        ).copy()
        live = slots >= 0
        packed[live] = q_np[slots[live]]

        step = self._steps.get(name)
        if step is None:
            spec = P(self.axis)

            def spmd(qs):
                return fn(qs[0])

            step = jax.jit(shard_map(
                spmd, mesh=self.mesh, in_specs=(spec,), out_specs=spec,
                check_vma=False))
            self._steps[name] = step

        sharding = NamedSharding(self.mesh, P(self.axis))
        out = step(jax.device_put(jnp.asarray(packed), sharding))

        def unpack(x):
            x = np.asarray(x).reshape((slots.size,) + x.shape[1:])
            res = np.zeros((len(q_np),) + x.shape[1:], x.dtype)
            res[slots[live]] = x[live.ravel()]
            return res

        return jax.tree.map(unpack, out), pstats

    # -- queries ----------------------------------------------------------

    def range_counts(self, qboxes: jax.Array):
        """Exact unique hit counts; stats carry the fan-out metric."""
        _, fanout = router.route_range(self.parts, qboxes)
        fanout_np = np.asarray(fanout)
        layout = self.layout
        # dense probe: per-query cost is uniform, so LPT packs by count;
        # fan-out becomes the cost weight once the local probe is pruned
        counts, pstats = self._sharded_call(
            "range_counts",
            lambda qs: range_mod.range_counts(qs, layout.canon_tiles),
            qboxes, np.ones(qboxes.shape[0], np.float64), _SENTINEL)
        stats = dict(fanout_mean=float(fanout_np.mean()),
                     fanout_max=int(fanout_np.max()), **pstats)
        return counts, stats

    def range_ids(self, qboxes: jax.Array, max_hits: int = 1024):
        """Exact unique hit-id sets (ascending, -1 padded) + overflow."""
        _, fanout = router.route_range(self.parts, qboxes)
        fanout_np = np.asarray(fanout)
        layout = self.layout
        (hit_ids, counts, overflow), pstats = self._sharded_call(
            f"range_ids_{max_hits}",
            lambda qs: range_mod.range_ids(qs, layout.canon_tiles,
                                           layout.ids, max_hits),
            qboxes, np.ones(qboxes.shape[0], np.float64), _SENTINEL)
        stats = dict(fanout_mean=float(fanout_np.mean()),
                     fanout_max=int(fanout_np.max()), **pstats)
        return hit_ids, counts, overflow, stats

    def knn(self, pts: jax.Array, k: int, max_cand: int = 1024):
        """Exact batched kNN; fan-out = MINDIST partitions a best-first
        search would visit given the answered kth distance."""
        layout = self.layout
        pad_pt = np.asarray((layout.uni[:2] + layout.uni[2:]) * 0.5)
        (nn_ids, nn_d2, radius, overflow), pstats = self._sharded_call(
            f"knn_{k}_{max_cand}",
            lambda qs: knn_mod.batched_knn(qs, k, layout.canon_tiles,
                                           layout.ids, layout.uni,
                                           max_cand=max_cand),
            pts, np.ones(pts.shape[0], np.float64), pad_pt)
        fanout = knn_mod.knn_fanout(jnp.asarray(pts),
                                    jnp.asarray(nn_d2[:, -1]),
                                    self.parts.boxes, self.parts.valid)
        stats = dict(fanout_mean=float(jnp.mean(fanout)),
                     fanout_max=int(jnp.max(fanout)), **pstats)
        return nn_ids, nn_d2, overflow, stats
