"""The batched spatial query server (stage once, serve forever).

LocationSpark's architecture in SPMD form: a dataset is staged **once**
under any of the six layouts — MASJ assignment into padded
``(T, cap, 4)`` member tiles (reusing ``assign.assign_padded``) plus a
canonical-copy mark so selection queries dedup for free (see
``query.range``) — then streams of query batches are answered by a
jitted ``shard_map`` step:

  route   — the global index maps the batch to partitions, yielding the
            per-query fan-out metric *and* a fixed-width ``(Q, F)``
            candidate-tile index over the layout's canonical probe
            boxes (``router.candidate_range`` / ``candidate_knn``),
  pack    — queries are LPT-packed onto devices with routed fan-out as
            the cost (the join engine's straggler story, applied to the
            query side: a batch of hotspot queries must not serialise
            on one device),
  probe   — each device probes its query shard's candidate tiles only,
            via the gathered ``range_probe`` Pallas kernel — O(Q·F·cap)
            work, and inside each candidate tile the **local index**
            (``local_index=True``: x-sorted members + per-128-slot
            chunk boxes) lets the chunk-skipping kernel variants drop
            dead chunks; the dense all-tile sweep is kept as the
            oracle path (``pruned=False``),
  gather  — results come back query-sharded and are unpermuted.

Two placements of the *data* are supported:

- **replicated** (``sharded=False``): every device holds the full
  staged layout; only queries are sharded.  Simple, but caps the
  dataset at one device's memory.
- **sharded** (``sharded=True``): tiles are placed on owner devices
  (``stage_sharded`` → capped-LPT ``core.placement.shard_tiles``, per
  device at most ``ceil(T/D)`` tiles — O(total/D) memory) and each
  batch runs the owner-routed ``all_to_all`` exchange step
  (``serve.exchange``): queries travel to the owners of their
  candidate tiles, owners probe locally, partials merge back at home.
  Answers are bit-identical to the dense single-device oracle, which
  stays available per call (``pruned=False``, host-staged on demand).

Exactness of the pruned path is never assumed: range candidate lists
are sized from the batch's true max fan-out, and kNN flags any query
whose refinement radius reaches a tile outside its frontier, which the
server retries with a doubled frontier until exact (worst case the
frontier is every tile — the dense sweep).  Converged candidate widths
are remembered per query kind (``WidthPolicy``), so steady query
streams pay recompiles and kNN widening ladders once.

Single-process use passes ``mesh=None`` and gets the same jitted maths
without the collective plumbing (sharded mode then runs the exchange
in vmap simulation — same answers, one device).
"""
from __future__ import annotations

import dataclasses
import logging
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import geometry, placement
from ..core.compat import shard_map
from ..core.partition import api, assign
from ..core.partition.assign import round_up
from ..kernels.range_probe import ops as rops
from ..query import knn as knn_mod, range as range_mod
from . import exchange, router

_SENTINEL = np.array(geometry.SENTINEL_BOX, np.float32)

log = logging.getLogger(__name__)


@partial(jax.tree_util.register_dataclass,
         data_fields=("tiles", "ids", "canon_tiles", "tile_boxes",
                      "probe_boxes", "chunk_boxes", "uni"),
         meta_fields=())
@dataclasses.dataclass(frozen=True)
class StagedLayout:
    """Device-resident staging of one partitioned dataset.

    tiles       : (T, cap, 4) member MBRs, sentinel-padded (all copies)
    ids         : (T, cap) int32 member ids, -1 in padding slots
    canon_tiles : (T, cap, 4) canonical copies only (others sentineled)
    tile_boxes  : (T, 4) partition regions (sentinel for invalid rows)
    probe_boxes : (T, 4) tight MBR over each tile's *canonical* member
                  MBRs (sentinel where a tile holds none) — the box set
                  the pruned executor routes on; covers every canonical
                  hit on all six layouts
    chunk_boxes : (T, C, 4) the **local index** (``local_index=True``
                  staging, else None): slots are sorted canonical-first
                  by ascending xmin, and chunk c's box is the tight MBR
                  over canonical members in slots [c·128, (c+1)·128) —
                  sentinel where a chunk holds none, so the ``*_skip``
                  probe kernels skip it outright
    uni         : (4,) dataset universe
    """

    tiles: jax.Array
    ids: jax.Array
    canon_tiles: jax.Array
    tile_boxes: jax.Array
    probe_boxes: jax.Array
    chunk_boxes: jax.Array | None
    uni: jax.Array


def _chunk_summary(canon_tiles: jax.Array) -> jax.Array:
    """(T, cap, 4) canonical tiles -> (T, ceil(cap/CHUNK), 4) chunk
    boxes: per 128-member slot group, the tight MBR over its canonical
    member MBRs (sentinel slots are min/max-neutral; an all-sentinel
    chunk collapses to the sentinel box and is always skipped)."""
    t, cap, _ = canon_tiles.shape
    c = -(-cap // rops.CHUNK)
    pad = c * rops.CHUNK - cap
    if pad:
        canon_tiles = jnp.concatenate(
            [canon_tiles,
             jnp.broadcast_to(jnp.asarray(_SENTINEL), (t, pad, 4))], axis=1)
    g = canon_tiles.reshape(t, c, rops.CHUNK, 4)
    return jnp.concatenate(
        [jnp.min(g[..., :2], axis=2), jnp.max(g[..., 2:], axis=2)], axis=-1)


def stage(parts: api.Partitioning, mbrs: jax.Array,
          capacity: int | None = None, local_index: bool = True
          ) -> tuple[StagedLayout, dict]:
    """MASJ-stage ``mbrs`` under ``parts``; 128-aligned, overflow-checked.

    mbrs: (N, 4) f32 -> ``(StagedLayout, stats)``; raises on capacity
    overflow (never silently drops members).  ``stats['replication']``
    is the paper's λ.

    ``local_index=True`` (default) additionally builds the intra-tile
    local index: each tile's slots are permuted so canonical members
    come first in ascending xmin order (non-canonical copies and
    padding sink to the tail, their relative order preserved), and a
    per-128-slot chunk-box summary is carried in ``chunk_boxes`` for
    the chunk-skipping probe kernels.  The permutation is applied to
    ``tiles``/``ids``/``canon_tiles`` consistently, so canonical
    marking — and therefore every query answer — is unchanged;
    ``local_index=False`` staging is the unindexed oracle.
    """
    n = mbrs.shape[0]
    counts, copies = assign.partition_counts(mbrs, parts)
    if capacity is None:
        capacity = round_up(max(int(jnp.max(counts)), 1), 128)
    members, mask, overflow = assign.assign_padded(mbrs, parts, capacity)
    if int(jnp.sum(overflow)) > 0:
        over = np.asarray(counts) - capacity
        raise ValueError(
            f"staging overflow: capacity {capacity} < max tile count "
            f"{int(jnp.max(counts))} ({int((over > 0).sum())} of "
            f"{int(parts.k())} tiles overflow, worst by "
            f"{int(over.max())} members — raise capacity or payload)")

    sentinel = jnp.asarray(_SENTINEL)
    tiles = jnp.where(mask[..., None], mbrs[members], sentinel)
    ids = jnp.where(mask, members, -1).astype(jnp.int32)

    # canonical mark: first copy of each id in tile-major order wins,
    # so every object has exactly one canonical slot
    flat = ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    s = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    canon = jnp.zeros_like(flat, bool).at[order].set(first & (s >= 0))
    canon = canon.reshape(ids.shape)
    canon_tiles = jnp.where(canon[..., None], tiles, sentinel)

    chunk_boxes = None
    if local_index:
        # intra-tile sort: canonical xmin ascending (sentinel 9e9 sinks
        # non-canonical copies and padding to the tail, stably)
        slot_order = jnp.argsort(canon_tiles[..., 0], axis=1, stable=True)

        def permute(a):
            idx = slot_order if a.ndim == 2 else slot_order[..., None]
            return jnp.take_along_axis(a, jnp.broadcast_to(idx, a.shape),
                                       axis=1)

        tiles, ids, canon_tiles = (permute(tiles), permute(ids),
                                   permute(canon_tiles))
        chunk_boxes = _chunk_summary(canon_tiles)

    # canonical probe boxes: sentinel slots are min/max-neutral, and an
    # all-sentinel tile collapses back to the sentinel box
    probe_boxes = jnp.concatenate(
        [jnp.min(canon_tiles[..., :2], axis=1),
         jnp.max(canon_tiles[..., 2:], axis=1)], axis=-1)

    tile_boxes = jnp.where(parts.valid[:, None], parts.boxes, sentinel)
    layout = StagedLayout(tiles=tiles, ids=ids, canon_tiles=canon_tiles,
                          tile_boxes=tile_boxes, probe_boxes=probe_boxes,
                          chunk_boxes=chunk_boxes,
                          uni=geometry.universe(mbrs))
    stats = dict(
        n=n, t=int(parts.k()), cap=capacity,
        # tiles holding >= 1 canonical member: the widest candidate list
        # the pruned executor can ever need (<= t, since padding rows and
        # canonically-empty tiles probe as sentinel)
        t_live=int(jnp.sum(probe_boxes[:, 0] <= probe_boxes[:, 2])),
        chunks=0 if chunk_boxes is None else int(chunk_boxes.shape[1]),
        replication=float(jnp.sum(counts)) / n - 1.0,
    )
    return layout, stats


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Owner-sharded staging: per-device tile shards + the routing maps.

    canon_shards : (D, T_local, cap, 4) canonical member MBRs, one tile
                   shard per device (sentinel-padded rows past a
                   device's tile count) — device-sharded when a mesh is
                   given, so per-device memory is O(total/D)
    id_shards    : (D, T_local, cap) int32 member ids (-1 padding)
    chunk_shards : (D, T_local, C, 4) per-shard local index (chunk
                   boxes in owner-local tile rows; None when staged
                   with ``local_index=False``)
    probe_boxes  : (T, 4) *global* canonical probe boxes — routing is a
                   host-side O(Q·T) scan, so the (small) index stays
                   replicated while the (large) member data shards
    chunk_boxes  : (T, C, 4) *global* chunk boxes (None when unindexed)
                   — like the probe boxes, a small replicated index;
                   used for host-side skip-rate reporting
    uni          : (4,) dataset universe
    owner        : (T,) int32 host map, global tile -> owner device
    local        : (T,) int32 host map, global tile -> row in the
                   owner's shard
    """

    canon_shards: jax.Array
    id_shards: jax.Array
    chunk_shards: jax.Array | None
    probe_boxes: jax.Array
    chunk_boxes: jax.Array | None
    uni: jax.Array
    owner: np.ndarray
    local: np.ndarray


def stage_sharded(parts: api.Partitioning, mbrs: jax.Array, n_shards: int,
                  capacity: int | None = None, mesh: Mesh | None = None,
                  axis: str = "d", local_index: bool = True
                  ) -> tuple[ShardedLayout, tuple, dict]:
    """Stage ``mbrs`` and shard the tiles across ``n_shards`` owners.

    Placement is cost-balanced capped LPT on per-tile member counts
    (``core.placement.shard_tiles``): probe cost spreads like the
    member mass while no device holds more than ``ceil(T/D)`` tiles, so
    per-device shard memory is at most one tile over an even split.
    With a mesh the shards are ``device_put`` sharded over ``axis``.
    ``local_index=True`` staging (see ``stage``) also shards the chunk
    boxes, owner-local, so owners probe their shards chunk-skipping.

    Returns ``(ShardedLayout, (canon_np, ids_np), stats)`` — the numpy
    pair is the host-side copy of the *unsharded* canonical staging,
    kept off-device for the ``pruned=False`` oracle path.
    """
    layout, stats = stage(parts, mbrs, capacity, local_index=local_index)
    canon_np = np.asarray(layout.canon_tiles)
    ids_np = np.asarray(layout.ids)
    t, cap = ids_np.shape
    d = max(1, int(n_shards))
    member_counts = (ids_np >= 0).sum(axis=1).astype(np.float64)
    owner, local, t_local, pstats = placement.shard_tiles(member_counts, d)

    canon_sh = np.broadcast_to(_SENTINEL, (d, t_local, cap, 4)).copy()
    ids_sh = np.full((d, t_local, cap), -1, np.int32)
    canon_sh[owner, local] = canon_np
    ids_sh[owner, local] = ids_np
    cb_sh = None
    if layout.chunk_boxes is not None:
        c = layout.chunk_boxes.shape[1]
        cb_sh = np.broadcast_to(_SENTINEL, (d, t_local, c, 4)).copy()
        cb_sh[owner, local] = np.asarray(layout.chunk_boxes)
    if mesh is not None:
        # device_put straight from host numpy: no transient full-size
        # single-device copy — peak per-device memory stays O(total/D)
        sharding = NamedSharding(mesh, P(axis))
        canon_shards = jax.device_put(canon_sh, sharding)
        id_shards = jax.device_put(ids_sh, sharding)
        chunk_shards = (None if cb_sh is None
                        else jax.device_put(cb_sh, sharding))
    else:
        canon_shards, id_shards = jnp.asarray(canon_sh), jnp.asarray(ids_sh)
        chunk_shards = None if cb_sh is None else jnp.asarray(cb_sh)

    slayout = ShardedLayout(canon_shards=canon_shards, id_shards=id_shards,
                            chunk_shards=chunk_shards,
                            probe_boxes=layout.probe_boxes,
                            chunk_boxes=layout.chunk_boxes, uni=layout.uni,
                            owner=owner, local=local)
    stats = dict(stats, shards=d, t_local=t_local,
                 shard_bytes=(canon_shards.nbytes + id_shards.nbytes) // d,
                 placement_skew=pstats["skew"])
    return slayout, (canon_np, ids_np), stats


# --------------------------------------------------------------------------
# query packing (host): fan-out-weighted LPT onto devices
# --------------------------------------------------------------------------

def pack_queries(costs: np.ndarray, n_devices: int
                 ) -> tuple[np.ndarray, dict]:
    """LPT-pack queries onto devices by per-query cost.

    costs: (Q,) — routed fan-out on the pruned path, so hotspot queries
    spread across devices instead of serialising one of them.  Returns
    ``(slots[D, Qpd] int32 query indices, stats)``; -1 slots are
    padding.  Qpd is the max per-device group size, so one straggler
    hotspot group bounds the step — exactly what LPT minimises.

    A degenerate all-zero cost vector falls back to uniform costs (LPT
    with equal weights round-robins), so queries still spread across
    devices instead of piling onto device 0.
    """
    d = max(1, n_devices)
    costs = costs.astype(np.float64)
    if costs.size and not np.any(costs > 0):
        costs = np.ones_like(costs)
    dev, makespan, mean_load = placement.lpt_pack(costs, d)
    groups = [np.flatnonzero(dev == i) for i in range(d)]
    qpd = max(1, max(len(g) for g in groups))
    slots = np.full((d, qpd), -1, np.int32)
    for i, g in enumerate(groups):
        slots[i, :len(g)] = g
    stats = dict(makespan=makespan, mean_load=mean_load,
                 skew=makespan / max(mean_load, 1e-9), qpd=qpd)
    return slots, stats


def _pack_rows(arr: np.ndarray, slots: np.ndarray, pad) -> np.ndarray:
    """Scatter per-query rows into the packed (D, Qpd, ...) slot grid,
    filling -1 slots with ``pad`` (the single definition shared by the
    replicated and sharded executors)."""
    a = np.asarray(arr)
    pad = np.asarray(pad, a.dtype)
    out = np.broadcast_to(pad, slots.shape + pad.shape).copy()
    live = slots >= 0
    out[live] = a[slots[live]]
    return out


def _unpack_rows(x, slots: np.ndarray, n_queries: int) -> np.ndarray:
    """Invert ``_pack_rows``: (D, Qpd, ...) step output -> per-query
    rows in original batch order.  (Steps that emit a flat
    (D·Qpd, ...) leading axis reshape before calling.)"""
    x = np.asarray(x)
    x = x.reshape((slots.size,) + x.shape[2:])
    live = slots >= 0
    res = np.zeros((n_queries,) + x.shape[1:], x.dtype)
    res[slots[live]] = x[live.ravel()]
    return res


def _f_width(fanout_max: int, t: int) -> int:
    """Candidate-list width: max batch fan-out rounded up to 8 (bounds
    jit recompiles to one per width bucket), capped at the tile count."""
    return min(max(t, 1), round_up(max(fanout_max, 1), 8))


class WidthPolicy:
    """Adaptive candidate-width cache (ROADMAP: adaptive ``f_max``).

    One policy per server, hence per (layout, dataset); keys are query
    kinds (``"range"`` or ``("knn", k, max_cand)``).  Widths only move
    up (``observe`` keeps the max — wider is always exact), and two
    lookup flavours serve the two consumers:

    - ``at_least(key, floor)`` — range batches: the answer must cover
      this batch's true fan-out, so return ``max(cached, floor)``; a
      narrow batch after a wide one reuses the already-compiled wider
      step instead of recompiling.
    - ``start(key, default)`` — kNN batches: any width is *correct*
      (the frontier-miss check widens until exact), so start straight
      from the converged width of earlier batches and skip their
      widening ladder; fall back to the density ``default`` cold.

    Cached widths are clamped to ``cap`` (the server passes its
    ``t_live`` — no candidate list can usefully exceed the live tile
    count), so one pathological batch can never inflate later batches'
    gather width and memory past the layout itself; ``reset()`` drops
    the cache entirely when a stream's width profile changes (e.g.
    after a burst of worst-case boxes).

    ``hits``/``misses`` count cache effectiveness; ``seed`` force-sets
    a width unclamped (tests use it to exercise the widen-and-retry
    path).
    """

    def __init__(self, cap: int | None = None):
        self.cap = cap
        self._w: dict = {}
        self.hits = 0
        self.misses = 0

    def _clamp(self, w: int) -> int:
        return w if self.cap is None else min(w, self.cap)

    def at_least(self, key, floor: int) -> int:
        w = self._w.get(key)
        if w is not None and w >= floor:
            self.hits += 1
            return w
        self.misses += 1
        return floor

    def start(self, key, default: int) -> int:
        w = self._w.get(key)
        if w is not None:
            self.hits += 1
            return w
        self.misses += 1
        return default

    def observe(self, key, width: int) -> None:
        self._w[key] = self._clamp(max(self._w.get(key, 0), width))

    def reset(self) -> None:
        """Forget every cached width (the next batch of each kind pays
        one recompile / widening ladder again, at its natural width)."""
        self._w.clear()

    def seed(self, key, width: int) -> None:
        self._w[key] = width


class SpatialServer:
    """Stage once, then serve batched range / kNN queries.

    ``pruned=True`` (default) routes every batch through the global
    index and probes only candidate tiles — exact on all six layouts,
    answers identical to ``pruned=False`` (the dense all-tile oracle
    sweep).  ``mesh=None`` serves in-process; with a mesh, every batch
    runs as a query-sharded SPMD step over ``mesh[axis]``.  Per-call
    ``pruned=`` overrides the default.

    ``sharded=False`` replicates the staged layout on every device
    (queries are the only sharded axis); ``sharded=True`` shards the
    *tiles* across devices too and serves through the owner-routed
    ``all_to_all`` exchange (``serve.exchange``) — per-device staged
    memory drops to O(total/D) and answers stay bit-identical to the
    oracle.  In-process (``mesh=None``) sharded serving simulates the
    exchange over ``shards`` virtual owners (default 1) — same maths,
    one device; useful for validation and for sizing shard counts.

    ``local_index=True`` (default) stages the intra-tile local index
    (sorted members + per-128-slot chunk boxes, see ``stage``) and
    probes candidate tiles with the chunk-skipping kernel variants —
    LocationSpark's second index layer, cutting the constant factor
    *inside* each candidate tile.  Answers are bit-identical to
    ``local_index=False`` (the unindexed oracle staging);
    ``chunk_skip_rate(qboxes)`` reports the realised skip fraction.
    """

    def __init__(self, parts: api.Partitioning, mbrs: jax.Array,
                 mesh: Mesh | None = None, axis: str = "d",
                 capacity: int | None = None, method: str | None = None,
                 pruned: bool = True, sharded: bool = False,
                 shards: int | None = None, local_index: bool = True):
        self.parts = parts
        self.mesh, self.axis = mesh, axis
        self.pruned = pruned
        self.sharded = sharded
        self.local_index = local_index
        self.n_devices = int(mesh.shape[axis]) if mesh is not None else 1
        if sharded:
            self.shards = int(shards) if shards else self.n_devices
            if mesh is not None and self.shards != self.n_devices:
                raise ValueError(
                    "sharded serving places exactly one tile shard per "
                    f"mesh device ({self.n_devices}), got shards="
                    f"{self.shards}")
            self.slayout, self._oracle_np, self.stats = stage_sharded(
                parts, mbrs, self.shards, capacity, mesh=mesh, axis=axis,
                local_index=local_index)
            self.layout = None
            self._oracle_jax = None
        else:
            self.shards = 1
            self.layout, self.stats = stage(parts, mbrs, capacity,
                                            local_index=local_index)
        self.stats["method"] = method
        self.stats["local_index"] = local_index
        self._steps: dict = {}
        self.widths = WidthPolicy(cap=self.stats["t_live"])

    @classmethod
    def from_method(cls, method: str, mbrs: jax.Array, payload: int,
                    mesh: Mesh | None = None, axis: str = "d",
                    **kw) -> "SpatialServer":
        parts = api.partition(method, mbrs, payload)
        return cls(parts, mbrs, mesh=mesh, axis=axis, method=method, **kw)

    # -- shared accessors -------------------------------------------------

    @property
    def probe_boxes(self) -> jax.Array:
        lay = self.slayout if self.sharded else self.layout
        return lay.probe_boxes

    @property
    def uni(self) -> jax.Array:
        lay = self.slayout if self.sharded else self.layout
        return lay.uni

    @property
    def chunk_boxes(self) -> jax.Array | None:
        """The (T, C, 4) global local index (None when unindexed)."""
        lay = self.slayout if self.sharded else self.layout
        return lay.chunk_boxes

    def chunk_skip_rate(self, qboxes: jax.Array) -> float:
        """Measured local-index effectiveness for one batch: the
        fraction of per-candidate 128-member chunks whose box the query
        misses (work the ``*_skip`` kernels drop).  0.0 when staged
        with ``local_index=False``.  Pure measurement — does not touch
        the width cache."""
        if self.chunk_boxes is None:
            return 0.0
        hit = router.probe_overlap(self.probe_boxes, qboxes)
        pf = np.asarray(jnp.sum(hit, axis=1, dtype=jnp.int32))
        f = _f_width(int(pf.max(initial=0)), self.stats["t_live"])
        cand, _, _ = router.candidates_from_overlap(hit, f)
        return float(rops.chunk_skip_rate(qboxes, self.chunk_boxes, cand))

    def resident_tile_bytes(self) -> int:
        """Per-device bytes of device-resident staged member data.

        Replicated serving holds the full staging (member tiles +
        canonical tiles + ids) on every device; sharded serving holds
        1/D of the canonical tiles + ids (the (T, 4) probe boxes stay
        replicated but are negligible).  This is the O(N) vs O(N/D)
        axis the benchmarks report.
        """
        if self.sharded:
            s = self.slayout
            return int(s.canon_shards.nbytes + s.id_shards.nbytes) \
                // self.shards
        lay = self.layout
        return int(lay.tiles.nbytes + lay.canon_tiles.nbytes
                   + lay.ids.nbytes)

    def _oracle(self) -> tuple[jax.Array, jax.Array]:
        """Dense single-device staging for the ``pruned=False`` oracle
        in sharded mode — staged to the default device on first use
        (debug/validation path; the sharded server never needs it)."""
        if self._oracle_jax is None:
            canon_np, ids_np = self._oracle_np
            self._oracle_jax = (jnp.asarray(canon_np), jnp.asarray(ids_np))
        return self._oracle_jax

    # -- SPMD plumbing ----------------------------------------------------

    def _sharded_call(self, name: str, fn, qarrays: tuple,
                      costs: np.ndarray, pads: tuple):
        """Run ``fn(*per_query_arrays) -> pytree`` query-sharded
        (replicated layout).

        Every array in ``qarrays`` is leading-axis (Q, ...); ``pads``
        gives the matching padding element for the slots LPT leaves
        empty.  The jitted step is cached under ``name`` (callers embed
        shape-determining params such as the candidate width).
        """
        if self.mesh is None:
            return fn(*qarrays), dict(skew=1.0)
        slots, pstats = pack_queries(costs, self.n_devices)
        packed = [_pack_rows(a, slots, p) for a, p in zip(qarrays, pads)]

        step = self._steps.get(name)
        if step is None:
            spec = P(self.axis)

            def spmd(*qs):
                return fn(*(x[0] for x in qs))

            step = jax.jit(shard_map(
                spmd, mesh=self.mesh, in_specs=(spec,) * len(qarrays),
                out_specs=spec, check_vma=False))
            self._steps[name] = step

        sharding = NamedSharding(self.mesh, P(self.axis))
        out = step(*(jax.device_put(jnp.asarray(p), sharding)
                     for p in packed))
        n_q = qarrays[0].shape[0]
        # step outputs concatenate per-device (Qpd, ...) blocks into a
        # flat (D·Qpd, ...) leading axis; restore the (D, Qpd) grid
        return jax.tree.map(
            lambda x: _unpack_rows(
                np.asarray(x).reshape(slots.shape + np.asarray(x).shape[1:]),
                slots, n_q),
            out), pstats

    def _exchange_plan(self, cand, costs: np.ndarray):
        """Host-side plan for one sharded batch: LPT query packing +
        owner-local candidate translation (``router.owner_split``)."""
        slots, pstats = pack_queries(costs, self.shards)
        send_slot, send_cand, xstats = router.owner_split(
            np.asarray(cand), slots, self.slayout.owner, self.slayout.local)
        return slots, send_slot, send_cand, {**pstats, **xstats}

    def _put(self, arr):
        a = jnp.asarray(arr)
        if self.mesh is not None:
            a = jax.device_put(a, NamedSharding(self.mesh, P(self.axis)))
        return a

    def _exchange_step(self, key: tuple, orch, n_sharded: int,
                       n_replicated: int = 0, **static):
        step = self._steps.get(key)
        if step is None:
            step = exchange.build_step(orch, self.mesh, self.axis,
                                       n_sharded, n_replicated, **static)
            self._steps[key] = step
        return step

    # -- routing helpers (host side, per batch) ---------------------------

    def _route_batch(self, qboxes: jax.Array):
        """Candidate-tile index for one range batch.  ``f_max`` covers
        the batch's true max probe fan-out — never truncating — and is
        ratcheted through the width cache so narrower follow-up batches
        reuse the compiled step.  Returns ``(cand[Q, F], costs[Q], F)``.
        """
        hit = router.probe_overlap(self.probe_boxes, qboxes)
        pf = np.asarray(jnp.sum(hit, axis=1, dtype=jnp.int32))
        floor = _f_width(int(pf.max(initial=0)), self.stats["t_live"])
        f = self.widths.at_least("range", floor)
        cand, _, _ = router.candidates_from_overlap(hit, f)
        self.widths.observe("range", f)
        return cand, pf.astype(np.float64), f

    def _fanout_stats(self, qboxes: jax.Array) -> dict:
        """The paper's reported metric: region fan-out from the global
        index (independent of the executor's probe-box routing)."""
        _, fanout = router.route_range(self.parts, qboxes)
        fanout_np = np.asarray(fanout)
        return dict(fanout_mean=float(fanout_np.mean()),
                    fanout_max=int(fanout_np.max()))

    # -- sharded executors (owner-routed all_to_all exchange) -------------

    def _sharded_range_counts(self, qboxes: jax.Array):
        cand, costs, f = self._route_batch(qboxes)
        slots, ss, sc, xstats = self._exchange_plan(cand, costs)
        qp = _pack_rows(np.asarray(qboxes, np.float32), slots, _SENTINEL)
        li = self.local_index
        extra = (self.slayout.chunk_shards,) if li else ()
        step = self._exchange_step(
            ("s_range_counts", qp.shape[1], ss.shape[2], sc.shape[3], li),
            exchange.serve_range_counts, n_sharded=4 + len(extra))
        out = step(self._put(qp), self._put(ss), self._put(sc),
                   self.slayout.canon_shards, *extra)
        counts = _unpack_rows(out, slots, qboxes.shape[0])
        return jnp.asarray(counts), dict(f_max=f, **xstats)

    def _sharded_range_ids(self, qboxes: jax.Array, max_hits: int):
        cand, costs, f = self._route_batch(qboxes)
        slots, ss, sc, xstats = self._exchange_plan(cand, costs)
        qp = _pack_rows(np.asarray(qboxes, np.float32), slots, _SENTINEL)
        cap = int(self.slayout.id_shards.shape[-1])
        mh_local = min(max_hits, sc.shape[3] * cap)
        li = self.local_index
        extra = (self.slayout.chunk_shards,) if li else ()
        step = self._exchange_step(
            ("s_range_ids", qp.shape[1], ss.shape[2], sc.shape[3],
             max_hits, li),
            exchange.serve_range_ids, n_sharded=5 + len(extra),
            max_hits=max_hits, mh_local=mh_local)
        out = step(self._put(qp), self._put(ss), self._put(sc),
                   self.slayout.canon_shards, self.slayout.id_shards,
                   *extra)
        n_q = qboxes.shape[0]
        hit_ids, counts, overflow = (
            _unpack_rows(x, slots, n_q) for x in out)
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), dict(f_max=f, **xstats))

    def _knn_cost_proxy(self, dist, k: int) -> np.ndarray:
        """LPT packing weight: tiles the first deepening box would
        touch (matches the radius the kernel actually starts from —
        density over the ``n`` live canonical members, not the padded
        slot count)."""
        uni = self.uni
        diag = float(np.linalg.norm(np.asarray(uni[2:] - uni[:2])))
        r0 = float(knn_mod.initial_radius(
            jnp.float32(diag), k, self.stats["n"]))
        return (1.0 + np.sum(np.asarray(dist) <= r0, axis=1)
                ).astype(np.float64)

    def _knn_retry_loop(self, pts: jax.Array, k: int, max_cand: int,
                        run_batch):
        """The exactness-critical widen-and-retry ladder, shared by the
        replicated and sharded executors.

        ``run_batch(f)`` answers the batch with frontier width ``f``
        and returns ``(nn_ids, nn_d2, radius, overflow, excluded,
        xstats)``.  Any query whose √2-inflated refinement radius
        reaches its nearest excluded tile may have missed a true
        neighbour, so the frontier doubles (logged) until no query can
        miss or the frontier holds every live tile.  Converged widths
        feed the width cache so a steady stream pays the ladder once.
        """
        t_live, n = self.stats["t_live"], self.stats["n"]
        wkey = ("knn", k, max_cand)
        f = self.widths.start(
            wkey, _f_width(4 * k * t_live // max(n, 1) + 3, t_live))
        retries = 0
        while True:
            nn_ids, nn_d2, radius, overflow, excl, xstats = run_batch(f)
            miss = np.asarray(excl) <= np.asarray(radius) * np.sqrt(2.0)
            if not miss.any() or f >= t_live:
                break
            new_f = _f_width(2 * f, t_live)
            log.info("kNN frontier miss on %d/%d queries: widening "
                     "f_max %d -> %d (retry %d)",
                     int(miss.sum()), pts.shape[0], f, new_f, retries + 1)
            f = new_f
            retries += 1
        self.widths.observe(wkey, f)
        overflow = np.asarray(overflow) | miss
        return nn_ids, nn_d2, overflow, dict(f_max=f, retries=retries,
                                             **xstats)

    def _sharded_knn(self, pts: jax.Array, k: int, max_cand: int):
        n_live = self.stats["n"]
        uni = self.uni
        pad_pt = np.asarray((uni[:2] + uni[2:]) * 0.5)
        n_q = pts.shape[0]
        li = self.local_index

        def run_batch(f):
            cand, dist, excl = router.candidate_knn(
                self.slayout.probe_boxes, pts, f)
            slots, ss, sc, xstats = self._exchange_plan(
                cand, self._knn_cost_proxy(dist, k))
            pp = _pack_rows(np.asarray(pts, np.float32), slots, pad_pt)
            dead = slots < 0
            orch = (exchange.serve_knn if li
                    else exchange.serve_knn_unindexed)
            extra = (self.slayout.chunk_shards,) if li else ()
            step = self._exchange_step(
                ("s_knn", k, max_cand, pp.shape[1], ss.shape[2],
                 sc.shape[3], li),
                orch, n_sharded=6 + len(extra), n_replicated=1,
                k=k, max_cand=max_cand, n_live=n_live)
            out = step(self._put(pp), self._put(ss), self._put(sc),
                       self._put(dead), self.slayout.canon_shards,
                       self.slayout.id_shards, *extra, uni)
            nn_ids, nn_d2, radius, overflow, rounds = (
                _unpack_rows(x, slots, n_q) for x in out)
            xstats = dict(xstats, rounds=int(rounds.max(initial=0)))
            return nn_ids, nn_d2, radius, overflow, excl, xstats

        nn_ids, nn_d2, overflow, stats = self._knn_retry_loop(
            pts, k, max_cand, run_batch)
        return (jnp.asarray(nn_ids), jnp.asarray(nn_d2),
                jnp.asarray(overflow), stats)

    # -- queries ----------------------------------------------------------

    def range_counts(self, qboxes: jax.Array, pruned: bool | None = None):
        """Exact unique hit counts -> ``((Q,) int32, stats)``.

        stats carry the region fan-out metric, the packing skew, and
        ``mode``/``f_max`` describing the executor that ran.
        """
        stats = self._fanout_stats(qboxes)
        use_pruned = self.pruned if pruned is None else pruned
        if self.sharded:
            if not use_pruned:
                canon, _ = self._oracle()
                counts = range_mod.range_counts(qboxes, canon)
                stats.update(mode="dense")
                return counts, stats
            counts, xstats = self._sharded_range_counts(qboxes)
            stats.update(mode="sharded", shards=self.shards, **xstats)
            return counts, stats
        layout = self.layout
        if use_pruned:
            cand, costs, f = self._route_batch(qboxes)
            cb = layout.chunk_boxes if self.local_index else None
            counts, pstats = self._sharded_call(
                f"range_counts_pruned_{f}_{self.local_index}",
                lambda qs, cd: range_mod.pruned_range_counts(
                    qs, layout.canon_tiles, cd, chunk_boxes=cb),
                (qboxes, cand), costs,
                (_SENTINEL, np.full((f,), -1, np.int32)))
            stats.update(mode="pruned", f_max=f, **pstats)
        else:
            counts, pstats = self._sharded_call(
                "range_counts",
                lambda qs: range_mod.range_counts(qs, layout.canon_tiles),
                (qboxes,), np.ones(qboxes.shape[0], np.float64),
                (_SENTINEL,))
            stats.update(mode="dense", **pstats)
        return counts, stats

    def range_ids(self, qboxes: jax.Array, max_hits: int = 1024,
                  pruned: bool | None = None):
        """Exact unique hit-id sets (ascending, -1 padded) + overflow
        -> ``(hit_ids[Q, max_hits], counts[Q], overflow[Q], stats)``."""
        stats = self._fanout_stats(qboxes)
        use_pruned = self.pruned if pruned is None else pruned
        if self.sharded:
            if not use_pruned:
                canon, ids = self._oracle()
                hit_ids, counts, overflow = range_mod.range_ids(
                    qboxes, canon, ids, max_hits)
                stats.update(mode="dense")
                return hit_ids, counts, overflow, stats
            hit_ids, counts, overflow, xstats = self._sharded_range_ids(
                qboxes, max_hits)
            stats.update(mode="sharded", shards=self.shards, **xstats)
            return hit_ids, counts, overflow, stats
        layout = self.layout
        if use_pruned:
            cand, costs, f = self._route_batch(qboxes)
            cb = layout.chunk_boxes if self.local_index else None
            (hit_ids, counts, overflow), pstats = self._sharded_call(
                f"range_ids_pruned_{f}_{max_hits}_{self.local_index}",
                lambda qs, cd: range_mod.pruned_range_ids(
                    qs, layout.canon_tiles, layout.ids, cd, max_hits,
                    chunk_boxes=cb),
                (qboxes, cand), costs,
                (_SENTINEL, np.full((f,), -1, np.int32)))
            stats.update(mode="pruned", f_max=f, **pstats)
        else:
            (hit_ids, counts, overflow), pstats = self._sharded_call(
                f"range_ids_{max_hits}",
                lambda qs: range_mod.range_ids(qs, layout.canon_tiles,
                                               layout.ids, max_hits),
                (qboxes,), np.ones(qboxes.shape[0], np.float64),
                (_SENTINEL,))
            stats.update(mode="dense", **pstats)
        return hit_ids, counts, overflow, stats

    def knn(self, pts: jax.Array, k: int, max_cand: int = 1024,
            pruned: bool | None = None):
        """Exact batched kNN -> ``(nn_ids[Q, k], nn_d2[Q, k],
        overflow[Q], stats)``; reported fan-out = MINDIST partitions a
        best-first search would visit given the answered kth distance.

        The pruned executor starts from a density-sized MINDIST
        frontier (or the width cache's converged start) and doubles it
        for any batch whose refinement radius reached an excluded tile
        — logged and counted in ``stats['retries']`` — so returned
        answers match the dense oracle exactly.
        """
        use_pruned = self.pruned if pruned is None else pruned
        if self.sharded:
            if not use_pruned:
                canon, ids = self._oracle()
                nn_ids, nn_d2, _, overflow, rounds = knn_mod.batched_knn(
                    pts, k, canon, ids, self.uni, max_cand=max_cand,
                    n_live=self.stats["n"])
                mode_stats = dict(
                    mode="dense",
                    rounds=int(np.asarray(rounds).max(initial=0)))
            else:
                nn_ids, nn_d2, overflow, xstats = self._sharded_knn(
                    pts, k, max_cand)
                mode_stats = dict(mode="sharded", shards=self.shards,
                                  **xstats)
        else:
            nn_ids, nn_d2, overflow, mode_stats = self._replicated_knn(
                pts, k, max_cand, use_pruned)
        fanout = knn_mod.knn_fanout(jnp.asarray(pts),
                                    jnp.asarray(nn_d2[:, -1]),
                                    self.parts.boxes, self.parts.valid)
        stats = dict(fanout_mean=float(jnp.mean(fanout)),
                     fanout_max=int(jnp.max(fanout)), **mode_stats)
        return nn_ids, nn_d2, overflow, stats

    def _replicated_knn(self, pts: jax.Array, k: int, max_cand: int,
                        use_pruned: bool):
        layout = self.layout
        n_live = self.stats["n"]
        pad_pt = np.asarray((layout.uni[:2] + layout.uni[2:]) * 0.5)
        if not use_pruned:
            (nn_ids, nn_d2, radius, overflow, rounds), pstats = \
                self._sharded_call(
                    f"knn_{k}_{max_cand}",
                    lambda qs: knn_mod.batched_knn(
                        qs, k, layout.canon_tiles, layout.ids, layout.uni,
                        max_cand=max_cand, n_live=n_live),
                    (pts,), np.ones(pts.shape[0], np.float64), (pad_pt,))
            return nn_ids, nn_d2, overflow, dict(
                mode="dense", rounds=int(np.asarray(rounds).max(initial=0)),
                **pstats)

        cb = layout.chunk_boxes if self.local_index else None

        def run_batch(f):
            cand, dist, excl = router.candidate_knn(
                layout.probe_boxes, pts, f)
            (nn_ids, nn_d2, radius, overflow, rounds), pstats = \
                self._sharded_call(
                    f"knn_pruned_{k}_{max_cand}_{f}_{self.local_index}",
                    lambda qs, cd, ex: knn_mod.pruned_knn(
                        qs, k, layout.canon_tiles, layout.ids,
                        layout.uni, cd, ex, max_cand=max_cand,
                        n_live=n_live, chunk_boxes=cb),
                    (pts, cand, excl),
                    self._knn_cost_proxy(dist, k),
                    (pad_pt, np.full((f,), -1, np.int32),
                     np.float32(np.inf)))
            pstats = dict(pstats,
                          rounds=int(np.asarray(rounds).max(initial=0)))
            return nn_ids, nn_d2, radius, overflow, excl, pstats

        nn_ids, nn_d2, overflow, stats = self._knn_retry_loop(
            pts, k, max_cand, run_batch)
        return nn_ids, nn_d2, overflow, dict(mode="pruned", **stats)
