"""The batched spatial query server (stage once, serve forever).

LocationSpark's architecture in SPMD form: a dataset is staged **once**
under any of the six layouts — MASJ assignment into padded
``(T, cap, 4)`` member tiles (reusing ``assign.assign_padded``) plus a
canonical-copy mark so selection queries dedup for free (see
``query.range``) — then streams of query batches are answered by a
jitted ``shard_map`` step:

  route   — the global index maps the batch to partitions, yielding the
            per-query fan-out metric *and* a fixed-width ``(Q, F)``
            candidate-tile index over the layout's canonical probe
            boxes (``router.candidate_range`` / ``candidate_knn``),
  pack    — queries are LPT-packed onto devices with routed fan-out as
            the cost (the join engine's straggler story, applied to the
            query side: a batch of hotspot queries must not serialise
            on one device),
  probe   — each device probes its query shard's candidate tiles only,
            via the gathered ``range_probe`` Pallas kernel — O(Q·F·cap)
            work; the dense all-tile sweep is kept as the oracle path
            (``pruned=False``),
  gather  — results come back query-sharded and are unpermuted.

Exactness of the pruned path is never assumed: range candidate lists
are sized from the batch's true max fan-out, and kNN flags any query
whose refinement radius reaches a tile outside its frontier, which the
server retries with a doubled frontier until exact (worst case the
frontier is every tile — the dense sweep).

Single-process use passes ``mesh=None`` and gets the same jitted maths
without the collective plumbing.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import geometry
from ..core.compat import shard_map
from ..core.partition import api, assign
from ..core.partition.assign import round_up
from ..query import balance, knn as knn_mod, range as range_mod
from . import router

_SENTINEL = np.array(geometry.SENTINEL_BOX, np.float32)


@partial(jax.tree_util.register_dataclass,
         data_fields=("tiles", "ids", "canon_tiles", "tile_boxes",
                      "probe_boxes", "uni"),
         meta_fields=())
@dataclasses.dataclass(frozen=True)
class StagedLayout:
    """Device-resident staging of one partitioned dataset.

    tiles       : (T, cap, 4) member MBRs, sentinel-padded (all copies)
    ids         : (T, cap) int32 member ids, -1 in padding slots
    canon_tiles : (T, cap, 4) canonical copies only (others sentineled)
    tile_boxes  : (T, 4) partition regions (sentinel for invalid rows)
    probe_boxes : (T, 4) tight MBR over each tile's *canonical* member
                  MBRs (sentinel where a tile holds none) — the box set
                  the pruned executor routes on; covers every canonical
                  hit on all six layouts
    uni         : (4,) dataset universe
    """

    tiles: jax.Array
    ids: jax.Array
    canon_tiles: jax.Array
    tile_boxes: jax.Array
    probe_boxes: jax.Array
    uni: jax.Array


def stage(parts: api.Partitioning, mbrs: jax.Array,
          capacity: int | None = None) -> tuple[StagedLayout, dict]:
    """MASJ-stage ``mbrs`` under ``parts``; 128-aligned, overflow-checked.

    mbrs: (N, 4) f32 -> ``(StagedLayout, stats)``; raises on capacity
    overflow (never silently drops members).  ``stats['replication']``
    is the paper's λ.
    """
    n = mbrs.shape[0]
    counts, copies = assign.partition_counts(mbrs, parts)
    if capacity is None:
        capacity = round_up(max(int(jnp.max(counts)), 1), 128)
    members, mask, overflow = assign.assign_padded(mbrs, parts, capacity)
    if int(jnp.sum(overflow)) > 0:
        raise ValueError(f"staging overflow: capacity {capacity} too small")

    sentinel = jnp.asarray(_SENTINEL)
    tiles = jnp.where(mask[..., None], mbrs[members], sentinel)
    ids = jnp.where(mask, members, -1).astype(jnp.int32)

    # canonical mark: first copy of each id in tile-major order wins,
    # so every object has exactly one canonical slot
    flat = ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    s = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    canon = jnp.zeros_like(flat, bool).at[order].set(first & (s >= 0))
    canon = canon.reshape(ids.shape)
    canon_tiles = jnp.where(canon[..., None], tiles, sentinel)

    # canonical probe boxes: sentinel slots are min/max-neutral, and an
    # all-sentinel tile collapses back to the sentinel box
    probe_boxes = jnp.concatenate(
        [jnp.min(canon_tiles[..., :2], axis=1),
         jnp.max(canon_tiles[..., 2:], axis=1)], axis=-1)

    tile_boxes = jnp.where(parts.valid[:, None], parts.boxes, sentinel)
    layout = StagedLayout(tiles=tiles, ids=ids, canon_tiles=canon_tiles,
                          tile_boxes=tile_boxes, probe_boxes=probe_boxes,
                          uni=geometry.universe(mbrs))
    stats = dict(
        n=n, t=int(parts.k()), cap=capacity,
        # tiles holding >= 1 canonical member: the widest candidate list
        # the pruned executor can ever need (<= t, since padding rows and
        # canonically-empty tiles probe as sentinel)
        t_live=int(jnp.sum(probe_boxes[:, 0] <= probe_boxes[:, 2])),
        replication=float(jnp.sum(counts)) / n - 1.0,
    )
    return layout, stats


# --------------------------------------------------------------------------
# query packing (host): fan-out-weighted LPT onto devices
# --------------------------------------------------------------------------

def pack_queries(costs: np.ndarray, n_devices: int
                 ) -> tuple[np.ndarray, dict]:
    """LPT-pack queries onto devices by per-query cost.

    costs: (Q,) — routed fan-out on the pruned path, so hotspot queries
    spread across devices instead of serialising one of them.  Returns
    ``(slots[D, Qpd] int32 query indices, stats)``; -1 slots are
    padding.  Qpd is the max per-device group size, so one straggler
    hotspot group bounds the step — exactly what LPT minimises.

    A degenerate all-zero cost vector falls back to uniform costs (LPT
    with equal weights round-robins), so queries still spread across
    devices instead of piling onto device 0.
    """
    d = max(1, n_devices)
    costs = costs.astype(np.float64)
    if costs.size and not np.any(costs > 0):
        costs = np.ones_like(costs)
    dev, makespan, mean_load = balance.lpt_pack(costs, d)
    groups = [np.flatnonzero(dev == i) for i in range(d)]
    qpd = max(1, max(len(g) for g in groups))
    slots = np.full((d, qpd), -1, np.int32)
    for i, g in enumerate(groups):
        slots[i, :len(g)] = g
    stats = dict(makespan=makespan, mean_load=mean_load,
                 skew=makespan / max(mean_load, 1e-9), qpd=qpd)
    return slots, stats


def _f_width(fanout_max: int, t: int) -> int:
    """Candidate-list width: max batch fan-out rounded up to 8 (bounds
    jit recompiles to one per width bucket), capped at the tile count."""
    return min(max(t, 1), round_up(max(fanout_max, 1), 8))


class SpatialServer:
    """Stage once, then serve batched range / kNN queries.

    ``pruned=True`` (default) routes every batch through the global
    index and probes only candidate tiles — exact on all six layouts,
    answers identical to ``pruned=False`` (the dense all-tile oracle
    sweep).  ``mesh=None`` serves in-process; with a mesh, every batch
    runs as a query-sharded SPMD step over ``mesh[axis]`` with the
    staged layout replicated (it was built once; queries are the
    streaming side).  Per-call ``pruned=`` overrides the default.
    """

    def __init__(self, parts: api.Partitioning, mbrs: jax.Array,
                 mesh: Mesh | None = None, axis: str = "d",
                 capacity: int | None = None, method: str | None = None,
                 pruned: bool = True):
        self.parts = parts
        self.layout, self.stats = stage(parts, mbrs, capacity)
        self.stats["method"] = method
        self.mesh, self.axis = mesh, axis
        self.pruned = pruned
        self.n_devices = int(mesh.shape[axis]) if mesh is not None else 1
        self._steps: dict = {}
        self._knn_f: dict = {}     # (k, max_cand) -> converged frontier

    @classmethod
    def from_method(cls, method: str, mbrs: jax.Array, payload: int,
                    mesh: Mesh | None = None, axis: str = "d",
                    **kw) -> "SpatialServer":
        parts = api.partition(method, mbrs, payload)
        return cls(parts, mbrs, mesh=mesh, axis=axis, method=method, **kw)

    # -- SPMD plumbing ----------------------------------------------------

    def _sharded_call(self, name: str, fn, qarrays: tuple,
                      costs: np.ndarray, pads: tuple):
        """Run ``fn(*per_query_arrays) -> pytree`` query-sharded.

        Every array in ``qarrays`` is leading-axis (Q, ...); ``pads``
        gives the matching padding element for the slots LPT leaves
        empty.  The jitted step is cached under ``name`` (callers embed
        shape-determining params such as the candidate width).
        """
        if self.mesh is None:
            return fn(*qarrays), dict(skew=1.0)
        slots, pstats = pack_queries(costs, self.n_devices)
        live = slots >= 0
        packed = []
        for arr, pad in zip(qarrays, pads):
            a = np.asarray(arr)
            pad = np.asarray(pad, a.dtype)
            p = np.broadcast_to(
                pad, (slots.shape[0], slots.shape[1]) + pad.shape).copy()
            p[live] = a[slots[live]]
            packed.append(p)

        step = self._steps.get(name)
        if step is None:
            spec = P(self.axis)

            def spmd(*qs):
                return fn(*(x[0] for x in qs))

            step = jax.jit(shard_map(
                spmd, mesh=self.mesh, in_specs=(spec,) * len(qarrays),
                out_specs=spec, check_vma=False))
            self._steps[name] = step

        sharding = NamedSharding(self.mesh, P(self.axis))
        out = step(*(jax.device_put(jnp.asarray(p), sharding)
                     for p in packed))

        def unpack(x):
            x = np.asarray(x).reshape((slots.size,) + x.shape[1:])
            res = np.zeros((qarrays[0].shape[0],) + x.shape[1:], x.dtype)
            res[slots[live]] = x[live.ravel()]
            return res

        return jax.tree.map(unpack, out), pstats

    # -- routing helpers (host side, per batch) ---------------------------

    def _route_batch(self, qboxes: jax.Array):
        """Candidate-tile index for one range batch: f_max is sized from
        the batch's true max probe fan-out, so the pruned answer never
        truncates.  Returns ``(cand[Q, F], costs[Q], F)``."""
        hit = router.probe_overlap(self.layout.probe_boxes, qboxes)
        pf = np.asarray(jnp.sum(hit, axis=1, dtype=jnp.int32))
        f = _f_width(int(pf.max(initial=0)), self.stats["t_live"])
        cand, _, _ = router.candidates_from_overlap(hit, f)
        return cand, pf.astype(np.float64), f

    def _fanout_stats(self, qboxes: jax.Array) -> dict:
        """The paper's reported metric: region fan-out from the global
        index (independent of the executor's probe-box routing)."""
        _, fanout = router.route_range(self.parts, qboxes)
        fanout_np = np.asarray(fanout)
        return dict(fanout_mean=float(fanout_np.mean()),
                    fanout_max=int(fanout_np.max()))

    # -- queries ----------------------------------------------------------

    def range_counts(self, qboxes: jax.Array, pruned: bool | None = None):
        """Exact unique hit counts -> ``((Q,) int32, stats)``.

        stats carry the region fan-out metric, the packing skew, and
        ``mode``/``f_max`` describing the executor that ran.
        """
        layout = self.layout
        stats = self._fanout_stats(qboxes)
        use_pruned = self.pruned if pruned is None else pruned
        if use_pruned:
            cand, costs, f = self._route_batch(qboxes)
            counts, pstats = self._sharded_call(
                f"range_counts_pruned_{f}",
                lambda qs, cd: range_mod.pruned_range_counts(
                    qs, layout.canon_tiles, cd),
                (qboxes, cand), costs,
                (_SENTINEL, np.full((f,), -1, np.int32)))
            stats.update(mode="pruned", f_max=f, **pstats)
        else:
            counts, pstats = self._sharded_call(
                "range_counts",
                lambda qs: range_mod.range_counts(qs, layout.canon_tiles),
                (qboxes,), np.ones(qboxes.shape[0], np.float64),
                (_SENTINEL,))
            stats.update(mode="dense", **pstats)
        return counts, stats

    def range_ids(self, qboxes: jax.Array, max_hits: int = 1024,
                  pruned: bool | None = None):
        """Exact unique hit-id sets (ascending, -1 padded) + overflow
        -> ``(hit_ids[Q, max_hits], counts[Q], overflow[Q], stats)``."""
        layout = self.layout
        stats = self._fanout_stats(qboxes)
        use_pruned = self.pruned if pruned is None else pruned
        if use_pruned:
            cand, costs, f = self._route_batch(qboxes)
            (hit_ids, counts, overflow), pstats = self._sharded_call(
                f"range_ids_pruned_{f}_{max_hits}",
                lambda qs, cd: range_mod.pruned_range_ids(
                    qs, layout.canon_tiles, layout.ids, cd, max_hits),
                (qboxes, cand), costs,
                (_SENTINEL, np.full((f,), -1, np.int32)))
            stats.update(mode="pruned", f_max=f, **pstats)
        else:
            (hit_ids, counts, overflow), pstats = self._sharded_call(
                f"range_ids_{max_hits}",
                lambda qs: range_mod.range_ids(qs, layout.canon_tiles,
                                               layout.ids, max_hits),
                (qboxes,), np.ones(qboxes.shape[0], np.float64),
                (_SENTINEL,))
            stats.update(mode="dense", **pstats)
        return hit_ids, counts, overflow, stats

    def knn(self, pts: jax.Array, k: int, max_cand: int = 1024,
            pruned: bool | None = None):
        """Exact batched kNN -> ``(nn_ids[Q, k], nn_d2[Q, k],
        overflow[Q], stats)``; reported fan-out = MINDIST partitions a
        best-first search would visit given the answered kth distance.

        The pruned executor starts from a density-sized MINDIST
        frontier and doubles it for any batch whose refinement radius
        reached an excluded tile, so returned answers match the dense
        oracle exactly; ``stats['retries']`` counts the widenings.
        """
        layout = self.layout
        t, cap = layout.ids.shape
        t_live = self.stats["t_live"]
        pad_pt = np.asarray((layout.uni[:2] + layout.uni[2:]) * 0.5)
        use_pruned = self.pruned if pruned is None else pruned
        if use_pruned:
            n = self.stats["n"]
            # frontier wide enough that ~4k canonical objects fit under
            # it; converged widths are remembered per (k, max_cand) so a
            # steady query stream pays the widening ladder only once
            f = self._knn_f.get(
                (k, max_cand),
                _f_width(4 * k * t_live // max(n, 1) + 3, t_live))
            retries = 0
            while True:
                cand, dist, excl = router.candidate_knn(
                    layout.probe_boxes, pts, f)
                # cost proxy: tiles the first deepening box would touch
                diag = float(np.linalg.norm(
                    np.asarray(layout.uni[2:] - layout.uni[:2])))
                r0 = float(knn_mod.initial_radius(
                    jnp.float32(diag), k, t * cap))
                costs = 1.0 + np.sum(np.asarray(dist) <= r0, axis=1)
                (nn_ids, nn_d2, radius, overflow), pstats = \
                    self._sharded_call(
                        f"knn_pruned_{k}_{max_cand}_{f}",
                        lambda qs, cd, ex: knn_mod.pruned_knn(
                            qs, k, layout.canon_tiles, layout.ids,
                            layout.uni, cd, ex, max_cand=max_cand),
                        (pts, cand, excl),
                        costs.astype(np.float64),
                        (pad_pt, np.full((f,), -1, np.int32),
                         np.float32(np.inf)))
                miss = (np.asarray(excl)
                        <= np.asarray(radius) * np.sqrt(2.0))
                if not miss.any() or f >= t_live:
                    break
                f = _f_width(2 * f, t_live)
                retries += 1
            self._knn_f[(k, max_cand)] = f
            mode_stats = dict(mode="pruned", f_max=f, retries=retries,
                              **pstats)
        else:
            (nn_ids, nn_d2, radius, overflow), pstats = self._sharded_call(
                f"knn_{k}_{max_cand}",
                lambda qs: knn_mod.batched_knn(qs, k, layout.canon_tiles,
                                               layout.ids, layout.uni,
                                               max_cand=max_cand),
                (pts,), np.ones(pts.shape[0], np.float64), (pad_pt,))
            mode_stats = dict(mode="dense", **pstats)
        fanout = knn_mod.knn_fanout(jnp.asarray(pts),
                                    jnp.asarray(nn_d2[:, -1]),
                                    self.parts.boxes, self.parts.valid)
        stats = dict(fanout_mean=float(jnp.mean(fanout)),
                     fanout_max=int(jnp.max(fanout)), **mode_stats)
        return nn_ids, nn_d2, overflow, stats
