"""`ServeConfig`: the one frozen description of how a server serves.

PR 1–4 grew the serving surface one boolean at a time —
``pruned=``/``sharded=``/``shards=``/``local_index=``/``capacity=`` on
the ``SpatialServer`` constructor, mirrored by two parallel staging
entry points (``stage`` vs ``stage_sharded``).  Every new feature had
to be wired through both placements and both flag spellings.  This
module replaces the flag sprawl with a single frozen dataclass that
names each axis of the design space once:

- ``placement`` — where the staged tiles live: ``"replicated"`` (full
  staging on every device, queries shard) or ``"sharded"`` (tiles shard
  across owner devices, queries travel through the all_to_all
  exchange).
- ``probe`` — the default executor: ``"pruned"`` (routed candidate
  tiles only) or ``"dense"`` (the all-tile oracle sweep).  Per-call
  ``pruned=`` overrides remain for validation.
- ``local_index`` — the intra-tile index: ``"off"`` (unindexed oracle
  staging), ``"x"`` (canonical-first sort by ascending xmin), or
  ``"hilbert"`` (canonical-first sort by the Hilbert key of each
  member's MBR centre — square-ish chunk boxes instead of x-strips).
- ``chunk`` — chunk-box granularity in member slots, a multiple of the
  kernels' native 128; coarser boxes (e.g. 256) are broadcast down to
  the 128-slot kernel grid, trading skip precision for summary size.
- ``capacity`` / ``slack`` — per-tile member slots.  ``capacity=None``
  sizes from the staged data's max tile count; ``slack`` reserves that
  many extra free slots per tile for ``SpatialServer.append`` before a
  tile overflow forces a re-stage.
- ``shards`` — owner count under ``placement="sharded"`` with no mesh
  (in-process exchange simulation); with a mesh it must equal the mesh
  axis size and may be left ``None``.
- ``axis`` — the mesh axis name serving shards over.
- ``compact_dead_frac`` / ``restage_dead_frac`` — the compaction
  policy for tombstone deletes (``SpatialServer.delete``/``update``).
  A tile whose dead-slot fraction reaches ``compact_dead_frac`` is
  compacted in place (slots re-sorted live-first, probe/chunk boxes
  tightened, pushed as one full-row scatter); when the *global* dead
  fraction reaches ``restage_dead_frac`` the whole layout re-stages
  from the live set (also reclaiming non-canonical copies).  Either
  may be ``None`` to disable that trigger; ``restage_dead_frac``
  defaults to off because tile-local compaction usually suffices.
- ``policy`` — a :class:`PlacementPolicy` describing how owner-routed
  placements follow the query log: the EWMA decay of the router heat
  tracker, how many of the hottest tiles ``placement="heat"`` keeps
  resident on a second owner, and (optionally) how often the server
  re-plans automatically.  Ignored (but still tracked, so a later
  ``rebalance()`` has data) under ``placement="replicated"``.

The config is frozen and hashable, so a server's serving behaviour is
one immutable value — loggable, comparable, and usable as a cache key.
"""
from __future__ import annotations

import dataclasses

from ..kernels.range_probe import ops as rops

PLACEMENTS = ("replicated", "sharded", "heat")
PROBES = ("pruned", "dense")
LOCAL_INDEXES = ("off", "x", "hilbert")


@dataclasses.dataclass(frozen=True)
class PlacementPolicy:
    """How owner-routed placements track query heat.

    - ``heat_decay`` — EWMA decay applied to the per-tile hit counts
      and the tile-pair co-occurrence sketch once per observed batch:
      ``heat = decay * heat + hits``.  1.0 never forgets; smaller
      values track drifting hotspots faster.
    - ``replicate_top`` — under ``placement="heat"``, how many of the
      hottest tiles keep a second live copy on another owner.  Each
      device budgets ``ceil(T/D) + replicate_top`` tile rows, so the
      sharded-memory story degrades by an explicit, bounded amount.
    - ``rebalance_every`` — re-plan automatically every N observed
      query batches (``None`` = only on explicit
      ``SpatialServer.rebalance()`` calls).
    """

    heat_decay: float = 0.85
    replicate_top: int = 0
    rebalance_every: int | None = None

    def __post_init__(self):
        if not 0.0 < self.heat_decay <= 1.0:
            raise ValueError(f"heat_decay must be in (0, 1], "
                             f"got {self.heat_decay}")
        if self.replicate_top < 0:
            raise ValueError(f"replicate_top must be >= 0, "
                             f"got {self.replicate_top}")
        if self.rebalance_every is not None and self.rebalance_every < 1:
            raise ValueError(f"rebalance_every must be >= 1 or None, "
                             f"got {self.rebalance_every}")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Frozen serving configuration (see module docstring for axes)."""

    placement: str = "replicated"
    probe: str = "pruned"
    local_index: str = "x"
    chunk: int = rops.CHUNK
    capacity: int | None = None
    slack: int = 0
    shards: int | None = None
    axis: str = "d"
    compact_dead_frac: float | None = 0.5
    restage_dead_frac: float | None = None
    policy: PlacementPolicy = PlacementPolicy()

    def __post_init__(self):
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement must be one of {PLACEMENTS}, "
                             f"got {self.placement!r}")
        if self.probe not in PROBES:
            raise ValueError(f"probe must be one of {PROBES}, "
                             f"got {self.probe!r}")
        if self.local_index not in LOCAL_INDEXES:
            raise ValueError(f"local_index must be one of {LOCAL_INDEXES}, "
                             f"got {self.local_index!r}")
        if self.chunk <= 0 or self.chunk % rops.CHUNK:
            raise ValueError(f"chunk must be a positive multiple of the "
                             f"kernel chunk {rops.CHUNK}, got {self.chunk}")
        if self.capacity is not None and self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.slack < 0:
            raise ValueError(f"slack must be >= 0, got {self.slack}")
        if self.shards is not None and self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.shards is not None and self.placement == "replicated":
            raise ValueError("shards is only meaningful with "
                             "placement='sharded' or 'heat'")
        if not isinstance(self.policy, PlacementPolicy):
            raise ValueError(f"policy must be a PlacementPolicy, "
                             f"got {type(self.policy).__name__}")
        for name in ("compact_dead_frac", "restage_dead_frac"):
            frac = getattr(self, name)
            if frac is not None and not 0.0 < frac <= 1.0:
                raise ValueError(f"{name} must be in (0, 1] or None, "
                                 f"got {frac}")

    @property
    def indexed(self) -> bool:
        """Whether staging builds the intra-tile local index."""
        return self.local_index != "off"

    def replace(self, **changes) -> "ServeConfig":
        """A copy with ``changes`` applied (``dataclasses.replace``)."""
        return dataclasses.replace(self, **changes)
