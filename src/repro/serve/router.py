"""The global partition index (LocationSpark's router, jit form).

For any ``Partitioning`` the router maps a query batch to the
partitions that must be probed.  There is no tree — with kmax in the
hundreds-to-thousands a dense vectorised scan of partition boxes beats
pointer chasing on accelerators — but the *semantics* are the global
index: range queries route by box overlap, kNN queries get a best-first
partition ordering by MINDIST.

Per-query fan-out (how many partitions one query touches) is the
paper's boundary-object cost made workload-facing: replicated boundary
objects are exactly what forces a range query into multiple partitions,
so layouts with lower λ route narrower and serve faster.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import geometry
from ..core.partition.api import Partitioning
from ..query.knn import mindist2


@jax.jit
def route_range(parts: Partitioning, qboxes: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """(Q, 4) query boxes -> ((Q, kmax) routing mask, (Q,) fan-out)."""
    mask = geometry.intersects(qboxes[:, None, :], parts.boxes[None, :, :])
    mask = mask & parts.valid[None, :]
    return mask, jnp.sum(mask, axis=1, dtype=jnp.int32)


@jax.jit
def route_knn(parts: Partitioning, pts: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """(Q, 2) query points -> best-first partition visit order.

    Returns ``(order[Q, kmax] int32, d2[Q, kmax] f32)``: partitions
    sorted by ascending MINDIST² (invalid partitions at the end with
    +inf), the order a branch-and-bound NN search visits them.
    """
    d2 = mindist2(pts, parts.boxes)
    d2 = jnp.where(parts.valid[None, :], d2, jnp.inf)
    order = jnp.argsort(d2, axis=1).astype(jnp.int32)
    return order, d2
