"""The global partition index (LocationSpark's router, jit form).

For any ``Partitioning`` the router maps a query batch to the
partitions that must be probed.  There is no tree — with kmax in the
hundreds-to-thousands a dense vectorised scan of partition boxes beats
pointer chasing on accelerators — but the *semantics* are the global
index: range queries route by box overlap, kNN queries get a best-first
partition ordering by MINDIST.

Per-query fan-out (how many partitions one query touches) is the
paper's boundary-object cost made workload-facing: replicated boundary
objects are exactly what forces a range query into multiple partitions,
so layouts with lower λ route narrower and serve faster.

Two box sets can be routed against:

- **partition regions** (``Partitioning.boxes``) — the paper's fan-out
  metric, reported with every answer (``route_range`` / ``route_knn``);
- **canonical probe boxes** (``StagedLayout.probe_boxes``: per-tile
  tight MBR over *canonical* member MBRs) — what the pruned executor
  routes on (``candidate_range`` / ``candidate_knn``).  If a query box
  intersects an object's MBR, it intersects the probe box of the tile
  holding that object's canonical copy, so routing on probe boxes
  covers every canonical hit on **all six layouts** — overlapping
  tight-MBR and disjoint covering alike — and pruned probing of only
  the candidate tiles stays exact with zero dedup work.

Candidate lists are fixed-width ``(Q, f_max)`` int32 with ``-1``
padding — the shape the gathered ``range_probe`` kernel consumes — and
come with per-query fan-out, the cost vector that LPT query packing
uses (``serve.engine.pack_queries``).

Under tile sharding the same global candidate lists are re-expressed
in ``(owner device, local tile)`` coordinates by ``owner_split`` — the
host-side translation feeding the ``serve.exchange`` all_to_all step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import geometry
from ..core.partition.api import Partitioning
from ..core.partition.assign import round_up
from ..query.knn import mindist2

_INF = jnp.float32(jnp.inf)


@jax.jit
def route_range(parts: Partitioning, qboxes: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    """(Q, 4) query boxes -> ((Q, kmax) routing mask, (Q,) fan-out)."""
    mask = geometry.intersects(qboxes[:, None, :], parts.boxes[None, :, :])
    mask = mask & parts.valid[None, :]
    return mask, jnp.sum(mask, axis=1, dtype=jnp.int32)


@jax.jit
def route_knn(parts: Partitioning, pts: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """(Q, 2) query points -> best-first partition visit order.

    Returns ``(order[Q, kmax] int32, d2[Q, kmax] f32)``: partitions
    sorted by ascending MINDIST² (invalid partitions at the end with
    +inf), the order a branch-and-bound NN search visits them.
    """
    d2 = mindist2(pts, parts.boxes)
    d2 = jnp.where(parts.valid[None, :], d2, jnp.inf)
    order = jnp.argsort(d2, axis=1).astype(jnp.int32)
    return order, d2


# --------------------------------------------------------------------------
# candidate-tile emission (the pruned executor's input)
# --------------------------------------------------------------------------

@jax.jit
def probe_overlap(boxes: jax.Array, qboxes: jax.Array) -> jax.Array:
    """(T, 4) probe boxes x (Q, 4) queries -> (Q, T) bool overlap
    matrix.  Sentinel (inverted) boxes intersect nothing, so empty /
    padded tiles never hit.  Computed once per batch: its row sums are
    the pruned path's per-query cost (the LPT packing weight) and size
    ``f_max``, and ``candidates_from_overlap`` turns it into the
    candidate index without re-testing geometry.
    """
    return geometry.intersects(qboxes[:, None, :], boxes[None, :, :])


@jax.jit
def probe_fanout(boxes: jax.Array, qboxes: jax.Array) -> jax.Array:
    """(T, 4) probe boxes x (Q, 4) queries -> (Q,) int32 overlap
    fan-out (row sums of ``probe_overlap``)."""
    return jnp.sum(probe_overlap(boxes, qboxes), axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("f_max",))
def candidates_from_overlap(hit: jax.Array, f_max: int
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-width candidate-tile index from an overlap matrix.

    hit: (Q, T) bool from ``probe_overlap``; static ``f_max``
    -> ``(cand[Q, f_max] int32, fanout[Q] int32, overflow[Q] bool)``.

    ``cand`` holds each query's overlapping tile indices in ascending
    tile order, ``-1`` beyond its fan-out.  Queries overlapping more
    than ``f_max`` tiles are truncated and flagged in ``overflow`` —
    never silently; the server sizes ``f_max`` from the fan-out so
    overflow does not occur on the exact path.
    """
    fanout = jnp.sum(hit, axis=1, dtype=jnp.int32)
    order = jnp.argsort(~hit, axis=1, stable=True)     # hits first
    cand = order[:, :f_max].astype(jnp.int32)
    live = jnp.take_along_axis(hit, cand, axis=1)
    return jnp.where(live, cand, -1), fanout, fanout > f_max


def candidate_range(boxes: jax.Array, qboxes: jax.Array, f_max: int
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-shot ``probe_overlap`` + ``candidates_from_overlap`` (same
    return contract); callers that already hold the overlap matrix use
    the two-step form to avoid re-testing O(Q·T) geometry."""
    return candidates_from_overlap(probe_overlap(boxes, qboxes), f_max)


# --------------------------------------------------------------------------
# query-heat tracking (feeds heat-aware placement)
# --------------------------------------------------------------------------

class HeatTracker:
    """EWMA per-tile hit counts + tile-pair co-occurrence sketch.

    Accumulated host-side from the router's candidate lists — the
    (Q, F) int32 arrays every batch already produces — so tracking
    costs O(Q·F) numpy per batch and zero device work.  Two signals:

    - ``heat[t]``: decayed count of queries whose candidate list
      contained tile ``t`` — what hot-tile *replication* ranks by;
    - ``cooc[i, j]``: decayed count of queries whose candidate list
      contained both ``i`` and ``j`` — the pair weight *co-location*
      cuts (``core.placement.colocate_tiles``), because each
      cross-owner pair is a query messaging two devices.

    Deterministic: same batch sequence ⇒ bit-identical state (pure
    float64 numpy, no sampling).  ``decay`` < 1 forgets old traffic so
    the plan can follow a moving hotspot.
    """

    def __init__(self, t: int, decay: float = 0.85):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.t = int(t)
        self.decay = float(decay)
        self.heat = np.zeros(self.t, np.float64)
        self.cooc = np.zeros((self.t, self.t), np.float64)
        self.batches = 0

    def observe(self, cand: np.ndarray) -> None:
        """Fold one batch's (Q, F) candidate lists (-1 padding) in."""
        cand = np.asarray(cand)
        if cand.ndim != 2:
            raise ValueError(f"cand must be (Q, F), got {cand.shape}")
        hot = np.zeros((cand.shape[0], self.t), np.float64)
        q, f = np.nonzero(cand >= 0)
        hot[q, cand[q, f]] = 1.0               # one-hot, dedups repeats
        pair = hot.T @ hot                     # (T, T) co-occurrence
        hits = np.diagonal(pair).copy()
        np.fill_diagonal(pair, 0.0)
        self.heat = self.decay * self.heat + hits
        self.cooc = self.decay * self.cooc + pair
        self.batches += 1

    def snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """Copies of ``(heat[T], cooc[T, T])`` for the planner."""
        return self.heat.copy(), self.cooc.copy()


# --------------------------------------------------------------------------
# owner translation (sharded layouts: global tiles -> (owner, local))
# --------------------------------------------------------------------------

def owner_split(cand: np.ndarray, slots: np.ndarray, owner: np.ndarray,
                local: np.ndarray, bucket: int = 8,
                alt_owner: np.ndarray | None = None,
                alt_local: np.ndarray | None = None,
                ) -> tuple[np.ndarray, np.ndarray, dict]:
    """Translate global candidate lists into per-owner exchange tables.

    cand: (Q, F) int32 global candidate tiles (-1 padding) from
    ``candidate_range`` / ``candidate_knn``; slots: (D, Qpd) query
    packing from ``serve.engine.pack_queries`` (home placement);
    owner/local: (T,) global-tile → (owner device, local shard row)
    maps from ``core.placement.shard_tiles``.

    Returns ``(send_slot[D, D, M], send_cand[D, D, M, F_local], stats)``
    — for home device ``h`` and owner ``o``, message ``m`` carries home
    query slot ``send_slot[h, o, m]`` (-1 padding) together with that
    query's candidate tiles *owned by o, in o's local coordinates*
    (``send_cand``, -1 padded, ascending local order).  A query emits
    one message per owner holding ≥ 1 of its candidates and none to the
    rest, so exchange volume scales with routed fan-out, not D.  ``M``
    and ``F_local`` are maxima over all pairs, rounded up to ``bucket``
    so jitted exchange steps recompile per size bucket, not per batch.

    ``alt_owner``/``alt_local`` (both (T,) int32, ``-1`` = no replica)
    describe a second live copy of some tiles (``HeatSharded``).  A
    replicated candidate may be probed on either owner — both rows are
    bit-exact — so the split routes it to whichever placement helps:
    an owner the query *already* messages (saving a whole message),
    else the owner with the fewest candidate rows gathered so far this
    batch (spreading probe load off the hot device).  Deterministic:
    fixed (home, slot, candidate) order, ties to the primary owner
    then the lower device id.  Each candidate still reaches exactly
    one owner, so the merge stays owner-disjoint and exact.

    Host-side numpy (runs once per batch, O(Q·F)); ``stats`` reports
    the message/width geometry for the serving stats dict, plus the
    per-owner probe load (gathered candidate rows), its max/mean
    imbalance, the padded exchange buffer bytes, and how many
    candidate rows took the alternate replica.
    """
    d, qpd = slots.shape
    send: list[list[list[tuple[int, np.ndarray]]]] = \
        [[[] for _ in range(d)] for _ in range(d)]
    f_local = 1
    n_msgs = 0
    probe_rows = np.zeros(d, np.int64)
    routed_alt = 0
    for h in range(d):
        for s in range(qpd):
            qi = slots[h, s]
            if qi < 0:
                continue
            c = cand[qi]
            c = c[c >= 0]
            if c.size == 0:
                continue
            ow = owner[c].copy()
            lc = local[c].copy()
            if alt_owner is not None:
                flex = np.flatnonzero(alt_owner[c] >= 0)
                if flex.size:
                    fixed_owners = set(np.unique(np.delete(ow, flex)))
                    for k in flex:
                        o1, o2 = int(ow[k]), int(alt_owner[c[k]])
                        if o1 in fixed_owners:
                            pick = o1
                        elif o2 in fixed_owners:
                            pick = o2
                        elif probe_rows[o2] < probe_rows[o1]:
                            pick = o2
                        else:
                            pick = o1
                        if pick != o1:
                            ow[k] = pick
                            lc[k] = alt_local[c[k]]
                            routed_alt += 1
                        fixed_owners.add(pick)
            np.add.at(probe_rows, ow, 1)
            for o in np.unique(ow):
                lt = np.sort(lc[ow == o])
                send[h][int(o)].append((s, lt))
                f_local = max(f_local, int(lt.size))
                n_msgs += 1
    m = max(1, max(len(send[h][o]) for h in range(d) for o in range(d)))
    m = min(qpd, round_up(m, bucket))
    f_local = round_up(f_local, bucket)
    send_slot = np.full((d, d, m), -1, np.int32)
    send_cand = np.full((d, d, m, f_local), -1, np.int32)
    for h in range(d):
        for o in range(d):
            for j, (s, lt) in enumerate(send[h][o]):
                send_slot[h, o, j] = s
                send_cand[h, o, j, :lt.size] = lt
    # Padded all_to_all buffer estimate for one range_counts exchange:
    # forward — per (home, owner) pair, m message slots each carrying a
    # slot id (4 B), a query box (16 B) and f_local local tiles (4 B
    # each); return — one count (4 B) per message slot.
    xbytes = d * d * m * (4 + 16 + 4 * f_local) + d * d * m * 4
    mean_rows = float(probe_rows.mean())
    stats = dict(m_per_pair=m, f_local=f_local, messages=n_msgs,
                 probe_rows=probe_rows.tolist(),
                 probe_load_imbalance=(float(probe_rows.max()) /
                                       max(mean_rows, 1e-9)),
                 exchange_bytes=int(xbytes),
                 routed_alt=int(routed_alt))
    return send_slot, send_cand, stats


def linf_dist(pts: jax.Array, boxes: jax.Array) -> jax.Array:
    """L∞ distance, point to closed box: (..., 2) x (T, 4) -> (..., T).

    0 inside the box; +inf for sentinel (inverted) boxes.  This is the
    kNN frontier metric: the deepening box ``[pt ± r]`` intersects a
    tile's probe box iff its L∞ distance is ≤ r.
    """
    x, y = pts[..., None, 0], pts[..., None, 1]
    dx = jnp.maximum(jnp.maximum(boxes[..., 0] - x, x - boxes[..., 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(boxes[..., 1] - y, y - boxes[..., 3]), 0.0)
    d = jnp.maximum(dx, dy)
    return jnp.where(boxes[..., 0] <= boxes[..., 2], d, _INF)


@functools.partial(jax.jit, static_argnames=("f_max",))
def candidate_knn(boxes: jax.Array, pts: jax.Array, f_max: int
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """MINDIST frontier: each point's ``f_max`` nearest tiles.

    boxes: (T, 4) probe boxes; pts: (Q, 2); static ``f_max``
    -> ``(cand[Q, f_max] int32, dist[Q, f_max] f32, excluded[Q] f32)``.

    ``cand`` lists tiles by ascending L∞ distance (``-1`` where fewer
    than ``f_max`` non-empty tiles exist), ``dist`` the matching
    distances, and ``excluded`` the L∞ distance of the *nearest tile
    left out* of the frontier (+inf when nothing is excluded).  A
    pruned kNN whose final refinement radius reaches ``excluded`` may
    have missed candidates and must flag overflow — exactness is
    checkable, never assumed.
    """
    d = linf_dist(pts, boxes)                          # (Q, T)
    order = jnp.argsort(d, axis=1).astype(jnp.int32)
    ds = jnp.take_along_axis(d, order, axis=1)
    cand = jnp.where(jnp.isfinite(ds[:, :f_max]), order[:, :f_max], -1)
    t = boxes.shape[0]
    excluded = ds[:, f_max] if f_max < t else jnp.full((pts.shape[0],), _INF)
    return cand, ds[:, :f_max], excluded
