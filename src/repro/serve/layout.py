"""The `TileLayout` protocol: one staging + serving contract, two
placements, and the streaming append lifecycle.

PR 1–4 grew two parallel serving stacks — a replicated one
(``stage`` → ``StagedLayout`` → query-sharded ``shard_map`` steps) and
a sharded one (``stage_sharded`` → ``ShardedLayout`` → the owner-routed
``all_to_all`` exchange) — and ``SpatialServer`` forked on
``self.sharded`` at every query entry point.  This module collapses the
fork: both placements implement one protocol —

- ``ReplicatedTiles`` — the full staging lives on every device; only
  queries shard.  Executors are the gathered ``query.range`` /
  ``query.knn`` paths under a query-sharded ``shard_map`` step (staging
  arrays ride along as replicated step *arguments*, never baked-in
  closures, so streaming appends refresh data without recompiles).
- ``ShardedTiles`` — tiles shard across owner devices
  (``core.placement.shard_tiles``) and every batch runs the
  ``serve.exchange`` orchestrations.  The replicated full staging is
  kept host-side only, as the ``probe="dense"`` oracle.

``SpatialServer`` (``serve.engine``) is written once against the
protocol: route → pack → ``tiles.range_counts(...)`` — no placement
branches.  Staging itself (``stage_tiles``) is configured by one frozen
``ServeConfig``: local-index mode ``off``/``x``/``hilbert`` (ascending
xmin vs Hilbert-key member order inside each tile — Hilbert makes chunk
boxes square-ish instead of x-strips), chunk-box granularity, and the
capacity/slack policy.

**Streaming appends** (the ROADMAP's moving-dataset item): staging
reserves ``config.slack`` free slots per tile past the observed max
tile count, and ``append(mbrs)`` inserts new objects into that slack —
host-side mirrors are updated incrementally (probe boxes and chunk
boxes union the new member MBRs, so routing and chunk skipping stay
exact) and pushed to the device without re-tracing any serving step.
The device refresh is an **O(M) scatter**: the mutation paths emit a
*scatter plan* — the touched ``(tile, slot)`` cells with their box /
id / alive values, the touched probe rows and chunk cells, plus full
rows for compacted tiles — and ``_scatter(plan)`` pushes exactly those
bytes with ``.at[]`` updates (replicated on ``ReplicatedTiles``;
owner-local under a mesh on ``ShardedTiles``, where a cached
``shard_map`` step keeps each device's own writes and ``mode="drop"``s
the rest, so no cross-device traffic moves).  Transfer cost is
proportional to the batch, never to T·cap; the host mirrors stay the
source of truth.

**Tombstone deletes and updates**: every slot carries an *alive* bit
(``StagedLayout.alive``) — True iff the slot holds a live canonical
member; initial staging sets it to the canonical mask.  ``delete(ids)``
flips only those bits (the smallest possible scatter) and leaves box
data in place: probe and chunk boxes stay exact *supersets*, so
routing is unchanged while the alive mask — threaded through all four
probe-kernel families — removes dead members from every answer.
``update(ids, mbrs)`` is a tombstone of the old canonical slots plus a
slack insert of the new MBRs under the same ids.  Dead slots are
reclaimed by **compaction**: when a tile's dead fraction reaches
``config.compact_dead_frac`` its slots are rebuilt live-first in local
sort order (probe row and chunk boxes tighten back to the live set)
and pushed as one full-row scatter; ``config.restage_dead_frac`` on
the *global* dead fraction escalates to a full re-stage, which also
reclaims the non-canonical copies tile-local compaction leaves behind.
A tile overflow triggers a **re-stage**: the layout is rebuilt from the
accumulated dataset at a grown capacity (same ``Partitioning``, fresh
sort + chunk boxes), owners re-balance under sharding
(``shard_tiles`` on the new member counts — the ``ceil(T/D)``
per-device memory bound is re-established, move counts reported), and
the server's ``WidthPolicy`` resets.  Because answers are functions of
the canonical membership *sets* — counts are sums, id lists are sorted
ascending, kNN ties break on ``(distance, id)`` — append-then-query is
bit-identical to re-staging from scratch, which the streaming tests
assert on all six layouts.

Membership for appends (and, identically, for re-stages) extends MASJ
assignment with **nearest-tile adoption**: an object intersecting no
partition region — possible on the non-covering hc/str layouts once
data moves — is assigned to the nearest valid tile.  Pruned routing
stays exact because probe boxes are unions of canonical *member* MBRs:
wherever an object lands, the probe box of that tile grows to cover
it.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import geometry, placement
from ..core.compat import shard_map
from ..core.partition import api, assign
from ..core.partition.assign import round_up
from ..kernels.hilbert import ops as hilbert_ops
from ..kernels.range_probe import ops as rops
from ..query import knn as knn_mod, range as range_mod
from . import exchange, router
from .config import ServeConfig

_SENTINEL = np.array(geometry.SENTINEL_BOX, np.float32)

log = logging.getLogger(__name__)


# --------------------------------------------------------------------------
# staged-array containers (unchanged pytree formats from PR 1–4)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagedLayout:
    """Device-resident staging of one partitioned dataset.

    tiles       : (T, cap, 4) member MBRs, sentinel-padded (all copies)
    ids         : (T, cap) int32 member ids, -1 in padding slots
    canon_tiles : (T, cap, 4) canonical copies only (others sentineled)
    tile_boxes  : (T, 4) partition regions (sentinel for invalid rows)
    probe_boxes : (T, 4) tight MBR over each tile's *canonical* member
                  MBRs (sentinel where a tile holds none) — the box set
                  the pruned executor routes on; covers every canonical
                  hit on all six layouts
    chunk_boxes : (T, C, 4) the **local index** (``None`` when staged
                  with ``local_index="off"``): slots are sorted
                  canonical-first by the configured key (ascending xmin
                  or Hilbert), and chunk c's box bounds the canonical
                  members in slots [c·128, (c+1)·128) — sentinel where
                  a chunk holds none, so the ``*_skip`` probe kernels
                  skip it outright
    alive       : (T, cap) bool — slot holds a *live* canonical member.
                  Initial staging sets it to the canonical mask;
                  tombstone deletes flip bits off in place.  Threaded
                  into every probe kernel so dead members stop
                  answering while their (stale, still-superset) probe
                  and chunk boxes keep routing exact
    uni         : (4,) dataset universe
    """

    tiles: jax.Array
    ids: jax.Array
    canon_tiles: jax.Array
    tile_boxes: jax.Array
    probe_boxes: jax.Array
    chunk_boxes: jax.Array | None
    alive: jax.Array
    uni: jax.Array


jax.tree_util.register_dataclass(
    StagedLayout,
    data_fields=("tiles", "ids", "canon_tiles", "tile_boxes",
                 "probe_boxes", "chunk_boxes", "alive", "uni"),
    meta_fields=())


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Owner-sharded staging: per-device tile shards + the routing maps.

    canon_shards : (D, T_local, cap, 4) canonical member MBRs, one tile
                   shard per device (sentinel-padded rows past a
                   device's tile count) — device-sharded when a mesh is
                   given, so per-device memory is O(total/D)
    id_shards    : (D, T_local, cap) int32 member ids (-1 padding)
    alive_shards : (D, T_local, cap) bool per-shard alive mask (see
                   ``StagedLayout.alive``; False in padding rows)
    chunk_shards : (D, T_local, C, 4) per-shard local index (chunk
                   boxes in owner-local tile rows; None when staged
                   with ``local_index="off"``)
    probe_boxes  : (T, 4) *global* canonical probe boxes — routing is a
                   host-side O(Q·T) scan, so the (small) index stays
                   replicated while the (large) member data shards
    chunk_boxes  : (T, C, 4) *global* chunk boxes (None when unindexed)
                   — like the probe boxes, a small replicated index;
                   used for host-side skip-rate reporting
    uni          : (4,) dataset universe
    owner        : (T,) int32 host map, global tile -> owner device
    local        : (T,) int32 host map, global tile -> row in the
                   owner's shard
    rep_owner    : (T,) int32 host map, global tile -> device holding
                   its *replica* (``-1`` = not replicated; ``None``
                   when staged without hot-tile replication).  Replica
                   rows live past ``t_local`` in the shard arrays
                   (rows ``t_local .. t_local + replicate_top``) and
                   are bit-exact copies of the primary rows — the
                   exchange may probe a candidate on either owner.
    rep_local    : (T,) int32 replica shard row (``-1`` / ``None`` as
                   above)
    """

    canon_shards: jax.Array
    id_shards: jax.Array
    alive_shards: jax.Array
    chunk_shards: jax.Array | None
    probe_boxes: jax.Array
    chunk_boxes: jax.Array | None
    uni: jax.Array
    owner: np.ndarray
    local: np.ndarray
    rep_owner: np.ndarray | None = None
    rep_local: np.ndarray | None = None


# --------------------------------------------------------------------------
# staging (stage once; the append path shares membership + marking rules)
# --------------------------------------------------------------------------

def membership(parts: api.Partitioning, mbrs: jax.Array) -> jax.Array:
    """(N, kmax) bool MASJ membership with nearest-tile adoption.

    Geometric membership is box intersection against every valid
    partition region (the paper's multi-assignment).  An object
    intersecting *no* region — possible for appends on the
    non-covering hc/str layouts — is adopted by the nearest valid tile
    (squared box-to-box distance, ties to the lowest tile index via
    ``argmin``), so staging is total: every object always holds at
    least one (hence exactly one canonical) slot.  For objects the
    regions do cover, adoption never fires and membership equals plain
    MASJ assignment.
    """
    b = parts.boxes
    hit = geometry.intersect_matrix(mbrs, b) & parts.valid[None, :]
    none = ~jnp.any(hit, axis=1)
    # reprolint: disable=host-sync -- staging-time guard, eager by
    # contract: skips the adoption pass in the covering common case
    if not bool(none.any()):       # host-called, eager: the covering /
        return hit                 # in-universe common case pays nothing
    dx = jnp.maximum(jnp.maximum(b[None, :, 0] - mbrs[:, None, 2],
                                 mbrs[:, None, 0] - b[None, :, 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(b[None, :, 1] - mbrs[:, None, 3],
                                 mbrs[:, None, 1] - b[None, :, 3]), 0.0)
    d2 = jnp.where(parts.valid[None, :], dx * dx + dy * dy, jnp.inf)
    nearest = jnp.argmin(d2, axis=1)
    adopt = none[:, None] & (jnp.arange(parts.kmax)[None, :]
                             == nearest[:, None])
    return hit | adopt


def _chunk_summary(canon_tiles: jax.Array, chunk: int) -> jax.Array:
    """(T, cap, 4) canonical tiles -> (T, ceil(cap/128), 4) chunk boxes
    at ``chunk``-slot granularity.

    Boxes are computed per ``chunk``-member slot group (the tight MBR
    over its canonical member MBRs; sentinel slots are min/max-neutral
    and an all-sentinel group collapses to the sentinel box) and then
    broadcast down to the kernels' native 128-slot grid — a ``chunk``
    of 256 stores each box twice, trading skip precision for summary
    size without touching the kernels.
    """
    t, cap, _ = canon_tiles.shape
    g = -(-cap // chunk)
    pad = g * chunk - cap
    if pad:
        canon_tiles = jnp.concatenate(
            [canon_tiles,
             jnp.broadcast_to(jnp.asarray(_SENTINEL), (t, pad, 4))], axis=1)
    grp = canon_tiles.reshape(t, g, chunk, 4)
    boxes = jnp.concatenate(
        [jnp.min(grp[..., :2], axis=2), jnp.max(grp[..., 2:], axis=2)],
        axis=-1)
    c128 = -(-cap // rops.CHUNK)
    return jnp.repeat(boxes, chunk // rops.CHUNK, axis=1)[:, :c128]


def _local_sort_order(canon_tiles: jax.Array, ids: jax.Array, mode: str,
                      uni: jax.Array) -> jax.Array:
    """Per-tile slot permutation for the local index.

    ``"x"``: stable argsort on canonical xmin — non-canonical copies
    and padding carry the sentinel 9e9 and sink to the tail in their
    original (live-before-padding) order.  ``"hilbert"``: canonical
    slots lead in ascending Hilbert key of their MBR centre
    (``kernels.hilbert`` over the dataset universe), with a three-tier
    primary key (canonical < non-canonical live < padding) so live
    slots stay a prefix — the invariant the append path's free-slot
    tracking relies on.
    """
    if mode == "x":
        return jnp.argsort(canon_tiles[..., 0], axis=1, stable=True)
    t, cap, _ = canon_tiles.shape
    canon = canon_tiles[..., 0] < 1e9
    centers = (canon_tiles[..., :2] + canon_tiles[..., 2:]) * 0.5
    keys = hilbert_ops.hilbert_keys(centers.reshape(-1, 2),
                                    uni).reshape(t, cap)
    tier = jnp.where(canon, 0, jnp.where(ids >= 0, 1, 2)).astype(jnp.int32)
    o1 = jnp.argsort(keys, axis=1, stable=True)
    o2 = jnp.argsort(jnp.take_along_axis(tier, o1, axis=1), axis=1,
                     stable=True)
    return jnp.take_along_axis(o1, o2, axis=1)


def stage_tiles(parts: api.Partitioning, mbrs: jax.Array,
                config: ServeConfig | None = None,
                ids: jax.Array | None = None
                ) -> tuple[StagedLayout, dict]:
    """MASJ-stage ``mbrs`` under ``parts`` per ``config``.

    mbrs: (N, 4) f32 -> ``(StagedLayout, stats)``; raises on capacity
    overflow (never silently drops members).  ``stats['replication']``
    is the paper's λ.  ``config.capacity=None`` sizes capacity from the
    staged data's max tile count plus ``config.slack`` reserved append
    slots, 128-aligned; an explicit capacity is used as given (its
    headroom over the max count *is* the slack).

    ``ids`` (optional, (N,) int32) assigns explicit object ids instead
    of ``0..N-1`` — the re-stage path of a layout that has seen deletes
    passes the surviving ids here, so the running id numbering (and
    therefore every query answer) survives re-staging a live set with
    holes in it.

    ``config.local_index`` other than ``"off"`` builds the intra-tile
    local index: each tile's slots are permuted canonical-first by the
    configured sort key (``_local_sort_order``) and a per-128-slot
    chunk-box summary at ``config.chunk`` granularity is carried in
    ``chunk_boxes`` for the chunk-skipping probe kernels.  The
    permutation is applied to ``tiles``/``ids``/``canon_tiles``
    consistently, so canonical marking — and therefore every query
    answer — is unchanged; ``local_index="off"`` staging is the
    unindexed oracle.
    """
    config = config or ServeConfig()
    n = mbrs.shape[0]
    hit = membership(parts, mbrs)
    counts = jnp.sum(hit, axis=0, dtype=jnp.int32)
    if config.capacity is None:
        capacity = round_up(max(int(jnp.max(counts)) + config.slack, 1), 128)
    else:
        capacity = config.capacity
    members, mask, overflow = assign.assign_from_hit(hit, capacity)
    if int(jnp.sum(overflow)) > 0:
        over = np.asarray(counts) - capacity
        raise ValueError(
            f"staging overflow: capacity {capacity} < max tile count "
            f"{int(jnp.max(counts))} ({int((over > 0).sum())} of "
            f"{int(parts.k())} tiles overflow, worst by "
            f"{int(over.max())} members — raise capacity or payload)")

    sentinel = jnp.asarray(_SENTINEL)
    tiles = jnp.where(mask[..., None], mbrs[members], sentinel)
    obj_ids = (jnp.arange(n, dtype=jnp.int32) if ids is None
               else jnp.asarray(ids, jnp.int32))
    ids = jnp.where(mask, obj_ids[members], -1).astype(jnp.int32)

    # canonical mark: first copy of each id in tile-major order wins,
    # so every object has exactly one canonical slot
    flat = ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    s = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    canon = jnp.zeros_like(flat, bool).at[order].set(first & (s >= 0))
    canon = canon.reshape(ids.shape)
    canon_tiles = jnp.where(canon[..., None], tiles, sentinel)

    uni = geometry.universe(mbrs)
    chunk_boxes = None
    if config.indexed:
        slot_order = _local_sort_order(canon_tiles, ids, config.local_index,
                                       uni)

        def permute(a):
            idx = slot_order if a.ndim == 2 else slot_order[..., None]
            return jnp.take_along_axis(a, jnp.broadcast_to(idx, a.shape),
                                       axis=1)

        tiles, ids, canon_tiles = (permute(tiles), permute(ids),
                                   permute(canon_tiles))
        chunk_boxes = _chunk_summary(canon_tiles, config.chunk)

    # canonical probe boxes: sentinel slots are min/max-neutral, and an
    # all-sentinel tile collapses back to the sentinel box
    probe_boxes = jnp.concatenate(
        [jnp.min(canon_tiles[..., :2], axis=1),
         jnp.max(canon_tiles[..., 2:], axis=1)], axis=-1)

    tile_boxes = jnp.where(parts.valid[:, None], parts.boxes, sentinel)
    # a freshly staged layout has no tombstones: alive == canonical mask
    # (serving always passes the mask, so the very first delete changes
    # only array *values* — no executor ever re-traces for it)
    alive = canon_tiles[..., 0] < 1e9
    layout = StagedLayout(tiles=tiles, ids=ids, canon_tiles=canon_tiles,
                          tile_boxes=tile_boxes, probe_boxes=probe_boxes,
                          chunk_boxes=chunk_boxes, alive=alive, uni=uni)
    stats = dict(
        n=n, t=int(parts.k()), cap=capacity,
        # tiles holding >= 1 canonical member: the widest candidate list
        # the pruned executor can ever need (<= t, since padding rows and
        # canonically-empty tiles probe as sentinel)
        t_live=int(jnp.sum(probe_boxes[:, 0] <= probe_boxes[:, 2])),
        chunks=0 if chunk_boxes is None else int(chunk_boxes.shape[1]),
        replication=float(jnp.sum(counts)) / n - 1.0,
        local_index=config.local_index, chunk=config.chunk,
        slack=config.slack,
    )
    return layout, stats


def _scatter_shards(canon_np: np.ndarray, ids_np: np.ndarray,
                    alive_np: np.ndarray,
                    chunk_np: np.ndarray | None, owner: np.ndarray,
                    local: np.ndarray, t_local: int, d: int,
                    mesh: Mesh | None, axis: str):
    """Host scatter of the global staging into (D, T_local, ...) shard
    arrays, device_put-sharded over ``axis`` when a mesh is given (no
    transient full-size single-device copy — peak per-device memory
    stays O(total/D))."""
    cap = ids_np.shape[1]
    canon_sh = np.broadcast_to(_SENTINEL, (d, t_local, cap, 4)).copy()
    ids_sh = np.full((d, t_local, cap), -1, np.int32)
    alive_sh = np.zeros((d, t_local, cap), bool)
    canon_sh[owner, local] = canon_np
    ids_sh[owner, local] = ids_np
    alive_sh[owner, local] = alive_np
    cb_sh = None
    if chunk_np is not None:
        c = chunk_np.shape[1]
        cb_sh = np.broadcast_to(_SENTINEL, (d, t_local, c, 4)).copy()
        cb_sh[owner, local] = chunk_np
    if mesh is not None:
        sharding = NamedSharding(mesh, P(axis))
        return (jax.device_put(canon_sh, sharding),
                jax.device_put(ids_sh, sharding),
                jax.device_put(alive_sh, sharding),
                None if cb_sh is None else jax.device_put(cb_sh, sharding))
    return (jnp.asarray(canon_sh), jnp.asarray(ids_sh),
            jnp.asarray(alive_sh),
            None if cb_sh is None else jnp.asarray(cb_sh))


def _plan_replicas(owner: np.ndarray, score: np.ndarray, t_local: int,
                   d: int, replicate_top: int,
                   cooc: np.ndarray | None = None):
    """Place one replica of each of the ``replicate_top`` hottest tiles
    on a second owner.  Replica rows occupy shard rows past
    ``t_local``; each device hosts at most ``replicate_top`` replicas,
    so the per-device row budget is exactly ``t_local +
    replicate_top``.  Targets are chosen greedily by descending tile
    score.  With ``cooc`` observed, the target is the non-primary
    device holding the most co-occurring traffic (primary tiles plus
    replicas already placed) — a query whose candidates straddle the
    primary cut can then resolve all of them on one owner.  Without it
    (or when no co-occurrence reaches other devices), the target is the
    least score-loaded device, with loads adjusted as if the replica
    takes half the tile's traffic — the same split the exchange's
    least-loaded routing converges to.  Deterministic."""
    t = owner.shape[0]
    rep_owner = np.full(t, -1, np.int32)
    rep_local = np.full(t, -1, np.int32)
    hot = np.argsort(-score, kind="stable")[:min(replicate_top, t)]
    dev_load = np.zeros(d, np.float64)
    np.add.at(dev_load, owner, score)
    rep_count = np.zeros(d, np.int64)
    aff = None
    if cooc is not None:
        w = np.asarray(cooc, np.float64)
        w = w + w.T
        np.fill_diagonal(w, 0.0)
        onehot = np.zeros((t, d), np.float64)
        onehot[np.arange(t), owner] = 1.0
        aff = w @ onehot            # (t, d) co-traffic per device
    for tt in hot.tolist():
        open_ = [dv for dv in range(d)
                 if dv != owner[tt] and rep_count[dv] < replicate_top]
        if not open_:
            continue
        if aff is not None and max(aff[tt, dv] for dv in open_) > 0:
            dv = max(open_, key=lambda x: (aff[tt, x], -dev_load[x], -x))
        else:
            dv = min(open_, key=lambda x: (dev_load[x], x))
        rep_owner[tt] = dv
        rep_local[tt] = t_local + rep_count[dv]
        rep_count[dv] += 1
        dev_load[dv] += 0.5 * score[tt]
        dev_load[owner[tt]] -= 0.5 * score[tt]
        if aff is not None:
            aff[:, dv] += w[:, tt]  # the replica is now resident on dv
    return rep_owner, rep_local


def shard_staged(layout: StagedLayout, stats: dict, n_shards: int,
                 mesh: Mesh | None = None, axis: str = "d",
                 prev_owner: np.ndarray | None = None,
                 cooc: np.ndarray | None = None,
                 heat: np.ndarray | None = None,
                 replicate_top: int = 0
                 ) -> tuple[ShardedLayout, tuple, dict]:
    """Shard a staged layout's tiles across ``n_shards`` owner devices.

    Placement is cost-balanced capped LPT on per-tile member counts
    (``core.placement.shard_tiles``): probe cost spreads like the
    member mass while no device holds more than ``ceil(T/D)`` tiles, so
    per-device shard memory is at most one tile over an even split.
    ``prev_owner`` (a streaming re-balance) adds the moved-tile count
    to the stats.

    The heat-aware extensions (``HeatSharded`` / ``rebalance``):
    ``cooc`` switches primary placement to the co-locating local search
    (``placement.colocate_tiles``), and ``replicate_top`` > 0 appends
    one bit-exact replica of each of the hottest tiles (ranked by
    ``heat`` when observed, member counts cold) in the shard rows past
    ``t_local`` — per-device rows are exactly ``t_local +
    replicate_top`` regardless of how many replicas actually place, so
    shard shapes (and the cached exchange steps) are stable across
    re-plans.

    Returns ``(ShardedLayout, (canon_np, ids_np), stats)`` — the numpy
    pair is the host-side copy of the *unsharded* canonical staging,
    kept off-device for the ``probe="dense"`` oracle path.
    """
    canon_np = np.asarray(layout.canon_tiles)
    ids_np = np.asarray(layout.ids)
    alive_np = np.asarray(layout.alive)
    chunk_np = (None if layout.chunk_boxes is None
                else np.asarray(layout.chunk_boxes))
    d = max(1, int(n_shards))
    if d == 1:
        replicate_top = 0      # a second owner needs a second device
    member_counts = (ids_np >= 0).sum(axis=1).astype(np.float64)
    owner, local, t_local, pstats = placement.shard_tiles(
        member_counts, d, prev_owner=prev_owner, cooc=cooc)
    rep_owner = rep_local = None
    t_rows = t_local
    n_rep = 0
    owner_all, local_all = owner, local
    data = (canon_np, ids_np, alive_np, chunk_np)
    if replicate_top > 0:
        score = member_counts
        if heat is not None and np.any(np.asarray(heat) > 0):
            score = np.asarray(heat, np.float64)
        rep_owner, rep_local = _plan_replicas(owner, score, t_local, d,
                                              int(replicate_top),
                                              cooc=cooc)
        t_rows = t_local + int(replicate_top)
        reps = np.flatnonzero(rep_owner >= 0)
        n_rep = int(reps.size)
        if n_rep:
            owner_all = np.concatenate([owner, rep_owner[reps]])
            local_all = np.concatenate([local, rep_local[reps]])
            data = tuple(
                None if a is None
                else np.concatenate([a, a[reps]], axis=0)
                for a in data)
    canon_shards, id_shards, alive_shards, chunk_shards = _scatter_shards(
        *data, owner_all, local_all, t_rows, d, mesh, axis)
    slayout = ShardedLayout(canon_shards=canon_shards, id_shards=id_shards,
                            alive_shards=alive_shards,
                            chunk_shards=chunk_shards,
                            probe_boxes=layout.probe_boxes,
                            chunk_boxes=layout.chunk_boxes, uni=layout.uni,
                            owner=owner, local=local,
                            rep_owner=rep_owner, rep_local=rep_local)
    stats = dict(stats, shards=d, t_local=t_local,
                 shard_bytes=(canon_shards.nbytes + id_shards.nbytes
                              + alive_shards.nbytes) // d,
                 placement_skew=pstats["skew"],
                 replicated_tiles=n_rep)
    for key in ("cut_before", "cut_after"):
        if key in pstats:
            stats[key] = pstats[key]
    if "moved" in pstats:
        stats["moved_tiles"] = pstats["moved"]
    return slayout, (canon_np, ids_np), stats


# --------------------------------------------------------------------------
# query packing (host): fan-out-weighted LPT onto devices
# --------------------------------------------------------------------------

def pack_queries(costs: np.ndarray, n_devices: int
                 ) -> tuple[np.ndarray, dict]:
    """LPT-pack queries onto devices by per-query cost.

    costs: (Q,) — routed fan-out on the pruned path, so hotspot queries
    spread across devices instead of serialising one of them.  Returns
    ``(slots[D, Qpd] int32 query indices, stats)``; -1 slots are
    padding.  Qpd is the max per-device group size, so one straggler
    hotspot group bounds the step — exactly what LPT minimises.

    A degenerate all-zero cost vector falls back to uniform costs (LPT
    with equal weights round-robins), so queries still spread across
    devices instead of piling onto device 0.
    """
    d = max(1, n_devices)
    costs = costs.astype(np.float64)
    if costs.size and not np.any(costs > 0):
        costs = np.ones_like(costs)
    dev, makespan, mean_load = placement.lpt_pack(costs, d)
    groups = [np.flatnonzero(dev == i) for i in range(d)]
    qpd = max(1, max(len(g) for g in groups))
    slots = np.full((d, qpd), -1, np.int32)
    for i, g in enumerate(groups):
        slots[i, :len(g)] = g
    stats = dict(makespan=makespan, mean_load=mean_load,
                 skew=makespan / max(mean_load, 1e-9), qpd=qpd)
    return slots, stats


def _pack_rows(arr: np.ndarray, slots: np.ndarray, pad) -> np.ndarray:
    """Scatter per-query rows into the packed (D, Qpd, ...) slot grid,
    filling -1 slots with ``pad`` (the single definition shared by the
    replicated and sharded executors)."""
    a = np.asarray(arr)
    pad = np.asarray(pad, a.dtype)
    out = np.broadcast_to(pad, slots.shape + pad.shape).copy()
    live = slots >= 0
    out[live] = a[slots[live]]
    return out


def _unpack_rows(x, slots: np.ndarray, n_queries: int) -> np.ndarray:
    """Invert ``_pack_rows``: (D, Qpd, ...) step output -> per-query
    rows in original batch order.  (Steps that emit a flat
    (D·Qpd, ...) leading axis reshape before calling.)"""
    x = np.asarray(x)
    x = x.reshape((slots.size,) + x.shape[2:])
    live = slots >= 0
    res = np.zeros((n_queries,) + x.shape[1:], x.dtype)
    res[slots[live]] = x[live.ravel()]
    return res


def _knn_cost_proxy(uni_np: np.ndarray, n: int, dist, k: int) -> np.ndarray:
    """LPT packing weight for a kNN batch: tiles the first deepening box
    would touch (matches the radius the kernel actually starts from —
    density over the ``n`` live canonical members, not the padded slot
    count)."""
    diag = float(np.linalg.norm(uni_np[2:] - uni_np[:2]))
    r0 = float(knn_mod.initial_radius(jnp.float32(diag), k, n))
    return (1.0 + np.sum(np.asarray(dist) <= r0, axis=1)
            ).astype(np.float64)


# --------------------------------------------------------------------------
# the protocol
# --------------------------------------------------------------------------

@runtime_checkable
class TileLayout(Protocol):
    """What ``SpatialServer`` serves against — one contract, two
    placements.

    ``mode`` names the routed executor in answer stats (``"pruned"``
    replicated, ``"sharded"`` owner-routed, ``"heat"`` owner-routed
    with heat-aware co-location + hot-tile replicas).  The routed
    executors take
    the server's already-routed ``(Q, F)`` candidate lists + LPT cost
    vector; ``knn_attempt`` routes its own MINDIST frontier at width
    ``f`` (one rung of the server's widen-and-retry ladder) and returns
    the excluded distance the exactness check needs.  The ``dense_*``
    trio is the all-tile oracle.  ``append`` / ``delete`` / ``update``
    / ``compact`` are the ingest lifecycle: slack inserts, tombstones,
    and slot reclamation, each pushed to the device as an O(M) scatter
    — re-staging (which re-balances owners under sharding) on tile
    overflow or past ``restage_dead_frac`` — mutating ``stats`` in
    place (``SpatialServer`` shares the dict).
    """

    parts: api.Partitioning
    config: ServeConfig
    stats: dict
    mode: str
    shards: int

    @property
    def probe_boxes(self) -> jax.Array: ...

    @property
    def chunk_boxes(self) -> jax.Array | None: ...

    @property
    def uni(self) -> jax.Array: ...

    def resident_tile_bytes(self) -> int: ...

    def append(self, mbrs) -> dict: ...

    def delete(self, ids) -> dict: ...

    def update(self, ids, mbrs) -> dict: ...

    def compact(self) -> dict: ...

    def rebalance(self, heat=None, cooc=None) -> dict: ...

    def range_counts(self, qboxes, cand, costs): ...

    def range_ids(self, qboxes, cand, costs, max_hits: int): ...

    def knn_attempt(self, pts, k: int, max_cand: int, f: int): ...

    def dense_range_counts(self, qboxes): ...

    def dense_range_ids(self, qboxes, max_hits: int): ...

    def dense_knn(self, pts, k: int, max_cand: int): ...


def _fmt_ids(arr) -> str:
    """Name the offending ids in an ingest error (first few + count)."""
    vals = ", ".join(str(int(i)) for i in arr[:8])
    if arr.size > 8:
        vals += f", ... ({int(arr.size)} total)"
    return vals


def _pad_pow2(idx: np.ndarray, *vals: np.ndarray):
    """Pad a scatter's leading dim to the next power of two by
    repeating the last entry.  Duplicate writes of an identical value
    are harmless, and size-bucketed shapes bound the eager scatter's
    recompiles to one per bucket instead of one per distinct batch
    size (the sharded owner scatter buckets the same way)."""
    k = idx.shape[0]
    kb = 1 << max(0, (k - 1).bit_length())
    if kb == k:
        return (idx, *vals)
    pad = kb - k
    return tuple(np.concatenate([a, np.repeat(a[-1:], pad, axis=0)])
                 for a in (idx, *vals))


def _merge_plans(a: dict, b: dict) -> dict:
    """Concatenate two scatter plans key-wise.  Entries are
    ``(index, values)`` pairs except ``"uni"`` (replace — later plan
    wins) and ``"rows"`` (whole-row rewrites; at most one producer per
    batch)."""
    out = dict(a)
    for key, val in b.items():
        if key not in out:
            out[key] = val
        elif key in ("uni", "rows"):
            out[key] = val
        else:
            ia, va = out[key]
            ib, vb = val
            out[key] = (np.concatenate([ia, ib]), np.concatenate([va, vb]))
    return out


class _TilesBase:
    """Shared staging mirrors + the streaming ingest lifecycle.

    Subclasses implement ``_install(layout)`` (full install: build the
    device-resident arrays from a fresh ``StagedLayout``) and
    ``_scatter(plan)`` (O(M) device refresh: push only the touched
    cells/rows of the mutated host mirrors — same shapes, no re-trace
    — returning the bytes transferred).

    A *scatter plan* is a dict of optional entries, all host numpy:

    - ``"boxes"`` / ``"ids"`` / ``"alive"``: ``((K, 2) [tile, slot]
      cells, (K, ...) values)`` per-slot writes into
      canon_tiles/ids/alive
    - ``"probe"``: ``((P,) rows, (P, 4) boxes)`` probe-row writes
    - ``"chunk"``: ``((C, 2) [tile, chunk] cells, (C, 4) boxes)``
    - ``"uni"``: ``(4,)`` replacement universe
    - ``"rows"``: full-row rewrites from compaction — ``dict(rows,
      boxes, ids, alive, probe, chunk)`` with leading dim R
    """

    mode = "base"
    shards = 1

    def __init__(self, parts: api.Partitioning, mbrs: jax.Array,
                 config: ServeConfig, mesh: Mesh | None):
        self.parts = parts
        self.config = config
        self.mesh = mesh
        self.axis = config.axis
        self.n_devices = (int(mesh.shape[config.axis])
                          if mesh is not None else 1)
        self._steps: dict = {}
        layout, stats = stage_tiles(parts, mbrs, config)
        self._n_total = stats["n"]      # running id numbering (never
        # decremented: deleted ids stay burned, appends continue past)
        self.stats = dict(stats, placement=config.placement,
                          probe=config.probe, restages=0, compactions=0,
                          n_total=self._n_total)
        self._mirror(layout)
        self._install(layout)

    # -- host mirrors (the ingest path's source of truth) ---------------

    def _mirror(self, layout: StagedLayout) -> None:
        # np.array (not asarray): jax buffers surface as read-only
        # views, and the ingest paths mutate these in place
        self._canon_np = np.array(layout.canon_tiles)
        self._ids_np = np.array(layout.ids)
        self._tb_np = np.array(layout.tile_boxes)
        self._probe_np = np.array(layout.probe_boxes)
        self._chunk_np = (None if layout.chunk_boxes is None
                          else np.array(layout.chunk_boxes))
        self._alive_np = np.array(layout.alive)
        self._uni_np = np.array(layout.uni)
        self._fill = (self._ids_np >= 0).sum(axis=1).astype(np.int64)
        # tombstone bookkeeping: per-tile dead canonical slots (feeds
        # the compaction trigger) and the id -> (tile, slot) canonical
        # placement + liveness maps the delete/update paths index by id.
        # A fresh layout stages live objects only, so dead counts are 0
        # and ids absent from the staging are exactly the deleted ones.
        self._dead = np.zeros(self._ids_np.shape[0], np.int64)
        # dead-slot free lists: tombstoned canonical slots inserts may
        # refill before consuming fresh slack (delete/append churn then
        # stops growing fill between compactions)
        self._free: dict[int, list[int]] = {}
        self._n_free = np.zeros(self._ids_np.shape[0], np.int64)
        cmask = self._canon_np[..., 0] < 1e9
        tt, ss = np.nonzero(cmask)
        idv = self._ids_np[tt, ss]
        self._canon_slot = np.full((self._n_total, 2), -1, np.int64)
        self._canon_slot[idv, 0] = tt
        self._canon_slot[idv, 1] = ss
        self._live_np = np.zeros(self._n_total, bool)
        self._live_np[idv] = True
        # the slack a re-stage must re-reserve: the configured value, or
        # the headroom an explicit capacity carried (its excess over the
        # hottest tile IS the user's slack policy — a re-stage must not
        # collapse it to minimal auto-sizing and then thrash)
        self._eff_slack = max(self.config.slack,
                              int(self.stats["cap"] - self._fill.max()))

    # -- streaming lifecycle --------------------------------------------

    def append(self, mbrs) -> dict:
        """Insert new objects into the staged layout (see module doc).

        mbrs: (M, 4) f32 new object MBRs; ids continue the running
        numbering (the first appended object is id ``n_total``).
        Returns an append report: ``appended``, ``restaged`` (a tile
        overflowed and the layout was rebuilt at a grown capacity), the
        new ``n`` / ``n_total`` / ``cap``, ``bytes_transferred`` (the
        O(M) scatter's device upload — or the full re-upload when a
        re-stage fired), and ``free_slots_min`` (the tightest tile's
        remaining slack).  Mutates ``stats`` in place.
        """
        new = np.asarray(mbrs, np.float32).reshape(-1, 4)
        m = new.shape[0]
        if m == 0:
            return dict(appended=0, restaged=False, n=self.stats["n"],
                        n_total=self._n_total, cap=self.stats["cap"],
                        bytes_transferred=0,
                        free_slots_min=int(self.stats["cap"]
                                           - self._fill.max()))
        n_before = self.stats["n"]
        new_ids = np.arange(self._n_total, self._n_total + m,
                            dtype=np.int32)
        self._n_total += m
        self._live_np = np.concatenate([self._live_np, np.ones(m, bool)])
        self._canon_slot = np.concatenate(
            [self._canon_slot, np.full((m, 2), -1, np.int64)])
        hit = np.asarray(membership(self.parts, jnp.asarray(new)))
        need = self._fill + np.maximum(hit.sum(axis=0) - self._n_free, 0)
        restaged = bool(need.max() > self.stats["cap"])
        if restaged:
            over = int((need > self.stats["cap"]).sum())
            log.info("append overflow: %d tile(s) past capacity %d — "
                     "re-staging %d objects", over, self.stats["cap"],
                     n_before + m)
            nbytes = self._restage(new, new_ids)
        else:
            nbytes = self._scatter(self._insert(new, hit, new_ids))
        self.stats["n"] = n_before + m
        self.stats["n_total"] = self._n_total
        self.stats["t_live"] = int(
            (self._probe_np[:, 0] <= self._probe_np[:, 2]).sum())
        self.stats["replication"] = (float(self._fill.sum())
                                     / self.stats["n"] - 1.0)
        return dict(appended=m, restaged=restaged, n=self.stats["n"],
                    n_total=self._n_total, cap=self.stats["cap"],
                    bytes_transferred=nbytes,
                    free_slots_min=int(self.stats["cap"]
                                       - self._fill.max()))

    def delete(self, ids) -> dict:
        """Tombstone-delete objects by id (see module doc).

        Flips the canonical slots' alive bits — the device refresh is a
        K-bool scatter; box data stays in place, so probe and chunk
        boxes remain exact supersets.  Raises ``ValueError`` naming the
        offending ids on an unknown id, an id repeated within the
        batch, or an already-deleted id (mirroring the staging-overflow
        contract: ingest never silently drops or double-counts).
        Returns a report (``deleted``, ``compacted_tiles``,
        ``restaged``, ``dead_frac``, ``bytes_transferred``, new ``n``)
        and mutates ``stats`` in place.
        """
        req = np.asarray(ids).reshape(-1).astype(np.int64)
        m = int(req.size)
        report = dict(deleted=m, restaged=False, compacted_tiles=0)
        if m == 0:
            return self._maintain({}, report)
        self._check_ids(req, "delete")
        ts = self._canon_slot[req]
        self._alive_np[ts[:, 0], ts[:, 1]] = False
        self._live_np[req] = False
        np.add.at(self._dead, ts[:, 0], 1)
        self._add_free(ts)
        self.stats["n"] -= m
        return self._maintain({"alive": (ts.copy(), np.zeros(m, bool))},
                              report)

    def update(self, ids, mbrs) -> dict:
        """Update objects' MBRs in place: tombstone the old canonical
        slots, then slack-insert the new MBRs under the *same* ids (so
        answers referencing the objects keep their identity).  The same
        id-validation contract as ``delete`` applies; a tile overflow
        re-stages exactly like ``append``.  Returns a report and
        mutates ``stats`` in place."""
        req = np.asarray(ids).reshape(-1).astype(np.int64)
        new = np.asarray(mbrs, np.float32).reshape(-1, 4)
        if int(req.size) != new.shape[0]:
            raise ValueError("update ids/mbrs length mismatch: "
                             f"{int(req.size)} ids, {new.shape[0]} MBRs")
        m = int(req.size)
        report = dict(updated=m, restaged=False, compacted_tiles=0)
        if m == 0:
            return self._maintain({}, report)
        self._check_ids(req, "update")
        ts = self._canon_slot[req]
        self._alive_np[ts[:, 0], ts[:, 1]] = False
        np.add.at(self._dead, ts[:, 0], 1)
        plan = {"alive": (ts.copy(), np.zeros(m, bool))}
        hit = np.asarray(membership(self.parts, jnp.asarray(new)))
        need = self._fill + np.maximum(hit.sum(axis=0) - self._n_free, 0)
        if bool(need.max() > self.stats["cap"]):
            log.info("update overflow: re-staging %d objects",
                     self.stats["n"])
            nbytes = self._restage(new, req.astype(np.int32))
            report.update(restaged=True, dead_frac=0.0, n=self.stats["n"],
                          n_total=self._n_total, bytes_transferred=nbytes)
            return report
        plan = _merge_plans(plan,
                            self._insert(new, hit, req.astype(np.int32)))
        # slots tombstoned *by this call* open for reuse only now: the
        # insert above must not target them, or its plan cells would
        # collide with the alive=False tombstone writes in one scatter
        self._add_free(ts)
        return self._maintain(plan, report)

    def compact(self) -> dict:
        """Force tile-local slot reclamation of *every* tile holding
        dead slots, regardless of ``config.compact_dead_frac`` (the
        threshold-triggered path runs automatically inside
        ``delete``/``update``)."""
        report = dict(restaged=False, compacted_tiles=0)
        tl = np.flatnonzero(self._dead > 0)
        plan: dict = {}
        if tl.size:
            plan = self._compact_tiles(tl, plan)
            report["compacted_tiles"] = int(tl.size)
            self.stats["compactions"] += int(tl.size)
        nbytes = self._scatter(plan)
        self.stats["t_live"] = int(
            (self._probe_np[:, 0] <= self._probe_np[:, 2]).sum())
        report.update(n=self.stats["n"], n_total=self._n_total,
                      dead_frac=0.0, bytes_transferred=nbytes)
        return report

    def rebalance(self, heat=None, cooc=None) -> dict:
        """Re-plan placement from query heat.  Replicated tiles have no
        owners to move, so this is a no-op report; the sharded
        placements override it."""
        return dict(placement=self.config.placement, moved_tiles=0,
                    replicated_tiles=0, bytes_transferred=0)

    def _add_free(self, ts: np.ndarray) -> None:
        """Open tombstoned canonical (tile, slot) cells for insert
        reuse (the delete/update paths call this; ``_insert`` drains
        the lists ascending, ``_compact_tiles`` voids them)."""
        for t in np.unique(ts[:, 0]):
            self._free.setdefault(int(t), []).extend(
                ts[ts[:, 0] == t, 1].tolist())
        np.add.at(self._n_free, ts[:, 0], 1)

    def _check_ids(self, req: np.ndarray, verb: str) -> None:
        bad = np.unique(req[(req < 0) | (req >= self._n_total)])
        if bad.size:
            raise ValueError(
                f"{verb} of unknown id(s): {_fmt_ids(bad)} — known ids "
                f"are 0..{self._n_total - 1}")
        uniq, cnt = np.unique(req, return_counts=True)
        dup = uniq[cnt > 1]
        if dup.size:
            raise ValueError(
                f"{verb} batch repeats id(s): {_fmt_ids(dup)}")
        dead = np.unique(req[~self._live_np[req]])
        if dead.size:
            raise ValueError(
                f"{verb} of already-deleted id(s): {_fmt_ids(dead)}")

    def _maintain(self, plan: dict, report: dict) -> dict:
        """Apply the compaction policy to a finished mutation, then
        push its scatter plan: a global dead fraction at
        ``config.restage_dead_frac`` escalates to a full re-stage
        (reclaiming non-canonical copies too); otherwise tiles whose
        dead fraction reaches ``config.compact_dead_frac`` are
        compacted tile-locally and ride along as full-row scatters."""
        cfg = self.config
        total_dead = int(self._dead.sum())
        dead_frac = total_dead / max(total_dead + self.stats["n"], 1)
        if (cfg.restage_dead_frac is not None and total_dead
                and self.stats["n"] > 0
                and dead_frac >= cfg.restage_dead_frac):
            nbytes = self._restage(None, None)
            report.update(restaged=True, dead_frac=0.0,
                          n=self.stats["n"], n_total=self._n_total,
                          bytes_transferred=nbytes)
            return report
        if cfg.compact_dead_frac is not None and total_dead:
            frac = self._dead / np.maximum(self._fill, 1)
            tl = np.flatnonzero((self._dead > 0)
                                & (frac >= cfg.compact_dead_frac))
            if tl.size:
                plan = self._compact_tiles(tl, plan)
                report["compacted_tiles"] = int(tl.size)
                self.stats["compactions"] += int(tl.size)
        nbytes = self._scatter(plan)
        self.stats["t_live"] = int(
            (self._probe_np[:, 0] <= self._probe_np[:, 2]).sum())
        total_dead = int(self._dead.sum())
        report.update(
            n=self.stats["n"], n_total=self._n_total,
            dead_frac=total_dead / max(total_dead + self.stats["n"], 1),
            bytes_transferred=nbytes)
        return report

    def _insert(self, new: np.ndarray, hit: np.ndarray,
                new_ids: np.ndarray) -> dict:
        """Slack-slot insert (host mirrors): each new object lands in
        every member tile's next free slot — live slots stay a prefix
        (a staging invariant of every sort mode) — with its canonical
        copy in the lowest member tile, matching ``stage_tiles``'s
        tile-major first-copy rule so a later re-stage reproduces the
        same canonical assignment.  Probe and chunk boxes union the new
        canonical MBRs (sentinel boxes are min/max-neutral), so routing
        and chunk skipping stay exact without a re-sort.

        Tombstoned canonical slots refill first: each tile's first
        ``n_free`` insertions land in its dead slots (ascending slot
        order) and only the rest extend the fill prefix — dead slots
        hold stale ids inside the prefix, so overwriting them preserves
        every staging invariant while delete/append churn stops
        consuming slack.

        Otherwise fully vectorised: slot targets are a per-tile rank
        cumsum over the hit matrix offset by the current fill (the same
        rank trick as ``assign_from_hit``), and the box unions are
        ``ufunc.at`` scatter-reductions — a bulk append costs numpy
        passes (plus one small loop over tiles with free slots), not
        M·(1+λ) interpreter iterations.  Returns the scatter plan for
        the touched cells (the O(M) device refresh).
        """
        rank = np.cumsum(hit, axis=0) - 1                   # (M, T)
        oi, ti = np.nonzero(hit)                            # row-major:
        r = rank[oi, ti]                                    # oi sorted
        nf0 = self._n_free[ti]
        reuse = r < nf0
        s = np.zeros(ti.shape[0], np.int64)
        s[~reuse] = self._fill[ti[~reuse]] + (r[~reuse] - nf0[~reuse])
        if reuse.any():
            for t in np.unique(ti[reuse]):
                m_t = reuse & (ti == t)
                free = sorted(self._free[int(t)])
                k = int(m_t.sum())
                s[m_t] = free[:k]       # ascending rank ↔ ascending slot
                self._free[int(t)] = free[k:]
                self._n_free[t] -= k
                self._dead[t] -= k
        ids_v = new_ids[oi].astype(np.int32)
        self._ids_np[ti, s] = ids_v
        first = np.r_[True, oi[1:] != oi[:-1]]     # lowest member tile
        boxes_v = np.where(first[:, None], new[oi],
                           _SENTINEL[None, :]).astype(np.float32)
        self._canon_np[ti, s] = boxes_v
        self._alive_np[ti, s] = first
        tc, sc, boxes = ti[first], s[first], new[oi[first]]
        self._canon_slot[ids_v[first], 0] = tc
        self._canon_slot[ids_v[first], 1] = sc
        self._live_np[ids_v[first]] = True
        np.minimum.at(self._probe_np[:, 0], tc, boxes[:, 0])
        np.minimum.at(self._probe_np[:, 1], tc, boxes[:, 1])
        np.maximum.at(self._probe_np[:, 2], tc, boxes[:, 2])
        np.maximum.at(self._probe_np[:, 3], tc, boxes[:, 3])
        if self._chunk_np is not None:
            cc = sc // rops.CHUNK
            np.minimum.at(self._chunk_np[:, :, 0], (tc, cc), boxes[:, 0])
            np.minimum.at(self._chunk_np[:, :, 1], (tc, cc), boxes[:, 1])
            np.maximum.at(self._chunk_np[:, :, 2], (tc, cc), boxes[:, 2])
            np.maximum.at(self._chunk_np[:, :, 3], (tc, cc), boxes[:, 3])
        self._fill += hit.sum(axis=0)
        if reuse.any():                 # reused slots were already filled
            self._fill -= np.bincount(ti[reuse],
                                      minlength=self._fill.shape[0])
        self._uni_np = np.concatenate(
            [np.minimum(self._uni_np[:2], new[:, :2].min(axis=0)),
             np.maximum(self._uni_np[2:], new[:, 2:].max(axis=0))]
        ).astype(np.float32)
        cells = np.stack([ti, s], axis=1)
        prows = np.unique(tc)
        plan = {
            "boxes": (cells, boxes_v),
            "ids": (cells, ids_v),
            "alive": (cells, first.copy()),
            "probe": (prows, self._probe_np[prows].copy()),
            "uni": self._uni_np,
        }
        if self._chunk_np is not None:
            ccells = np.unique(np.stack([tc, sc // rops.CHUNK], axis=1),
                               axis=0)
            plan["chunk"] = (ccells,
                             self._chunk_np[ccells[:, 0],
                                            ccells[:, 1]].copy())
        return plan

    def _compact_tiles(self, tl: np.ndarray, plan: dict) -> dict:
        """Tile-local slot reclamation: rebuild each tile's slots from
        its live members — surviving canonical slots lead in local sort
        order, then the non-canonical copies of still-live ids; dead
        canonical slots and copies of dead ids are dropped.  (Stale
        non-canonical copies of *updated* objects persist until a
        re-stage — they are answer-irrelevant, since serving probes
        canonical data only.)  Probe rows and chunk boxes tighten back
        to the surviving canonical members.  Mutates the host mirrors
        and appends one full-row scatter entry per tile to ``plan``."""
        cap = self._ids_np.shape[1]
        mode = self.config.local_index
        rows, rb, ri, ra, rp = [], [], [], [], []
        rc = [] if self._chunk_np is not None else None
        for t in tl.tolist():
            ids_row = self._ids_np[t]
            occ = ids_row >= 0
            cmask = self._canon_np[t, :, 0] < 1e9
            live_id = np.zeros(cap, bool)
            live_id[occ] = self._live_np[ids_row[occ]]
            cidx = np.flatnonzero(self._alive_np[t])
            ncidx = np.flatnonzero(occ & ~cmask & live_id)
            if cidx.size and mode == "x":
                cidx = cidx[np.argsort(self._canon_np[t, cidx, 0],
                                       kind="stable")]
            elif cidx.size and mode == "hilbert":
                b = self._canon_np[t, cidx]
                keys = np.asarray(hilbert_ops.hilbert_keys(
                    jnp.asarray((b[:, :2] + b[:, 2:]) * 0.5),
                    jnp.asarray(self._uni_np)))
                cidx = cidx[np.argsort(keys, kind="stable")]
            nk, nc = cidx.size, ncidx.size
            new_ids = np.full(cap, -1, np.int32)
            new_canon = np.broadcast_to(_SENTINEL, (cap, 4)).copy()
            new_alive = np.zeros(cap, bool)
            new_ids[:nk] = ids_row[cidx]
            new_ids[nk:nk + nc] = ids_row[ncidx]
            new_canon[:nk] = self._canon_np[t, cidx]
            new_alive[:nk] = True
            self._ids_np[t] = new_ids
            self._canon_np[t] = new_canon
            self._alive_np[t] = new_alive
            self._canon_slot[new_ids[:nk], 0] = t
            self._canon_slot[new_ids[:nk], 1] = np.arange(nk)
            self._fill[t] = nk + nc
            self._dead[t] = 0
            self._free.pop(t, None)     # slots re-packed: stale offsets
            self._n_free[t] = 0
            self._probe_np[t] = (np.concatenate(
                [new_canon[:nk, :2].min(axis=0),
                 new_canon[:nk, 2:].max(axis=0)]) if nk else _SENTINEL)
            rows.append(t)
            rb.append(new_canon)
            ri.append(new_ids)
            ra.append(new_alive)
            rp.append(self._probe_np[t].copy())
            if rc is not None:
                self._chunk_np[t] = self._chunk_row(new_canon)
                rc.append(self._chunk_np[t].copy())
        plan = dict(plan)
        plan["rows"] = dict(
            rows=np.asarray(rows, np.int64), boxes=np.stack(rb),
            ids=np.stack(ri), alive=np.stack(ra), probe=np.stack(rp),
            chunk=None if rc is None else np.stack(rc))
        return plan

    def _chunk_row(self, canon_row: np.ndarray) -> np.ndarray:
        """One tile's chunk boxes from its (cap, 4) canonical slots —
        the numpy mirror of ``_chunk_summary`` for compaction."""
        chunk = self.config.chunk
        cap = canon_row.shape[0]
        g = -(-cap // chunk)
        pad = g * chunk - cap
        if pad:
            canon_row = np.concatenate(
                [canon_row, np.broadcast_to(_SENTINEL, (pad, 4))])
        grp = canon_row.reshape(g, chunk, 4)
        boxes = np.concatenate(
            [grp[..., :2].min(axis=1), grp[..., 2:].max(axis=1)], axis=-1)
        c128 = -(-cap // rops.CHUNK)
        return np.repeat(boxes, chunk // rops.CHUNK,
                         axis=0)[:c128].astype(np.float32)

    def _dataset_np(self) -> tuple[np.ndarray, np.ndarray]:
        """The *live* dataset ``(boxes, ids)``, read straight off the
        alive slots: every live object has exactly one alive canonical
        slot (an invariant every ingest path preserves), and deleted
        ids simply never appear — a re-stage of this pair reproduces
        the live membership sets exactly."""
        live = self._alive_np
        return (self._canon_np[live].astype(np.float32),
                self._ids_np[live].astype(np.int32))

    def _restage(self, extra: np.ndarray | None,
                 extra_ids: np.ndarray | None = None) -> int:
        """Rebuild the staging from the live dataset plus the
        not-yet-inserted ``extra`` batch at a fresh capacity
        (``capacity=None`` re-sizes from the new max tile count +
        slack), refresh mirrors and device arrays, and bump the step
        generation so no cached executor can serve stale shapes.
        Reclaims every tombstoned slot (canonical and copies).
        Subclass ``_install`` re-balances owners under sharding.
        Returns the full re-upload's byte count."""
        boxes, ids = self._dataset_np()
        if extra is not None and len(extra):
            boxes = np.concatenate([boxes, extra], axis=0)
            ids = np.concatenate([ids, np.asarray(extra_ids, np.int32)])
        layout, stats = stage_tiles(
            self.parts, jnp.asarray(boxes),
            self.config.replace(capacity=None, slack=self._eff_slack),
            ids=jnp.asarray(ids))
        for key in ("n", "t", "cap", "t_live", "chunks", "replication"):
            self.stats[key] = stats[key]
        self.stats["restages"] += 1
        self._steps.clear()     # shapes changed: no stale executor survives
        self._mirror(layout)
        self._install(layout)
        nbytes = int(layout.canon_tiles.nbytes + layout.ids.nbytes
                     + layout.alive.nbytes)
        if layout.chunk_boxes is not None:
            nbytes += int(layout.chunk_boxes.nbytes)
        return nbytes

    # -- shared accessors ------------------------------------------------

    @property
    def uni(self) -> jax.Array:
        return jnp.asarray(self._uni_np)

# --------------------------------------------------------------------------
# replicated placement
# --------------------------------------------------------------------------

class ReplicatedTiles(_TilesBase):
    """Full staging on every device; only queries shard.

    The routed executors are the gathered ``query.range`` /
    ``query.knn`` paths; with a mesh each batch runs as one
    query-sharded ``shard_map`` step.  Staging arrays are passed to the
    step as *replicated arguments* (``P()`` specs) rather than closure
    captures, so streaming appends refresh the served data without
    invalidating compiled steps — shapes are unchanged until a
    re-stage, which bumps the step generation.
    """

    mode = "pruned"
    shards = 1

    def _install(self, layout: StagedLayout) -> None:
        # the served executors read canonical data only — drop the
        # all-copies member tiles instead of keeping (T, cap, 4) bytes
        # resident (and re-uploading them on every append)
        layout = dataclasses.replace(layout, tiles=None)
        # under a mesh, place the staging replicated ONCE per install:
        # the arrays then enter every step as already-resident P()
        # inputs instead of re-broadcasting O(T·cap) bytes per batch
        if self.mesh is not None:
            rep = NamedSharding(self.mesh, P())
            layout = jax.tree.map(lambda a: jax.device_put(a, rep), layout)
        self.staged = layout

    def _scatter(self, plan: dict) -> int:
        """O(M) device refresh: ``.at[]``-scatter only the touched
        cells/rows of the mutated host mirrors into the resident
        staging (plan arrays are device_put replicated under a mesh).
        Returns the bytes uploaded — proportional to the plan, never
        to T·cap."""
        if not plan:
            return 0
        lay = self.staged
        rep = (NamedSharding(self.mesh, P())
               if self.mesh is not None else None)
        nbytes = 0

        def put(x):
            nonlocal nbytes
            a = jnp.asarray(x)
            nbytes += a.nbytes
            return a if rep is None else jax.device_put(a, rep)

        canon, ids, alive = lay.canon_tiles, lay.ids, lay.alive
        probe, cbx, uni = lay.probe_boxes, lay.chunk_boxes, lay.uni
        if "boxes" in plan:
            idx, vals = _pad_pow2(*plan["boxes"])
            canon = canon.at[put(idx[:, 0]), put(idx[:, 1])].set(put(vals))
        if "ids" in plan:
            idx, vals = _pad_pow2(*plan["ids"])
            ids = ids.at[put(idx[:, 0]), put(idx[:, 1])].set(put(vals))
        if "alive" in plan:
            idx, vals = _pad_pow2(*plan["alive"])
            alive = alive.at[put(idx[:, 0]), put(idx[:, 1])].set(put(vals))
        if "probe" in plan:
            rows, vals = _pad_pow2(*plan["probe"])
            probe = probe.at[put(rows)].set(put(vals))
        if "chunk" in plan and cbx is not None:
            idx, vals = _pad_pow2(*plan["chunk"])
            cbx = cbx.at[put(idx[:, 0]), put(idx[:, 1])].set(put(vals))
        if "uni" in plan:
            uni = put(plan["uni"])
        if "rows" in plan:
            e = plan["rows"]
            rws, bx, iv, al, pr = _pad_pow2(e["rows"], e["boxes"],
                                            e["ids"], e["alive"],
                                            e["probe"])
            rows = put(rws)
            canon = canon.at[rows].set(put(bx))
            ids = ids.at[rows].set(put(iv))
            alive = alive.at[rows].set(put(al))
            probe = probe.at[rows].set(put(pr))
            if e["chunk"] is not None and cbx is not None:
                _, ck = _pad_pow2(e["rows"], e["chunk"])
                cbx = cbx.at[rows].set(put(ck))
        self.staged = dataclasses.replace(
            lay, canon_tiles=canon, ids=ids, alive=alive,
            probe_boxes=probe, chunk_boxes=cbx, uni=uni)
        return int(nbytes)

    # -- accessors -------------------------------------------------------

    @property
    def probe_boxes(self) -> jax.Array:
        return self.staged.probe_boxes

    @property
    def chunk_boxes(self) -> jax.Array | None:
        return self.staged.chunk_boxes

    def resident_tile_bytes(self) -> int:
        lay = self.staged
        return int(lay.canon_tiles.nbytes + lay.ids.nbytes)

    # -- SPMD plumbing ---------------------------------------------------

    def _call(self, key: tuple, fn, qarrays: tuple, costs: np.ndarray,
              pads: tuple, consts: tuple = ()):
        """Run ``fn(*per_query_arrays, *consts) -> pytree``
        query-sharded.

        Every array in ``qarrays`` is leading-axis (Q, ...); ``pads``
        gives the matching padding element for the slots LPT leaves
        empty; ``consts`` (the staging arrays) replicate to every
        device as step arguments.  The jitted step is cached under
        ``key``, which must carry every non-array static baked into
        ``fn``'s code (shapes re-trace via jit on their own; re-stages
        clear the cache).
        """
        if self.mesh is None:
            return fn(*qarrays, *consts), dict(skew=1.0)
        slots, pstats = pack_queries(costs, self.n_devices)
        packed = [_pack_rows(a, slots, p) for a, p in zip(qarrays, pads)]
        nq = len(qarrays)
        step = self._steps.get(key)
        if step is None:
            spec = P(self.axis)

            def spmd(*args):
                return fn(*(x[0] for x in args[:nq]), *args[nq:])

            step = jax.jit(shard_map(
                spmd, mesh=self.mesh,
                in_specs=(spec,) * nq + (P(),) * len(consts),
                out_specs=spec, check_vma=False))
            self._steps[key] = step

        sharding = NamedSharding(self.mesh, P(self.axis))
        out = step(*(jax.device_put(jnp.asarray(p), sharding)
                     for p in packed), *consts)
        n_q = qarrays[0].shape[0]
        # step outputs concatenate per-device (Qpd, ...) blocks into a
        # flat (D·Qpd, ...) leading axis; restore the (D, Qpd) grid
        return jax.tree.map(
            lambda x: _unpack_rows(
                np.asarray(x).reshape(slots.shape + np.asarray(x).shape[1:]),
                slots, n_q),
            out), pstats

    # -- routed executors ------------------------------------------------

    def range_counts(self, qboxes, cand, costs):
        lay = self.staged
        cb = lay.chunk_boxes
        f = cand.shape[1]
        consts = (lay.canon_tiles, lay.alive) + (() if cb is None
                                                 else (cb,))
        if cb is None:
            fn = lambda qs, cd, ct, al: range_mod.pruned_range_counts(
                qs, ct, cd, alive=al)
        else:
            fn = lambda qs, cd, ct, al, cbx: range_mod.pruned_range_counts(
                qs, ct, cd, chunk_boxes=cbx, alive=al)
        counts, pstats = self._call(
            ("range_counts_pruned", cb is not None), fn,
            (qboxes, cand), costs,
            (_SENTINEL, np.full((f,), -1, np.int32)), consts)
        return jnp.asarray(counts), pstats

    def range_ids(self, qboxes, cand, costs, max_hits: int):
        lay = self.staged
        cb = lay.chunk_boxes
        f = cand.shape[1]
        consts = (lay.canon_tiles, lay.ids, lay.alive) + (
            () if cb is None else (cb,))
        if cb is None:
            fn = lambda qs, cd, ct, ii, al: range_mod.pruned_range_ids(
                qs, ct, ii, cd, max_hits, alive=al)
        else:
            fn = lambda qs, cd, ct, ii, al, cbx: range_mod.pruned_range_ids(
                qs, ct, ii, cd, max_hits, chunk_boxes=cbx, alive=al)
        (hit_ids, counts, overflow), pstats = self._call(
            ("range_ids_pruned", max_hits, cb is not None), fn,
            (qboxes, cand), costs,
            (_SENTINEL, np.full((f,), -1, np.int32)), consts)
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), pstats)

    def knn_attempt(self, pts, k: int, max_cand: int, f: int):
        lay = self.staged
        n_live = self.stats["n"]
        cb = lay.chunk_boxes
        pad_pt = np.asarray((self._uni_np[:2] + self._uni_np[2:]) * 0.5)
        cand, dist, excl = router.candidate_knn(lay.probe_boxes, pts, f)
        # n_live rides along as a traced scalar, NOT a static baked into
        # the step: appends change n every batch and must not re-trace
        consts = (lay.canon_tiles, lay.ids, lay.alive, lay.uni,
                  jnp.int32(n_live)) + (() if cb is None else (cb,))
        if cb is None:
            fn = lambda qs, cd, ex, ct, ii, al, un, nl: knn_mod.pruned_knn(
                qs, k, ct, ii, un, cd, ex, max_cand=max_cand,
                n_live=nl, alive=al)
        else:
            fn = (lambda qs, cd, ex, ct, ii, al, un, nl, cbx:
                  knn_mod.pruned_knn(
                      qs, k, ct, ii, un, cd, ex, max_cand=max_cand,
                      n_live=nl, chunk_boxes=cbx, alive=al))
        (nn_ids, nn_d2, radius, overflow, rounds), pstats = self._call(
            ("knn_pruned", k, max_cand, cb is not None), fn,
            (pts, cand, excl),
            _knn_cost_proxy(self._uni_np, n_live, dist, k),
            (pad_pt, np.full((f,), -1, np.int32), np.float32(np.inf)),
            consts)
        pstats = dict(pstats,
                      rounds=int(np.asarray(rounds).max(initial=0)))
        return nn_ids, nn_d2, radius, overflow, excl, pstats

    # -- dense oracle ----------------------------------------------------

    def dense_range_counts(self, qboxes):
        lay = self.staged
        counts, pstats = self._call(
            ("range_counts_dense",),
            lambda qs, ct, al: range_mod.range_counts(qs, ct, al),
            (qboxes,), np.ones(qboxes.shape[0], np.float64),
            (_SENTINEL,), (lay.canon_tiles, lay.alive))
        return jnp.asarray(counts), pstats

    def dense_range_ids(self, qboxes, max_hits: int):
        lay = self.staged
        (hit_ids, counts, overflow), pstats = self._call(
            ("range_ids_dense", max_hits),
            lambda qs, ct, ii, al: range_mod.range_ids(
                qs, ct, ii, max_hits, al),
            (qboxes,), np.ones(qboxes.shape[0], np.float64),
            (_SENTINEL,), (lay.canon_tiles, lay.ids, lay.alive))
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), pstats)

    def dense_knn(self, pts, k: int, max_cand: int):
        lay = self.staged
        n_live = self.stats["n"]
        pad_pt = np.asarray((self._uni_np[:2] + self._uni_np[2:]) * 0.5)
        (nn_ids, nn_d2, radius, overflow, rounds), pstats = self._call(
            ("knn_dense", k, max_cand),
            lambda qs, ct, ii, al, un, nl: knn_mod.batched_knn(
                qs, k, ct, ii, un, max_cand=max_cand, n_live=nl,
                alive=al),
            (pts,), np.ones(pts.shape[0], np.float64), (pad_pt,),
            (lay.canon_tiles, lay.ids, lay.alive, lay.uni,
             jnp.int32(n_live)))
        return nn_ids, nn_d2, overflow, dict(
            rounds=int(np.asarray(rounds).max(initial=0)), **pstats)


# --------------------------------------------------------------------------
# sharded placement (owner-routed all_to_all exchange)
# --------------------------------------------------------------------------

class ShardedTiles(_TilesBase):
    """Tiles shard across owner devices; queries travel to them.

    Staging shards via capped-LPT placement (``shard_staged``) and
    every batch runs the ``serve.exchange`` orchestrations — under a
    mesh as a real ``all_to_all`` step, in-process as the vmap
    simulation over ``config.shards`` virtual owners.  The host keeps
    the full canonical staging as mirrors: the append path mutates
    them, and the ``probe="dense"`` oracle stages them to one device on
    first use.  A streaming re-stage re-balances owners on the fresh
    member counts (``stats['moved_tiles']`` reports the data movement)
    and re-establishes the ``ceil(T/D)`` per-device memory bound.
    """

    mode = "sharded"

    def __init__(self, parts, mbrs, config: ServeConfig,
                 mesh: Mesh | None):
        self.shards = 0        # set in _install, called by the base ctor
        self._owner = None
        self._heat = None      # last observed heat/cooc (rebalance
        self._cooc = None      # feeds them; re-stages re-plan on them)
        super().__init__(parts, mbrs, config, mesh)

    @property
    def _replicate_top(self) -> int:
        return 0               # HeatSharded budgets replica rows

    def _install(self, layout: StagedLayout) -> None:
        cfg = self.config
        if not self.shards:
            self.shards = (int(cfg.shards) if cfg.shards
                           else self.n_devices)
            if self.mesh is not None and self.shards != self.n_devices:
                raise ValueError(
                    "sharded serving places exactly one tile shard per "
                    f"mesh device ({self.n_devices}), got shards="
                    f"{self.shards}")
        slayout, _, stats = shard_staged(
            layout, self.stats, self.shards, mesh=self.mesh,
            axis=self.axis, prev_owner=self._owner, cooc=self._cooc,
            heat=self._heat, replicate_top=self._replicate_top)
        self.slayout = slayout
        self._owner = slayout.owner       # prev_owner for the next
        # re-balance; everything else reads the maps off self.slayout
        for key in ("shards", "t_local", "shard_bytes", "placement_skew",
                    "moved_tiles", "replicated_tiles", "cut_before",
                    "cut_after"):
            if key in stats:
                self.stats[key] = stats[key]
        self._oracle_jax = None

    def rebalance(self, heat=None, cooc=None) -> dict:
        """Apply a heat-aware placement plan under traffic.

        ``heat``/``cooc`` (a ``HeatTracker.snapshot()``) update the
        stored signals; the tile→owner map is re-planned — co-locating
        on the co-occurrence graph, seeded from the current owners so
        only tiles whose move pays for itself travel — and the shard
        arrays re-scatter from the host mirrors.  No re-staging: tile
        contents, ids, slots, probe/chunk boxes are all unchanged, so
        answers are bit-identical before and after, and shard shapes
        are stable (cached exchange steps survive).  Returns a report;
        re-stages keep using the stored signals.
        """
        if heat is not None:
            self._heat = np.asarray(heat, np.float64)
        if cooc is not None:
            self._cooc = np.asarray(cooc, np.float64)
        s = self.slayout
        layout = StagedLayout(
            tiles=None, ids=self._ids_np, canon_tiles=self._canon_np,
            tile_boxes=self._tb_np, probe_boxes=s.probe_boxes,
            chunk_boxes=s.chunk_boxes, alive=self._alive_np, uni=s.uni)
        self._install(layout)
        s = self.slayout
        nbytes = int(s.canon_shards.nbytes + s.id_shards.nbytes
                     + s.alive_shards.nbytes)
        if s.chunk_shards is not None:
            nbytes += int(s.chunk_shards.nbytes)
        return dict(placement=self.config.placement,
                    moved_tiles=self.stats.get("moved_tiles", 0),
                    replicated_tiles=self.stats.get("replicated_tiles", 0),
                    cut_before=self.stats.get("cut_before"),
                    cut_after=self.stats.get("cut_after"),
                    bytes_transferred=nbytes)

    def _placements(self, t_idx: np.ndarray):
        """Expand global tiles to every resident copy: ``(owner, local,
        sel)`` where ``sel`` indexes back into ``t_idx`` — one entry
        per primary row plus one per live replica, so every shard write
        fans out to all copies and replicas stay bit-exact."""
        s = self.slayout
        t_idx = np.asarray(t_idx)
        o = s.owner[t_idx].astype(np.int32)
        l = s.local[t_idx].astype(np.int32)
        sel = np.arange(t_idx.shape[0])
        if s.rep_owner is not None:
            ro = s.rep_owner[t_idx]
            rep = np.flatnonzero(ro >= 0)
            if rep.size:
                o = np.concatenate([o, ro[rep].astype(np.int32)])
                l = np.concatenate(
                    [l, s.rep_local[t_idx][rep].astype(np.int32)])
                sel = np.concatenate([sel, rep])
        return o, l, sel

    def _owner_scatter(self, arr, t_idx, slot_idx, vals):
        """Owner-local scatter into a (D, T_rows, ...) shard array at
        global tiles ``t_idx`` — per-slot when ``slot_idx`` is given,
        whole rows otherwise.  Writes fan out to every resident copy
        (primary + replica rows, via ``_placements``), which is what
        keeps replicated tiles bit-exact through the ingest lifecycle.
        In-process this is a plain ``.at[]`` update on translated
        (owner, local) coordinates; under a mesh it runs as a cached
        ``shard_map`` step in which each device keeps only its own
        tiles' writes (non-owned rows index out of bounds and
        ``mode="drop"``), so the update is SPMD with zero cross-device
        traffic.  Plan sizes bucket up to the next power of two
        (padding rows carry owner -1, which no device claims) to bound
        the number of step retraces."""
        o, l, sel = self._placements(t_idx)
        vals = np.ascontiguousarray(vals)[sel]
        if slot_idx is not None:
            slot_idx = np.asarray(slot_idx, np.int32)[sel]
        if self.mesh is None:
            if slot_idx is None:
                return arr.at[jnp.asarray(o), jnp.asarray(l)].set(
                    jnp.asarray(vals))
            return arr.at[jnp.asarray(o), jnp.asarray(l),
                          jnp.asarray(slot_idx, np.int32)].set(
                jnp.asarray(vals))
        k = len(o)
        kb = 1 << max(0, (k - 1).bit_length())
        pad = kb - k
        o = np.concatenate([o, np.full(pad, -1, np.int32)])
        l = np.concatenate([l, np.zeros(pad, np.int32)])
        sl = (None if slot_idx is None else np.concatenate(
            [np.asarray(slot_idx, np.int32), np.zeros(pad, np.int32)]))
        vals = np.concatenate(
            [vals, np.zeros((pad,) + vals.shape[1:], vals.dtype)])
        key = ("owner_scatter", slot_idx is not None, kb, arr.shape,
               str(vals.dtype))
        step = self._steps.get(key)
        if step is None:
            axis = self.axis
            if slot_idx is not None:
                def spmd(a, o_, l_, s_, v):
                    row = jnp.where(o_ == jax.lax.axis_index(axis), l_,
                                    a.shape[1])
                    return a.at[0, row, s_].set(v, mode="drop")
                in_specs = (P(axis), P(), P(), P(), P())
            else:
                def spmd(a, o_, l_, v):
                    row = jnp.where(o_ == jax.lax.axis_index(axis), l_,
                                    a.shape[1])
                    return a.at[0, row].set(v, mode="drop")
                in_specs = (P(axis), P(), P(), P())
            step = jax.jit(shard_map(spmd, mesh=self.mesh,
                                     in_specs=in_specs,
                                     out_specs=P(axis), check_vma=False))
            self._steps[key] = step
        args = (arr, jnp.asarray(o), jnp.asarray(l)) + (
            () if sl is None else (jnp.asarray(sl),)) + (jnp.asarray(vals),)
        return step(*args)

    def _scatter(self, plan: dict) -> int:
        """O(M) device refresh of the sharded staging: owner-local
        ``.at[]`` scatters for the shard arrays plus plain updates for
        the small replicated routing index (probe/chunk boxes, uni).
        Returns the bytes uploaded."""
        if not plan:
            return 0
        s = self.slayout
        nbytes = 0

        def count(*arrs):
            nonlocal nbytes
            for a in arrs:
                nbytes += np.asarray(a).nbytes

        canon_sh, id_sh = s.canon_shards, s.id_shards
        alive_sh, chunk_sh = s.alive_shards, s.chunk_shards
        probe, cbx, uni = s.probe_boxes, s.chunk_boxes, s.uni
        if "boxes" in plan:
            idx, vals = plan["boxes"]
            canon_sh = self._owner_scatter(canon_sh, idx[:, 0],
                                           idx[:, 1], vals)
            count(idx, vals)
        if "ids" in plan:
            idx, vals = plan["ids"]
            id_sh = self._owner_scatter(id_sh, idx[:, 0], idx[:, 1], vals)
            count(idx, vals)
        if "alive" in plan:
            idx, vals = plan["alive"]
            alive_sh = self._owner_scatter(alive_sh, idx[:, 0],
                                           idx[:, 1], vals)
            count(idx, vals)
        if "probe" in plan:
            rows, vals = plan["probe"]
            probe = probe.at[jnp.asarray(rows)].set(jnp.asarray(vals))
            count(rows, vals)
        if "chunk" in plan:
            idx, vals = plan["chunk"]
            if chunk_sh is not None:
                # chunk cells share a tile: scatter each (tile, chunk)
                # cell through the owner map as a slot-indexed write
                chunk_sh = self._owner_scatter(chunk_sh, idx[:, 0],
                                               idx[:, 1], vals)
            if cbx is not None:
                cbx = cbx.at[jnp.asarray(idx[:, 0]),
                             jnp.asarray(idx[:, 1])].set(jnp.asarray(vals))
            count(idx, vals, idx, vals)
        if "uni" in plan:
            uni = jnp.asarray(plan["uni"])
            count(plan["uni"])
        if "rows" in plan:
            e = plan["rows"]
            rows = e["rows"]
            canon_sh = self._owner_scatter(canon_sh, rows, None, e["boxes"])
            id_sh = self._owner_scatter(id_sh, rows, None, e["ids"])
            alive_sh = self._owner_scatter(alive_sh, rows, None, e["alive"])
            probe = probe.at[jnp.asarray(rows)].set(jnp.asarray(e["probe"]))
            count(rows, e["boxes"], e["ids"], e["alive"], e["probe"])
            if e["chunk"] is not None:
                if chunk_sh is not None:
                    chunk_sh = self._owner_scatter(chunk_sh, rows, None,
                                                   e["chunk"])
                if cbx is not None:
                    cbx = cbx.at[jnp.asarray(rows)].set(
                        jnp.asarray(e["chunk"]))
                count(e["chunk"])
        self.slayout = dataclasses.replace(
            s, canon_shards=canon_sh, id_shards=id_sh,
            alive_shards=alive_sh, chunk_shards=chunk_sh,
            probe_boxes=probe, chunk_boxes=cbx, uni=uni)
        self._oracle_jax = None
        return int(nbytes)

    # -- accessors -------------------------------------------------------

    @property
    def probe_boxes(self) -> jax.Array:
        return self.slayout.probe_boxes

    @property
    def chunk_boxes(self) -> jax.Array | None:
        return self.slayout.chunk_boxes

    @property
    def oracle_np(self) -> tuple[np.ndarray, np.ndarray]:
        """Host copies of the unsharded canonical staging (the
        ``probe="dense"`` oracle's input, also the append mirrors)."""
        return self._canon_np, self._ids_np

    def resident_tile_bytes(self) -> int:
        s = self.slayout
        return int(s.canon_shards.nbytes + s.id_shards.nbytes) \
            // self.shards

    def _oracle(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Dense single-device staging for the ``probe="dense"`` oracle
        — staged to the default device on first use (debug/validation
        path; the sharded executors never need it)."""
        if self._oracle_jax is None:
            self._oracle_jax = (jnp.asarray(self._canon_np),
                                jnp.asarray(self._ids_np),
                                jnp.asarray(self._alive_np))
        return self._oracle_jax

    # -- exchange plumbing -----------------------------------------------

    def _exchange_plan(self, cand, costs: np.ndarray):
        """Host-side plan for one sharded batch: LPT query packing +
        owner-local candidate translation (``router.owner_split``,
        replica-aware when hot tiles hold a second copy)."""
        slots, pstats = pack_queries(costs, self.shards)
        send_slot, send_cand, xstats = router.owner_split(
            np.asarray(cand), slots, self.slayout.owner,
            self.slayout.local, alt_owner=self.slayout.rep_owner,
            alt_local=self.slayout.rep_local)
        return slots, send_slot, send_cand, {**pstats, **xstats}

    def _put(self, arr):
        a = jnp.asarray(arr)
        if self.mesh is not None:
            a = jax.device_put(a, NamedSharding(self.mesh, P(self.axis)))
        return a

    def _exchange_step(self, key: tuple, orch, n_sharded: int,
                       n_replicated: int = 0, **static):
        step = self._steps.get(key)
        if step is None:
            step = exchange.build_step(orch, self.mesh, self.axis,
                                       n_sharded, n_replicated, **static)
            self._steps[key] = step
        return step

    # -- routed executors ------------------------------------------------

    def range_counts(self, qboxes, cand, costs):
        slots, ss, sc, xstats = self._exchange_plan(cand, costs)
        qp = _pack_rows(np.asarray(qboxes, np.float32), slots, _SENTINEL)
        li = self.config.indexed
        extra = (self.slayout.chunk_shards,) if li else ()
        step = self._exchange_step(
            ("s_range_counts", qp.shape[1], ss.shape[2], sc.shape[3], li),
            exchange.serve_range_counts, n_sharded=5 + len(extra))
        out = step(self._put(qp), self._put(ss), self._put(sc),
                   self.slayout.canon_shards, self.slayout.alive_shards,
                   *extra)
        counts = _unpack_rows(out, slots, qboxes.shape[0])
        return jnp.asarray(counts), dict(shards=self.shards, **xstats)

    def range_ids(self, qboxes, cand, costs, max_hits: int):
        slots, ss, sc, xstats = self._exchange_plan(cand, costs)
        qp = _pack_rows(np.asarray(qboxes, np.float32), slots, _SENTINEL)
        cap = int(self.slayout.id_shards.shape[-1])
        mh_local = min(max_hits, sc.shape[3] * cap)
        li = self.config.indexed
        extra = (self.slayout.chunk_shards,) if li else ()
        step = self._exchange_step(
            ("s_range_ids", qp.shape[1], ss.shape[2], sc.shape[3],
             max_hits, mh_local, li),
            exchange.serve_range_ids, n_sharded=6 + len(extra),
            max_hits=max_hits, mh_local=mh_local)
        out = step(self._put(qp), self._put(ss), self._put(sc),
                   self.slayout.canon_shards, self.slayout.id_shards,
                   self.slayout.alive_shards, *extra)
        n_q = qboxes.shape[0]
        hit_ids, counts, overflow = (
            _unpack_rows(x, slots, n_q) for x in out)
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), dict(shards=self.shards, **xstats))

    def knn_attempt(self, pts, k: int, max_cand: int, f: int):
        n_live = self.stats["n"]
        pad_pt = np.asarray((self._uni_np[:2] + self._uni_np[2:]) * 0.5)
        n_q = pts.shape[0]
        li = self.config.indexed
        cand, dist, excl = router.candidate_knn(
            self.slayout.probe_boxes, pts, f)
        slots, ss, sc, xstats = self._exchange_plan(
            cand, _knn_cost_proxy(self._uni_np, n_live, dist, k))
        pp = _pack_rows(np.asarray(pts, np.float32), slots, pad_pt)
        dead = slots < 0
        orch = exchange.serve_knn if li else exchange.serve_knn_unindexed
        extra = (self.slayout.chunk_shards,) if li else ()
        # n_live is a replicated traced scalar, not a static: appends
        # change n every batch and must not re-trace the exchange step
        step = self._exchange_step(
            ("s_knn", k, max_cand, pp.shape[1], ss.shape[2],
             sc.shape[3], li),
            orch, n_sharded=7 + len(extra), n_replicated=2,
            k=k, max_cand=max_cand)
        out = step(self._put(pp), self._put(ss), self._put(sc),
                   self._put(dead), self.slayout.canon_shards,
                   self.slayout.id_shards, self.slayout.alive_shards,
                   *extra, self.slayout.uni, jnp.int32(n_live))
        nn_ids, nn_d2, radius, overflow, rounds = (
            _unpack_rows(x, slots, n_q) for x in out)
        xstats = dict(xstats, shards=self.shards,
                      rounds=int(rounds.max(initial=0)))
        return nn_ids, nn_d2, radius, overflow, excl, xstats

    # -- dense oracle ----------------------------------------------------

    def dense_range_counts(self, qboxes):
        canon, _, alive = self._oracle()
        return range_mod.range_counts(qboxes, canon, alive), {}

    def dense_range_ids(self, qboxes, max_hits: int):
        canon, ids, alive = self._oracle()
        hit_ids, counts, overflow = range_mod.range_ids(
            qboxes, canon, ids, max_hits, alive)
        return hit_ids, counts, overflow, {}

    def dense_knn(self, pts, k: int, max_cand: int):
        canon, ids, alive = self._oracle()
        nn_ids, nn_d2, _, overflow, rounds = knn_mod.batched_knn(
            pts, k, canon, ids, jnp.asarray(self._uni_np),
            max_cand=max_cand, n_live=self.stats["n"], alive=alive)
        return nn_ids, nn_d2, overflow, dict(
            rounds=int(np.asarray(rounds).max(initial=0)))


class HeatSharded(ShardedTiles):
    """Sharded placement that follows the query log: co-located
    primaries + hot-tile replicas.

    The replicated/sharded hybrid the ``TileLayout`` protocol was
    built to host as a third implementation.  Placement differs from
    ``ShardedTiles`` in two ways, both planned host-side from the
    ``HeatTracker`` signals the server feeds through ``rebalance``:

    - primaries co-locate on the candidate co-occurrence graph
      (``placement.colocate_tiles``), cutting the cross-owner pairs
      that force a query to message two devices;
    - the ``config.policy.replicate_top`` hottest tiles keep a
      bit-exact second copy on another owner, in the shard rows past
      ``t_local`` — per-device rows are exactly ``ceil(T/D) +
      replicate_top``, the explicit memory cost of the hybrid — and
      ``router.owner_split`` routes each candidate to whichever copy
      saves a message or carries less probe load.

    Every ingest write fans out to all copies (``_placements``), so
    answers stay bit-identical to the dense oracle through appends,
    tombstone deletes, and compaction.  Cold (before any heat is
    observed) it replicates by member counts and places primaries like
    ``ShardedTiles`` — strictly a superset of the count-balanced plan.
    """

    mode = "heat"

    @property
    def _replicate_top(self) -> int:
        return self.config.policy.replicate_top


_PLACEMENT_CLS = {"replicated": ReplicatedTiles, "sharded": ShardedTiles,
                  "heat": HeatSharded}


def build_tiles(parts: api.Partitioning, mbrs: jax.Array,
                config: ServeConfig, mesh: Mesh | None = None
                ) -> TileLayout:
    """Construct the placement ``config`` names (the one place the
    placement string is dispatched)."""
    return _PLACEMENT_CLS[config.placement](parts, mbrs, config, mesh)
