"""The `TileLayout` protocol: one staging + serving contract, two
placements, and the streaming append lifecycle.

PR 1–4 grew two parallel serving stacks — a replicated one
(``stage`` → ``StagedLayout`` → query-sharded ``shard_map`` steps) and
a sharded one (``stage_sharded`` → ``ShardedLayout`` → the owner-routed
``all_to_all`` exchange) — and ``SpatialServer`` forked on
``self.sharded`` at every query entry point.  This module collapses the
fork: both placements implement one protocol —

- ``ReplicatedTiles`` — the full staging lives on every device; only
  queries shard.  Executors are the gathered ``query.range`` /
  ``query.knn`` paths under a query-sharded ``shard_map`` step (staging
  arrays ride along as replicated step *arguments*, never baked-in
  closures, so streaming appends refresh data without recompiles).
- ``ShardedTiles`` — tiles shard across owner devices
  (``core.placement.shard_tiles``) and every batch runs the
  ``serve.exchange`` orchestrations.  The replicated full staging is
  kept host-side only, as the ``probe="dense"`` oracle.

``SpatialServer`` (``serve.engine``) is written once against the
protocol: route → pack → ``tiles.range_counts(...)`` — no placement
branches.  Staging itself (``stage_tiles``) is configured by one frozen
``ServeConfig``: local-index mode ``off``/``x``/``hilbert`` (ascending
xmin vs Hilbert-key member order inside each tile — Hilbert makes chunk
boxes square-ish instead of x-strips), chunk-box granularity, and the
capacity/slack policy.

**Streaming appends** (the ROADMAP's moving-dataset item): staging
reserves ``config.slack`` free slots per tile past the observed max
tile count, and ``append(mbrs)`` inserts new objects into that slack —
host-side mirrors are updated incrementally (probe boxes and chunk
boxes union the new member MBRs, so routing and chunk skipping stay
exact) and pushed to the device without re-tracing any serving step.
The device refresh re-uploads the full mirrors (O(T·cap) per append —
the shapes compiled steps already expect); a device-side ``.at[]``
scatter of only the touched slots would cut that to O(M) and is the
known follow-up, but the host mirrors stay the source of truth either
way.
A tile overflow triggers a **re-stage**: the layout is rebuilt from the
accumulated dataset at a grown capacity (same ``Partitioning``, fresh
sort + chunk boxes), owners re-balance under sharding
(``shard_tiles`` on the new member counts — the ``ceil(T/D)``
per-device memory bound is re-established, move counts reported), and
the server's ``WidthPolicy`` resets.  Because answers are functions of
the canonical membership *sets* — counts are sums, id lists are sorted
ascending, kNN ties break on ``(distance, id)`` — append-then-query is
bit-identical to re-staging from scratch, which the streaming tests
assert on all six layouts.

Membership for appends (and, identically, for re-stages) extends MASJ
assignment with **nearest-tile adoption**: an object intersecting no
partition region — possible on the non-covering hc/str layouts once
data moves — is assigned to the nearest valid tile.  Pruned routing
stays exact because probe boxes are unions of canonical *member* MBRs:
wherever an object lands, the probe box of that tile grows to cover
it.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import geometry, placement
from ..core.compat import shard_map
from ..core.partition import api, assign
from ..core.partition.assign import round_up
from ..kernels.hilbert import ops as hilbert_ops
from ..kernels.range_probe import ops as rops
from ..query import knn as knn_mod, range as range_mod
from . import exchange, router
from .config import ServeConfig

_SENTINEL = np.array(geometry.SENTINEL_BOX, np.float32)

log = logging.getLogger(__name__)


# --------------------------------------------------------------------------
# staged-array containers (unchanged pytree formats from PR 1–4)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagedLayout:
    """Device-resident staging of one partitioned dataset.

    tiles       : (T, cap, 4) member MBRs, sentinel-padded (all copies)
    ids         : (T, cap) int32 member ids, -1 in padding slots
    canon_tiles : (T, cap, 4) canonical copies only (others sentineled)
    tile_boxes  : (T, 4) partition regions (sentinel for invalid rows)
    probe_boxes : (T, 4) tight MBR over each tile's *canonical* member
                  MBRs (sentinel where a tile holds none) — the box set
                  the pruned executor routes on; covers every canonical
                  hit on all six layouts
    chunk_boxes : (T, C, 4) the **local index** (``None`` when staged
                  with ``local_index="off"``): slots are sorted
                  canonical-first by the configured key (ascending xmin
                  or Hilbert), and chunk c's box bounds the canonical
                  members in slots [c·128, (c+1)·128) — sentinel where
                  a chunk holds none, so the ``*_skip`` probe kernels
                  skip it outright
    uni         : (4,) dataset universe
    """

    tiles: jax.Array
    ids: jax.Array
    canon_tiles: jax.Array
    tile_boxes: jax.Array
    probe_boxes: jax.Array
    chunk_boxes: jax.Array | None
    uni: jax.Array


jax.tree_util.register_dataclass(
    StagedLayout,
    data_fields=("tiles", "ids", "canon_tiles", "tile_boxes",
                 "probe_boxes", "chunk_boxes", "uni"),
    meta_fields=())


@dataclasses.dataclass(frozen=True)
class ShardedLayout:
    """Owner-sharded staging: per-device tile shards + the routing maps.

    canon_shards : (D, T_local, cap, 4) canonical member MBRs, one tile
                   shard per device (sentinel-padded rows past a
                   device's tile count) — device-sharded when a mesh is
                   given, so per-device memory is O(total/D)
    id_shards    : (D, T_local, cap) int32 member ids (-1 padding)
    chunk_shards : (D, T_local, C, 4) per-shard local index (chunk
                   boxes in owner-local tile rows; None when staged
                   with ``local_index="off"``)
    probe_boxes  : (T, 4) *global* canonical probe boxes — routing is a
                   host-side O(Q·T) scan, so the (small) index stays
                   replicated while the (large) member data shards
    chunk_boxes  : (T, C, 4) *global* chunk boxes (None when unindexed)
                   — like the probe boxes, a small replicated index;
                   used for host-side skip-rate reporting
    uni          : (4,) dataset universe
    owner        : (T,) int32 host map, global tile -> owner device
    local        : (T,) int32 host map, global tile -> row in the
                   owner's shard
    """

    canon_shards: jax.Array
    id_shards: jax.Array
    chunk_shards: jax.Array | None
    probe_boxes: jax.Array
    chunk_boxes: jax.Array | None
    uni: jax.Array
    owner: np.ndarray
    local: np.ndarray


# --------------------------------------------------------------------------
# staging (stage once; the append path shares membership + marking rules)
# --------------------------------------------------------------------------

def membership(parts: api.Partitioning, mbrs: jax.Array) -> jax.Array:
    """(N, kmax) bool MASJ membership with nearest-tile adoption.

    Geometric membership is box intersection against every valid
    partition region (the paper's multi-assignment).  An object
    intersecting *no* region — possible for appends on the
    non-covering hc/str layouts — is adopted by the nearest valid tile
    (squared box-to-box distance, ties to the lowest tile index via
    ``argmin``), so staging is total: every object always holds at
    least one (hence exactly one canonical) slot.  For objects the
    regions do cover, adoption never fires and membership equals plain
    MASJ assignment.
    """
    b = parts.boxes
    hit = geometry.intersect_matrix(mbrs, b) & parts.valid[None, :]
    none = ~jnp.any(hit, axis=1)
    if not bool(none.any()):       # host-called, eager: the covering /
        return hit                 # in-universe common case pays nothing
    dx = jnp.maximum(jnp.maximum(b[None, :, 0] - mbrs[:, None, 2],
                                 mbrs[:, None, 0] - b[None, :, 2]), 0.0)
    dy = jnp.maximum(jnp.maximum(b[None, :, 1] - mbrs[:, None, 3],
                                 mbrs[:, None, 1] - b[None, :, 3]), 0.0)
    d2 = jnp.where(parts.valid[None, :], dx * dx + dy * dy, jnp.inf)
    nearest = jnp.argmin(d2, axis=1)
    adopt = none[:, None] & (jnp.arange(parts.kmax)[None, :]
                             == nearest[:, None])
    return hit | adopt


def _chunk_summary(canon_tiles: jax.Array, chunk: int) -> jax.Array:
    """(T, cap, 4) canonical tiles -> (T, ceil(cap/128), 4) chunk boxes
    at ``chunk``-slot granularity.

    Boxes are computed per ``chunk``-member slot group (the tight MBR
    over its canonical member MBRs; sentinel slots are min/max-neutral
    and an all-sentinel group collapses to the sentinel box) and then
    broadcast down to the kernels' native 128-slot grid — a ``chunk``
    of 256 stores each box twice, trading skip precision for summary
    size without touching the kernels.
    """
    t, cap, _ = canon_tiles.shape
    g = -(-cap // chunk)
    pad = g * chunk - cap
    if pad:
        canon_tiles = jnp.concatenate(
            [canon_tiles,
             jnp.broadcast_to(jnp.asarray(_SENTINEL), (t, pad, 4))], axis=1)
    grp = canon_tiles.reshape(t, g, chunk, 4)
    boxes = jnp.concatenate(
        [jnp.min(grp[..., :2], axis=2), jnp.max(grp[..., 2:], axis=2)],
        axis=-1)
    c128 = -(-cap // rops.CHUNK)
    return jnp.repeat(boxes, chunk // rops.CHUNK, axis=1)[:, :c128]


def _local_sort_order(canon_tiles: jax.Array, ids: jax.Array, mode: str,
                      uni: jax.Array) -> jax.Array:
    """Per-tile slot permutation for the local index.

    ``"x"``: stable argsort on canonical xmin — non-canonical copies
    and padding carry the sentinel 9e9 and sink to the tail in their
    original (live-before-padding) order.  ``"hilbert"``: canonical
    slots lead in ascending Hilbert key of their MBR centre
    (``kernels.hilbert`` over the dataset universe), with a three-tier
    primary key (canonical < non-canonical live < padding) so live
    slots stay a prefix — the invariant the append path's free-slot
    tracking relies on.
    """
    if mode == "x":
        return jnp.argsort(canon_tiles[..., 0], axis=1, stable=True)
    t, cap, _ = canon_tiles.shape
    canon = canon_tiles[..., 0] < 1e9
    centers = (canon_tiles[..., :2] + canon_tiles[..., 2:]) * 0.5
    keys = hilbert_ops.hilbert_keys(centers.reshape(-1, 2),
                                    uni).reshape(t, cap)
    tier = jnp.where(canon, 0, jnp.where(ids >= 0, 1, 2)).astype(jnp.int32)
    o1 = jnp.argsort(keys, axis=1, stable=True)
    o2 = jnp.argsort(jnp.take_along_axis(tier, o1, axis=1), axis=1,
                     stable=True)
    return jnp.take_along_axis(o1, o2, axis=1)


def stage_tiles(parts: api.Partitioning, mbrs: jax.Array,
                config: ServeConfig | None = None
                ) -> tuple[StagedLayout, dict]:
    """MASJ-stage ``mbrs`` under ``parts`` per ``config``.

    mbrs: (N, 4) f32 -> ``(StagedLayout, stats)``; raises on capacity
    overflow (never silently drops members).  ``stats['replication']``
    is the paper's λ.  ``config.capacity=None`` sizes capacity from the
    staged data's max tile count plus ``config.slack`` reserved append
    slots, 128-aligned; an explicit capacity is used as given (its
    headroom over the max count *is* the slack).

    ``config.local_index`` other than ``"off"`` builds the intra-tile
    local index: each tile's slots are permuted canonical-first by the
    configured sort key (``_local_sort_order``) and a per-128-slot
    chunk-box summary at ``config.chunk`` granularity is carried in
    ``chunk_boxes`` for the chunk-skipping probe kernels.  The
    permutation is applied to ``tiles``/``ids``/``canon_tiles``
    consistently, so canonical marking — and therefore every query
    answer — is unchanged; ``local_index="off"`` staging is the
    unindexed oracle.
    """
    config = config or ServeConfig()
    n = mbrs.shape[0]
    hit = membership(parts, mbrs)
    counts = jnp.sum(hit, axis=0, dtype=jnp.int32)
    if config.capacity is None:
        capacity = round_up(max(int(jnp.max(counts)) + config.slack, 1), 128)
    else:
        capacity = config.capacity
    members, mask, overflow = assign.assign_from_hit(hit, capacity)
    if int(jnp.sum(overflow)) > 0:
        over = np.asarray(counts) - capacity
        raise ValueError(
            f"staging overflow: capacity {capacity} < max tile count "
            f"{int(jnp.max(counts))} ({int((over > 0).sum())} of "
            f"{int(parts.k())} tiles overflow, worst by "
            f"{int(over.max())} members — raise capacity or payload)")

    sentinel = jnp.asarray(_SENTINEL)
    tiles = jnp.where(mask[..., None], mbrs[members], sentinel)
    ids = jnp.where(mask, members, -1).astype(jnp.int32)

    # canonical mark: first copy of each id in tile-major order wins,
    # so every object has exactly one canonical slot
    flat = ids.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    s = flat[order]
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    canon = jnp.zeros_like(flat, bool).at[order].set(first & (s >= 0))
    canon = canon.reshape(ids.shape)
    canon_tiles = jnp.where(canon[..., None], tiles, sentinel)

    uni = geometry.universe(mbrs)
    chunk_boxes = None
    if config.indexed:
        slot_order = _local_sort_order(canon_tiles, ids, config.local_index,
                                       uni)

        def permute(a):
            idx = slot_order if a.ndim == 2 else slot_order[..., None]
            return jnp.take_along_axis(a, jnp.broadcast_to(idx, a.shape),
                                       axis=1)

        tiles, ids, canon_tiles = (permute(tiles), permute(ids),
                                   permute(canon_tiles))
        chunk_boxes = _chunk_summary(canon_tiles, config.chunk)

    # canonical probe boxes: sentinel slots are min/max-neutral, and an
    # all-sentinel tile collapses back to the sentinel box
    probe_boxes = jnp.concatenate(
        [jnp.min(canon_tiles[..., :2], axis=1),
         jnp.max(canon_tiles[..., 2:], axis=1)], axis=-1)

    tile_boxes = jnp.where(parts.valid[:, None], parts.boxes, sentinel)
    layout = StagedLayout(tiles=tiles, ids=ids, canon_tiles=canon_tiles,
                          tile_boxes=tile_boxes, probe_boxes=probe_boxes,
                          chunk_boxes=chunk_boxes, uni=uni)
    stats = dict(
        n=n, t=int(parts.k()), cap=capacity,
        # tiles holding >= 1 canonical member: the widest candidate list
        # the pruned executor can ever need (<= t, since padding rows and
        # canonically-empty tiles probe as sentinel)
        t_live=int(jnp.sum(probe_boxes[:, 0] <= probe_boxes[:, 2])),
        chunks=0 if chunk_boxes is None else int(chunk_boxes.shape[1]),
        replication=float(jnp.sum(counts)) / n - 1.0,
        local_index=config.local_index, chunk=config.chunk,
        slack=config.slack,
    )
    return layout, stats


def _scatter_shards(canon_np: np.ndarray, ids_np: np.ndarray,
                    chunk_np: np.ndarray | None, owner: np.ndarray,
                    local: np.ndarray, t_local: int, d: int,
                    mesh: Mesh | None, axis: str):
    """Host scatter of the global staging into (D, T_local, ...) shard
    arrays, device_put-sharded over ``axis`` when a mesh is given (no
    transient full-size single-device copy — peak per-device memory
    stays O(total/D))."""
    cap = ids_np.shape[1]
    canon_sh = np.broadcast_to(_SENTINEL, (d, t_local, cap, 4)).copy()
    ids_sh = np.full((d, t_local, cap), -1, np.int32)
    canon_sh[owner, local] = canon_np
    ids_sh[owner, local] = ids_np
    cb_sh = None
    if chunk_np is not None:
        c = chunk_np.shape[1]
        cb_sh = np.broadcast_to(_SENTINEL, (d, t_local, c, 4)).copy()
        cb_sh[owner, local] = chunk_np
    if mesh is not None:
        sharding = NamedSharding(mesh, P(axis))
        return (jax.device_put(canon_sh, sharding),
                jax.device_put(ids_sh, sharding),
                None if cb_sh is None else jax.device_put(cb_sh, sharding))
    return (jnp.asarray(canon_sh), jnp.asarray(ids_sh),
            None if cb_sh is None else jnp.asarray(cb_sh))


def shard_staged(layout: StagedLayout, stats: dict, n_shards: int,
                 mesh: Mesh | None = None, axis: str = "d",
                 prev_owner: np.ndarray | None = None
                 ) -> tuple[ShardedLayout, tuple, dict]:
    """Shard a staged layout's tiles across ``n_shards`` owner devices.

    Placement is cost-balanced capped LPT on per-tile member counts
    (``core.placement.shard_tiles``): probe cost spreads like the
    member mass while no device holds more than ``ceil(T/D)`` tiles, so
    per-device shard memory is at most one tile over an even split.
    ``prev_owner`` (a streaming re-balance) adds the moved-tile count
    to the stats.

    Returns ``(ShardedLayout, (canon_np, ids_np), stats)`` — the numpy
    pair is the host-side copy of the *unsharded* canonical staging,
    kept off-device for the ``probe="dense"`` oracle path.
    """
    canon_np = np.asarray(layout.canon_tiles)
    ids_np = np.asarray(layout.ids)
    chunk_np = (None if layout.chunk_boxes is None
                else np.asarray(layout.chunk_boxes))
    d = max(1, int(n_shards))
    member_counts = (ids_np >= 0).sum(axis=1).astype(np.float64)
    owner, local, t_local, pstats = placement.shard_tiles(
        member_counts, d, prev_owner=prev_owner)
    canon_shards, id_shards, chunk_shards = _scatter_shards(
        canon_np, ids_np, chunk_np, owner, local, t_local, d, mesh, axis)
    slayout = ShardedLayout(canon_shards=canon_shards, id_shards=id_shards,
                            chunk_shards=chunk_shards,
                            probe_boxes=layout.probe_boxes,
                            chunk_boxes=layout.chunk_boxes, uni=layout.uni,
                            owner=owner, local=local)
    stats = dict(stats, shards=d, t_local=t_local,
                 shard_bytes=(canon_shards.nbytes + id_shards.nbytes) // d,
                 placement_skew=pstats["skew"])
    if "moved" in pstats:
        stats["moved_tiles"] = pstats["moved"]
    return slayout, (canon_np, ids_np), stats


# --------------------------------------------------------------------------
# query packing (host): fan-out-weighted LPT onto devices
# --------------------------------------------------------------------------

def pack_queries(costs: np.ndarray, n_devices: int
                 ) -> tuple[np.ndarray, dict]:
    """LPT-pack queries onto devices by per-query cost.

    costs: (Q,) — routed fan-out on the pruned path, so hotspot queries
    spread across devices instead of serialising one of them.  Returns
    ``(slots[D, Qpd] int32 query indices, stats)``; -1 slots are
    padding.  Qpd is the max per-device group size, so one straggler
    hotspot group bounds the step — exactly what LPT minimises.

    A degenerate all-zero cost vector falls back to uniform costs (LPT
    with equal weights round-robins), so queries still spread across
    devices instead of piling onto device 0.
    """
    d = max(1, n_devices)
    costs = costs.astype(np.float64)
    if costs.size and not np.any(costs > 0):
        costs = np.ones_like(costs)
    dev, makespan, mean_load = placement.lpt_pack(costs, d)
    groups = [np.flatnonzero(dev == i) for i in range(d)]
    qpd = max(1, max(len(g) for g in groups))
    slots = np.full((d, qpd), -1, np.int32)
    for i, g in enumerate(groups):
        slots[i, :len(g)] = g
    stats = dict(makespan=makespan, mean_load=mean_load,
                 skew=makespan / max(mean_load, 1e-9), qpd=qpd)
    return slots, stats


def _pack_rows(arr: np.ndarray, slots: np.ndarray, pad) -> np.ndarray:
    """Scatter per-query rows into the packed (D, Qpd, ...) slot grid,
    filling -1 slots with ``pad`` (the single definition shared by the
    replicated and sharded executors)."""
    a = np.asarray(arr)
    pad = np.asarray(pad, a.dtype)
    out = np.broadcast_to(pad, slots.shape + pad.shape).copy()
    live = slots >= 0
    out[live] = a[slots[live]]
    return out


def _unpack_rows(x, slots: np.ndarray, n_queries: int) -> np.ndarray:
    """Invert ``_pack_rows``: (D, Qpd, ...) step output -> per-query
    rows in original batch order.  (Steps that emit a flat
    (D·Qpd, ...) leading axis reshape before calling.)"""
    x = np.asarray(x)
    x = x.reshape((slots.size,) + x.shape[2:])
    live = slots >= 0
    res = np.zeros((n_queries,) + x.shape[1:], x.dtype)
    res[slots[live]] = x[live.ravel()]
    return res


def _knn_cost_proxy(uni_np: np.ndarray, n: int, dist, k: int) -> np.ndarray:
    """LPT packing weight for a kNN batch: tiles the first deepening box
    would touch (matches the radius the kernel actually starts from —
    density over the ``n`` live canonical members, not the padded slot
    count)."""
    diag = float(np.linalg.norm(uni_np[2:] - uni_np[:2]))
    r0 = float(knn_mod.initial_radius(jnp.float32(diag), k, n))
    return (1.0 + np.sum(np.asarray(dist) <= r0, axis=1)
            ).astype(np.float64)


# --------------------------------------------------------------------------
# the protocol
# --------------------------------------------------------------------------

@runtime_checkable
class TileLayout(Protocol):
    """What ``SpatialServer`` serves against — one contract, two
    placements.

    ``mode`` names the routed executor in answer stats (``"pruned"``
    replicated, ``"sharded"`` owner-routed).  The routed executors take
    the server's already-routed ``(Q, F)`` candidate lists + LPT cost
    vector; ``knn_attempt`` routes its own MINDIST frontier at width
    ``f`` (one rung of the server's widen-and-retry ladder) and returns
    the excluded distance the exactness check needs.  The ``dense_*``
    trio is the all-tile oracle.  ``append`` is the streaming
    lifecycle: insert into slack, refresh probe/chunk boxes, re-stage
    (re-balancing owners under sharding) on tile overflow — mutating
    ``stats`` in place (``SpatialServer`` shares the dict).
    """

    parts: api.Partitioning
    config: ServeConfig
    stats: dict
    mode: str
    shards: int

    @property
    def probe_boxes(self) -> jax.Array: ...

    @property
    def chunk_boxes(self) -> jax.Array | None: ...

    @property
    def uni(self) -> jax.Array: ...

    def resident_tile_bytes(self) -> int: ...

    def append(self, mbrs) -> dict: ...

    def range_counts(self, qboxes, cand, costs): ...

    def range_ids(self, qboxes, cand, costs, max_hits: int): ...

    def knn_attempt(self, pts, k: int, max_cand: int, f: int): ...

    def dense_range_counts(self, qboxes): ...

    def dense_range_ids(self, qboxes, max_hits: int): ...

    def dense_knn(self, pts, k: int, max_cand: int): ...


class _TilesBase:
    """Shared staging mirrors + the streaming append lifecycle.

    Subclasses implement ``_install(layout)`` (full install: build the
    device-resident arrays from a fresh ``StagedLayout``) and
    ``_install_incremental()`` (refresh device arrays from the mutated
    host mirrors after a slack insert — same shapes, no re-trace).
    """

    mode = "base"
    shards = 1

    def __init__(self, parts: api.Partitioning, mbrs: jax.Array,
                 config: ServeConfig, mesh: Mesh | None):
        self.parts = parts
        self.config = config
        self.mesh = mesh
        self.axis = config.axis
        self.n_devices = (int(mesh.shape[config.axis])
                          if mesh is not None else 1)
        self._steps: dict = {}
        layout, stats = stage_tiles(parts, mbrs, config)
        self.stats = dict(stats, placement=config.placement,
                          probe=config.probe, restages=0)
        self._mirror(layout)
        self._install(layout)

    # -- host mirrors (the append path's source of truth) ---------------

    def _mirror(self, layout: StagedLayout) -> None:
        # np.array (not asarray): jax buffers surface as read-only
        # views, and the append path mutates these in place
        self._canon_np = np.array(layout.canon_tiles)
        self._ids_np = np.array(layout.ids)
        self._tb_np = np.array(layout.tile_boxes)
        self._probe_np = np.array(layout.probe_boxes)
        self._chunk_np = (None if layout.chunk_boxes is None
                          else np.array(layout.chunk_boxes))
        self._uni_np = np.array(layout.uni)
        self._fill = (self._ids_np >= 0).sum(axis=1).astype(np.int64)
        # the slack a re-stage must re-reserve: the configured value, or
        # the headroom an explicit capacity carried (its excess over the
        # hottest tile IS the user's slack policy — a re-stage must not
        # collapse it to minimal auto-sizing and then thrash)
        self._eff_slack = max(self.config.slack,
                              int(self.stats["cap"] - self._fill.max()))

    # -- streaming lifecycle --------------------------------------------

    def append(self, mbrs) -> dict:
        """Insert new objects into the staged layout (see module doc).

        mbrs: (M, 4) f32 new object MBRs; ids continue the running
        numbering (the first appended object is id ``n``).  Returns an
        append report: ``appended``, ``restaged`` (a tile overflowed
        and the layout was rebuilt at a grown capacity), the new ``n``
        and ``cap``, and ``free_slots_min`` (the tightest tile's
        remaining slack).  Mutates ``stats`` in place.
        """
        new = np.asarray(mbrs, np.float32).reshape(-1, 4)
        m = new.shape[0]
        if m == 0:
            return dict(appended=0, restaged=False, n=self.stats["n"],
                        cap=self.stats["cap"],
                        free_slots_min=int(self.stats["cap"]
                                           - self._fill.max()))
        start_n = self.stats["n"]
        hit = np.asarray(membership(self.parts, jnp.asarray(new)))
        need = self._fill + hit.sum(axis=0)
        restaged = bool(need.max() > self.stats["cap"])
        if restaged:
            over = int((need > self.stats["cap"]).sum())
            log.info("append overflow: %d tile(s) past capacity %d — "
                     "re-staging %d objects", over, self.stats["cap"],
                     start_n + m)
            self._restage(new)
        else:
            self._insert(new, hit, start_n)
            self._install_incremental()
        self.stats["n"] = start_n + m
        self.stats["t_live"] = int(
            (self._probe_np[:, 0] <= self._probe_np[:, 2]).sum())
        self.stats["replication"] = (float(self._fill.sum())
                                     / self.stats["n"] - 1.0)
        return dict(appended=m, restaged=restaged, n=self.stats["n"],
                    cap=self.stats["cap"],
                    free_slots_min=int(self.stats["cap"]
                                       - self._fill.max()))

    def _insert(self, new: np.ndarray, hit: np.ndarray,
                start_n: int) -> None:
        """Slack-slot insert (host mirrors): each new object lands in
        every member tile's next free slot — live slots stay a prefix
        (a staging invariant of every sort mode) — with its canonical
        copy in the lowest member tile, matching ``stage_tiles``'s
        tile-major first-copy rule so a later re-stage reproduces the
        same canonical assignment.  Probe and chunk boxes union the new
        canonical MBRs (sentinel boxes are min/max-neutral), so routing
        and chunk skipping stay exact without a re-sort.

        Fully vectorised: slot targets are a per-tile rank cumsum over
        the hit matrix offset by the current fill (the same rank trick
        as ``assign_from_hit``), and the box unions are ``ufunc.at``
        scatter-reductions — a bulk append costs numpy passes, not
        M·(1+λ) interpreter iterations.
        """
        rank = np.cumsum(hit, axis=0) - 1                   # (M, T)
        oi, ti = np.nonzero(hit)                            # row-major:
        s = (self._fill[ti] + rank[oi, ti]).astype(np.int64)  # oi sorted
        self._ids_np[ti, s] = start_n + oi
        first = np.r_[True, oi[1:] != oi[:-1]]     # lowest member tile
        self._canon_np[ti, s] = np.where(first[:, None], new[oi],
                                         _SENTINEL[None, :])
        tc, sc, boxes = ti[first], s[first], new[oi[first]]
        np.minimum.at(self._probe_np[:, 0], tc, boxes[:, 0])
        np.minimum.at(self._probe_np[:, 1], tc, boxes[:, 1])
        np.maximum.at(self._probe_np[:, 2], tc, boxes[:, 2])
        np.maximum.at(self._probe_np[:, 3], tc, boxes[:, 3])
        if self._chunk_np is not None:
            cc = sc // rops.CHUNK
            np.minimum.at(self._chunk_np[:, :, 0], (tc, cc), boxes[:, 0])
            np.minimum.at(self._chunk_np[:, :, 1], (tc, cc), boxes[:, 1])
            np.maximum.at(self._chunk_np[:, :, 2], (tc, cc), boxes[:, 2])
            np.maximum.at(self._chunk_np[:, :, 3], (tc, cc), boxes[:, 3])
        self._fill += hit.sum(axis=0)
        self._uni_np = np.concatenate(
            [np.minimum(self._uni_np[:2], new[:, :2].min(axis=0)),
             np.maximum(self._uni_np[2:], new[:, 2:].max(axis=0))]
        ).astype(np.float32)

    def _dataset_np(self) -> np.ndarray:
        """The accumulated dataset, reconstructed from the canonical
        host mirrors: every object has exactly one canonical slot (a
        staging invariant ``_insert`` preserves), so scattering
        canonical boxes by id rebuilds the (N, 4) input — appends
        included, in arrival order, since ids are the running
        numbering — without a second host copy of the data."""
        out = np.empty((self.stats["n"], 4), np.float32)
        live = self._canon_np[..., 0] < 1e9        # canonical slots only
        out[self._ids_np[live]] = self._canon_np[live]
        return out

    def _restage(self, extra: np.ndarray) -> None:
        """Rebuild the staging from the accumulated dataset plus the
        not-yet-inserted ``extra`` batch at a grown capacity
        (``capacity=None`` re-sizes from the new max tile count +
        slack), refresh mirrors and device arrays, and bump the step
        generation so no cached executor can serve stale shapes.
        Subclass ``_install`` re-balances owners under sharding."""
        data = np.concatenate([self._dataset_np(), extra], axis=0)
        layout, stats = stage_tiles(
            self.parts, jnp.asarray(data),
            self.config.replace(capacity=None, slack=self._eff_slack))
        for key in ("n", "t", "cap", "t_live", "chunks", "replication"):
            self.stats[key] = stats[key]
        self.stats["restages"] += 1
        self._steps.clear()     # shapes changed: no stale executor survives
        self._mirror(layout)
        self._install(layout)

    # -- shared accessors ------------------------------------------------

    @property
    def uni(self) -> jax.Array:
        return jnp.asarray(self._uni_np)

# --------------------------------------------------------------------------
# replicated placement
# --------------------------------------------------------------------------

class ReplicatedTiles(_TilesBase):
    """Full staging on every device; only queries shard.

    The routed executors are the gathered ``query.range`` /
    ``query.knn`` paths; with a mesh each batch runs as one
    query-sharded ``shard_map`` step.  Staging arrays are passed to the
    step as *replicated arguments* (``P()`` specs) rather than closure
    captures, so streaming appends refresh the served data without
    invalidating compiled steps — shapes are unchanged until a
    re-stage, which bumps the step generation.
    """

    mode = "pruned"
    shards = 1

    def _install(self, layout: StagedLayout) -> None:
        # the served executors read canonical data only — drop the
        # all-copies member tiles instead of keeping (T, cap, 4) bytes
        # resident (and re-uploading them on every append)
        layout = dataclasses.replace(layout, tiles=None)
        # under a mesh, place the staging replicated ONCE per install:
        # the arrays then enter every step as already-resident P()
        # inputs instead of re-broadcasting O(T·cap) bytes per batch
        if self.mesh is not None:
            rep = NamedSharding(self.mesh, P())
            layout = jax.tree.map(lambda a: jax.device_put(a, rep), layout)
        self.staged = layout

    def _install_incremental(self) -> None:
        self._install(StagedLayout(
            tiles=None,
            ids=jnp.asarray(self._ids_np),
            canon_tiles=jnp.asarray(self._canon_np),
            tile_boxes=jnp.asarray(self._tb_np),
            probe_boxes=jnp.asarray(self._probe_np),
            chunk_boxes=(None if self._chunk_np is None
                         else jnp.asarray(self._chunk_np)),
            uni=jnp.asarray(self._uni_np)))

    # -- accessors -------------------------------------------------------

    @property
    def probe_boxes(self) -> jax.Array:
        return self.staged.probe_boxes

    @property
    def chunk_boxes(self) -> jax.Array | None:
        return self.staged.chunk_boxes

    def resident_tile_bytes(self) -> int:
        lay = self.staged
        return int(lay.canon_tiles.nbytes + lay.ids.nbytes)

    # -- SPMD plumbing ---------------------------------------------------

    def _call(self, key: tuple, fn, qarrays: tuple, costs: np.ndarray,
              pads: tuple, consts: tuple = ()):
        """Run ``fn(*per_query_arrays, *consts) -> pytree``
        query-sharded.

        Every array in ``qarrays`` is leading-axis (Q, ...); ``pads``
        gives the matching padding element for the slots LPT leaves
        empty; ``consts`` (the staging arrays) replicate to every
        device as step arguments.  The jitted step is cached under
        ``key``, which must carry every non-array static baked into
        ``fn``'s code (shapes re-trace via jit on their own; re-stages
        clear the cache).
        """
        if self.mesh is None:
            return fn(*qarrays, *consts), dict(skew=1.0)
        slots, pstats = pack_queries(costs, self.n_devices)
        packed = [_pack_rows(a, slots, p) for a, p in zip(qarrays, pads)]
        nq = len(qarrays)
        step = self._steps.get(key)
        if step is None:
            spec = P(self.axis)

            def spmd(*args):
                return fn(*(x[0] for x in args[:nq]), *args[nq:])

            step = jax.jit(shard_map(
                spmd, mesh=self.mesh,
                in_specs=(spec,) * nq + (P(),) * len(consts),
                out_specs=spec, check_vma=False))
            self._steps[key] = step

        sharding = NamedSharding(self.mesh, P(self.axis))
        out = step(*(jax.device_put(jnp.asarray(p), sharding)
                     for p in packed), *consts)
        n_q = qarrays[0].shape[0]
        # step outputs concatenate per-device (Qpd, ...) blocks into a
        # flat (D·Qpd, ...) leading axis; restore the (D, Qpd) grid
        return jax.tree.map(
            lambda x: _unpack_rows(
                np.asarray(x).reshape(slots.shape + np.asarray(x).shape[1:]),
                slots, n_q),
            out), pstats

    # -- routed executors ------------------------------------------------

    def range_counts(self, qboxes, cand, costs):
        lay = self.staged
        cb = lay.chunk_boxes
        f = cand.shape[1]
        consts = (lay.canon_tiles,) + (() if cb is None else (cb,))
        if cb is None:
            fn = lambda qs, cd, ct: range_mod.pruned_range_counts(qs, ct, cd)
        else:
            fn = lambda qs, cd, ct, cbx: range_mod.pruned_range_counts(
                qs, ct, cd, chunk_boxes=cbx)
        counts, pstats = self._call(
            ("range_counts_pruned", cb is not None), fn,
            (qboxes, cand), costs,
            (_SENTINEL, np.full((f,), -1, np.int32)), consts)
        return jnp.asarray(counts), pstats

    def range_ids(self, qboxes, cand, costs, max_hits: int):
        lay = self.staged
        cb = lay.chunk_boxes
        f = cand.shape[1]
        consts = (lay.canon_tiles, lay.ids) + (() if cb is None else (cb,))
        if cb is None:
            fn = lambda qs, cd, ct, ii: range_mod.pruned_range_ids(
                qs, ct, ii, cd, max_hits)
        else:
            fn = lambda qs, cd, ct, ii, cbx: range_mod.pruned_range_ids(
                qs, ct, ii, cd, max_hits, chunk_boxes=cbx)
        (hit_ids, counts, overflow), pstats = self._call(
            ("range_ids_pruned", max_hits, cb is not None), fn,
            (qboxes, cand), costs,
            (_SENTINEL, np.full((f,), -1, np.int32)), consts)
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), pstats)

    def knn_attempt(self, pts, k: int, max_cand: int, f: int):
        lay = self.staged
        n_live = self.stats["n"]
        cb = lay.chunk_boxes
        pad_pt = np.asarray((self._uni_np[:2] + self._uni_np[2:]) * 0.5)
        cand, dist, excl = router.candidate_knn(lay.probe_boxes, pts, f)
        # n_live rides along as a traced scalar, NOT a static baked into
        # the step: appends change n every batch and must not re-trace
        consts = (lay.canon_tiles, lay.ids, lay.uni,
                  jnp.int32(n_live)) + (() if cb is None else (cb,))
        if cb is None:
            fn = lambda qs, cd, ex, ct, ii, un, nl: knn_mod.pruned_knn(
                qs, k, ct, ii, un, cd, ex, max_cand=max_cand,
                n_live=nl)
        else:
            fn = lambda qs, cd, ex, ct, ii, un, nl, cbx: knn_mod.pruned_knn(
                qs, k, ct, ii, un, cd, ex, max_cand=max_cand,
                n_live=nl, chunk_boxes=cbx)
        (nn_ids, nn_d2, radius, overflow, rounds), pstats = self._call(
            ("knn_pruned", k, max_cand, cb is not None), fn,
            (pts, cand, excl),
            _knn_cost_proxy(self._uni_np, n_live, dist, k),
            (pad_pt, np.full((f,), -1, np.int32), np.float32(np.inf)),
            consts)
        pstats = dict(pstats,
                      rounds=int(np.asarray(rounds).max(initial=0)))
        return nn_ids, nn_d2, radius, overflow, excl, pstats

    # -- dense oracle ----------------------------------------------------

    def dense_range_counts(self, qboxes):
        lay = self.staged
        counts, pstats = self._call(
            ("range_counts_dense",),
            lambda qs, ct: range_mod.range_counts(qs, ct),
            (qboxes,), np.ones(qboxes.shape[0], np.float64),
            (_SENTINEL,), (lay.canon_tiles,))
        return jnp.asarray(counts), pstats

    def dense_range_ids(self, qboxes, max_hits: int):
        lay = self.staged
        (hit_ids, counts, overflow), pstats = self._call(
            ("range_ids_dense", max_hits),
            lambda qs, ct, ii: range_mod.range_ids(qs, ct, ii, max_hits),
            (qboxes,), np.ones(qboxes.shape[0], np.float64),
            (_SENTINEL,), (lay.canon_tiles, lay.ids))
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), pstats)

    def dense_knn(self, pts, k: int, max_cand: int):
        lay = self.staged
        n_live = self.stats["n"]
        pad_pt = np.asarray((self._uni_np[:2] + self._uni_np[2:]) * 0.5)
        (nn_ids, nn_d2, radius, overflow, rounds), pstats = self._call(
            ("knn_dense", k, max_cand),
            lambda qs, ct, ii, un, nl: knn_mod.batched_knn(
                qs, k, ct, ii, un, max_cand=max_cand, n_live=nl),
            (pts,), np.ones(pts.shape[0], np.float64), (pad_pt,),
            (lay.canon_tiles, lay.ids, lay.uni, jnp.int32(n_live)))
        return nn_ids, nn_d2, overflow, dict(
            rounds=int(np.asarray(rounds).max(initial=0)), **pstats)


# --------------------------------------------------------------------------
# sharded placement (owner-routed all_to_all exchange)
# --------------------------------------------------------------------------

class ShardedTiles(_TilesBase):
    """Tiles shard across owner devices; queries travel to them.

    Staging shards via capped-LPT placement (``shard_staged``) and
    every batch runs the ``serve.exchange`` orchestrations — under a
    mesh as a real ``all_to_all`` step, in-process as the vmap
    simulation over ``config.shards`` virtual owners.  The host keeps
    the full canonical staging as mirrors: the append path mutates
    them, and the ``probe="dense"`` oracle stages them to one device on
    first use.  A streaming re-stage re-balances owners on the fresh
    member counts (``stats['moved_tiles']`` reports the data movement)
    and re-establishes the ``ceil(T/D)`` per-device memory bound.
    """

    mode = "sharded"

    def __init__(self, parts, mbrs, config: ServeConfig,
                 mesh: Mesh | None):
        self.shards = 0        # set in _install, called by the base ctor
        self._owner = None
        super().__init__(parts, mbrs, config, mesh)

    def _install(self, layout: StagedLayout) -> None:
        cfg = self.config
        if not self.shards:
            self.shards = (int(cfg.shards) if cfg.shards
                           else self.n_devices)
            if self.mesh is not None and self.shards != self.n_devices:
                raise ValueError(
                    "sharded serving places exactly one tile shard per "
                    f"mesh device ({self.n_devices}), got shards="
                    f"{self.shards}")
        slayout, _, stats = shard_staged(
            layout, self.stats, self.shards, mesh=self.mesh,
            axis=self.axis, prev_owner=self._owner)
        self.slayout = slayout
        self._owner = slayout.owner       # prev_owner for the next
        # re-balance; everything else reads the maps off self.slayout
        for key in ("shards", "t_local", "shard_bytes", "placement_skew",
                    "moved_tiles"):
            if key in stats:
                self.stats[key] = stats[key]
        self._oracle_jax = None

    def _install_incremental(self) -> None:
        """Re-scatter the mutated host mirrors into the existing
        owner/local placement (slack inserts never move tiles)."""
        s = self.slayout
        canon_shards, id_shards, chunk_shards = _scatter_shards(
            self._canon_np, self._ids_np, self._chunk_np, s.owner,
            s.local, int(self.stats["t_local"]), self.shards, self.mesh,
            self.axis)
        self.slayout = ShardedLayout(
            canon_shards=canon_shards, id_shards=id_shards,
            chunk_shards=chunk_shards,
            probe_boxes=jnp.asarray(self._probe_np),
            chunk_boxes=(None if self._chunk_np is None
                         else jnp.asarray(self._chunk_np)),
            uni=jnp.asarray(self._uni_np), owner=s.owner, local=s.local)
        self._oracle_jax = None

    # -- accessors -------------------------------------------------------

    @property
    def probe_boxes(self) -> jax.Array:
        return self.slayout.probe_boxes

    @property
    def chunk_boxes(self) -> jax.Array | None:
        return self.slayout.chunk_boxes

    @property
    def oracle_np(self) -> tuple[np.ndarray, np.ndarray]:
        """Host copies of the unsharded canonical staging (the
        ``probe="dense"`` oracle's input, also the append mirrors)."""
        return self._canon_np, self._ids_np

    def resident_tile_bytes(self) -> int:
        s = self.slayout
        return int(s.canon_shards.nbytes + s.id_shards.nbytes) \
            // self.shards

    def _oracle(self) -> tuple[jax.Array, jax.Array]:
        """Dense single-device staging for the ``probe="dense"`` oracle
        — staged to the default device on first use (debug/validation
        path; the sharded executors never need it)."""
        if self._oracle_jax is None:
            self._oracle_jax = (jnp.asarray(self._canon_np),
                                jnp.asarray(self._ids_np))
        return self._oracle_jax

    # -- exchange plumbing -----------------------------------------------

    def _exchange_plan(self, cand, costs: np.ndarray):
        """Host-side plan for one sharded batch: LPT query packing +
        owner-local candidate translation (``router.owner_split``)."""
        slots, pstats = pack_queries(costs, self.shards)
        send_slot, send_cand, xstats = router.owner_split(
            np.asarray(cand), slots, self.slayout.owner,
            self.slayout.local)
        return slots, send_slot, send_cand, {**pstats, **xstats}

    def _put(self, arr):
        a = jnp.asarray(arr)
        if self.mesh is not None:
            a = jax.device_put(a, NamedSharding(self.mesh, P(self.axis)))
        return a

    def _exchange_step(self, key: tuple, orch, n_sharded: int,
                       n_replicated: int = 0, **static):
        step = self._steps.get(key)
        if step is None:
            step = exchange.build_step(orch, self.mesh, self.axis,
                                       n_sharded, n_replicated, **static)
            self._steps[key] = step
        return step

    # -- routed executors ------------------------------------------------

    def range_counts(self, qboxes, cand, costs):
        slots, ss, sc, xstats = self._exchange_plan(cand, costs)
        qp = _pack_rows(np.asarray(qboxes, np.float32), slots, _SENTINEL)
        li = self.config.indexed
        extra = (self.slayout.chunk_shards,) if li else ()
        step = self._exchange_step(
            ("s_range_counts", qp.shape[1], ss.shape[2], sc.shape[3], li),
            exchange.serve_range_counts, n_sharded=4 + len(extra))
        out = step(self._put(qp), self._put(ss), self._put(sc),
                   self.slayout.canon_shards, *extra)
        counts = _unpack_rows(out, slots, qboxes.shape[0])
        return jnp.asarray(counts), dict(shards=self.shards, **xstats)

    def range_ids(self, qboxes, cand, costs, max_hits: int):
        slots, ss, sc, xstats = self._exchange_plan(cand, costs)
        qp = _pack_rows(np.asarray(qboxes, np.float32), slots, _SENTINEL)
        cap = int(self.slayout.id_shards.shape[-1])
        mh_local = min(max_hits, sc.shape[3] * cap)
        li = self.config.indexed
        extra = (self.slayout.chunk_shards,) if li else ()
        step = self._exchange_step(
            ("s_range_ids", qp.shape[1], ss.shape[2], sc.shape[3],
             max_hits, mh_local, li),
            exchange.serve_range_ids, n_sharded=5 + len(extra),
            max_hits=max_hits, mh_local=mh_local)
        out = step(self._put(qp), self._put(ss), self._put(sc),
                   self.slayout.canon_shards, self.slayout.id_shards,
                   *extra)
        n_q = qboxes.shape[0]
        hit_ids, counts, overflow = (
            _unpack_rows(x, slots, n_q) for x in out)
        return (jnp.asarray(hit_ids), jnp.asarray(counts),
                jnp.asarray(overflow), dict(shards=self.shards, **xstats))

    def knn_attempt(self, pts, k: int, max_cand: int, f: int):
        n_live = self.stats["n"]
        pad_pt = np.asarray((self._uni_np[:2] + self._uni_np[2:]) * 0.5)
        n_q = pts.shape[0]
        li = self.config.indexed
        cand, dist, excl = router.candidate_knn(
            self.slayout.probe_boxes, pts, f)
        slots, ss, sc, xstats = self._exchange_plan(
            cand, _knn_cost_proxy(self._uni_np, n_live, dist, k))
        pp = _pack_rows(np.asarray(pts, np.float32), slots, pad_pt)
        dead = slots < 0
        orch = exchange.serve_knn if li else exchange.serve_knn_unindexed
        extra = (self.slayout.chunk_shards,) if li else ()
        # n_live is a replicated traced scalar, not a static: appends
        # change n every batch and must not re-trace the exchange step
        step = self._exchange_step(
            ("s_knn", k, max_cand, pp.shape[1], ss.shape[2],
             sc.shape[3], li),
            orch, n_sharded=6 + len(extra), n_replicated=2,
            k=k, max_cand=max_cand)
        out = step(self._put(pp), self._put(ss), self._put(sc),
                   self._put(dead), self.slayout.canon_shards,
                   self.slayout.id_shards, *extra, self.slayout.uni,
                   jnp.int32(n_live))
        nn_ids, nn_d2, radius, overflow, rounds = (
            _unpack_rows(x, slots, n_q) for x in out)
        xstats = dict(xstats, shards=self.shards,
                      rounds=int(rounds.max(initial=0)))
        return nn_ids, nn_d2, radius, overflow, excl, xstats

    # -- dense oracle ----------------------------------------------------

    def dense_range_counts(self, qboxes):
        canon, _ = self._oracle()
        return range_mod.range_counts(qboxes, canon), {}

    def dense_range_ids(self, qboxes, max_hits: int):
        canon, ids = self._oracle()
        hit_ids, counts, overflow = range_mod.range_ids(
            qboxes, canon, ids, max_hits)
        return hit_ids, counts, overflow, {}

    def dense_knn(self, pts, k: int, max_cand: int):
        canon, ids = self._oracle()
        nn_ids, nn_d2, _, overflow, rounds = knn_mod.batched_knn(
            pts, k, canon, ids, jnp.asarray(self._uni_np),
            max_cand=max_cand, n_live=self.stats["n"])
        return nn_ids, nn_d2, overflow, dict(
            rounds=int(np.asarray(rounds).max(initial=0)))


def build_tiles(parts: api.Partitioning, mbrs: jax.Array,
                config: ServeConfig, mesh: Mesh | None = None
                ) -> TileLayout:
    """Construct the placement ``config`` names (the one place the
    placement string is dispatched)."""
    cls = ShardedTiles if config.placement == "sharded" else ReplicatedTiles
    return cls(parts, mbrs, config, mesh)
