from . import compress, sharding  # noqa: F401
