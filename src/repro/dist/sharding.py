"""GSPMD parameter partitioning rules (name + shape driven).

``param_specs`` maps a (possibly abstract) parameter tree to
``PartitionSpec``s over the ``"model"`` mesh axis: contraction-friendly
tensor-parallel layout for attention/MLP stacks, expert- or
FF-sharding for MoE stacks (``shard_experts``), replication for norms,
biases, and anything whose target dim does not divide the axis.  A
``mesh`` is required to check divisibility; with no ``"model"`` axis
everything replicates.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

# stacked weights: name -> axis to shard over "model" (negative = from end)
_TP_AXIS = {
    "wq": -1, "wk": -1, "wv": -1,        # (L, D, H·hd): split heads
    "wo": -2,                            # (L, H·hd, D): split contraction
    "w1": -1, "w3": -1,                  # (L, [E,] D, F): split FF
    "w2": -2,                            # (L, [E,] F, D): split contraction
}
_MOE_NAMES = {"wr", "w1", "w3", "w2"}


def _model_size(mesh) -> int:
    try:
        return int(mesh.shape["model"])
    except (KeyError, TypeError, AttributeError):
        return 0


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _in_moe(path) -> bool:
    return any(getattr(e, "key", None) == "moe" for e in path)


def _spec(path, leaf, tp: int, shard_experts: bool) -> P:
    shape = getattr(leaf, "shape", ())
    name = _leaf_name(path)
    ndim = len(shape)
    if tp <= 1 or ndim < 2:
        return P()
    axis = None
    if _in_moe(path) and name in _MOE_NAMES:
        if shard_experts:
            # expert axis: wr (L, D, E) -> -1; w1/w3/w2 (L, E, ..) -> 1
            axis = ndim - 1 if name == "wr" else 1
        elif name != "wr":
            axis = _TP_AXIS[name] % ndim
    elif name in _TP_AXIS:
        axis = _TP_AXIS[name] % ndim
    if axis is None or shape[axis] % tp != 0:
        return P()
    spec = [None] * ndim
    spec[axis] = "model"
    return P(*spec)


def param_specs(params, *, shard_experts: bool = False, mesh=None):
    """Parameter tree -> PartitionSpec tree (same structure)."""
    tp = _model_size(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec(path, leaf, tp, shard_experts), params)
