"""Gradient compression for cross-pod reduction (int8 + error feedback).

``compressed_psum`` quantises each leaf to symmetric int8 before the
collective and carries the quantisation residual forward (error
feedback), so long-run drift stays bounded while the reduction moves
4x fewer bytes.  Used by the training substrate; the spatial engine
reuses ``quantize``/``dequantize`` for compact stat exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantisation: returns ``(q, scale)`` with
    ``|dequantize(q, scale) - x| <= scale / 2`` elementwise."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis: str, err_tree):
    """Quantised ``lax.psum`` with error feedback.

    Each leaf is compensated by its carried residual, quantised to int8,
    reduced, and the local quantisation error becomes the new residual.
    Returns ``(reduced_tree, new_err_tree)``; call from inside
    ``shard_map`` over ``axis``.
    """

    def leaf(x, e):
        y = x + e
        q, scale = quantize(y)
        deq = dequantize(q, scale)
        red = jax.lax.pmean(deq, axis)   # gradient-averaging semantics
        return red, y - deq

    flat_x, treedef = jax.tree.flatten(tree)
    flat_e = treedef.flatten_up_to(err_tree)
    pairs = [leaf(x, e) for x, e in zip(flat_x, flat_e)]
    red = treedef.unflatten([p[0] for p in pairs])
    new_err = treedef.unflatten([p[1] for p in pairs])
    return red, new_err
